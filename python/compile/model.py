"""Layer-2 JAX compute graph: the fingerprint + dedup-preprocessing model.

This is the full build-time computation the Rust coordinator invokes on its
hot path (per batch of chunks), lowered once by :mod:`compile.aot`:

``fingerprint_pipeline``
    1. SHA-1 digest per chunk (the Pallas kernel, :mod:`kernels.sha1`);
    2. intra-batch duplicate detection: for every chunk, the index of the
       first batch row with an identical digest.  The coordinator uses this
       to collapse duplicates *before* issuing CIT lookups over the
       (simulated) network — a batch-local form of the paper's cluster-wide
       dedup that removes redundant fingerprint-lookup I/Os;
    3. placement bucket per chunk: the first digest word, which the Rust
       side feeds to the CRUSH-like straw2 placement (content-based
       placement, §2.3 of the paper).

``gear_boundaries``
    CDC cut-point candidate bitmap (the gear-hash Pallas kernel) for the
    variable-size chunking mode.

Everything here is shape-static; one HLO artifact is produced per
(batch, chunk_bytes) variant listed in :data:`compile.aot.VARIANTS`.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import sha1 as sha1_kernel
from .kernels import gearhash as gear_kernel


def intra_batch_first_index(digests: jnp.ndarray) -> jnp.ndarray:
    """For each row of uint32[batch, 5] digests, the first row with an
    identical digest (``first[i] <= i``; unique rows map to themselves).

    O(batch^2) word comparisons — for the hot-path batch sizes (<=128)
    this is far cheaper than a device sort and fuses into a handful of
    XLA ops.
    """
    batch = digests.shape[0]
    eq = (digests[:, None, :] == digests[None, :, :]).all(axis=-1)  # [b, b]
    lower = jnp.tril(jnp.ones((batch, batch), dtype=bool))
    eq = eq & lower
    idx = jnp.arange(batch, dtype=jnp.int32)[None, :]
    big = jnp.full((batch, batch), batch, dtype=jnp.int32)
    first = jnp.where(eq, idx, big).min(axis=1)
    return first.astype(jnp.int32)


def fingerprint_pipeline(words: jnp.ndarray, tile: int = 0):
    """Digest + first-duplicate-index + placement bucket for one batch.

    ``words``: uint32[batch, chunk_bytes//4] big-endian packed chunks.
    Returns ``(digests u32[batch,5], first_idx i32[batch],
    bucket u32[batch])``.
    """
    digests = sha1_kernel.sha1_pallas(words, tile=tile)
    first = intra_batch_first_index(digests)
    bucket = digests[:, 0]
    return digests, first, bucket


def gear_boundaries(data: jnp.ndarray, mask: int) -> jnp.ndarray:
    """CDC cut-point candidates; see :func:`kernels.gearhash.gearhash_pallas`."""
    return gear_kernel.gearhash_pallas(data, mask)
