"""Layer-1 Pallas kernel: batched SHA-1 content fingerprinting.

The paper fingerprints every data chunk with SHA-1 and names this the
dominant CPU cost of cluster-wide deduplication ("fingerprint overhead can
be further minimized by employing hardware-accelerator such as GPU for
parallel fingerprint computation", §3).  This kernel is exactly that
accelerator, rethought for TPU:

Hardware adaptation (GPU → TPU)
-------------------------------
A GPU fingerprint engine would assign one chunk per threadblock and use
warp-level parallelism inside the compression function.  SHA-1 compression
is strictly sequential *within* a chunk, so the only exploitable
parallelism is *across* chunks.  On TPU we therefore:

* tile the batch dimension into VMEM-resident blocks (``BlockSpec`` over
  the batch axis — the HBM→VMEM schedule a GPU kernel would express with
  threadblocks),
* run the 80-round compression as straight-line uint32 VPU code with every
  vector lane holding a different chunk (8x128 vregs = 1024 chunks in
  flight per core), and
* keep the message schedule as a 16-entry rotating register file (not an
  80-entry scratch array), so the VMEM working set per lane is 16 + 5 + 5
  words.

SHA-1 has no matmul structure, so the MXU is idle by construction; the
roofline for this kernel is the VPU integer issue rate (see DESIGN.md
§Hardware-Adaptation for the arithmetic).

The kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); correctness is asserted bit-exactly against
``ref.sha1_ref`` and transitively against ``hashlib.sha1``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref


def _round_constant(t: int) -> int:
    return ref.K[t // 20]


def _f(t: int, b, c, d):
    """SHA-1 round boolean function, vectorized over the chunk lanes."""
    if t < 20:
        return (b & c) | ((jnp.uint32(0xFFFFFFFF) ^ b) & d)
    if t < 40:
        return b ^ c ^ d
    if t < 60:
        return (b & c) | (b & d) | (c & d)
    return b ^ c ^ d


def _compress_columns(state, cols):
    """80 unrolled SHA-1 rounds; ``cols`` is a list of 16 uint32[TILE] vectors.

    The schedule ``w`` is kept as a 16-slot rotating register file:
    ``w[t % 16]`` is overwritten in place once it has been consumed, which
    is the classic low-memory SHA-1 formulation and keeps per-lane state at
    26 words.
    """
    w = list(cols)
    a, b, c, d, e = state
    for t in range(80):
        if t < 16:
            wt = w[t]
        else:
            wt = ref.rotl(w[(t - 3) % 16] ^ w[(t - 8) % 16] ^ w[(t - 14) % 16] ^ w[t % 16], 1)
            w[t % 16] = wt
        tmp = ref.rotl(a, 5) + _f(t, b, c, d) + e + jnp.uint32(_round_constant(t)) + wt
        e, d, c, b, a = d, c, ref.rotl(b, 30), a, tmp
    return (state[0] + a, state[1] + b, state[2] + c, state[3] + d, state[4] + e)


def _sha1_kernel(x_ref, o_ref, *, n_blocks: int, bitlen: int):
    """Pallas kernel body: SHA-1 over one batch tile.

    ``x_ref``: uint32[TILE, n_blocks * 16] big-endian packed chunk words.
    ``o_ref``: uint32[TILE, 5] digests.
    """
    tile = x_ref.shape[0]
    init = tuple(jnp.full((tile,), h, dtype=jnp.uint32) for h in ref.H0)

    def body(blk, state):
        # HBM→VMEM block fetch a GPU kernel would do per-threadblock: one
        # 16-word message block per lane, dynamically indexed.
        block = pl.load(x_ref, (slice(None), pl.dslice(blk * 16, 16)))
        cols = [block[:, i] for i in range(16)]
        return _compress_columns(state, cols)

    state = lax.fori_loop(0, n_blocks, body, init)

    # Constant padding block: chunk size is static per compiled variant, so
    # the Merkle–Damgård padding is a compile-time constant.
    pad = [jnp.full((tile,), 0x80000000, dtype=jnp.uint32)]
    pad += [jnp.zeros((tile,), dtype=jnp.uint32)] * 13
    pad.append(jnp.full((tile,), (bitlen >> 32) & 0xFFFFFFFF, dtype=jnp.uint32))
    pad.append(jnp.full((tile,), bitlen & 0xFFFFFFFF, dtype=jnp.uint32))
    state = _compress_columns(state, pad)

    o_ref[...] = jnp.stack(state, axis=1)


@functools.partial(jax.jit, static_argnames=("tile",))
def sha1_pallas(words: jnp.ndarray, tile: int = 0) -> jnp.ndarray:
    """Batched SHA-1 via the Pallas kernel.

    ``words``: uint32[batch, n_words] big-endian packed chunks
    (``n_words % 16 == 0``).  Returns uint32[batch, 5] digests, bit-equal
    to ``ref.sha1_ref`` and ``hashlib.sha1``.

    ``tile`` selects the batch-tile (grid) size; 0 means whole batch in
    one tile.  ``batch % tile`` must be 0.
    """
    batch, n_words = words.shape
    if n_words % 16 != 0:
        raise ValueError("n_words must be a multiple of 16")
    if tile <= 0:
        tile = batch
    if batch % tile != 0:
        raise ValueError("batch must be divisible by tile")
    n_blocks = n_words // 16
    bitlen = n_words * 4 * 8
    kernel = functools.partial(_sha1_kernel, n_blocks=n_blocks, bitlen=bitlen)
    return pl.pallas_call(
        kernel,
        grid=(batch // tile,),
        in_specs=[pl.BlockSpec((tile, n_words), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, 5), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, 5), jnp.uint32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(words)
