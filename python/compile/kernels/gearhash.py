"""Layer-1 Pallas kernel: gear-hash rolling fingerprint for CDC.

Content-defined chunking (CDC) is the standard alternative to the paper's
fixed-size chunking ("small fixed or variable chunk-based transactions",
§1); the Rust chunker exposes both, and this kernel is the accelerated
boundary scan for the variable-size mode.

The gear hash is a linear scan ``h = (h << 1) + GEAR[byte]``; byte ``i`` is
a cut-point *candidate* when ``h & mask == 0``.  Because ``<<`` discards
high bits, ``h_i`` depends only on the trailing 32 bytes — so the scan
parallelizes into 32 shifted gather-adds, which is how we map a seemingly
sequential recurrence onto the TPU VPU (each lane processes a different
stream position; no cross-lane dependency remains).

The kernel emits the dense candidate bitmap; min/max chunk-size enforcement
is inherently sequential and cheap, so it stays in the Rust coordinator
(``dedup::chunker``), exactly as a GPU implementation would leave it on the
host.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _gear_kernel(x_ref, table_ref, o_ref, *, mask: int):
    """Kernel body: candidate bitmap over one [TILE, n] uint32 byte tile.

    ``x_ref`` holds the payload as uint32 (one byte per element — the CPU
    interpret path and the xla crate's literal API are friendliest to
    32-bit lanes; a real Mosaic build would pack 4 bytes/lane).
    ``table_ref`` is the 256-entry gear table, VMEM-resident for the whole
    grid (Pallas requires captured constants to be explicit inputs).
    """
    data = x_ref[...]
    tile, n = data.shape
    table = table_ref[...]
    g = table[data.astype(jnp.int32)]
    acc = jnp.zeros((tile, n), dtype=jnp.uint32)
    for back in range(32):
        shifted = g << back
        if back:
            shifted = jnp.pad(shifted, ((0, 0), (back, 0)))[:, :n]
        acc = acc + shifted
    o_ref[...] = ((acc & jnp.uint32(mask)) == 0).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("mask",))
def gearhash_pallas(data: jnp.ndarray, mask: int) -> jnp.ndarray:
    """CDC boundary candidates via the Pallas kernel.

    ``data``: uint32[batch, n] with one payload byte per element.
    Returns uint32[batch, n] — 1 where ``gear_hash & mask == 0``.
    Bit-equal to ``ref.gearhash_boundaries_ref``.
    """
    batch, n = data.shape
    kernel = functools.partial(_gear_kernel, mask=mask)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((batch, n), lambda i: (0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((batch, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.uint32),
        interpret=True,
    )(data, jnp.asarray(ref.GEAR))
