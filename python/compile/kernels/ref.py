"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must produce bit-identical results to the functions here, and the SHA-1
reference itself is validated against :mod:`hashlib` in the pytest suite.

All functions operate on *batched, fixed-size* chunks: the AOT pipeline
compiles one HLO artifact per (batch, chunk_size) shape, so shapes are
static by construction.

Data layout
-----------
A chunk of ``chunk_bytes`` bytes is packed big-endian into ``chunk_bytes //
4`` uint32 words (SHA-1 is defined over big-endian words).  A batch is a
``[batch, chunk_bytes // 4]`` uint32 array.  Digests are ``[batch, 5]``
uint32 arrays (the 5 SHA-1 state words, big-endian order).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# SHA-1 round constants (one per 20-round stage).
K = (0x5A827999, 0x6ED9EBA1, 0x8F1BBCDC, 0xCA62C1D6)

# SHA-1 initial state.
H0 = (0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476, 0xC3D2E1F0)


def gear_table() -> np.ndarray:
    """256-entry uint32 gear table derived from splitmix64(seed=golden).

    Deterministically derived so the Rust implementation
    (``rust/src/dedup/chunker.rs``) regenerates the identical table.
    """
    out = np.zeros(256, dtype=np.uint64)
    x = np.uint64(0x9E3779B97F4A7C15)
    mask64 = np.uint64(0xFFFFFFFFFFFFFFFF)
    with np.errstate(over="ignore"):
        for i in range(256):
            x = (x + np.uint64(0x9E3779B97F4A7C15)) & mask64
            z = x
            z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & mask64
            z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & mask64
            z = z ^ (z >> np.uint64(31))
            out[i] = z
    return (out & np.uint64(0xFFFFFFFF)).astype(np.uint32)


GEAR = gear_table()


def rotl(x, n: int):
    """Rotate-left on uint32 lanes."""
    n = n % 32
    if n == 0:
        return x
    return (x << n) | (x >> (32 - n))


def pack_chunks(data: bytes, chunk_bytes: int) -> np.ndarray:
    """Pack raw bytes into a [batch, chunk_bytes//4] big-endian uint32 array.

    ``data`` is zero-padded up to a whole number of chunks.  This mirrors
    the packing the Rust runtime performs before invoking the AOT artifact.
    """
    if chunk_bytes % 64 != 0:
        raise ValueError("chunk_bytes must be a multiple of 64")
    n = (len(data) + chunk_bytes - 1) // chunk_bytes
    n = max(n, 1)
    buf = np.zeros(n * chunk_bytes, dtype=np.uint8)
    buf[: len(data)] = np.frombuffer(data, dtype=np.uint8)
    words = buf.reshape(n, chunk_bytes // 4, 4)
    w = (
        words[..., 0].astype(np.uint32) << 24
        | words[..., 1].astype(np.uint32) << 16
        | words[..., 2].astype(np.uint32) << 8
        | words[..., 3].astype(np.uint32)
    )
    return w


def _compress(state, block):
    """One SHA-1 compression over a batch: state 5x[batch], block 16x[batch]."""
    w = list(block)
    a, b, c, d, e = state
    for t in range(80):
        if t >= 16:
            wt = rotl(w[(t - 3) % 16] ^ w[(t - 8) % 16] ^ w[(t - 14) % 16] ^ w[t % 16], 1)
            w[t % 16] = wt
        else:
            wt = w[t]
        if t < 20:
            f = (b & c) | ((jnp.uint32(0xFFFFFFFF) ^ b) & d)
            k = K[0]
        elif t < 40:
            f = b ^ c ^ d
            k = K[1]
        elif t < 60:
            f = (b & c) | (b & d) | (c & d)
            k = K[2]
        else:
            f = b ^ c ^ d
            k = K[3]
        tmp = rotl(a, 5) + f + e + jnp.uint32(k) + wt
        e, d, c, b, a = d, c, rotl(b, 30), a, tmp
    return (
        state[0] + a,
        state[1] + b,
        state[2] + c,
        state[3] + d,
        state[4] + e,
    )


def sha1_ref(words: jnp.ndarray) -> jnp.ndarray:
    """Batched SHA-1 over fixed-size chunks; pure-jnp oracle.

    ``words``: uint32[batch, n_words] big-endian packed chunk contents,
    where ``n_words * 4`` is the chunk size in bytes (multiple of 64).
    Returns uint32[batch, 5] digests, identical to ``hashlib.sha1`` over
    the corresponding ``n_words * 4``-byte messages.

    The (constant) padding block for a ``c``-byte message with ``c % 64 ==
    0`` is ``0x80000000, 0...0, bitlen_hi, bitlen_lo``.
    """
    batch, n_words = words.shape
    if n_words % 16 != 0:
        raise ValueError("n_words must be a multiple of 16")
    n_blocks = n_words // 16
    bitlen = n_words * 4 * 8

    state = tuple(jnp.full((batch,), h, dtype=jnp.uint32) for h in H0)
    for blk in range(n_blocks):
        block = tuple(words[:, blk * 16 + i] for i in range(16))
        state = _compress(state, block)
    pad = [jnp.full((batch,), 0x80000000, dtype=jnp.uint32)] + [
        jnp.zeros((batch,), dtype=jnp.uint32) for _ in range(13)
    ]
    pad.append(jnp.full((batch,), (bitlen >> 32) & 0xFFFFFFFF, dtype=jnp.uint32))
    pad.append(jnp.full((batch,), bitlen & 0xFFFFFFFF, dtype=jnp.uint32))
    state = _compress(state, tuple(pad))
    return jnp.stack(state, axis=1)


def gearhash_boundaries_ref(data: jnp.ndarray, mask: int) -> jnp.ndarray:
    """Gear-hash CDC boundary detector; pure-jnp oracle.

    ``data``: uint8[batch, n] chunk payloads.  The gear hash is the linear
    scan ``h = (h << 1) + GEAR[byte]`` (uint32 wraparound); position ``i``
    is a cut-point candidate iff ``h_i & mask == 0`` after absorbing byte
    ``i``.  Returns uint32[batch, n] with 1 at candidate positions.

    Uses a windowed formulation: ``h_i = sum_j GEAR[b_j] << (i-j)``
    truncated to uint32 — only the last 32 bytes contribute, so the hash is
    a stack of 32 shifted contributions (bit-exact vs the sequential
    definition because ``<<`` drops high bits).
    """
    batch, n = data.shape
    g = jnp.asarray(GEAR)[data.astype(jnp.int32)]  # uint32[batch, n]
    acc = jnp.zeros((batch, n), dtype=jnp.uint32)
    for back in range(32):
        shifted = g << back
        rolled = jnp.pad(shifted, ((0, 0), (back, 0)))[:, :n] if back else shifted
        acc = acc + rolled
    hits = (acc & jnp.uint32(mask)) == 0
    return hits.astype(jnp.uint32)


def sha1_hex(digest_row) -> str:
    """Format one uint32[5] digest row as the canonical 40-char hex string."""
    return "".join(f"{int(w) & 0xFFFFFFFF:08x}" for w in digest_row)
