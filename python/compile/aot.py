"""AOT pipeline: lower the Layer-2 model to HLO *text* artifacts.

Python runs exactly once (``make artifacts``); the Rust coordinator loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and executes
them on the PJRT CPU client.  HLO text — not ``.serialize()`` — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are accompanied by ``artifacts/manifest.tsv`` with one line per
artifact::

    name\tkind\tbatch\tchunk_bytes\ttile\tmask\tfile

which the Rust runtime parses to pick the right executable for a request
shape (no serde dependency on either side — plain TSV).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, batch, chunk_bytes, tile) fingerprint variants.  The default hot
# path is fp_b64_c4096; the larger-chunk variants serve the paper's
# 64KB-512KB sweep (Fig. 4a) in batched form.
FP_VARIANTS = [
    ("fp_b64_c4096", 64, 4096, 16),
    ("fp_b32_c8192", 32, 8192, 16),
    ("fp_b16_c65536", 16, 65536, 8),
]

# (name, batch, n_bytes, mask) gear-hash CDC variants.  mask 0x1FFF ~ 8KB
# mean chunk size.
GEAR_VARIANTS = [
    ("gear_b4_n65536", 4, 65536, 0x1FFF),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fingerprint(batch: int, chunk_bytes: int, tile: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, chunk_bytes // 4), jnp.uint32)
    fn = lambda w: model.fingerprint_pipeline(w, tile=tile)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_gear(batch: int, n_bytes: int, mask: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, n_bytes), jnp.uint32)
    fn = lambda d: (model.gear_boundaries(d, mask),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="build a single named variant")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, batch, chunk_bytes, tile in FP_VARIANTS:
        if args.only and name != args.only:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_fingerprint(batch, chunk_bytes, tile)
        with open(path, "w") as f:
            f.write(text)
        manifest.append((name, "fingerprint", batch, chunk_bytes, tile, 0, f"{name}.hlo.txt"))
        print(f"wrote {path} ({len(text)} chars)")

    for name, batch, n_bytes, mask in GEAR_VARIANTS:
        if args.only and name != args.only:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_gear(batch, n_bytes, mask)
        with open(path, "w") as f:
            f.write(text)
        manifest.append((name, "gear", batch, n_bytes, 0, mask, f"{name}.hlo.txt"))
        print(f"wrote {path} ({len(text)} chars)")

    if not args.only:
        with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
            f.write("# name\tkind\tbatch\tchunk_bytes\ttile\tmask\tfile\n")
            for row in manifest:
                f.write("\t".join(str(x) for x in row) + "\n")
        print(f"wrote {os.path.join(args.out_dir, 'manifest.tsv')} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
