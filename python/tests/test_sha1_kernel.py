"""Pallas SHA-1 kernel vs pure-jnp ref vs hashlib — the core L1 signal."""

import hashlib

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sha1


def rand_bytes(rng, n):
    return bytes(rng.integers(0, 256, size=n, dtype=np.uint8))


def hashlib_digests(data: bytes, chunk_bytes: int) -> np.ndarray:
    n = len(data) // chunk_bytes
    out = np.zeros((n, 5), dtype=np.uint32)
    for i in range(n):
        d = hashlib.sha1(data[i * chunk_bytes : (i + 1) * chunk_bytes]).digest()
        out[i] = np.frombuffer(d, dtype=">u4").astype(np.uint32)
    return out


class TestRefOracle:
    """ref.sha1_ref is itself validated against hashlib first."""

    @pytest.mark.parametrize("chunk_bytes", [64, 128, 256, 512, 4096])
    def test_matches_hashlib(self, chunk_bytes):
        rng = np.random.default_rng(chunk_bytes)
        data = rand_bytes(rng, 4 * chunk_bytes)
        w = jnp.asarray(ref.pack_chunks(data, chunk_bytes))
        got = np.asarray(ref.sha1_ref(w))
        exp = hashlib_digests(data, chunk_bytes)
        np.testing.assert_array_equal(got, exp)

    def test_known_vector_abc_block(self):
        # 64-byte message of 'a' repeated — cross-checked with hashlib.
        data = b"a" * 64
        w = jnp.asarray(ref.pack_chunks(data, 64))
        got = ref.sha1_hex(np.asarray(ref.sha1_ref(w))[0])
        assert got == hashlib.sha1(data).hexdigest()

    def test_zero_chunk(self):
        w = jnp.zeros((1, 16), dtype=jnp.uint32)
        got = ref.sha1_hex(np.asarray(ref.sha1_ref(w))[0])
        assert got == hashlib.sha1(b"\x00" * 64).hexdigest()

    def test_rejects_bad_width(self):
        with pytest.raises(ValueError):
            ref.sha1_ref(jnp.zeros((1, 15), dtype=jnp.uint32))


class TestPallasKernel:
    @pytest.mark.parametrize("batch,chunk_bytes,tile", [
        (1, 64, 0),
        (4, 64, 2),
        (8, 256, 4),
        (16, 512, 8),
        (64, 4096, 16),
    ])
    def test_matches_ref(self, batch, chunk_bytes, tile):
        rng = np.random.default_rng(batch * chunk_bytes)
        data = rand_bytes(rng, batch * chunk_bytes)
        w = jnp.asarray(ref.pack_chunks(data, chunk_bytes))
        got = np.asarray(sha1.sha1_pallas(w, tile=tile))
        exp = np.asarray(ref.sha1_ref(w))
        np.testing.assert_array_equal(got, exp)

    def test_matches_hashlib_end_to_end(self):
        rng = np.random.default_rng(7)
        data = rand_bytes(rng, 8 * 128)
        w = jnp.asarray(ref.pack_chunks(data, 128))
        got = np.asarray(sha1.sha1_pallas(w))
        exp = hashlib_digests(data, 128)
        np.testing.assert_array_equal(got, exp)

    def test_duplicate_rows_same_digest(self):
        rng = np.random.default_rng(9)
        row = rand_bytes(rng, 256)
        data = row * 3
        w = jnp.asarray(ref.pack_chunks(data, 256))
        d = np.asarray(sha1.sha1_pallas(w))
        assert (d[0] == d[1]).all() and (d[1] == d[2]).all()

    def test_tile_divisibility_enforced(self):
        w = jnp.zeros((6, 16), dtype=jnp.uint32)
        with pytest.raises(ValueError):
            sha1.sha1_pallas(w, tile=4)

    @settings(max_examples=20, deadline=None)
    @given(
        batch=st.integers(min_value=1, max_value=8),
        blocks=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_sweep(self, batch, blocks, seed):
        """Randomized shape/content sweep: kernel == ref == hashlib."""
        chunk_bytes = blocks * 64
        rng = np.random.default_rng(seed)
        data = rand_bytes(rng, batch * chunk_bytes)
        w = jnp.asarray(ref.pack_chunks(data, chunk_bytes))
        got = np.asarray(sha1.sha1_pallas(w))
        np.testing.assert_array_equal(got, np.asarray(ref.sha1_ref(w)))
        np.testing.assert_array_equal(got, hashlib_digests(data, chunk_bytes))


class TestPacking:
    def test_pack_roundtrip_be(self):
        data = bytes(range(64))
        w = ref.pack_chunks(data, 64)
        assert w.shape == (1, 16)
        assert w[0, 0] == 0x00010203
        assert w[0, 15] == 0x3C3D3E3F

    def test_pack_pads_with_zeros(self):
        w = ref.pack_chunks(b"\xff", 64)
        assert w[0, 0] == 0xFF000000
        assert (w[0, 1:] == 0).all()

    def test_pack_rejects_unaligned_chunk(self):
        with pytest.raises(ValueError):
            ref.pack_chunks(b"", 60)
