"""L2 model graph: fingerprint_pipeline semantics + lowering sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, aot
from compile.kernels import ref


def make_words(rng, batch, chunk_bytes, dup_pairs=()):
    data = rng.integers(0, 256, size=batch * chunk_bytes, dtype=np.uint8)
    for dst, src in dup_pairs:
        data[dst * chunk_bytes : (dst + 1) * chunk_bytes] = data[
            src * chunk_bytes : (src + 1) * chunk_bytes
        ]
    return jnp.asarray(ref.pack_chunks(bytes(data), chunk_bytes))


class TestIntraBatchFirstIndex:
    def test_all_unique(self):
        rng = np.random.default_rng(0)
        w = make_words(rng, 8, 64)
        d, first, _ = model.fingerprint_pipeline(w)
        np.testing.assert_array_equal(np.asarray(first), np.arange(8))

    def test_duplicates_map_to_first(self):
        rng = np.random.default_rng(1)
        w = make_words(rng, 8, 64, dup_pairs=[(5, 2), (7, 2), (6, 0)])
        _, first, _ = model.fingerprint_pipeline(w)
        f = np.asarray(first)
        assert f[5] == 2 and f[7] == 2 and f[6] == 0
        assert f[2] == 2 and f[0] == 0

    def test_all_identical(self):
        w = jnp.zeros((6, 16), dtype=jnp.uint32)
        _, first, _ = model.fingerprint_pipeline(w)
        assert (np.asarray(first) == 0).all()

    @settings(max_examples=15, deadline=None)
    @given(batch=st.integers(2, 12), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_first_index_invariants(self, batch, seed):
        rng = np.random.default_rng(seed)
        dup = [(batch - 1, 0)] if batch >= 2 else []
        w = make_words(rng, batch, 64, dup_pairs=dup)
        d, first, _ = model.fingerprint_pipeline(w)
        d, f = np.asarray(d), np.asarray(first)
        for i in range(batch):
            assert f[i] <= i
            np.testing.assert_array_equal(d[f[i]], d[i])
            # f[i] is the FIRST matching row
            for j in range(f[i]):
                assert not (d[j] == d[i]).all()


class TestBucket:
    def test_bucket_is_first_digest_word(self):
        rng = np.random.default_rng(3)
        w = make_words(rng, 4, 64)
        d, _, bucket = model.fingerprint_pipeline(w)
        np.testing.assert_array_equal(np.asarray(bucket), np.asarray(d)[:, 0])


class TestLowering:
    """AOT lowering sanity: HLO text parses, has one while loop (no unroll
    blowup), and declares the right parameter/result shapes."""

    @pytest.fixture(scope="class")
    def hlo(self):
        return aot.lower_fingerprint(batch=8, chunk_bytes=256, tile=4)

    def test_hlo_nonempty_and_parses_header(self, hlo):
        assert hlo.startswith("HloModule")

    def test_single_while_loop(self, hlo):
        # the fori_loop over SHA-1 blocks must lower to a while op, not an
        # unrolled 80*n_blocks instruction stream; one while per grid step.
        assert 0 < hlo.count(" while(") <= 8

    def test_parameter_shape(self, hlo):
        assert "u32[8,64]" in hlo  # batch=8, 256/4=64 words

    def test_result_shapes(self, hlo):
        assert "u32[8,5]" in hlo and "s32[8]" in hlo

    def test_gear_lowering(self):
        hlo = aot.lower_gear(batch=2, n_bytes=128, mask=0xFF)
        assert hlo.startswith("HloModule")
        assert "u32[2,128]" in hlo


class TestManifestFormat:
    def test_variants_well_formed(self):
        for name, batch, chunk_bytes, tile in aot.FP_VARIANTS:
            assert chunk_bytes % 64 == 0
            assert batch % max(tile, 1) == 0
            assert name.startswith("fp_")
        for name, batch, n_bytes, mask in aot.GEAR_VARIANTS:
            assert name.startswith("gear_") and mask > 0
