"""Gear-hash CDC kernel vs ref vs sequential scalar definition."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, gearhash


def sequential_gear(buf: np.ndarray, mask: int) -> np.ndarray:
    """The scalar definition: h = (h << 1) + GEAR[b]; hit iff h & mask == 0."""
    out = np.zeros(buf.shape, dtype=np.uint32)
    with np.errstate(over="ignore"):
        for b in range(buf.shape[0]):
            h = np.uint64(0)
            for i in range(buf.shape[1]):
                h = (h << np.uint64(1)) + np.uint64(ref.GEAR[buf[b, i]])
                h &= np.uint64(0xFFFFFFFF)
                out[b, i] = 1 if (h & np.uint64(mask)) == 0 else 0
    return out


class TestGearTable:
    def test_table_shape_and_determinism(self):
        t1, t2 = ref.gear_table(), ref.gear_table()
        assert t1.shape == (256,) and t1.dtype == np.uint32
        np.testing.assert_array_equal(t1, t2)

    def test_table_known_first_entries(self):
        # Pinned values so the Rust reimplementation can assert the same
        # constants (see rust/src/hash/gear.rs tests).
        t = ref.gear_table()
        assert int(t[0]) == 0xA1B965F4
        assert int(t[255]) == 0xB7C7534D

    def test_table_entropy(self):
        t = ref.gear_table()
        assert len(np.unique(t)) == 256  # no collisions in 256 draws


class TestRefOracle:
    @pytest.mark.parametrize("mask", [0x0F, 0xFF, 0x1FFF])
    def test_matches_sequential(self, mask):
        rng = np.random.default_rng(mask)
        buf = rng.integers(0, 256, size=(3, 300), dtype=np.uint8)
        got = np.asarray(ref.gearhash_boundaries_ref(jnp.asarray(buf), mask))
        np.testing.assert_array_equal(got, sequential_gear(buf, mask))

    def test_zero_mask_all_hits(self):
        buf = np.zeros((1, 10), dtype=np.uint8)
        got = np.asarray(ref.gearhash_boundaries_ref(jnp.asarray(buf), 0))
        assert (got == 1).all()


class TestPallasKernel:
    @pytest.mark.parametrize("batch,n,mask", [(1, 64, 0x0F), (4, 1024, 0xFF), (2, 4096, 0x1FF)])
    def test_matches_ref(self, batch, n, mask):
        rng = np.random.default_rng(n + mask)
        buf = rng.integers(0, 256, size=(batch, n), dtype=np.uint8)
        got = np.asarray(gearhash.gearhash_pallas(jnp.asarray(buf, dtype=jnp.uint32), mask))
        exp = np.asarray(ref.gearhash_boundaries_ref(jnp.asarray(buf), mask))
        np.testing.assert_array_equal(got, exp)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=33, max_value=512),
        mask=st.sampled_from([0x07, 0x3F, 0x1FF]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, mask, seed):
        rng = np.random.default_rng(seed)
        buf = rng.integers(0, 256, size=(2, n), dtype=np.uint8)
        got = np.asarray(gearhash.gearhash_pallas(jnp.asarray(buf, dtype=jnp.uint32), mask))
        np.testing.assert_array_equal(got, sequential_gear(buf, mask))

    def test_expected_cut_density(self):
        # mask with k bits set → candidate probability ~2^-k.
        rng = np.random.default_rng(42)
        buf = rng.integers(0, 256, size=(4, 8192), dtype=np.uint8)
        hits = np.asarray(gearhash.gearhash_pallas(jnp.asarray(buf, dtype=jnp.uint32), 0x3F))
        density = hits.mean()
        assert 0.5 / 64 < density < 2.0 / 64
