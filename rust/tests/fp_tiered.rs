//! Integration: the tiered fingerprint pipeline.
//!
//! * State parity — an inline-hashing cluster and a tiered cluster
//!   driven by the same workload end in byte-identical per-server state
//!   once the pending queue is flushed, while the tiered cluster spends
//!   strictly fewer inline strong hashes and batches its deferred ones.
//! * Verify-before-merge — an adversarial weak-hash collision (two
//!   distinct payloads with equal masked weak64) never merges chunk
//!   identities: the collision is detected by byte-compare, counted in
//!   `fp_verify_rejects`, and both payloads stay readable bit-for-bit.
//! * Crash matrix — every pending→content-addressed migration crash
//!   point converges to a clean audit after restart + flush + deep
//!   scrub + GC, with pre-crash data intact.
//! * Restart re-queue — pending chunks survive losing the in-memory
//!   queue: the recovery scan re-registers them and a flush drains them
//!   into the content-addressed domain.

use std::collections::HashMap;

use snss_dedup::api::{Cluster, ClusterConfig, Consistency, FpMode, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::cit::{CitEntry, CommitFlag};
use snss_dedup::dedup::fpipe::{pending_fp, weak64, weak_mask};
use snss_dedup::dedup::Chunking;
use snss_dedup::failure::CrashPoint;
use snss_dedup::workload::{Generator, WorkloadSpec};

const CHUNK: usize = 2048;

/// Inline-valid consistency keeps commit flags deterministic, so the
/// parity and collision tests compare state without async-flag races.
fn boot(servers: usize, fp_mode: FpMode) -> Cluster {
    Cluster::new(ClusterConfig {
        servers,
        replication: 1,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        fp_mode,
        ..Default::default()
    })
    .expect("boot")
}

/// One deterministic chunk-sized payload per tag.
fn payload(tag: u64) -> Vec<u8> {
    let mut v = vec![0u8; CHUNK];
    for (j, b) in v.iter_mut().enumerate() {
        *b = (tag
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((j as u64).wrapping_mul(131))
            % 251) as u8;
    }
    v
}

/// Brute-force an adversarial pair: two *distinct* payloads whose weak
/// hashes agree under an 8-bit mask (256 buckets — a handful of tries by
/// birthday), plus a third payload from a *different* bucket to use as
/// filter-eviction traffic.
fn collision_pair() -> (Vec<u8>, Vec<u8>, Vec<u8>) {
    let mask = weak_mask(8);
    let mut seen: HashMap<u64, Vec<u8>> = HashMap::new();
    for tag in 0u64..4096 {
        let p = payload(tag);
        let m = weak64(&p) & mask;
        if let Some(prev) = seen.get(&m) {
            if *prev != p {
                let a = prev.clone();
                let evict = (0u64..4096)
                    .map(payload)
                    .find(|c| weak64(c) & mask != m)
                    .expect("an off-bucket payload");
                return (a, p, evict);
            }
        } else {
            seen.insert(m, p);
        }
    }
    panic!("no masked weak64 collision in 4096 candidates");
}

#[test]
fn tiered_and_inline_reach_identical_state() {
    let gen = Generator::new(WorkloadSpec {
        object_size: 16 << 10,
        unit: CHUNK,
        dedup_pct: 50,
        pool_blocks: 32,
        zipf_theta: 0.0,
        seed: 0xF1BE,
    });
    let mut snapshots = Vec::new();
    let mut strong_hashes = Vec::new();
    for mode in [FpMode::Inline, FpMode::tiered()] {
        let cluster = boot(4, mode);
        let client = cluster.client();
        for i in 0..24 {
            let (name, data) = gen.named_object(i);
            client.put_object(&name, &data).expect("put");
        }
        // overwrites and deletes exercise pending-chunk release too
        let (name1, _) = gen.named_object(1);
        client.put_object(&name1, &gen.object(100)).expect("overwrite");
        for i in [0u64, 6, 12] {
            let (name, _) = gen.named_object(i);
            client.delete_object(&name).expect("delete");
        }
        // drain the pending queue, then let GC reclaim the zero-ref
        // leftovers both pipelines produce (orphaned pending chunks on
        // the tiered side, orphaned strong chunks on the inline side)
        cluster.fp_flush().unwrap();
        cluster.flush_consistency().unwrap();
        cluster.run_gc(0).unwrap();
        for i in [2u64, 7, 23] {
            let (name, data) = gen.named_object(i);
            assert_eq!(client.get_object(&name).unwrap(), data, "{mode:?}");
        }
        let audit = cluster.audit().unwrap();
        assert!(audit.is_ok(), "{mode:?}: {:?}", audit.violations);
        let stats = cluster.stats();
        let per_server: Vec<(u32, usize, u64, usize)> = stats
            .per_server
            .iter()
            .map(|p| (p.server, p.chunks_stored, p.bytes_stored, p.objects))
            .collect();
        snapshots.push(per_server);
        strong_hashes.push(stats.fp_strong_hashes);
        if mode.is_tiered() {
            assert!(stats.fp_deferred > 0, "nothing was deferred: {stats:?}");
            assert!(stats.fp_weak_hits > 0, "50% dedup must hit the filter");
            assert!(stats.fp_migrations > 0, "flush migrated nothing");
            assert!(stats.fp_batch_calls > 0, "no batched digest calls");
            assert!(
                stats.fp_batch_items > stats.fp_batch_calls,
                "deferred hashing must batch (mean batch size > 1): \
                 {} items over {} calls",
                stats.fp_batch_items,
                stats.fp_batch_calls
            );
        }
        cluster.shutdown();
    }
    assert_eq!(
        snapshots[0], snapshots[1],
        "inline and tiered pipelines must land byte-identical state"
    );
    assert!(
        strong_hashes[1] < strong_hashes[0],
        "tiered must spend fewer inline strong hashes: {} vs {}",
        strong_hashes[1],
        strong_hashes[0]
    );
}

#[test]
fn same_put_weak_collision_is_rejected_not_merged() {
    let (a, b, evict) = collision_pair();
    // a single filter slot makes eviction deterministic: the off-bucket
    // middle chunk evicts the first chunk's weak, so the third chunk
    // (same masked weak as the first, different bytes) misses the
    // filter and resolves to the *same pending identity* as chunk one —
    // the byte-verify must reject it onto the inline strong path
    let mode = FpMode::Tiered {
        filter_slots: 1,
        batch: 8,
        weak_bits: 8,
    };
    let cluster = boot(3, mode);
    let client = cluster.client();

    let mut three = a.clone();
    three.extend_from_slice(&evict);
    three.extend_from_slice(&b);
    client.put_object("three", &three).unwrap();
    let stats = cluster.stats();
    assert!(stats.fp_deferred >= 2, "chunks 1+2 should defer: {stats:?}");
    assert!(
        stats.fp_verify_rejects >= 1,
        "the colliding third chunk must be rejected, not merged: {stats:?}"
    );
    assert_eq!(client.get_object("three").unwrap(), three, "pre-flush read");

    cluster.fp_flush().unwrap();
    cluster.flush_consistency().unwrap();
    assert_eq!(client.get_object("three").unwrap(), three, "post-flush read");
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn weak_collision_against_stored_pending_chunk_is_verified() {
    let (a, b, _) = collision_pair();
    let mode = FpMode::Tiered {
        filter_slots: 1 << 12,
        batch: 8,
        weak_bits: 8,
    };
    let cluster = boot(3, mode);
    let client = cluster.client();

    // plant a quarantined pending chunk holding `a` directly in the
    // object primary's CIT + store — the deterministic equivalent of an
    // earlier deferred put that has not been migrated yet (going
    // through a real put would race the tier-2 worker)
    let primary = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain("obj")[0])
        .unwrap();
    let pid = pending_fp("obj", weak64(&a) & weak_mask(8));
    cluster
        .with_osd(primary, |sh| {
            sh.shard.cit_put(
                &pid,
                &CitEntry {
                    refcount: 1,
                    flag: CommitFlag::Pending,
                    len: a.len() as u32,
                    flagged_at_ms: sh.now_ms(),
                },
            )?;
            sh.store.put(&pid.to_bytes(), &a)
        })
        .unwrap()
        .unwrap();

    // `b` has the same masked weak64 and the same object name, so tier 1
    // resolves it to the planted identity; the bytes differ, so
    // verify-before-merge must reject and strong-hash inline
    client.put_object("obj", &b).unwrap();
    let stats = cluster.stats();
    assert!(
        stats.fp_verify_rejects >= 1,
        "colliding put must be rejected by byte-compare: {stats:?}"
    );
    assert_eq!(client.get_object("obj").unwrap(), b, "collision merged!");

    // the planted identity is now an orphan (refcount with no indexed
    // referrers — the post-crash shape): GC must reclaim it, after
    // which the audit is clean
    cluster.fp_flush().unwrap();
    cluster.flush_consistency().unwrap();
    cluster.run_gc(0).unwrap();
    assert_eq!(client.get_object("obj").unwrap(), b, "post-GC read");
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn migration_crash_matrix_converges_to_clean_audit() {
    let points = [
        CrashPoint::BeforeFpMigrateStore,
        CrashPoint::AfterFpMigrateStore,
        CrashPoint::AfterFpMigrateOmap,
    ];
    let gen = Generator::new(WorkloadSpec {
        object_size: 8 << 10,
        unit: CHUNK,
        dedup_pct: 50,
        pool_blocks: 16,
        zipf_theta: 0.0,
        seed: 0xF1BE,
    });
    for point in points {
        let cluster = Cluster::new(ClusterConfig {
            servers: 3,
            replication: 2,
            chunking: Chunking::Fixed { size: CHUNK },
            fp_mode: FpMode::tiered(),
            ..Default::default()
        })
        .expect("boot");
        let client = cluster.client();
        for i in 0..4 {
            let (name, data) = gen.named_object(i);
            client.put_object(&name, &data).expect("seed put");
        }
        for s in 0..3 {
            cluster.arm_crash(ServerId(s), point).unwrap();
        }
        // aborts and ServerDown errors are expected while servers die:
        // the armed points fire inside pending→strong migration, driven
        // either by the background worker or by the explicit flush
        for i in 4..10 {
            let (name, data) = gen.named_object(i);
            let _ = client.put_object(&name, &data);
        }
        let _ = cluster.fp_flush();
        for s in 0..3 {
            let _ = cluster.restart_server(ServerId(s));
        }
        cluster.fp_flush().unwrap();
        cluster.flush_consistency().unwrap();
        cluster.start_scrub(ScrubOptions::deep()).unwrap();
        cluster.scrub_wait().unwrap();
        cluster.run_gc(0).unwrap();
        let audit = cluster.audit().unwrap();
        assert!(audit.is_ok(), "{point:?}: {:?}", audit.violations);
        // pre-crash data stays readable
        for i in 0..4 {
            let (name, data) = gen.named_object(i);
            assert_eq!(client.get_object(&name).unwrap(), data, "{point:?}");
        }
        cluster.shutdown();
    }
}

#[test]
fn restart_requeues_pending_chunks() {
    let gen = Generator::new(WorkloadSpec {
        object_size: 8 << 10,
        unit: CHUNK,
        dedup_pct: 0,
        pool_blocks: 16,
        zipf_theta: 0.0,
        seed: 0x5EED,
    });
    let cluster = boot(3, FpMode::tiered());
    let client = cluster.client();
    for i in 0..6 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).expect("put");
    }
    let stats = cluster.stats();
    assert!(stats.fp_deferred > 0, "unique chunks should defer: {stats:?}");

    // kill wipes each server's in-memory pending queue; restart's
    // recovery scan must rebuild it from the Pending commit flags
    for s in 0..3 {
        cluster.kill_server(ServerId(s)).unwrap();
    }
    for s in 0..3 {
        cluster.restart_server(ServerId(s)).unwrap();
    }
    cluster.fp_flush().unwrap();
    cluster.flush_consistency().unwrap();
    let stats = cluster.stats();
    assert!(stats.fp_migrations > 0, "nothing migrated after restart: {stats:?}");
    for s in 0..3 {
        let drained = cluster
            .with_osd(ServerId(s), |sh| {
                sh.fpipe.is_empty() && sh.fpipe.inflight() == 0
            })
            .unwrap();
        assert!(drained, "server {s} still holds queued pending chunks");
    }
    cluster.run_gc(0).unwrap();
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    for i in 0..6 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).unwrap(), data);
    }
    cluster.shutdown();
}
