//! Deterministic `VerifyCopy` storm: a deep scrub over chunks with
//! max-length replica chains floods the replica lanes with verification
//! probes. The replica-side gate must never admit more than its
//! in-flight cap (test-hook counter), every `Busy` NACK must be retried
//! to completion, and the scrub report must still end clean.

use snss_dedup::api::{ClockSource, Cluster, ClusterConfig, DedupMode, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::util::rng::XorShift128Plus;
use snss_dedup::Error;
use std::time::Duration;

const SERVERS: usize = 4;
const CAP: usize = 2;

fn storm_cluster() -> Cluster {
    Cluster::new(ClusterConfig {
        servers: SERVERS,
        // max-length replica chain: every chunk has a copy on every
        // other server, so each scrubbing primary scatters probes at
        // every peer
        replication: SERVERS,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 512 },
        // virtual clock: nothing in this test depends on wall time
        clock: ClockSource::Sim,
        verify_inflight_cap: CAP,
        ..Default::default()
    })
    .unwrap()
}

fn payload(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = XorShift128Plus::new(seed);
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

/// Make every replica lane's verification service slow (test hook), so
/// the pipelined scatter visibly queues and the storm is deterministic.
fn slow_replica_lanes(cluster: &Cluster) {
    for i in 0..SERVERS as u32 {
        cluster
            .with_osd(ServerId(i), |sh| {
                sh.verify_gate.set_hold_for_tests(Duration::from_millis(2));
            })
            .unwrap();
    }
}

#[test]
fn verify_copy_storm_respects_cap_and_retries_to_completion() {
    let cluster = storm_cluster();
    let client = cluster.client();
    // 64 distinct 512-byte chunks spread over all four primaries; with
    // replication = 4 each primary deep-scrubs ~16 chunks × 3 peers of
    // probes, window-pipelined — far more than CAP per lane
    client.put_object("hot", &payload(0xB00B5, 32 * 1024)).unwrap();
    cluster.flush_consistency().unwrap();
    slow_replica_lanes(&cluster);

    cluster
        .start_scrub(ScrubOptions::deep().with_window(64))
        .unwrap();
    let report = cluster.scrub_wait().unwrap();
    assert!(report.all_done(), "failure: {:?}", report.first_failure());
    assert_eq!(report.corruptions_found, 0);
    assert_eq!(report.lost, 0);
    assert_eq!(report.copies_unverified, 0, "no probe may be abandoned");

    // the storm actually formed and was shed + retried, never dropped
    let stats = cluster.stats();
    assert!(stats.backpressure_busy > 0, "storm never tripped a gate");
    assert!(stats.backpressure_retries > 0, "Busy NACKs were not retried");
    assert!(
        stats.backpressure_window_shrinks > 0,
        "sender never shrank its window"
    );
    assert_eq!(
        stats.backpressure_gave_up, 0,
        "every NACKed probe must be retried to a verdict"
    );

    // the cap held on every lane (admitted in-flight never exceeded it),
    // while at least one lane observed more than the cap arriving
    let mut observed_over_cap = false;
    for i in 0..SERVERS as u32 {
        let (admitted, observed) = cluster
            .with_osd(ServerId(i), |sh| {
                (
                    sh.verify_gate.admitted_peak(),
                    sh.verify_gate.observed_peak(),
                )
            })
            .unwrap();
        assert!(
            admitted <= CAP as u64,
            "osd.{i} admitted {admitted} in flight > cap {CAP}"
        );
        observed_over_cap |= observed > CAP as u64;
    }
    assert!(
        observed_over_cap,
        "no replica lane ever saw more than the cap in flight — no storm"
    );

    // and the cluster state is untouched by all the shedding/retrying
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

/// Satellite regression: a second `start_scrub` racing an in-flight pass
/// is rejected with the typed [`Error::ScrubBusy`] — it neither clobbers
/// the running pass's status nor stacks a second pass.
#[test]
fn concurrent_start_scrub_is_typed_scrub_busy() {
    let cluster = storm_cluster();
    let client = cluster.client();
    client.put_object("hot", &payload(7, 32 * 1024)).unwrap();
    cluster.flush_consistency().unwrap();
    // pin the deep pass slow so the race window is wide and deterministic
    slow_replica_lanes(&cluster);

    cluster
        .start_scrub(ScrubOptions::deep().with_window(64))
        .unwrap();
    match cluster.start_scrub(ScrubOptions::light()) {
        Err(Error::ScrubBusy(_)) => {}
        other => panic!("expected ScrubBusy, got {other:?}"),
    }

    // the first pass was not disturbed: it still completes cleanly
    let report = cluster.scrub_wait().unwrap();
    assert!(report.all_done(), "failure: {:?}", report.first_failure());
    assert_eq!(report.corruptions_found, 0);

    // once idle, a new pass is accepted again
    cluster.start_scrub(ScrubOptions::light()).unwrap();
    let report = cluster.scrub_wait().unwrap();
    assert!(report.all_done(), "failure: {:?}", report.first_failure());
    cluster.shutdown();
}
