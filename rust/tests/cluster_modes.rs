//! Integration: write/read/delete correctness across all four dedup
//! architectures, chunking modes, replication levels and the refcount
//! invariant after every scenario.

use snss_dedup::api::{Cluster, ClusterConfig, Consistency, DedupMode, Placement};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};

fn write_read_delete(cfg: ClusterConfig) {
    let dedup = cfg.dedup;
    let cluster = Cluster::new(cfg).expect("boot");
    let client = cluster.client();
    let gen = Generator::new(WorkloadSpec {
        object_size: 96 << 10,
        unit: 4096,
        dedup_pct: 40,
        pool_blocks: 16,
        ..Default::default()
    });
    // write
    for i in 0..12 {
        let (name, data) = gen.named_object(i);
        let (logical, _) = client.put_object(&name, &data).expect("put");
        assert_eq!(logical, data.len() as u64, "{dedup:?}");
    }
    // read back
    for i in 0..12 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).expect("get"), data, "{dedup:?} {name}");
    }
    // overwrite an object with new content and read the new version
    let (name0, _) = gen.named_object(0);
    let fresh: Vec<u8> = (0..50_000u32).map(|i| (i % 255) as u8).collect();
    client.put_object(&name0, &fresh).expect("overwrite");
    assert_eq!(client.get_object(&name0).expect("get fresh"), fresh);
    // delete half
    for i in (0..12).step_by(2) {
        let (name, _) = gen.named_object(i);
        client.delete_object(&name).expect("delete");
        assert!(client.get_object(&name).is_err(), "{dedup:?}: deleted object readable");
    }
    // survivors still intact
    for i in (1..12).step_by(2) {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).expect("get survivor"), data);
    }
    cluster.flush_consistency().ok();
    if dedup != DedupMode::None {
        let audit = cluster.audit().expect("audit");
        assert!(audit.is_ok(), "{dedup:?} violations: {:?}", audit.violations);
    }
    cluster.shutdown();
}

#[test]
fn cluster_wide_roundtrip() {
    write_read_delete(ClusterConfig {
        servers: 5,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    });
}

#[test]
fn central_roundtrip() {
    write_read_delete(ClusterConfig {
        servers: 4,
        replication: 1,
        dedup: DedupMode::Central,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    });
}

#[test]
fn disk_local_roundtrip() {
    write_read_delete(ClusterConfig {
        servers: 4,
        replication: 1,
        dedup: DedupMode::DiskLocal,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    });
}

#[test]
fn no_dedup_roundtrip() {
    write_read_delete(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::None,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    });
}

#[test]
fn cdc_chunking_roundtrip() {
    write_read_delete(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::cdc_with_mean(4096),
        ..Default::default()
    });
}

#[test]
fn rendezvous_placement_roundtrip() {
    write_read_delete(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        placement: Placement::Rendezvous,
        ..Default::default()
    });
}

#[test]
fn all_consistency_modes_roundtrip() {
    for consistency in [
        Consistency::None,
        Consistency::AsyncTagged,
        Consistency::SyncChunk,
        Consistency::SyncObject,
    ] {
        write_read_delete(ClusterConfig {
            servers: 3,
            replication: 1,
            dedup: DedupMode::ClusterWide,
            consistency,
            chunking: Chunking::Fixed { size: 8192 },
            ..Default::default()
        });
    }
}

#[test]
fn single_server_cluster_works() {
    write_read_delete(ClusterConfig {
        servers: 1,
        replication: 1,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    });
}

#[test]
fn savings_equivalence_cluster_vs_central() {
    // cluster-wide and central find the SAME duplicate set (exact dedup):
    // savings must match; only performance differs.
    let mut savings = Vec::new();
    for mode in [DedupMode::ClusterWide, DedupMode::Central] {
        let cluster = Cluster::new(ClusterConfig {
            servers: 4,
            replication: 1,
            dedup: mode,
            chunking: Chunking::Fixed { size: 4096 },
            ..Default::default()
        })
        .unwrap();
        let client = cluster.client();
        let gen = Generator::new(WorkloadSpec {
            object_size: 128 << 10,
            unit: 4096,
            dedup_pct: 60,
            pool_blocks: 8,
            ..Default::default()
        });
        for i in 0..10 {
            let (name, data) = gen.named_object(i);
            client.put_object(&name, &data).unwrap();
        }
        let s = cluster.stats();
        savings.push((s.savings() * 1000.0).round() / 1000.0);
        cluster.shutdown();
    }
    assert_eq!(savings[0], savings[1], "exact dedup must be mode-independent");
    assert!(savings[0] > 0.3);
}

#[test]
fn empty_and_tiny_objects() {
    let cluster = Cluster::new(ClusterConfig {
        servers: 3,
        replication: 2,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    client.put_object("empty", b"").unwrap();
    assert_eq!(client.get_object("empty").unwrap(), b"");
    client.put_object("one", b"x").unwrap();
    assert_eq!(client.get_object("one").unwrap(), b"x");
    // exactly one chunk
    let chunk = vec![9u8; 4096];
    client.put_object("exact", &chunk).unwrap();
    assert_eq!(client.get_object("exact").unwrap(), chunk);
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn get_unknown_object_is_not_found() {
    let cluster = Cluster::new(ClusterConfig::default()).unwrap();
    let client = cluster.client();
    assert!(matches!(
        client.get_object("never-written"),
        Err(snss_dedup::Error::ObjectNotFound(_))
    ));
    cluster.shutdown();
}
