//! Integration: the per-server metrics registry and its exposition.
//!
//! * Aggregation — after a kill/restart + scrub + GC workload, the
//!   per-server sums in [`Cluster::metrics_snapshot`] equal the typed
//!   cluster-global counters in [`Cluster::stats`] (each increment
//!   lands on exactly one registry entry), and the work really is
//!   spread across entries (the skew/hot-shard signal the per-server
//!   registry exists for).
//! * Sampler — under the virtual clock the periodic sampler captures
//!   one snapshot per crossed period boundary, with a live put-latency
//!   histogram (p99 non-zero, p50 ≤ p99).

use snss_dedup::api::{ClockSource, Cluster, ClusterConfig, Consistency, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::obs::{ObsConfig, CLIENT_SCOPE};
use snss_dedup::workload::{Generator, WorkloadSpec};

const CHUNK: usize = 2048;

fn workload_cluster(obs: ObsConfig, clock: ClockSource) -> Cluster {
    Cluster::new(ClusterConfig {
        servers: 3,
        replication: 2,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        clock,
        obs,
        ..Default::default()
    })
    .expect("boot")
}

#[test]
fn per_server_sums_match_cluster_stats() {
    let cluster = workload_cluster(ObsConfig::default(), ClockSource::Wall);
    let client = cluster.client();
    let gen = Generator::new(WorkloadSpec {
        object_size: 16 << 10,
        unit: CHUNK,
        dedup_pct: 50,
        pool_blocks: 32,
        zipf_theta: 0.0,
        seed: 0x0B5E,
    });
    for i in 0..16 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).expect("put");
    }
    for i in [1u64, 5, 9] {
        let (name, _) = gen.named_object(i);
        client.delete_object(&name).expect("delete");
    }
    // a full kill/restart cycle plus scrub + GC exercises the repair,
    // scrub and reclaim counters on top of the write-path ones
    cluster.kill_server(ServerId(1)).unwrap();
    cluster.restart_server(ServerId(1)).unwrap();
    cluster.flush_consistency().unwrap();
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    cluster.scrub_wait().unwrap();
    cluster.run_gc(0).unwrap();
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);

    // snapshot first: stats() itself sends GetStats control messages,
    // which must not land between the two reads of the same atomics
    let snap = cluster.metrics_snapshot();
    let stats = cluster.stats();
    let expect: &[(&str, u64)] = &[
        ("bytes_logical", stats.logical_bytes),
        ("dedup_hits", stats.dedup_hits),
        ("unique_chunks", stats.unique_chunks),
        ("cit_lookups", stats.cit_lookups),
        ("repairs", stats.repairs),
        ("gc_reclaimed", stats.gc_reclaimed),
        ("tx_aborts", stats.tx_aborts),
        ("probe_batches", stats.probe_batches),
        ("probe_hits", stats.probe_hits),
        ("store_batches", stats.store_batches),
        ("batch_items", stats.batch_items),
        ("wire_bytes", stats.wire_bytes),
        ("scrub_chunks_checked", stats.scrub_chunks_checked),
        ("scrub_bytes_verified", stats.scrub_bytes_verified),
        ("backref_updates", stats.backref_updates),
        ("backref_lookups", stats.backref_lookups),
        ("backref_rebuilds", stats.backref_rebuilds),
    ];
    for (name, want) in expect {
        assert_eq!(
            snap.counter_total(name),
            *want,
            "per-server sum of {name} diverged from the cluster stat"
        );
    }
    assert!(stats.unique_chunks > 0, "workload stored chunks");
    assert!(stats.scrub_chunks_checked > 0, "deep scrub ran");

    // the registry really attributes work per server: the cluster-scope
    // entry plus all three servers exist, and at least two real servers
    // stored unique chunks (so skew is a meaningful signal)
    assert_eq!(snap.servers.len(), 4);
    assert!(snap.servers.iter().any(|s| s.server == CLIENT_SCOPE));
    let chunk_servers = snap
        .servers
        .iter()
        .filter(|s| s.server != CLIENT_SCOPE)
        .filter(|s| {
            s.counters
                .iter()
                .any(|(n, v)| *n == "unique_chunks" && *v > 0)
        })
        .count();
    assert!(chunk_servers >= 2, "chunks all landed on one server");
    assert!(snap.skew("unique_chunks") >= 1.0);

    // every real server exposes its four lane-depth gauges (idle ⇒ 0)
    // and its four flow-budget classes
    for s in snap.servers.iter().filter(|s| s.server != CLIENT_SCOPE) {
        let lanes: Vec<&str> = s.queue_depths.iter().map(|(n, _)| *n).collect();
        for lane in ["Frontend", "Backend", "Replica", "Control"] {
            assert!(lanes.contains(&lane), "server {} missing {lane}", s.server);
        }
        assert!(s.queue_depths.iter().all(|(_, d)| *d == 0), "idle lanes");
        let classes: Vec<&str> = s.flow.iter().map(|f| f.class).collect();
        assert_eq!(classes, vec!["scrub", "rebalance", "gc", "recovery"]);
    }

    // renderers cover the new metrics end to end
    let text = snap.to_prometheus();
    assert!(text.contains("snss_read_amp_reads"));
    assert!(text.contains("snss_queue_depth"));
    let json = snap.to_json();
    assert!(json.contains("\"put_latency\""));
    cluster.shutdown();
}

#[test]
fn sim_clock_sampler_captures_latency_trajectories() {
    let cluster = workload_cluster(
        ObsConfig {
            sample_every_ms: 100,
            ..ObsConfig::default()
        },
        ClockSource::Sim,
    );
    let client = cluster.client();
    let data = vec![7u8; 8 << 10];
    for i in 0..6u8 {
        client.put_object(&format!("obj-{i}"), &data).unwrap();
        assert_eq!(client.get_object(&format!("obj-{i}")).unwrap(), data);
    }

    assert!(cluster.sampled_snapshots().is_empty(), "no boundary yet");
    cluster.advance_clock(150).unwrap(); // crosses 100 → one sample
    cluster.advance_clock(40).unwrap(); // still inside the same period
    cluster.advance_clock(100).unwrap(); // crosses 200 → second sample
    let samples = cluster.sampled_snapshots();
    assert_eq!(samples.len(), 2, "one snapshot per crossed boundary");

    let put = samples.last().unwrap().histogram_total("put_latency");
    assert_eq!(put.count, 6, "one sample per put");
    assert!(put.p99_us() > 0, "p99 readout is live");
    assert!(put.p50_us() <= put.p90_us() && put.p90_us() <= put.p99_us());
    let get = samples.last().unwrap().histogram_total("get_latency");
    assert_eq!(get.count, 6, "one sample per get");
    cluster.shutdown();
}
