//! Integration: the online scrub & repair subsystem — bit-rot detection
//! and healing under foreground load, replica re-push, and convergence
//! after a crash in the middle of a repair.

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::failure::CrashPoint;
use snss_dedup::workload::{Generator, WorkloadSpec};

fn boot() -> Cluster {
    Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .expect("boot")
}

/// Flip one bit in the first chunk stored on `id`; returns false when the
/// server holds no chunks.
fn corrupt_first_chunk(cluster: &Cluster, id: ServerId) -> bool {
    cluster
        .with_osd(id, |sh| {
            let keys = sh.store.keys()?;
            for key in keys {
                if key.len() != 20 {
                    continue; // only content-addressed chunks
                }
                let Some(mut data) = sh.store.get(&key)? else {
                    continue;
                };
                if data.is_empty() {
                    continue;
                }
                data[0] ^= 0x01;
                sh.store.put(&key, &data)?;
                return Ok(true);
            }
            Ok::<bool, snss_dedup::Error>(false)
        })
        .expect("with_osd")
        .expect("store io")
}

fn write_corpus(cluster: &Cluster, n: u64) -> Generator {
    let gen = Generator::new(WorkloadSpec {
        object_size: 64 << 10,
        unit: 4096,
        dedup_pct: 0,
        ..Default::default()
    });
    let client = cluster.client();
    for i in 0..n {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).expect("put");
    }
    cluster.flush_consistency().ok();
    gen
}

#[test]
fn deep_scrub_repairs_bit_rot_under_load() {
    let cluster = boot();
    let gen = write_corpus(&cluster, 8);

    // inject bit-rot into a primary chunk copy
    assert!(corrupt_first_chunk(&cluster, ServerId(0)), "osd.0 holds chunks");

    // foreground traffic keeps flowing while the scrub runs (no quiesce)
    let writer = {
        let client = cluster.client();
        std::thread::spawn(move || {
            for i in 0..20u32 {
                let data: Vec<u8> = (0..32_768u32).map(|j| (j * 31 + i * 7) as u8).collect();
                client.put_object(&format!("live-{i}"), &data).expect("live put");
            }
        })
    };

    cluster
        .start_scrub(ScrubOptions::deep().with_window(32))
        .expect("start deep scrub");
    let report = cluster.scrub_wait().expect("scrub wait");
    assert!(report.all_done(), "{report:?}");
    assert!(report.corruptions_found >= 1, "bit-flip not detected: {report:?}");
    assert!(report.repaired >= 1, "bit-flip not repaired: {report:?}");
    assert!(report.chunks_checked > 0 && report.bytes_verified > 0);

    writer.join().expect("writer");
    cluster.flush_consistency().ok();

    // quiesced reconcile pass settles any drift from in-flight writes
    cluster.scrub().expect("light scrub");
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{:?}", audit.violations);

    // every object — pre-existing and written-during-scrub — reads clean
    let client = cluster.client();
    for i in 0..8 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).expect("read"), data, "{name}");
    }
    for i in 0..20u32 {
        let data: Vec<u8> = (0..32_768u32).map(|j| (j * 31 + i * 7) as u8).collect();
        assert_eq!(client.get_object(&format!("live-{i}")).expect("read live"), data);
    }

    // the new counters surface in cluster stats
    let stats = cluster.stats();
    assert!(stats.scrub_chunks_checked > 0);
    assert!(stats.scrub_bytes_verified > 0);
    assert!(stats.scrub_corruptions_found >= 1);
    assert!(stats.scrub_repaired >= 1);
    cluster.shutdown();
}

#[test]
fn deep_scrub_repushes_dropped_replica_copy() {
    let cluster = boot();
    write_corpus(&cluster, 6);

    // drop one replica copy (disk losing a sector's worth of redundancy)
    let dropped: Option<Vec<u8>> = cluster
        .with_osd(ServerId(1), |sh| {
            for key in sh.replica_store.keys()? {
                if key.starts_with(b"c:") && key.len() == 22 {
                    sh.replica_store.delete(&key)?;
                    return Ok(Some(key));
                }
            }
            Ok::<Option<Vec<u8>>, snss_dedup::Error>(None)
        })
        .expect("with_osd")
        .expect("replica io");
    let key = dropped.expect("osd.1 holds replica copies");

    cluster.start_scrub(ScrubOptions::deep()).expect("start");
    let report = cluster.scrub_wait().expect("wait");
    assert!(report.repaired >= 1, "copy not re-pushed: {report:?}");

    let restored = cluster
        .with_osd(ServerId(1), |sh| sh.replica_store.stat(&key))
        .expect("with_osd")
        .expect("stat");
    assert!(restored, "replica copy missing after deep scrub");
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn crash_mid_repair_then_rescrub_converges() {
    let cluster = boot();
    let gen = write_corpus(&cluster, 6);

    assert!(corrupt_first_chunk(&cluster, ServerId(0)), "osd.0 holds chunks");
    cluster
        .arm_crash(ServerId(0), CrashPoint::BeforeScrubRepair)
        .expect("arm");

    // the scrub detects the rot, then osd.0 dies before the repair lands
    cluster.start_scrub(ScrubOptions::deep()).expect("start");
    let _ = cluster.scrub_wait().expect("wait skips the dead server");
    assert!(cluster.is_dead(ServerId(0)), "crash point must fire");

    // restart + a fresh scrub heals the still-present corruption
    cluster.restart_server(ServerId(0)).expect("restart");
    cluster.flush_consistency().ok();
    cluster.start_scrub(ScrubOptions::deep()).expect("rescrub");
    let report = cluster.scrub_wait().expect("wait");
    assert!(report.corruptions_found >= 1, "{report:?}");
    assert!(report.repaired >= 1, "{report:?}");

    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{:?}", audit.violations);
    let client = cluster.client();
    for i in 0..6 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).expect("read"), data, "{name}");
    }
    cluster.shutdown();
}

#[test]
fn scrub_rejects_concurrent_pass_and_reports_rate_limited_progress() {
    let cluster = boot();
    write_corpus(&cluster, 4);

    // slow the pass down enough to observe it running (the bucket's
    // one-second burst is well below the per-server verify volume)
    cluster
        .start_scrub(ScrubOptions::deep().with_rate(16 << 10).with_window(4))
        .expect("start");
    // a second scrub while one runs is refused somewhere in the cluster
    let second = cluster.start_scrub(ScrubOptions::light());
    assert!(second.is_err(), "concurrent scrub must be rejected");
    let report = cluster.scrub_wait().expect("wait");
    assert!(report.all_done(), "{report:?}");
    assert!(report.chunks_checked > 0);
    cluster.shutdown();
}
