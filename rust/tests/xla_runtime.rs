//! Integration: the XLA/Pallas fingerprint engine against the scalar
//! path, end to end through the cluster. Skipped (cleanly) when
//! `artifacts/` has not been built.

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, FingerprintBackend};
use snss_dedup::dedup::fingerprint::{FingerprintProvider, RustSha1Provider};
use snss_dedup::dedup::Chunking;
use snss_dedup::runtime::XlaFingerprintService;
use snss_dedup::util::rng::XorShift128Plus;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.tsv").exists()
}

fn random_chunks(n: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = XorShift128Plus::new(seed);
    (0..n)
        .map(|_| {
            let mut v = vec![0u8; len];
            rng.fill_bytes(&mut v);
            v
        })
        .collect()
}

#[test]
fn xla_digests_bit_identical_to_scalar() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let svc = XlaFingerprintService::start("artifacts").expect("start service");
    // compiled shape (4096) exercises the accelerator; odd shapes fall back
    for len in [4096usize, 8192, 65536, 100, 4095] {
        let chunks = random_chunks(70, len, len as u64);
        let refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
        let xla = svc.digests(&refs);
        let scalar = RustSha1Provider.digests(&refs);
        assert_eq!(xla, scalar, "len {len}");
    }
    // exercised the accelerator at least once
    assert!(
        svc.accel_chunks.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "accelerator never used"
    );
    assert!(
        svc.scalar_chunks.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "fallback never used"
    );
}

#[test]
fn xla_service_is_shared_across_threads() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let svc = std::sync::Arc::new(XlaFingerprintService::start("artifacts").unwrap());
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let chunks = random_chunks(16, 4096, t);
            let refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
            let a = svc.digests(&refs);
            let b = RustSha1Provider.digests(&refs);
            assert_eq!(a, b);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn cluster_parity_between_fingerprint_engines() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Same workload through both engines → identical stored bytes and
    // savings (digests are bit-identical, so dedup decisions are too).
    let mut stored = Vec::new();
    for fp in [
        FingerprintBackend::RustSha1,
        FingerprintBackend::Xla {
            artifacts_dir: "artifacts".into(),
        },
    ] {
        let cluster = Cluster::new(ClusterConfig {
            servers: 4,
            replication: 1,
            dedup: DedupMode::ClusterWide,
            chunking: Chunking::Fixed { size: 4096 },
            fingerprint: fp,
            ..Default::default()
        })
        .unwrap();
        let client = cluster.client();
        let gen = snss_dedup::workload::Generator::new(snss_dedup::workload::WorkloadSpec {
            object_size: 128 << 10,
            unit: 4096,
            dedup_pct: 50,
            pool_blocks: 8,
            ..Default::default()
        });
        for i in 0..8 {
            let (name, data) = gen.named_object(i);
            client.put_object(&name, &data).unwrap();
            assert_eq!(client.get_object(&name).unwrap(), data);
        }
        stored.push(cluster.stats().stored_bytes);
        cluster.shutdown();
    }
    assert_eq!(stored[0], stored[1], "engines disagree on dedup");
}

#[test]
fn manifest_variants_sane() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let specs = snss_dedup::runtime::parse_manifest(std::path::Path::new("artifacts")).unwrap();
    assert!(specs.iter().any(|s| s.kind == "fingerprint"));
    for s in &specs {
        assert!(s.file.exists(), "{} missing", s.file.display());
        if s.kind == "fingerprint" {
            assert_eq!(s.chunk_bytes % 64, 0);
            assert!(s.batch > 0);
        }
    }
}
