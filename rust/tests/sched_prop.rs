//! Maintenance-scheduler properties under a deterministic virtual clock
//! ([`snss_dedup::util::clock::SimClock`]): random kill/restart/GC/put
//! interleavings never stop a live server's scheduled scrub from firing
//! within `every_ticks + jitter`, the shared maintenance budget bounds
//! combined scrub+rebalance+GC token draw (asserted from metrics — no
//! wall-clock timing anywhere), and the cluster still converges to a
//! clean audit. With failure detection armed, random kill + grace
//! expiry + restart interleavings of a designated victim must converge
//! to full replication and a clean audit. The elastic-membership matrix
//! (PR 7) extends the alphabet with add/evict/rejoin: every map change
//! must fire exactly one auto-rebalance, all maintenance must stay
//! within the shared flow budget, and the grown-and-shrunk cluster
//! still converges clean.

use snss_dedup::api::{
    ClockSource, Cluster, ClusterConfig, DedupMode, FailureDetection, FlowConfig, ScrubOptions,
    ScrubSchedule,
};
use snss_dedup::cluster::{ServerId, ServerState};
use snss_dedup::dedup::Chunking;
use snss_dedup::Error;
use snss_dedup::util::prop::{check, Config};
use snss_dedup::util::rng::{SplitMix64, XorShift128Plus};
use std::collections::{HashMap, HashSet};

const SERVERS: u32 = 3;
/// Scrub cadence in virtual ticks (ms of cluster time).
const EVERY: u64 = 100;
/// Jitter bound on each arming.
const JITTER: u64 = 20;
/// Virtual time advanced per test step.
const TICK: u64 = 10;
/// Shared maintenance budget per server per tick. Sized so a pass never
/// has to wait for refill in these tiny-data cases (each advance refills
/// far more than one pass costs) while staying finite, so the ≤-budget
/// assertion below is a real bound, not a vacuous one.
const BUDGET_PER_TICK: u64 = 64 * 1024;
const BURST_TICKS: u64 = 100;

fn config(chunking: Chunking) -> ClusterConfig {
    ClusterConfig {
        servers: SERVERS as usize,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking,
        clock: ClockSource::Sim,
        maint_flow: FlowConfig {
            budget_per_tick: BUDGET_PER_TICK,
            weights: [2, 1, 1, 2],
            burst_ticks: BURST_TICKS,
        },
        ..Default::default()
    }
}

#[derive(Debug, Clone)]
enum Op {
    /// (name index, payload seed, payload length)
    Put(u64, u64, usize),
    Delete(u64),
    Kill(u32),
    Restart(u32),
    Gc,
}

fn payload(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = XorShift128Plus::new(seed);
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

/// Current per-server scheduled-fire counts (live servers only).
fn fires(cluster: &Cluster) -> Result<HashMap<u32, u64>, String> {
    Ok(cluster
        .schedule_status()
        .map_err(|e| e.to_string())?
        .into_iter()
        .map(|s| (s.server, s.fires))
        .collect())
}

fn run_case(ops: &[Op], chunking: Chunking) -> Result<(), String> {
    let cluster = Cluster::new(config(chunking)).map_err(|e| e.to_string())?;
    let client = cluster.client();
    cluster
        .set_schedule(Some(ScrubSchedule::light_every(EVERY).with_jitter(JITTER)))
        .map_err(|e| e.to_string())?;
    let mut advanced: u64 = 0;

    for op in ops {
        match op {
            // data-path errors are expected while servers are down
            Op::Put(i, seed, len) => {
                let _ = client.put_object(&format!("obj-{i}"), &payload(*seed, *len));
            }
            Op::Delete(i) => {
                let _ = client.delete_object(&format!("obj-{i}"));
            }
            Op::Kill(s) => {
                let _ = cluster.kill_server(ServerId(s % SERVERS));
            }
            Op::Restart(s) => {
                let _ = cluster.restart_server(ServerId(s % SERVERS));
            }
            Op::Gc => {
                let _ = cluster.run_gc(0);
            }
        }
        // virtual time marches on; due schedules fire as it does
        cluster.advance_clock(TICK).map_err(|e| e.to_string())?;
        advanced += TICK;
    }

    // property: with everything revived, every server's scheduled scrub
    // fires within one period + jitter of virtual time
    for i in 0..SERVERS {
        let _ = cluster.restart_server(ServerId(i));
    }
    let _ = cluster.scrub_wait();
    let before = fires(&cluster)?;
    let mut fired: HashSet<u32> = HashSet::new();
    let max_steps = (EVERY + JITTER) / TICK + 2;
    let mut steps = 0u64;
    while fired.len() < SERVERS as usize {
        if steps >= max_steps {
            return Err(format!(
                "scheduled scrub missed its {}-tick window; fired so far: {fired:?}",
                EVERY + JITTER
            ));
        }
        cluster.advance_clock(TICK).map_err(|e| e.to_string())?;
        advanced += TICK;
        steps += 1;
        let _ = cluster.scrub_wait();
        for (server, n) in fires(&cluster)? {
            if n > before.get(&server).copied().unwrap_or(0) {
                fired.insert(server);
            }
        }
    }

    // property: combined maintenance draw stays within the shared
    // budget over the elapsed virtual time (plus the boot burst)
    let stats = cluster.stats();
    let draw = stats.flow_granted_scrub + stats.flow_granted_rebalance + stats.flow_granted_gc;
    let bound = SERVERS as u64 * BUDGET_PER_TICK * (advanced + BURST_TICKS);
    if draw > bound {
        return Err(format!("maintenance draw {draw} exceeds budget bound {bound}"));
    }

    // converge: disarm the schedule (so nothing races the final pass),
    // settle flags, deep-scrub, collect garbage, audit
    cluster.set_schedule(None).map_err(|e| e.to_string())?;
    let _ = cluster.scrub_wait();
    cluster.flush_consistency().map_err(|e| e.to_string())?;
    // a scheduled pass queued moments before the disarm may still be
    // draining through a worker; wait it out and retry the typed Busy
    let mut attempts = 0;
    loop {
        match cluster.start_scrub(ScrubOptions::deep()) {
            Ok(()) => break,
            Err(Error::ScrubBusy(_)) if attempts < 100 => {
                attempts += 1;
                let _ = cluster.scrub_wait();
            }
            Err(e) => return Err(format!("start_scrub: {e}")),
        }
    }
    cluster.scrub_wait().map_err(|e| format!("scrub_wait: {e}"))?;
    cluster.run_gc(0).map_err(|e| format!("gc: {e}"))?;

    let audit = cluster.audit().map_err(|e| format!("audit: {e}"))?;
    if !audit.is_ok() {
        return Err(format!("audit violations: {:?}", audit.violations));
    }
    cluster.shutdown();
    Ok(())
}

fn gen_ops(rng: &mut SplitMix64, size: u32) -> Vec<Op> {
    let count = 4 + (size as usize) / 8; // ramps 4 → ~16 ops
    (0..count)
        .map(|_| match rng.below(8) {
            0 | 1 | 2 => Op::Put(
                rng.below(5),
                rng.next_u64(),
                1024 + rng.below(8 * 1024) as usize,
            ),
            3 => Op::Delete(rng.below(5)),
            4 => Op::Kill(rng.next_u32()),
            5 => Op::Restart(rng.next_u32()),
            _ => Op::Gc,
        })
        .collect::<Vec<Op>>()
}

#[test]
fn random_interleavings_never_break_the_scrub_cadence() {
    check(
        Config {
            cases: 6,
            ..Config::default()
        },
        gen_ops,
        |ops| run_case(ops, Chunking::Fixed { size: 2048 }),
    );
}

/// The same matrix over gear-CDC chunking (variable chunk boundaries
/// spread fingerprints over many homes, so scheduled passes on every
/// server have real work).
#[test]
fn cdc_random_interleavings_never_break_the_scrub_cadence() {
    check(
        Config {
            cases: 3,
            ..Config::default()
        },
        gen_ops,
        |ops| run_case(ops, Chunking::cdc_with_mean(2048)),
    );
}

// ---- detector-driven Down/Out transitions (PR 5) ----

/// Detector windows for the matrix, in virtual ticks. Sized against
/// TICK=10 so a killed victim can traverse Up → Down → Out within one
/// random case, and a restart inside the grace window stays Up.
const PROBE: u64 = 10;
const GRACE: u64 = 30;
const OUT: u64 = 80;
const DET_SERVERS: u32 = 4;

fn detector_config() -> ClusterConfig {
    ClusterConfig {
        servers: DET_SERVERS as usize,
        failure_detection: Some(FailureDetection {
            probe_every_ticks: PROBE,
            grace_ticks: GRACE,
            out_ticks: OUT,
            observers: 3,
            out_quorum: 2,
        }),
        ..config(Chunking::Fixed { size: 2048 })
    }
}

/// Ops for the detector matrix: kills/restarts target one designated
/// victim (so at most one server can ever go Out — replication 2 then
/// guarantees no data loss and "full replication" is assertable).
fn gen_detector_ops(rng: &mut SplitMix64, size: u32) -> Vec<Op> {
    let count = 6 + (size as usize) / 6; // ramps 6 → ~22 ops
    (0..count)
        .map(|_| match rng.below(8) {
            0 | 1 | 2 => Op::Put(
                rng.below(5),
                rng.next_u64(),
                1024 + rng.below(8 * 1024) as usize,
            ),
            3 => Op::Delete(rng.below(5)),
            4 | 5 => Op::Kill(1),
            6 => Op::Restart(1),
            _ => Op::Gc,
        })
        .collect::<Vec<Op>>()
}

fn run_detector_case(ops: &[Op]) -> Result<(), String> {
    let victim = ServerId(1);
    let cluster = Cluster::new(detector_config()).map_err(|e| e.to_string())?;
    let client = cluster.client();
    for op in ops {
        match op {
            // data-path errors are expected while the victim is down
            Op::Put(i, seed, len) => {
                let _ = client.put_object(&format!("obj-{i}"), &payload(*seed, *len));
            }
            Op::Delete(i) => {
                let _ = client.delete_object(&format!("obj-{i}"));
            }
            // kills/restarts hit only the victim; a restart of an
            // already-Out victim is the typed ServerRemoved error
            Op::Kill(_) => {
                let _ = cluster.kill_server(victim);
            }
            Op::Restart(_) => {
                let _ = cluster.restart_server(victim);
            }
            Op::Gc => {
                let _ = cluster.run_gc(0);
            }
        }
        cluster.advance_clock(TICK).map_err(|e| e.to_string())?;
    }

    // settle: revive the victim if it is still revivable, give the
    // detector time to re-mark it Up (or finish marking it Out), then
    // drain recovery while keeping virtual time (budget refill) moving
    let _ = cluster.restart_server(victim);
    for _ in 0..(OUT / TICK + 4) {
        cluster.advance_clock(TICK).map_err(|e| e.to_string())?;
    }
    let mut steps = 0u64;
    loop {
        let report = cluster.recovery_status().map_err(|e| e.to_string())?;
        if !report.is_running() {
            if let Some(fail) = report.first_failure() {
                return Err(format!("recovery failed: {fail}"));
            }
            break;
        }
        if steps > 2_000 {
            return Err("recovery never drained".into());
        }
        steps += 1;
        cluster.advance_clock(TICK).map_err(|e| e.to_string())?;
        std::thread::sleep(std::time::Duration::from_millis(1));
    }

    // converge: settle flags, heal with one deep scrub + GC, audit
    cluster.flush_consistency().map_err(|e| e.to_string())?;
    deep_scrub_retrying(&cluster)?;
    cluster.run_gc(0).map_err(|e| format!("gc: {e}"))?;
    let audit = cluster.audit().map_err(|e| format!("audit: {e}"))?;
    if !audit.is_ok() {
        return Err(format!("audit violations: {:?}", audit.violations));
    }

    // full replication: a second deep scrub finds nothing left to do
    let report = deep_scrub_retrying(&cluster)?;
    if report.repaired != 0 || report.lost != 0 || report.corruptions_found != 0 {
        return Err(format!(
            "not at full replication: repaired={} lost={} corruptions={}",
            report.repaired, report.lost, report.corruptions_found
        ));
    }
    cluster.shutdown();
    Ok(())
}

/// Start a deep scrub, retrying the typed Busy while a scheduled or
/// in-flight pass drains, and wait for its report.
fn deep_scrub_retrying(cluster: &Cluster) -> Result<snss_dedup::api::ScrubReport, String> {
    let mut attempts = 0;
    loop {
        match cluster.start_scrub(ScrubOptions::deep()) {
            Ok(()) => break,
            Err(Error::ScrubBusy(_)) if attempts < 100 => {
                attempts += 1;
                let _ = cluster.scrub_wait();
            }
            Err(e) => return Err(format!("start_scrub: {e}")),
        }
    }
    cluster.scrub_wait().map_err(|e| format!("scrub_wait: {e}"))
}

/// Random kill + grace expiry + restart interleavings of one victim
/// under armed failure detection: whatever the detector concluded (Up
/// again, Down, or Out + recovery backfill), the cluster converges to
/// full replication and a clean audit.
#[test]
fn detector_kill_restart_interleavings_converge_to_full_replication() {
    check(
        Config {
            cases: 4,
            ..Config::default()
        },
        gen_detector_ops,
        |ops| run_detector_case(ops),
    );
}

// ---- elastic membership: add / evict / rejoin interleavings (PR 7) ----

/// Ops for the membership matrix. Kill/evict/rejoin target one
/// designated victim (so replication 2 guarantees no data loss and the
/// end state is assertable); `Add` grows the cluster permanently.
#[derive(Debug, Clone)]
enum MemberOp {
    /// (name index, payload seed, payload length)
    Put(u64, u64, usize),
    Delete(u64),
    Add,
    Kill,
    Evict,
    Rejoin,
    Gc,
    Scrub,
}

fn gen_membership_ops(rng: &mut SplitMix64, size: u32) -> Vec<MemberOp> {
    let count = 6 + (size as usize) / 6; // ramps 6 → ~22 ops
    (0..count)
        .map(|_| match rng.below(10) {
            0 | 1 | 2 => MemberOp::Put(
                rng.below(5),
                rng.next_u64(),
                1024 + rng.below(8 * 1024) as usize,
            ),
            3 => MemberOp::Delete(rng.below(5)),
            4 => MemberOp::Add,
            5 => MemberOp::Kill,
            6 => MemberOp::Evict,
            7 => MemberOp::Rejoin,
            8 => MemberOp::Gc,
            _ => MemberOp::Scrub,
        })
        .collect::<Vec<MemberOp>>()
}

fn run_membership_case(ops: &[MemberOp]) -> Result<(), String> {
    let victim = ServerId(1);
    let cluster =
        Cluster::new(config(Chunking::Fixed { size: 2048 })).map_err(|e| e.to_string())?;
    let client = cluster.client();
    let mut advanced: u64 = 0;
    // every *successful* map change (add, evict, rejoin) must fire
    // exactly one auto-rebalance; no detector is armed here, so these
    // three are the only sources
    let mut expected_auto = 0u64;
    let mut servers = SERVERS as u64;

    for op in ops {
        match op {
            // data-path errors are expected while the victim is down/out
            MemberOp::Put(i, seed, len) => {
                let _ = client.put_object(&format!("obj-{i}"), &payload(*seed, *len));
            }
            MemberOp::Delete(i) => {
                let _ = client.delete_object(&format!("obj-{i}"));
            }
            MemberOp::Add => {
                cluster.add_server().map_err(|e| format!("add_server: {e}"))?;
                servers += 1;
                expected_auto += 1;
            }
            MemberOp::Kill => {
                let _ = cluster.kill_server(victim);
            }
            // evicting an already-Out victim / rejoining a live one are
            // the typed errors, not map changes
            MemberOp::Evict => {
                if cluster.remove_server(victim).is_ok() {
                    expected_auto += 1;
                }
            }
            MemberOp::Rejoin => {
                if cluster.rejoin_server(victim).is_ok() {
                    expected_auto += 1;
                }
            }
            MemberOp::Gc => {
                let _ = cluster.run_gc(0);
            }
            MemberOp::Scrub => {
                let _ = cluster.start_scrub(ScrubOptions::light());
            }
        }
        cluster.advance_clock(TICK).map_err(|e| e.to_string())?;
        advanced += TICK;
    }

    // settle the victim back into the cluster from whatever state the
    // interleaving left it in
    match cluster.server_state(victim).map_err(|e| e.to_string())? {
        ServerState::Out => {
            cluster
                .rejoin_server(victim)
                .map_err(|e| format!("settle rejoin: {e}"))?;
            expected_auto += 1;
        }
        _ => {
            if cluster.is_dead(victim) {
                cluster
                    .restart_server(victim)
                    .map_err(|e| format!("settle restart: {e}"))?;
            }
        }
    }

    // drain rebalance + recovery while keeping virtual time (and so the
    // finite budget's refill) moving
    let mut steps = 0u64;
    loop {
        let rec = cluster.recovery_status().map_err(|e| e.to_string())?;
        let reb = cluster.rebalance_status().map_err(|e| e.to_string())?;
        if !rec.is_running() && !reb.is_running() {
            if let Some(fail) = rec.first_failure() {
                return Err(format!("recovery failed: {fail}"));
            }
            // a rebalance scan that died with a killed victim reports
            // Failed("server crashed") — expected; the settle
            // rejoin/restart re-queued a fresh scan that completed
            break;
        }
        if steps > 2_000 {
            return Err("maintenance never drained".into());
        }
        steps += 1;
        cluster.advance_clock(TICK).map_err(|e| e.to_string())?;
        advanced += TICK;
        std::thread::sleep(std::time::Duration::from_millis(1));
    }

    // property: one auto-rebalance per map change, no more, no fewer
    let stats = cluster.stats();
    if stats.membership_auto_rebalances != expected_auto {
        return Err(format!(
            "auto-rebalance fired {} times for {} map changes",
            stats.membership_auto_rebalances, expected_auto
        ));
    }

    // property: combined maintenance draw stays within the shared
    // budget over the elapsed virtual time (final server count × full
    // window bounds the staggered joins from above)
    let draw = stats.flow_granted_scrub
        + stats.flow_granted_rebalance
        + stats.flow_granted_gc
        + stats.flow_granted_recovery;
    let bound = servers * BUDGET_PER_TICK * (advanced + BURST_TICKS);
    if draw > bound {
        return Err(format!("maintenance draw {draw} exceeds budget bound {bound}"));
    }

    // converge: settle flags, heal with one deep scrub + GC, audit,
    // then prove full replication with a second deep scrub
    cluster.flush_consistency().map_err(|e| e.to_string())?;
    deep_scrub_retrying(&cluster)?;
    cluster.run_gc(0).map_err(|e| format!("gc: {e}"))?;
    let audit = cluster.audit().map_err(|e| format!("audit: {e}"))?;
    if !audit.is_ok() {
        return Err(format!("audit violations: {:?}", audit.violations));
    }
    let report = deep_scrub_retrying(&cluster)?;
    if report.repaired != 0 || report.lost != 0 || report.corruptions_found != 0 {
        return Err(format!(
            "not at full replication: repaired={} lost={} corruptions={}",
            report.repaired, report.lost, report.corruptions_found
        ));
    }
    cluster.shutdown();
    Ok(())
}

/// Random add/kill/evict/rejoin/GC/scrub interleavings under the
/// virtual clock: auto-rebalance fires exactly once per map change,
/// maintenance stays within the shared flow budget (asserted from
/// metrics), and the grown-and-shrunk cluster converges to full
/// replication and a clean audit.
#[test]
fn membership_interleavings_keep_auto_rebalance_and_budget_invariants() {
    check(
        Config {
            cases: 4,
            ..Config::default()
        },
        gen_membership_ops,
        |ops| run_membership_case(ops),
    );
}

/// The acceptance scenario: ≥ 3 consecutive scheduled passes fire on
/// cadence, across a kill/restart of one server, with the shared
/// FlowController's combined scrub+rebalance draw bounded by the
/// configured budget — everything asserted from virtual time and
/// metrics, never from wall-clock sleeps.
#[test]
fn three_scheduled_passes_fire_on_cadence_across_kill_restart() {
    let cluster = Cluster::new(config(Chunking::Fixed { size: 2048 })).unwrap();
    let client = cluster.client();
    for i in 0..4u64 {
        client
            .put_object(&format!("obj-{i}"), &payload(i, 8192))
            .unwrap();
    }
    cluster.flush_consistency().unwrap();
    cluster
        .set_schedule(Some(ScrubSchedule::light_every(EVERY).with_jitter(JITTER)))
        .unwrap();

    let victim = ServerId(1);
    let mut advanced = 0u64;
    for round in 1u64..=3 {
        if round == 2 {
            cluster.kill_server(victim).unwrap();
        }
        if round == 3 {
            cluster.restart_server(victim).unwrap();
        }
        // the victim misses round 2 entirely, so by round 3 it is one
        // fire behind the always-live servers (cron: no backfill)
        let target = |server: u32| {
            if server == victim.0 && round == 3 {
                round - 1
            } else {
                round
            }
        };
        let max_steps = (EVERY + JITTER) / TICK + 2;
        let mut steps = 0u64;
        loop {
            assert!(
                steps < max_steps,
                "round {round}: scheduled pass missed its {}-tick window",
                EVERY + JITTER
            );
            cluster.advance_clock(TICK).unwrap();
            advanced += TICK;
            steps += 1;
            let _ = cluster.scrub_wait();
            let statuses = cluster.schedule_status().unwrap();
            if statuses.iter().all(|s| s.fires >= target(s.server)) {
                break;
            }
        }
    }

    // the restarted server resumed (one catch-up fire, possibly one
    // more if its re-armed period elapsed before round 3 ended) — but
    // never a backfill burst of the whole missed downtime
    let victim_fires = cluster
        .schedule_status()
        .unwrap()
        .into_iter()
        .find(|s| s.server == victim.0)
        .map(|s| s.fires)
        .unwrap();
    assert!(
        (2..=3).contains(&victim_fires),
        "victim fired {victim_fires} times; want catch-up without backfill"
    );

    // budget invariant, from metrics: combined scrub+rebalance draw
    // never exceeds budget × elapsed ticks (+ boot burst) per server
    let stats = cluster.stats();
    let draw = stats.flow_granted_scrub + stats.flow_granted_rebalance;
    let bound = SERVERS as u64 * BUDGET_PER_TICK * (advanced + BURST_TICKS);
    assert!(draw <= bound, "draw {draw} exceeds budget bound {bound}");
    assert!(
        stats.sched_fires >= 8,
        "3 + 3 + 2 scheduled fires expected, saw {}",
        stats.sched_fires
    );

    // and the cluster is still healthy
    cluster.set_schedule(None).unwrap();
    let _ = cluster.scrub_wait();
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}
