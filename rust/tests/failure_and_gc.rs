//! Integration: crash points, restart recovery, GC, scrub, degraded reads
//! — the paper's robustness claims, one crash point at a time.

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::failure::CrashPoint;
use snss_dedup::workload::{Generator, WorkloadSpec};

fn boot() -> Cluster {
    Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .expect("boot")
}

/// Full recovery drill for one chunk-server crash point: write fails or
/// survives, stable data stays readable, restart + scrub + GC restore the
/// audit invariant, and the doomed object can be rewritten and read.
fn crash_drill(point: CrashPoint) {
    let cluster = boot();
    let client = cluster.client();

    let stable = vec![5u8; 32 << 10];
    client.put_object("stable", &stable).expect("stable put");
    cluster.flush_consistency().ok();

    cluster.arm_crash(ServerId(2), point).unwrap();
    let doomed: Vec<u8> = (0..96u32 << 10).map(|i| (i * 131 >> 3) as u8).collect();
    let _ = client.put_object("doomed", &doomed); // may fail; that's fine

    // stable object must remain readable regardless (replica fallback)
    assert_eq!(client.get_object("stable").expect("degraded"), stable, "{point:?}");

    cluster.restart_server(ServerId(2)).unwrap();
    cluster.flush_consistency().ok();
    cluster.scrub().expect("scrub");
    cluster.run_gc(0).expect("gc");

    // rewrite and read the doomed object
    client.put_object("doomed", &doomed).expect("rewrite");
    assert_eq!(client.get_object("doomed").expect("read"), doomed, "{point:?}");
    cluster.flush_consistency().ok();
    cluster.scrub().expect("scrub2");

    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{point:?}: {:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn crash_after_cit_insert() {
    crash_drill(CrashPoint::AfterCitInsert);
}

#[test]
fn crash_after_data_store() {
    crash_drill(CrashPoint::AfterDataStore);
}

#[test]
fn crash_before_replicate() {
    crash_drill(CrashPoint::BeforeReplicate);
}

#[test]
fn crash_before_omap_write() {
    // primary-side crash: the object's primary dies between chunk stores
    // and the OMAP write. NB the primary for "doomed2" may be any server;
    // arm all, restart all.
    let cluster = boot();
    let client = cluster.client();
    for i in 0..4 {
        cluster.arm_crash(ServerId(i), CrashPoint::BeforeOmapWrite).unwrap();
    }
    let doomed: Vec<u8> = vec![7u8; 64 << 10];
    assert!(client.put_object("doomed2", &doomed).is_err(), "must fail");
    for i in 0..4 {
        cluster.restart_server(ServerId(i)).unwrap();
    }
    cluster.flush_consistency().ok();
    // the object was never committed
    assert!(client.get_object("doomed2").is_err());
    // its chunks are garbage (refcount>0 leak is repaired by scrub, then
    // refcount-0 invalid entries age out via GC)
    cluster.scrub().expect("scrub");
    cluster.run_gc(0).expect("gc");
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{:?}", audit.violations);
    // after scrub+GC nothing may reference the doomed chunks
    let stats = cluster.stats();
    assert_eq!(stats.per_server.iter().map(|s| s.objects).sum::<usize>(), 0);
    cluster.shutdown();
}

#[test]
fn gc_reclaims_garbage_but_not_live_data() {
    let cluster = boot();
    let client = cluster.client();
    let gen = Generator::new(WorkloadSpec {
        object_size: 64 << 10,
        unit: 4096,
        dedup_pct: 0,
        ..Default::default()
    });
    for i in 0..6 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).unwrap();
    }
    cluster.flush_consistency().ok();
    // delete three objects → their chunks drop to refcount 0
    for i in 0..3 {
        client.delete_object(&gen.name(i)).unwrap();
    }
    let before = cluster.stats();
    cluster.run_gc(0).unwrap();
    let after = cluster.stats();
    assert!(
        after.stored_bytes < before.stored_bytes,
        "GC reclaimed nothing: {} -> {}",
        before.stored_bytes,
        after.stored_bytes
    );
    // survivors unharmed
    for i in 3..6 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).unwrap(), data);
    }
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn gc_threshold_spares_young_entries() {
    let cluster = boot();
    let client = cluster.client();
    client.put_object("obj", &vec![1u8; 32 << 10]).unwrap();
    client.delete_object("obj").unwrap();
    // huge threshold: nothing is old enough to collect
    cluster.run_gc(3_600_000).unwrap();
    let stats = cluster.stats();
    assert!(stats.stored_bytes > 0, "young garbage must survive the pass");
    cluster.run_gc(0).unwrap();
    let stats = cluster.stats();
    assert_eq!(stats.stored_bytes, 0, "aged garbage must be reclaimed");
    cluster.shutdown();
}

#[test]
fn killed_server_reads_fall_back_to_replicas() {
    let cluster = boot();
    let client = cluster.client();
    let gen = Generator::new(WorkloadSpec {
        object_size: 128 << 10,
        unit: 4096,
        dedup_pct: 0,
        ..Default::default()
    });
    for i in 0..8 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).unwrap();
    }
    cluster.flush_consistency().ok();
    cluster.kill_server(ServerId(1)).unwrap();
    for i in 0..8 {
        let (name, data) = gen.named_object(i);
        assert_eq!(
            client.get_object(&name).expect("degraded read"),
            data,
            "{name} lost with one server down"
        );
    }
    cluster.shutdown();
}

#[test]
fn restart_recovers_pending_flags() {
    // kill wipes the in-memory registration queue; the restart recovery
    // scan must re-register stored-but-invalid chunks so they become
    // valid without waiting for a duplicate-write repair.
    use snss_dedup::api::Consistency;
    let cluster = Cluster::new(ClusterConfig {
        servers: 2,
        replication: 1,
        dedup: DedupMode::ClusterWide,
        consistency: Consistency::AsyncTagged,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    client.put_object("x", &vec![3u8; 64 << 10]).unwrap();
    // kill immediately — some flags may still be pending (queue wiped)
    cluster.kill_server(ServerId(0)).unwrap();
    cluster.kill_server(ServerId(1)).unwrap();
    cluster.restart_server(ServerId(0)).unwrap();
    cluster.restart_server(ServerId(1)).unwrap();
    cluster.flush_consistency().ok();
    // after recovery, a GC pass must reclaim nothing (all data valid)
    let before = cluster.stats().stored_bytes;
    cluster.run_gc(0).unwrap();
    assert_eq!(cluster.stats().stored_bytes, before);
    assert_eq!(client.get_object("x").unwrap(), vec![3u8; 64 << 10]);
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}
