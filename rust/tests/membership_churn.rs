//! Elastic-membership churn under a deterministic virtual clock
//! (`snss_dedup::membership`, DESIGN.md §13).
//!
//! The headline harness for wipe-and-rejoin: servers join, fail, get
//! evicted by the quorum detector and rejoin — under continuous
//! put/get/delete traffic — and on every seed the cluster must converge
//! to full replication (a second deep scrub with nothing left to do), a
//! zero-finding audit, zero abandoned backpressure probes, and every
//! surviving object readable byte-for-byte. A companion test pins the
//! quorum argument end to end: one persistently flaky heartbeat
//! observer, lying about *every* server under the same traffic, never
//! evicts anyone.

use snss_dedup::api::{
    ClockSource, Cluster, ClusterConfig, FailureDetection, ObserverVerdict, ScrubOptions,
};
use snss_dedup::cluster::{ServerId, ServerState};
use snss_dedup::dedup::Chunking;
use snss_dedup::util::rng::{SplitMix64, XorShift128Plus};
use std::collections::HashMap;

const TICK: u64 = 10;
const PROBE: u64 = 10;
const GRACE: u64 = 40;
const OUT: u64 = 120;

fn churn_config() -> ClusterConfig {
    ClusterConfig {
        servers: 4,
        replication: 2,
        chunking: Chunking::Fixed { size: 1024 },
        clock: ClockSource::Sim,
        failure_detection: Some(FailureDetection {
            probe_every_ticks: PROBE,
            grace_ticks: GRACE,
            out_ticks: OUT,
            observers: 3,
            out_quorum: 2,
        }),
        ..Default::default()
    }
}

fn payload(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = XorShift128Plus::new(seed);
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

/// What the test believes the cluster holds: object name → (payload
/// seed, payload length) of the last *successful* put. A failed put or
/// any delete drops the name — its durable state is legitimately
/// unknown mid-failure, so nothing is asserted about it later.
type Model = HashMap<String, (u64, usize)>;

/// Drive `steps` random client ops, advancing the virtual clock one
/// tick per op (so detector probes, schedules and flow refill keep
/// moving with the traffic). Data-path errors are tolerated — servers
/// are dead or mid-eviction on purpose — but any *successful* read of a
/// modeled object must return exactly the modeled bytes.
fn traffic(cluster: &Cluster, rng: &mut SplitMix64, model: &mut Model, steps: usize) {
    let client = cluster.client();
    for _ in 0..steps {
        let name = format!("obj-{}", rng.below(16));
        match rng.below(4) {
            0 | 1 => {
                let seed = rng.next_u64();
                let len = 1024 + rng.below(8 * 1024) as usize;
                match client.put_object(&name, &payload(seed, len)) {
                    Ok(_) => {
                        model.insert(name, (seed, len));
                    }
                    Err(_) => {
                        model.remove(&name);
                    }
                }
            }
            2 => {
                if let Ok(data) = client.get_object(&name) {
                    if let Some((seed, len)) = model.get(&name) {
                        assert_eq!(data, payload(*seed, *len), "{name} content diverged");
                    }
                }
            }
            _ => {
                let _ = client.delete_object(&name);
                model.remove(&name);
            }
        }
        cluster.advance_clock(TICK).unwrap();
    }
}

/// Converge-and-verify: settle async flags, heal with one deep scrub +
/// GC, demand a zero-finding audit, then prove full replication with a
/// second deep scrub that must find nothing to repair. Finally every
/// modeled object must read back byte-for-byte.
fn assert_converged(cluster: &Cluster, model: &Model, ctx: &str) {
    cluster.flush_consistency().unwrap();
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let heal = cluster.scrub_wait().unwrap();
    assert!(heal.all_done(), "{ctx}: {:?}", heal.first_failure());
    cluster.run_gc(0).unwrap();
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{ctx}: audit violations {:?}", audit.violations);
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let scrub = cluster.scrub_wait().unwrap();
    assert!(scrub.all_done(), "{ctx}: {:?}", scrub.first_failure());
    assert_eq!(
        scrub.repaired + scrub.lost + scrub.corruptions_found,
        0,
        "{ctx}: not at full replication: {scrub:?}"
    );
    let client = cluster.client();
    for (name, (seed, len)) in model {
        assert_eq!(
            client.get_object(name).unwrap(),
            payload(*seed, *len),
            "{ctx}: {name} lost in the churn"
        );
    }
}

/// One full churn cycle for one seed: traffic → silent crash → quorum
/// eviction (detector-driven, traffic still running) → recovery →
/// wipe-and-rejoin → cluster growth → more traffic → converge.
fn churn_case(seed: u64) {
    let mut rng = SplitMix64::new(seed);
    let cluster = Cluster::new(churn_config()).unwrap();
    let victim = ServerId(1);
    let mut model = Model::new();

    // steady-state traffic first, so the victim holds real data
    traffic(&cluster, &mut rng, &mut model, 16);

    // silent crash; the quorum detector walks it Down → Out while the
    // client keeps hammering the cluster
    cluster.kill_server(victim).unwrap();
    let mut steps = 0u64;
    while cluster.server_state(victim).unwrap() != ServerState::Out {
        assert!(
            steps < (GRACE + OUT) / TICK + 32,
            "seed {seed}: victim never marked Out under traffic"
        );
        traffic(&cluster, &mut rng, &mut model, 1);
        steps += 1;
    }

    // recovery backfill re-replicates the victim's holdings (default
    // budget is unlimited, so the workers run free of the virtual clock)
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "seed {seed}: {report:?}");

    // wipe-and-rejoin the evicted server, then grow the cluster — two
    // more map changes, each auto-rebalanced
    cluster.rejoin_server(victim).unwrap();
    assert_eq!(cluster.server_state(victim).unwrap(), ServerState::Up);
    cluster.rebalance_wait().unwrap();
    let added = cluster.add_server().unwrap();
    assert_eq!(cluster.server_state(added).unwrap(), ServerState::Up);

    // traffic over the grown five-server map
    traffic(&cluster, &mut rng, &mut model, 12);

    assert_converged(&cluster, &model, &format!("seed {seed}"));

    let stats = cluster.stats();
    assert_eq!(
        stats.backpressure_gave_up, 0,
        "seed {seed}: probes abandoned under backpressure"
    );
    assert_eq!(stats.detector_marked_out, 1, "seed {seed}");
    assert_eq!(stats.membership_rejoins, 1, "seed {seed}");
    assert_eq!(stats.membership_wipes, 1, "seed {seed}");
    assert!(
        stats.membership_auto_rebalances >= 3,
        "seed {seed}: out + rejoin + add are map changes: {}",
        stats.membership_auto_rebalances
    );
    cluster.shutdown();
}

/// The acceptance loop: the full churn cycle must converge on every one
/// of 8 deterministic seeds.
#[test]
fn membership_churn_converges_on_every_seed() {
    for seed in 0..8 {
        churn_case(seed);
    }
}

/// Quorum regression under traffic: one observer lying "dead" about
/// *every* server, for twice the grace+out window of continuous load,
/// never walks anyone Down — let alone Out — because the two honest
/// Alive votes stay below the out quorum every round.
#[test]
fn flaky_observer_never_evicts_anyone_under_traffic() {
    let mut rng = SplitMix64::new(0xF1A5);
    let cluster = Cluster::new(churn_config()).unwrap();
    cluster
        .set_observer_hook(Some(Box::new(|observer, _id, verdict| {
            if observer == 0 {
                ObserverVerdict::Dead
            } else {
                verdict
            }
        })))
        .unwrap();
    let mut model = Model::new();
    traffic(
        &cluster,
        &mut rng,
        &mut model,
        (2 * (GRACE + OUT) / TICK) as usize,
    );
    for id in 0..4u32 {
        assert_eq!(
            cluster.server_state(ServerId(id)).unwrap(),
            ServerState::Up,
            "osd.{id} evicted by a single flaky observer"
        );
    }
    let stats = cluster.stats();
    assert_eq!(stats.detector_marked_down, 0, "liar outvoted every round");
    assert_eq!(stats.detector_marked_out, 0);
    assert_eq!(stats.membership_auto_rebalances, 0, "no map change happened");
    assert_converged(&cluster, &model, "flaky observer");
    cluster.shutdown();
}

/// Repeat-churn determinism: the same seed twice produces the same
/// surviving model — the harness has no hidden wall-time dependence in
/// what it asserts about.
#[test]
fn churn_is_deterministic_for_a_fixed_seed() {
    let run = |seed: u64| {
        let mut rng = SplitMix64::new(seed);
        let cluster = Cluster::new(churn_config()).unwrap();
        let mut model = Model::new();
        traffic(&cluster, &mut rng, &mut model, 24);
        let mut names: Vec<String> = model.keys().cloned().collect();
        names.sort();
        let seeds: Vec<(u64, usize)> = names.iter().map(|n| model[n]).collect();
        cluster.shutdown();
        (names, seeds)
    };
    assert_eq!(run(7), run(7), "same seed, same surviving model");
}
