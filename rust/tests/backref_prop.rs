//! Property: the backreference index is an exact inversion of each
//! server's OMAP.
//!
//! Two regimes are checked, reusing the `scrub_prop.rs`-style harness:
//!
//! * **Steady state** — random interleavings of puts, overwrites,
//!   deletes, GC, rebalance (server add) and online scrubs, with *no*
//!   crashes, must keep every server's index ≡ OMAP at every quiesce
//!   point, with no rebuild ever having run (the per-write maintenance
//!   alone must be exact).
//! * **Crash + recovery** — interleavings that also kill/restart servers
//!   mid-transaction must converge back to index ≡ OMAP after the
//!   converge sequence (restart revives + re-derives the index from the
//!   OMAP, the source of truth).
//!
//! Both directions of containment are covered by `DmShard::backref_audit`
//! (stale record ⇒ index ⊄ OMAP; missing record ⇒ OMAP ⊄ index), and the
//! indexed reference counts must equal the full-scan reference counts for
//! every fingerprint either structure knows about.

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::util::prop::{check, Config};
use snss_dedup::util::rng::{SplitMix64, XorShift128Plus};
use snss_dedup::Fingerprint;

const SERVERS: u32 = 3;

#[derive(Debug, Clone)]
enum Op {
    /// (name index, payload seed, payload length)
    Put(u64, u64, usize),
    Delete(u64),
    Gc,
    Scrub,
    AddServer,
    Kill(u32),
    Restart(u32),
}

fn payload(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = XorShift128Plus::new(seed);
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

/// Check index ≡ OMAP on every live server: audit clean, and indexed
/// counts equal to full-scan counts for every known fingerprint.
fn assert_index_exact(cluster: &Cluster, ctx: &str) -> Result<(), String> {
    let stats = cluster.stats();
    for st in &stats.per_server {
        let id = ServerId(st.server);
        if cluster.is_dead(id) {
            continue;
        }
        let (problems, fps) = cluster
            .with_osd(id, |sh| {
                let problems = sh.shard.backref_audit()?;
                let fps = sh.shard.cit_fingerprints()?;
                Ok::<_, snss_dedup::Error>((problems, fps))
            })
            .map_err(|e| format!("{ctx}: with_osd: {e}"))?
            .map_err(|e| format!("{ctx}: audit: {e}"))?;
        if !problems.is_empty() {
            return Err(format!("{ctx}: osd.{} index != omap: {problems:?}", st.server));
        }
        // indexed counts must equal the reference full-scan counts
        let fps: Vec<Fingerprint> = fps;
        let ok = cluster
            .with_osd(id, |sh| {
                let indexed = sh.shard.backref_refs_many(&fps)?;
                let scanned = sh.shard.count_refs_scan(&fps)?;
                Ok::<_, snss_dedup::Error>(indexed == scanned)
            })
            .map_err(|e| format!("{ctx}: with_osd: {e}"))?
            .map_err(|e| format!("{ctx}: counts: {e}"))?;
        if !ok {
            return Err(format!("{ctx}: osd.{} indexed counts != scan counts", st.server));
        }
    }
    Ok(())
}

fn run_case(ops: &[Op], with_crashes: bool, chunking: Chunking) -> Result<(), String> {
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS as usize,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking,
        ..Default::default()
    })
    .map_err(|e| e.to_string())?;
    let client = cluster.client();

    for op in ops {
        match op {
            Op::Put(i, seed, len) => {
                let _ = client.put_object(&format!("obj-{i}"), &payload(*seed, *len));
            }
            Op::Delete(i) => {
                let _ = client.delete_object(&format!("obj-{i}"));
            }
            Op::Gc => {
                let _ = cluster.run_gc(0);
            }
            Op::Scrub => {
                let _ = cluster.start_scrub(ScrubOptions::light());
                let _ = cluster.scrub_wait();
            }
            Op::AddServer => {
                let _ = cluster.add_server();
            }
            Op::Kill(s) if with_crashes => {
                let _ = cluster.kill_server(ServerId(s % SERVERS));
            }
            Op::Restart(s) if with_crashes => {
                let _ = cluster.restart_server(ServerId(s % SERVERS));
            }
            Op::Kill(_) | Op::Restart(_) => {} // steady-state regime
        }
        if !with_crashes {
            // steady state: the index must be exact after EVERY op, with
            // no rebuild masking a maintenance bug
            assert_index_exact(&cluster, &format!("after {op:?}"))?;
        }
    }

    if with_crashes {
        // converge: revive everything (restart re-derives the index),
        // settle flags, scrub, collect garbage
        for i in 0..SERVERS {
            let _ = cluster.restart_server(ServerId(i));
        }
        cluster.flush_consistency().map_err(|e| e.to_string())?;
        let _ = cluster.start_scrub(ScrubOptions::light());
        let _ = cluster.scrub_wait();
        let _ = cluster.run_gc(0);
        assert_index_exact(&cluster, "after converge")?;
    }

    // the cluster-wide audit now embeds the per-server index cross-check
    cluster.flush_consistency().map_err(|e| e.to_string())?;
    let audit = cluster.audit().map_err(|e| format!("audit: {e}"))?;
    let backref_violations: Vec<&String> = audit
        .violations
        .iter()
        .filter(|v| v.contains("backref"))
        .collect();
    if !backref_violations.is_empty() {
        return Err(format!("audit backref violations: {backref_violations:?}"));
    }
    cluster.shutdown();
    Ok(())
}

fn gen_ops(rng: &mut SplitMix64, size: u32, crashes: bool) -> Vec<Op> {
    let count = 4 + (size as usize) / 8;
    (0..count)
        .map(|_| match rng.below(if crashes { 12 } else { 9 }) {
            0..=3 => Op::Put(
                rng.below(5),
                rng.next_u64(),
                1024 + rng.below(16 * 1024) as usize,
            ),
            4 | 5 => Op::Delete(rng.below(5)),
            6 => Op::Gc,
            7 => Op::Scrub,
            8 => Op::AddServer,
            9 => Op::Kill(rng.next_u32()),
            10 => Op::Restart(rng.next_u32()),
            _ => Op::Kill(rng.next_u32()),
        })
        .collect::<Vec<Op>>()
}

#[test]
fn steady_state_index_is_exact_without_rebuilds() {
    check(
        Config {
            cases: 8,
            ..Config::default()
        },
        |rng, size| gen_ops(rng, size, false),
        |ops| run_case(ops, false, Chunking::Fixed { size: 2048 }),
    );
}

#[test]
fn crash_restart_interleavings_converge_to_exact_index() {
    check(
        Config {
            cases: 8,
            ..Config::default()
        },
        |rng, size| gen_ops(rng, size, true),
        |ops| run_case(ops, true, Chunking::Fixed { size: 2048 }),
    );
}

/// The crash/restart matrix over gear-CDC chunking: variable-size
/// chunks through the batched two-phase write path must keep the index
/// convergent exactly like fixed-size ones.
#[test]
fn cdc_crash_restart_interleavings_converge_to_exact_index() {
    check(
        Config {
            cases: 4,
            ..Config::default()
        },
        |rng, size| gen_ops(rng, size, true),
        |ops| run_case(ops, true, Chunking::cdc_with_mean(2048)),
    );
}
