//! Integration: storage rebalancing on cluster growth and disk-backed
//! durability across process-level restart of a server's stores.

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, Durability};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};

#[test]
fn grow_cluster_keeps_all_objects_readable() {
    let cluster = Cluster::new(ClusterConfig {
        servers: 3,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    let gen = Generator::new(WorkloadSpec {
        object_size: 64 << 10,
        unit: 4096,
        dedup_pct: 25,
        pool_blocks: 16,
        ..Default::default()
    });
    for i in 0..24 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).unwrap();
    }
    cluster.flush_consistency().ok();

    // grow twice
    for _ in 0..2 {
        cluster.add_server().unwrap();
        for i in 0..24 {
            let (name, data) = gen.named_object(i);
            assert_eq!(client.get_object(&name).unwrap(), data, "{name}");
        }
        let audit = cluster.audit().unwrap();
        assert!(audit.is_ok(), "{:?}", audit.violations);
    }
    // savings unchanged by rebalancing (no data was duplicated or lost)
    let stats = cluster.stats();
    assert!(stats.savings() > 0.1, "savings {}", stats.savings());
    cluster.shutdown();
}

#[test]
fn rebalance_moves_data_to_new_server() {
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 1,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    let gen = Generator::new(WorkloadSpec {
        object_size: 256 << 10,
        unit: 4096,
        dedup_pct: 0,
        ..Default::default()
    });
    for i in 0..16 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).unwrap();
    }
    cluster.flush_consistency().ok();
    let new_id = cluster.add_server().unwrap();
    let stats = cluster.stats();
    let newcomer = stats
        .per_server
        .iter()
        .find(|s| s.server == new_id.0)
        .expect("new server in stats");
    assert!(
        newcomer.bytes_stored > 0,
        "rebalance moved nothing to {new_id}"
    );
    // movement should be minimal-ish: well under half the data
    let total: u64 = stats.per_server.iter().map(|s| s.bytes_stored).sum();
    assert!(
        newcomer.bytes_stored < total / 2,
        "moved too much: {}/{total}",
        newcomer.bytes_stored
    );
    cluster.shutdown();
}

#[test]
fn disk_durability_across_cluster_reboot() {
    let root = std::env::temp_dir().join(format!("snss-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let gen = Generator::new(WorkloadSpec {
        object_size: 64 << 10,
        unit: 4096,
        dedup_pct: 30,
        pool_blocks: 8,
        ..Default::default()
    });
    let cfg = ClusterConfig {
        servers: 3,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        durability: Durability::Disk(root.clone()),
        ..Default::default()
    };
    // first life: write, flush, shut down
    {
        let cluster = Cluster::new(cfg.clone()).unwrap();
        let client = cluster.client();
        for i in 0..10 {
            let (name, data) = gen.named_object(i);
            client.put_object(&name, &data).unwrap();
        }
        cluster.flush_consistency().ok();
        cluster.shutdown();
    }
    // second life: everything must still be there (LogKv replay +
    // FileStore rescan), including the dedup metadata.
    {
        let cluster = Cluster::new(cfg).unwrap();
        let client = cluster.client();
        for i in 0..10 {
            let (name, data) = gen.named_object(i);
            assert_eq!(client.get_object(&name).unwrap(), data, "{name} lost on reboot");
        }
        let audit = cluster.audit().unwrap();
        assert!(audit.is_ok(), "{:?}", audit.violations);
        cluster.shutdown();
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn property_random_workloads_hold_invariants() {
    // cluster-level property test: random (seed, dedup%, object sizes) →
    // all reads verify and the audit balances.
    use snss_dedup::util::prop;
    let mut case = 0u32;
    prop::check(
        prop::Config { cases: 6, ..Default::default() },
        |rng, size| {
            let objects = 3 + rng.below(6);
            let object_kb = 16 + rng.below(1 + size as u64 * 2);
            let dedup_pct = rng.below(101) as u8;
            let seed = rng.next_u64();
            (objects, object_kb, dedup_pct, seed)
        },
        |&(objects, object_kb, dedup_pct, seed)| {
            case += 1;
            let cluster = Cluster::new(ClusterConfig {
                servers: 3,
                replication: 2,
                dedup: DedupMode::ClusterWide,
                chunking: Chunking::Fixed { size: 4096 },
                ..Default::default()
            })
            .map_err(|e| e.to_string())?;
            let client = cluster.client();
            let gen = Generator::new(WorkloadSpec {
                object_size: (object_kb as usize) << 10,
                unit: 4096,
                dedup_pct,
                pool_blocks: 8,
                seed,
                ..Default::default()
            });
            for i in 0..objects {
                let (name, data) = gen.named_object(i);
                client.put_object(&name, &data).map_err(|e| e.to_string())?;
            }
            for i in 0..objects {
                let (name, data) = gen.named_object(i);
                let back = client.get_object(&name).map_err(|e| e.to_string())?;
                if back != data {
                    return Err(format!("case {case}: readback mismatch {name}"));
                }
            }
            cluster.flush_consistency().ok();
            let audit = cluster.audit().map_err(|e| e.to_string())?;
            if !audit.is_ok() {
                return Err(format!("case {case}: {:?}", audit.violations));
            }
            cluster.shutdown();
            Ok(())
        },
    );
}
