//! Refcount-banded redundancy (DESIGN.md §15).
//!
//! * Property — under random kill/restart/GC interleavings while
//!   refcounts are driven back and forth across the band thresholds,
//!   the cluster converges to the *exact* banded copy count for every
//!   chunk (no under-, no over-replication), with a clean audit and
//!   zero abandoned backpressure probes, on every seed.
//! * Under-replication is never silent — a replica peer killed mid-put
//!   is counted in `replica_push_failures` and recorded as repair debt,
//!   and the next scrub pass restores the target copy count.
//! * A demotion landing on a server whose replica-slot entry is a
//!   selective-duplication locality plant keeps the plant: it was never
//!   counted toward the banded target, so dropping it would trade read
//!   locality for nothing.

use snss_dedup::api::{
    ClockSource, Cluster, ClusterConfig, Consistency, RedundancyPolicy, ScrubOptions,
};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::engine::chunk_copy_key;
use snss_dedup::dedup::Chunking;
use snss_dedup::util::rng::SplitMix64;
use snss_dedup::Fingerprint;
use std::collections::HashMap;

const CHUNK: usize = 1024;
const TICK: u64 = 10;

fn banded_config() -> ClusterConfig {
    ClusterConfig {
        servers: 5,
        replication: 2,
        redundancy: RedundancyPolicy::banded(),
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        clock: ClockSource::Sim,
        ..Default::default()
    }
}

/// One of a handful of shared 1-chunk blocks; objects repeat these, so
/// a block's refcount is the total repetition count across live
/// objects — the knob the property test turns across band thresholds.
fn block(k: u64) -> Vec<u8> {
    let mut v = vec![0u8; CHUNK];
    for (i, b) in v.iter_mut().enumerate() {
        *b = ((k * 131 + 17) as usize * 251 + i * 7) as u8;
    }
    v
}

/// Object payload: `reps` repetitions of shared block `k`.
fn payload(k: u64, reps: usize) -> Vec<u8> {
    block(k).repeat(reps)
}

/// What the test believes the cluster holds: object name → (block,
/// reps) of the last successful put. Failed puts and deletes drop the
/// name — its durable state is legitimately unknown mid-failure.
type Model = HashMap<String, (u64, usize)>;

/// Drive refcounts across the 8/64 band thresholds under random
/// kill/restart/GC interleavings. Data-path errors are tolerated (a
/// server is dead on purpose roughly a third of the time); the virtual
/// clock advances one tick per op.
fn churn(cluster: &Cluster, rng: &mut SplitMix64, model: &mut Model, steps: usize) {
    let client = cluster.client();
    let mut dead: Option<ServerId> = None;
    for step in 0..steps {
        let name = format!("obj-{}", rng.below(12));
        match rng.below(8) {
            0..=3 => {
                let k = rng.below(3);
                // repetition counts straddling both band thresholds
                let reps = [1, 4, 10, 30, 70][rng.below(5) as usize];
                match client.put_object(&name, &payload(k, reps)) {
                    Ok(_) => {
                        model.insert(name, (k, reps));
                    }
                    Err(_) => {
                        model.remove(&name);
                    }
                }
            }
            4 | 5 => {
                let _ = client.delete_object(&name);
                model.remove(&name);
            }
            6 => {
                // toggle one server's liveness: kill one, or restart
                // the previously killed one
                match dead.take() {
                    Some(id) => cluster.restart_server(id).unwrap(),
                    None => {
                        let id = ServerId(rng.below(5) as u32);
                        cluster.kill_server(id).unwrap();
                        dead = Some(id);
                    }
                }
            }
            _ => {
                if step % 3 == 0 {
                    cluster.run_gc(0).unwrap();
                }
            }
        }
        cluster.advance_clock(TICK).unwrap();
    }
    if let Some(id) = dead {
        cluster.restart_server(id).unwrap();
    }
}

/// Converge-and-verify: settle async state, heal + demote with deep
/// scrubs, then demand a zero-finding audit, the *exact* banded copy
/// count for every chunk, and every modeled object byte-for-byte.
fn assert_banded_convergence(cluster: &Cluster, model: &Model, ctx: &str) {
    cluster.flush_consistency().unwrap();
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let heal = cluster.scrub_wait().unwrap();
    assert!(heal.all_done(), "{ctx}: {:?}", heal.first_failure());
    cluster.run_gc(0).unwrap();
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{ctx}: audit violations {:?}", audit.violations);
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let scrub = cluster.scrub_wait().unwrap();
    assert!(scrub.all_done(), "{ctx}: {:?}", scrub.first_failure());
    let report = cluster.redundancy_report().unwrap();
    assert!(report.chunks > 0, "{ctx}: nothing to census");
    assert!(
        report.is_converged(),
        "{ctx}: copy counts off the banded target: {report:?}"
    );
    let client = cluster.client();
    for (name, (k, reps)) in model {
        assert_eq!(
            client.get_object(name).unwrap(),
            payload(*k, *reps),
            "{ctx}: {name} lost in the churn"
        );
    }
}

#[test]
fn banded_copy_counts_converge_under_churn_on_every_seed() {
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(seed);
        let cluster = Cluster::new(banded_config()).unwrap();
        let mut model = Model::new();
        churn(&cluster, &mut rng, &mut model, 48);
        assert_banded_convergence(&cluster, &model, &format!("seed {seed}"));
        let stats = cluster.stats();
        assert_eq!(
            stats.backpressure_gave_up, 0,
            "seed {seed}: probes abandoned under backpressure"
        );
        assert!(
            stats.redundancy_target_copies > 0,
            "seed {seed}: write path never consulted the policy"
        );
        cluster.shutdown();
    }
}

/// The online hooks move copy counts in both directions without any
/// scrub: pushing a chunk's refcount over a threshold promotes it at
/// once, dropping back demotes it — and the demotion never goes below
/// the new band's target.
#[test]
fn threshold_crossings_promote_and_demote_online() {
    let cluster = Cluster::new(banded_config()).unwrap();
    let client = cluster.client();

    // refs 1 → target 2; refs 10 → target 3 (band ≥ 8)
    client.put_object("base", &payload(0, 1)).unwrap();
    client.put_object("bulk", &payload(0, 9)).unwrap();
    let stats = cluster.stats();
    assert!(
        stats.redundancy_promotions >= 1,
        "crossing the ≥8 band must promote online: {stats:?}"
    );
    let report = cluster.redundancy_report().unwrap();
    assert!(report.is_converged(), "after promote: {report:?}");

    client.delete_object("bulk").unwrap();
    cluster.flush_consistency().unwrap();
    let stats = cluster.stats();
    assert!(
        stats.redundancy_demotions >= 1,
        "dropping below the band must demote online: {stats:?}"
    );
    let report = cluster.redundancy_report().unwrap();
    assert!(report.is_converged(), "after demote: {report:?}");
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

/// Satellite regression: a replica peer killed mid-put must be counted
/// (`replica_push_failures`) and recorded as repair debt, and the next
/// scrub pass must restore the target copy count on the revived peer.
#[test]
fn killed_replica_peer_is_counted_and_healed_by_next_scrub() {
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        clock: ClockSource::Sim,
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    let data = block(7);
    let fp = Fingerprint::of(&data);
    let chain = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key()))
        .unwrap();
    let (home, replica_peer) = (chain[0], chain[1]);
    assert_ne!(home, replica_peer);
    // the object's frontend must not be the peer we are about to kill,
    // or the put fails outright instead of degrading its fan-out
    let name = (0..256)
        .map(|i| format!("rc-{i}"))
        .find(|n| {
            cluster
                .with_osd(ServerId(0), |sh| sh.object_chain(n)[0])
                .unwrap()
                != replica_peer
        })
        .expect("no object name avoiding the victim frontend");

    cluster.kill_server(replica_peer).unwrap();
    let before = cluster.stats();
    client.put_object(&name, &data).unwrap();
    let after = cluster.stats();
    assert!(
        after.replica_push_failures > before.replica_push_failures,
        "the dead replica slot must be counted, not shrugged off"
    );
    assert!(
        !cluster
            .with_osd(replica_peer, |sh| sh
                .replica_store
                .stat(&chunk_copy_key(&fp))
                .unwrap())
            .unwrap(),
        "precondition: the copy cannot have landed on a dead peer"
    );

    cluster.restart_server(replica_peer).unwrap();
    cluster.start_scrub(ScrubOptions::light()).unwrap();
    let scrub = cluster.scrub_wait().unwrap();
    assert!(scrub.all_done(), "{:?}", scrub.first_failure());
    assert!(
        cluster
            .with_osd(replica_peer, |sh| sh
                .replica_store
                .stat(&chunk_copy_key(&fp))
                .unwrap())
            .unwrap(),
        "the scrub's repair-debt drain must restore the copy even on a \
         light pass"
    );
    let report = cluster.redundancy_report().unwrap();
    assert!(report.is_converged(), "{report:?}");
    cluster.shutdown();
}

/// Satellite regression: a demotion landing on a locality plant keeps
/// the plant — it was never counted toward the banded target, so the
/// holder answers `NotFound` and the copy (and its registration)
/// survive.
#[test]
fn demotion_spares_locality_plants() {
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        // refs ≥ 2 → one extra copy, so a single duplicate object
        // promotes and a single delete demotes
        redundancy: RedundancyPolicy::new([(2, 1)]),
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        clock: ClockSource::Sim,
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    let data = block(3);
    let fp = Fingerprint::of(&data);
    let chain = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key()))
        .unwrap();
    // the chain slot a promotion fills and a demotion later drains
    let extra_slot = chain[2];

    client.put_object("dup-a", &data).unwrap();
    // the extra slot independently planted a locality copy of the chunk
    cluster
        .with_osd(extra_slot, |sh| {
            sh.replica_store.put(&chunk_copy_key(&fp), &data).unwrap();
            sh.chunk_cache.plant_register(&fp, data.len() as u64, 1 << 20);
        })
        .unwrap();

    // refs 1 → 2 promotes onto the extra slot (same key as the plant)
    client.put_object("dup-b", &data).unwrap();
    // refs 2 → 1 demotes the extra slot — which must keep the plant
    client.delete_object("dup-b").unwrap();
    cluster.flush_consistency().unwrap();

    let (planted, copy_present) = cluster
        .with_osd(extra_slot, |sh| {
            (
                sh.chunk_cache.planted_contains(&fp),
                sh.replica_store.stat(&chunk_copy_key(&fp)).unwrap(),
            )
        })
        .unwrap();
    assert!(planted, "the plant registration must survive the demotion");
    assert!(copy_present, "the planted copy must survive the demotion");

    // the census agrees: the plant is not a redundancy copy, so the
    // chunk sits exactly at its (flat-band) target of 2
    let report = cluster.redundancy_report().unwrap();
    assert!(report.is_converged(), "{report:?}");
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}
