//! Property: random interleavings of writes, deletes, kills, restarts,
//! GC, bit-rot injection and online scrubs never leave the cluster in a
//! state that a converge sequence (restart-all → flush → scrub → GC)
//! cannot bring back to a clean audit.

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::util::prop::{check, Config};
use snss_dedup::util::rng::{SplitMix64, XorShift128Plus};

const SERVERS: u32 = 3;

#[derive(Debug, Clone)]
enum Op {
    /// (name index, payload seed, payload length)
    Put(u64, u64, usize),
    Delete(u64),
    Kill(u32),
    Restart(u32),
    Gc,
    ScrubLight,
    ScrubDeep,
    /// Flip a bit in the first chunk stored on this server.
    Corrupt(u32),
}

fn payload(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = XorShift128Plus::new(seed);
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

fn corrupt_first_chunk(cluster: &Cluster, id: ServerId) {
    let _ = cluster.with_osd(id, |sh| -> snss_dedup::Result<()> {
        for key in sh.store.keys()? {
            if key.len() != 20 {
                continue;
            }
            if let Some(mut data) = sh.store.get(&key)? {
                if !data.is_empty() {
                    data[0] ^= 0x80;
                    sh.store.put(&key, &data)?;
                    return Ok(());
                }
            }
        }
        Ok(())
    });
}

fn run_case(ops: &[Op], chunking: Chunking) -> Result<(), String> {
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS as usize,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking,
        ..Default::default()
    })
    .map_err(|e| e.to_string())?;
    let client = cluster.client();

    for op in ops {
        match op {
            // data-path errors are expected while servers are down
            Op::Put(i, seed, len) => {
                let _ = client.put_object(&format!("obj-{i}"), &payload(*seed, *len));
            }
            Op::Delete(i) => {
                let _ = client.delete_object(&format!("obj-{i}"));
            }
            Op::Kill(s) => {
                let _ = cluster.kill_server(ServerId(s % SERVERS));
            }
            Op::Restart(s) => {
                let _ = cluster.restart_server(ServerId(s % SERVERS));
            }
            Op::Gc => {
                let _ = cluster.run_gc(0);
            }
            Op::ScrubLight => {
                let _ = cluster.start_scrub(ScrubOptions::light());
                let _ = cluster.scrub_wait();
            }
            Op::ScrubDeep => {
                let _ = cluster.start_scrub(ScrubOptions::deep().with_window(16));
                let _ = cluster.scrub_wait();
            }
            Op::Corrupt(s) => corrupt_first_chunk(&cluster, ServerId(s % SERVERS)),
        }
    }

    // converge: revive everything, settle flags, scrub, collect garbage
    for i in 0..SERVERS {
        let _ = cluster.restart_server(ServerId(i));
    }
    cluster.flush_consistency().map_err(|e| e.to_string())?;
    cluster
        .start_scrub(ScrubOptions::deep())
        .map_err(|e| format!("start_scrub: {e}"))?;
    cluster.scrub_wait().map_err(|e| format!("scrub_wait: {e}"))?;
    cluster.run_gc(0).map_err(|e| format!("gc: {e}"))?;

    let audit = cluster.audit().map_err(|e| format!("audit: {e}"))?;
    if !audit.is_ok() {
        return Err(format!("audit violations: {:?}", audit.violations));
    }
    cluster.shutdown();
    Ok(())
}

fn gen_ops(rng: &mut SplitMix64, size: u32) -> Vec<Op> {
    let count = 4 + (size as usize) / 8; // ramps 4 → ~16 ops
    (0..count)
        .map(|_| match rng.below(10) {
            0 | 1 | 2 => Op::Put(
                rng.below(5),
                rng.next_u64(),
                1024 + rng.below(16 * 1024) as usize,
            ),
            3 => Op::Delete(rng.below(5)),
            4 => Op::Kill(rng.next_u32()),
            5 => Op::Restart(rng.next_u32()),
            6 => Op::Gc,
            7 => Op::ScrubLight,
            8 => Op::ScrubDeep,
            _ => Op::Corrupt(rng.next_u32()),
        })
        .collect::<Vec<Op>>()
}

#[test]
fn random_fault_and_scrub_interleavings_converge_to_clean_audit() {
    check(
        Config {
            cases: 8,
            ..Config::default()
        },
        gen_ops,
        |ops| run_case(ops, Chunking::Fixed { size: 2048 }),
    );
}

/// The same fault/scrub matrix over gear-CDC chunking (variable chunk
/// boundaries exercise the batched write path with mixed-size batches
/// and many distinct homes per object).
#[test]
fn cdc_fault_and_scrub_interleavings_converge_to_clean_audit() {
    check(
        Config {
            cases: 4,
            ..Config::default()
        },
        gen_ops,
        |ops| run_case(ops, Chunking::cdc_with_mean(2048)),
    );
}
