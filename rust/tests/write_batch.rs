//! Integration: the batched two-phase write path.
//!
//! * Message budget — a put costs at most one `ProbeChunks` plus one
//!   `StoreChunkBatch` per distinct remote chunk home (vs one
//!   `StoreChunk` per unique chunk on the legacy path), and a
//!   duplicate-heavy put ships almost no payload bytes.
//! * State parity — batched and legacy clusters driven by the same
//!   workload end in identical state (placement, bytes, savings).
//! * NeedData NACK — a probe hint invalidated between the two phases
//!   (GC reclaimed the chunk) is re-shipped with payload, not lost.
//! * Crash matrix — every write-transaction crash point, with batching
//!   on, converges to a clean audit after restart + scrub + GC.

use snss_dedup::api::{Cluster, ClusterConfig, Consistency, ScrubOptions, WriteBatching};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::failure::CrashPoint;
use snss_dedup::net::Lane;
use snss_dedup::storage::proto::Req;
use snss_dedup::workload::{Generator, WorkloadSpec};
use snss_dedup::Fingerprint;

const CHUNK: usize = 2048;

/// Inline-valid consistency keeps commit flags deterministic (no async
/// flag-manager race), so probe-hit counts can be asserted exactly.
fn boot(servers: usize, batching: WriteBatching) -> Cluster {
    Cluster::new(ClusterConfig {
        servers,
        replication: 1,
        write_batching: batching,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        ..Default::default()
    })
    .expect("boot")
}

/// A payload of `n` distinct chunks (no intra-object duplicates).
fn unique_payload(n: usize) -> Vec<u8> {
    let mut data = vec![0u8; n * CHUNK];
    for (i, block) in data.chunks_mut(CHUNK).enumerate() {
        for (j, b) in block.iter_mut().enumerate() {
            *b = ((i * 131 + j * 7) % 251) as u8;
        }
    }
    data
}

#[test]
fn batched_put_sends_two_messages_per_home() {
    let cluster = boot(4, WriteBatching::TwoPhase);
    let client = cluster.client();
    let data = unique_payload(32);

    // where will the chunks land, relative to the object's primary?
    let writer = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain("obj")[0])
        .unwrap();
    let mut homes = std::collections::HashSet::new();
    let mut remote_fps = 0u64;
    for chunk in data.chunks(CHUNK) {
        let fp = Fingerprint::of(chunk);
        let home = cluster
            .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
            .unwrap();
        if home != writer {
            homes.insert(home);
            remote_fps += 1;
        }
    }
    let homes = homes.len() as u64;
    assert!(homes >= 1, "workload places no chunk remotely");

    let before = cluster.stats();
    client.put_object("obj", &data).unwrap();
    let after = cluster.stats();
    assert_eq!(after.probe_batches - before.probe_batches, homes);
    assert_eq!(after.store_batches - before.store_batches, homes);
    assert_eq!(after.need_data_resends, before.need_data_resends);
    let first_wire = after.wire_bytes - before.wire_bytes;

    // identical overwrite (same name → same writer): every remote probe
    // hits, payloads are elided, and the wire cost collapses
    let (_, unique) = client.put_object("obj", &data).unwrap();
    let second = cluster.stats();
    assert_eq!(unique, 0, "second copy should store nothing");
    assert_eq!(second.probe_hits - after.probe_hits, remote_fps);
    let second_wire = second.wire_bytes - after.wire_bytes;
    assert!(
        second_wire * 4 < first_wire,
        "duplicate put should be near-free on the wire: {second_wire} vs {first_wire}"
    );

    assert_eq!(client.get_object("obj").unwrap(), data);
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn batched_and_legacy_reach_identical_state() {
    let gen = Generator::new(WorkloadSpec {
        object_size: 16 << 10,
        unit: CHUNK,
        dedup_pct: 50,
        pool_blocks: 32,
        zipf_theta: 0.0,
        seed: 0xBA7C,
    });
    let mut snapshots = Vec::new();
    for batching in [WriteBatching::Off, WriteBatching::TwoPhase] {
        let cluster = boot(4, batching);
        let client = cluster.client();
        for i in 0..24 {
            let (name, data) = gen.named_object(i);
            client.put_object(&name, &data).expect("put");
        }
        // overwrites and deletes exercise the DecRefBatch paths too
        let (name1, _) = gen.named_object(1);
        client.put_object(&name1, &gen.object(100)).expect("overwrite");
        for i in [0u64, 6, 12] {
            let (name, _) = gen.named_object(i);
            client.delete_object(&name).expect("delete");
        }
        cluster.flush_consistency().unwrap();
        for i in [2u64, 7, 23] {
            let (name, data) = gen.named_object(i);
            assert_eq!(client.get_object(&name).unwrap(), data, "{batching:?}");
        }
        let audit = cluster.audit().unwrap();
        assert!(audit.is_ok(), "{batching:?}: {:?}", audit.violations);
        let stats = cluster.stats();
        let per_server: Vec<(u32, usize, u64, usize)> = stats
            .per_server
            .iter()
            .map(|p| (p.server, p.chunks_stored, p.bytes_stored, p.objects))
            .collect();
        snapshots.push((stats.unique_chunks, stats.stored_bytes, per_server));
        cluster.shutdown();
    }
    assert_eq!(
        snapshots[0],
        snapshots[1],
        "legacy and batched write paths must land byte-identical state"
    );
}

#[test]
fn stale_probe_hint_is_resent_via_need_data() {
    let cluster = boot(4, WriteBatching::TwoPhase);
    let client = cluster.client();
    let data = unique_payload(1);
    let fp = Fingerprint::of(&data);
    let home = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
        .unwrap();
    // pick a writer (object primary) that is not the chunk's home, so
    // the chunk travels through the batched remote path
    let mut name_b = String::new();
    for i in 0..64 {
        let cand = format!("b-{i}");
        let primary = cluster
            .with_osd(ServerId(0), |sh| sh.object_chain(&cand)[0])
            .unwrap();
        if primary != home {
            name_b = cand;
            break;
        }
    }
    assert!(!name_b.is_empty(), "no suitable object name found");
    let writer = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain(&name_b)[0])
        .unwrap();

    // seed the chunk (inline-valid flag), then orphan it: a Valid
    // zero-ref CIT entry is exactly what a probe will hit and GC will
    // reclaim
    client.put_object("a-seed", &data).unwrap();
    client.delete_object("a-seed").unwrap();

    // between probe and store, run GC at the home: the probed entry is
    // reclaimed, so the payload-less grant must come back NeedData
    cluster
        .with_osd(writer, |sh| {
            let dir = sh.dir.clone();
            let hook = move || {
                if let Ok(addr) = dir.lookup(home, Lane::Control) {
                    let _ = addr.call(Req::RunGc { threshold_ms: 0 }, 64);
                }
            };
            *sh.probe_gap_hook.lock().unwrap() = Some(Box::new(hook));
        })
        .unwrap();

    let before = cluster.stats();
    client.put_object(&name_b, &data).unwrap();
    let after = cluster.stats();
    assert_eq!(
        after.need_data_resends - before.need_data_resends,
        1,
        "the stale hint must be NACKed and re-shipped exactly once"
    );
    assert!(after.probe_hits > before.probe_hits, "probe should have hit");
    assert_eq!(client.get_object(&name_b).unwrap(), data);
    cluster.flush_consistency().unwrap();
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn batched_crash_matrix_converges_to_clean_audit() {
    let points = [
        CrashPoint::AfterCitInsert,
        CrashPoint::AfterDataStore,
        CrashPoint::BeforeReplicate,
        CrashPoint::BeforeOmapWrite,
        CrashPoint::AfterOmapWrite,
    ];
    let gen = Generator::new(WorkloadSpec {
        object_size: 8 << 10,
        unit: CHUNK,
        dedup_pct: 50,
        pool_blocks: 16,
        zipf_theta: 0.0,
        seed: 0xC4A5,
    });
    for point in points {
        let cluster = Cluster::new(ClusterConfig {
            servers: 3,
            replication: 2,
            write_batching: WriteBatching::TwoPhase,
            chunking: Chunking::Fixed { size: CHUNK },
            ..Default::default()
        })
        .expect("boot");
        let client = cluster.client();
        for i in 0..4 {
            let (name, data) = gen.named_object(i);
            client.put_object(&name, &data).expect("seed put");
        }
        for s in 0..3 {
            cluster.arm_crash(ServerId(s), point).unwrap();
        }
        // aborts and ServerDown errors are expected while servers die
        for i in 4..10 {
            let (name, data) = gen.named_object(i);
            let _ = client.put_object(&name, &data);
        }
        for s in 0..3 {
            let _ = cluster.restart_server(ServerId(s));
        }
        cluster.flush_consistency().unwrap();
        cluster.start_scrub(ScrubOptions::deep()).unwrap();
        cluster.scrub_wait().unwrap();
        cluster.run_gc(0).unwrap();
        let audit = cluster.audit().unwrap();
        assert!(audit.is_ok(), "{point:?}: {:?}", audit.violations);
        // pre-crash data stays readable
        for i in 0..4 {
            let (name, data) = gen.named_object(i);
            assert_eq!(client.get_object(&name).unwrap(), data, "{point:?}");
        }
        cluster.shutdown();
    }
}
