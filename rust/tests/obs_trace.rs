//! Integration: distributed tracing end to end.
//!
//! * Acceptance tree — an over-threshold put through the batched write
//!   path yields one reassembled cross-server span tree covering
//!   client root → `Frontend/PutObject` → `Backend/ProbeChunks` +
//!   `Backend/StoreChunkBatch` → `Replica/VerifyCopy`, fully
//!   deterministic under the virtual clock (the probe-gap hook advances
//!   simulated time mid-put to trip the tail sampler).
//! * Propagation property — with the tail threshold at zero every span
//!   of every client operation is reachable from its client root.
//! * Crash semantics — a restarted server's span ring is cleared, so no
//!   server span leaks across `restart_server`.
//! * Sampling policy — the tail sampler retains exactly the slow ops;
//!   the head sampler retains exactly every Nth op.

use snss_dedup::api::{ClockSource, Cluster, ClusterConfig, Consistency, WriteBatching};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::obs::{ObsConfig, CLIENT_SCOPE};

const CHUNK: usize = 1024;

/// Deterministic cluster: virtual clock, inline-valid flags (no async
/// flag-manager traffic), batched writes, generous span rings.
fn boot(obs: ObsConfig) -> Cluster {
    Cluster::new(ClusterConfig {
        servers: 3,
        replication: 2,
        write_batching: WriteBatching::TwoPhase,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        clock: ClockSource::Sim,
        verify_write: true,
        obs,
        ..Default::default()
    })
    .expect("boot")
}

/// A payload of `n` distinct chunks (no intra-object duplicates).
fn unique_payload(n: usize, salt: u8) -> Vec<u8> {
    let mut data = vec![0u8; n * CHUNK];
    for (i, block) in data.chunks_mut(CHUNK).enumerate() {
        for (j, b) in block.iter_mut().enumerate() {
            *b = ((i * 131 + j * 7) % 251) as u8 ^ salt;
        }
    }
    data
}

/// Arm the probe-gap hook on `name`'s write primary so the put spends
/// `ms` of simulated time between its two phases (making it slow under
/// the tail threshold without perturbing any other op).
fn arm_slow_put(cluster: &Cluster, name: &str, ms: u64) {
    let writer = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain(name)[0])
        .unwrap();
    let sim = cluster.sim_clock().unwrap();
    cluster
        .with_osd(writer, move |sh| {
            let hook = move || {
                sim.advance(ms);
            };
            *sh.probe_gap_hook.lock().unwrap() = Some(Box::new(hook));
        })
        .unwrap();
}

#[test]
fn slow_put_yields_cross_server_span_tree() {
    let cluster = boot(ObsConfig {
        slow_op_threshold_ms: 10,
        span_ring_capacity: 4096,
        ..ObsConfig::default()
    });
    let client = cluster.client();

    arm_slow_put(&cluster, "obj", 50);
    client.put_object("obj", &unique_payload(16, 0)).unwrap();

    let dump = cluster.trace_dump();
    assert_eq!(dump.traces.len(), 1, "exactly the slow put is retained");
    let tree = &dump.traces[0];
    let root = tree.root().expect("client root span survived");
    assert_eq!(root.name, "client/put");
    assert_eq!(root.server, CLIENT_SCOPE);
    assert!(root.duration_ms() >= 50, "hook advanced the virtual clock");

    // the acceptance chain: client root → frontend handler → batched
    // two-phase fan-out → post-write replica verification
    let frontend = tree.find("Frontend/PutObject").expect("frontend span");
    for name in [
        "Frontend/PutObject",
        "Backend/ProbeChunks",
        "Backend/StoreChunkBatch",
        "Replica/VerifyCopy",
    ] {
        let span = tree.find(name).unwrap_or_else(|| panic!("missing {name}"));
        assert!(
            tree.reachable_from_root(span.span_id),
            "{name} must parent-link back to the client root"
        );
    }
    // the tree really crosses servers: batched groups only form for
    // remote chunk homes, so the probe lands off the write primary
    let probe = tree.find("Backend/ProbeChunks").unwrap();
    assert_ne!(probe.server, frontend.server, "probe span is remote");
    cluster.shutdown();
}

#[test]
fn every_span_is_reachable_from_its_client_root() {
    // threshold 0: every op is tail-retained, so the dump is the full
    // population and reachability can be asserted universally
    let cluster = boot(ObsConfig {
        slow_op_threshold_ms: 0,
        span_ring_capacity: 8192,
        retained_traces: 256,
        ..ObsConfig::default()
    });
    let client = cluster.client();
    let mut ops = 0usize;
    for i in 0..8u8 {
        let name = format!("obj-{i}");
        let data = unique_payload(8, i);
        client.put_object(&name, &data).unwrap();
        assert_eq!(client.get_object(&name).unwrap(), data);
        ops += 2;
    }
    for i in [0u8, 3, 6] {
        client.delete_object(&format!("obj-{i}")).unwrap();
        ops += 1;
    }

    let dump = cluster.trace_dump();
    assert_eq!(dump.traces.len(), ops, "one retained trace per client op");
    for tree in &dump.traces {
        let root = tree.root().expect("root survived (ring is oversized)");
        assert!(root.name.starts_with("client/"), "{}", root.name);
        for span in &tree.spans {
            assert!(
                tree.reachable_from_root(span.span_id),
                "span {} ({}) orphaned in trace {}",
                span.span_id,
                span.name,
                tree.trace_id
            );
        }
    }
    cluster.shutdown();
}

#[test]
fn restart_clears_server_spans() {
    let cluster = boot(ObsConfig {
        slow_op_threshold_ms: 0,
        span_ring_capacity: 4096,
        ..ObsConfig::default()
    });
    let client = cluster.client();
    for i in 0..3u8 {
        client
            .put_object(&format!("obj-{i}"), &unique_payload(8, i))
            .unwrap();
    }
    let before = cluster.trace_dump();
    assert!(
        before
            .traces
            .iter()
            .flat_map(|t| t.spans.iter())
            .any(|s| s.server != CLIENT_SCOPE),
        "sanity: server-side spans exist before the restarts"
    );

    for s in 0..3 {
        cluster.kill_server(ServerId(s)).unwrap();
        cluster.restart_server(ServerId(s)).unwrap();
    }
    let after = cluster.trace_dump();
    assert_eq!(after.traces.len(), 3, "retention survives the restarts");
    for tree in &after.traces {
        for span in &tree.spans {
            assert_eq!(
                span.server, CLIENT_SCOPE,
                "span {} leaked across restart_server",
                span.name
            );
        }
    }
    cluster.shutdown();
}

#[test]
fn tail_sampler_retains_exactly_the_slow_ops() {
    let cluster = boot(ObsConfig {
        slow_op_threshold_ms: 10,
        span_ring_capacity: 4096,
        ..ObsConfig::default()
    });
    let client = cluster.client();
    let mut slow = Vec::new();
    for i in 0..6u8 {
        let name = format!("obj-{i}");
        if i % 2 == 0 {
            arm_slow_put(&cluster, &name, 50);
            slow.push(name.clone());
        }
        client.put_object(&name, &unique_payload(8, i)).unwrap();
    }
    assert_eq!(slow.len(), 3);
    let dump = cluster.trace_dump();
    assert_eq!(dump.traces.len(), slow.len(), "only the slow ops retained");
    for tree in &dump.traces {
        let root = tree.root().expect("root");
        assert!(root.duration_ms() >= 10, "retained op really was slow");
    }
    cluster.shutdown();
}

#[test]
fn head_sampler_retains_every_nth_op() {
    let cluster = boot(ObsConfig {
        // tail sampling effectively off; only the 1-in-3 exemplar stream
        slow_op_threshold_ms: 1_000_000,
        head_sample_every: 3,
        span_ring_capacity: 4096,
        ..ObsConfig::default()
    });
    let client = cluster.client();
    for i in 0..9u8 {
        client
            .put_object(&format!("obj-{i}"), &unique_payload(4, i))
            .unwrap();
    }
    let dump = cluster.trace_dump();
    assert_eq!(dump.traces.len(), 3, "every 3rd of 9 ops is an exemplar");
    for tree in &dump.traces {
        assert_eq!(tree.root().expect("root").name, "client/put");
    }
    cluster.shutdown();
}
