//! Integration: the production read path (DESIGN.md §14).
//!
//! * Byte identity — batched and legacy reads return identical bytes
//!   across dedup ratios (0/50/90%).
//! * Message budget — a batched read costs at most one
//!   `FetchChunkBatch` per distinct live remote chunk home, and a
//!   repeat read is answered entirely from the hot-chunk cache.
//! * Degraded reads — a killed chunk home degrades per item through
//!   the legacy fallback; the bytes still come back correct.
//! * Cache coherence — the invalidation matrix (GC reclaim, scrub
//!   quarantine, recovery re-home, rejoin wipe, kill) proves no stale
//!   cache entry survives any event that retires a CIT entry.
//! * Selective duplication — a hot remote chunk gets a planted
//!   locality copy, after which reads of it stop touching the fabric.

use snss_dedup::api::{
    CacheConfig, Cluster, ClusterConfig, ClockSource, Consistency, DedupMode, DupPolicy,
    ReadBatching, ScrubOptions,
};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};
use snss_dedup::Fingerprint;

const CHUNK: usize = 2048;

/// Inline-valid consistency keeps commit flags deterministic, so the
/// message-budget counters can be asserted exactly.
fn boot(servers: usize, cfg: impl FnOnce(&mut ClusterConfig)) -> Cluster {
    let mut c = ClusterConfig {
        servers,
        replication: 1,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        ..Default::default()
    };
    cfg(&mut c);
    Cluster::new(c).expect("boot")
}

/// A payload of `n` distinct chunks (no intra-object duplicates).
fn unique_payload(n: usize) -> Vec<u8> {
    let mut data = vec![0u8; n * CHUNK];
    for (i, block) in data.chunks_mut(CHUNK).enumerate() {
        for (j, b) in block.iter_mut().enumerate() {
            *b = ((i * 131 + j * 7) % 251) as u8;
        }
    }
    data
}

/// Find an object name whose frontend primary is `want` (or, with
/// `invert`, is anything but `want`).
fn name_with_primary(cluster: &Cluster, want: ServerId, invert: bool) -> String {
    for i in 0..256 {
        let cand = format!("rp-{i}");
        let primary = cluster
            .with_osd(ServerId(0), |sh| sh.object_chain(&cand)[0])
            .unwrap();
        if (primary == want) != invert {
            return cand;
        }
    }
    panic!("no object name with the required primary found");
}

#[test]
fn batched_and_legacy_reads_byte_identical_across_dedup_ratios() {
    for dedup_pct in [0u8, 50, 90] {
        let gen = Generator::new(WorkloadSpec {
            object_size: 8 << 10,
            unit: CHUNK,
            dedup_pct,
            pool_blocks: 24,
            zipf_theta: 0.0,
            seed: 0x5EED ^ dedup_pct as u64,
        });
        for batching in [ReadBatching::Off, ReadBatching::PerHome] {
            let cluster = boot(4, |c| c.read_batching = batching);
            let client = cluster.client();
            for i in 0..12 {
                let (name, data) = gen.named_object(i);
                client.put_object(&name, &data).expect("put");
            }
            // two passes: cold (store/fabric) and warm (cache) reads
            for pass in 0..2 {
                for i in 0..12 {
                    let (name, data) = gen.named_object(i);
                    assert_eq!(
                        client.get_object(&name).unwrap(),
                        data,
                        "{batching:?} dedup={dedup_pct}% pass={pass} object={i}"
                    );
                }
            }
            let audit = cluster.audit().unwrap();
            assert!(audit.is_ok(), "{batching:?}: {:?}", audit.violations);
            cluster.shutdown();
        }
    }
}

#[test]
fn batched_read_message_budget_is_one_per_remote_home() {
    let cluster = boot(4, |_| {});
    let client = cluster.client();
    let data = unique_payload(32);

    let reader = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain("obj")[0])
        .unwrap();
    let mut remote_homes = std::collections::HashSet::new();
    let mut unique = 0u64;
    for chunk in data.chunks(CHUNK) {
        let fp = Fingerprint::of(chunk);
        let home = cluster
            .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
            .unwrap();
        unique += 1;
        if home != reader {
            remote_homes.insert(home);
        }
    }
    let remote_homes = remote_homes.len() as u64;
    assert!(remote_homes >= 1, "workload places no chunk remotely");

    client.put_object("obj", &data).unwrap();
    let before = cluster.stats();
    assert_eq!(client.get_object("obj").unwrap(), data);
    let after = cluster.stats();
    assert_eq!(
        after.read_batches - before.read_batches,
        remote_homes,
        "≤ 1 backend message per distinct live chunk home per read"
    );
    assert_eq!(
        after.read_chunk_fetches, before.read_chunk_fetches,
        "no per-chunk messages on a healthy batched read"
    );
    assert_eq!(after.read_fallbacks, before.read_fallbacks);

    // warm read: everything from the hot-chunk cache, zero fabric msgs
    assert_eq!(client.get_object("obj").unwrap(), data);
    let warm = cluster.stats();
    assert_eq!(warm.read_batches, after.read_batches);
    assert_eq!(warm.read_chunk_fetches, after.read_chunk_fetches);
    assert_eq!(
        warm.read_cache_hits - after.read_cache_hits,
        unique,
        "repeat read must be answered entirely from cache"
    );
    cluster.shutdown();
}

#[test]
fn degraded_read_with_killed_home_falls_back_per_item() {
    let cluster = boot(4, |c| c.replication = 2);
    let client = cluster.client();
    let data = unique_payload(16);

    let reader = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain("victim-obj")[0])
        .unwrap();
    // pick the primary of a chunk whose whole chain avoids the reader,
    // so killing it forces a fabric batch to degrade (the reader can't
    // quietly serve that chunk from its own replica slot)
    let mut victim = None;
    for chunk in data.chunks(CHUNK) {
        let fp = Fingerprint::of(chunk);
        let chain = cluster
            .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key()))
            .unwrap();
        if !chain.contains(&reader) {
            victim = Some(chain[0]);
            break;
        }
    }
    let victim = victim.expect("no remote chunk home to kill");

    client.put_object("victim-obj", &data).unwrap();
    cluster.kill_server(victim).unwrap();

    let before = cluster.stats();
    assert_eq!(
        client.get_object("victim-obj").unwrap(),
        data,
        "read must survive a dead chunk home via replica copies"
    );
    let after = cluster.stats();
    assert!(
        after.read_degraded_dead > before.read_degraded_dead,
        "the dead home must be counted as a degraded fallback"
    );
    assert!(
        after.read_fallbacks > before.read_fallbacks,
        "batch items on the dead home must fall back per item"
    );
    cluster.shutdown();
}

#[test]
fn gc_reclaim_invalidates_cached_chunk() {
    let cluster = boot(4, |c| c.clock = ClockSource::Sim);
    let client = cluster.client();
    let data = unique_payload(1);
    let fp = Fingerprint::of(&data);
    let home = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
        .unwrap();
    // route the object through the chunk's home so the (local-primary)
    // read populates the cache on the same server GC will reclaim on
    let name = name_with_primary(&cluster, home, false);

    client.put_object(&name, &data).unwrap();
    assert_eq!(client.get_object(&name).unwrap(), data);
    assert!(
        cluster.with_osd(home, |sh| sh.chunk_cache.contains(&fp)).unwrap(),
        "read must have cached the chunk at its home"
    );

    client.delete_object(&name).unwrap();
    cluster.flush_consistency().unwrap();
    cluster.advance_clock(10).unwrap();
    let before = cluster.stats();
    cluster.run_gc(0).unwrap();
    let after = cluster.stats();
    assert!(after.gc_reclaimed > before.gc_reclaimed, "GC must reclaim");
    assert!(
        !cluster.with_osd(home, |sh| sh.chunk_cache.contains(&fp)).unwrap(),
        "a reclaimed chunk must not survive in the cache"
    );
    assert!(after.read_cache_invalidations > before.read_cache_invalidations);
    cluster.shutdown();
}

#[test]
fn scrub_quarantine_invalidates_cached_chunk() {
    let cluster = boot(4, |c| c.clock = ClockSource::Sim);
    let client = cluster.client();
    let data = unique_payload(1);
    let fp = Fingerprint::of(&data);
    let home = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
        .unwrap();
    client.put_object("scrub-obj", &data).unwrap();

    // cache the chunk at its home, then lose the primary bytes with no
    // replica anywhere (replication 1): scrub must quarantine it
    cluster
        .with_osd(home, |sh| {
            sh.chunk_cache.insert(fp, &data, false);
            sh.store.delete(&fp.to_bytes()).unwrap();
        })
        .unwrap();
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    cluster.scrub_wait().unwrap();
    assert!(
        !cluster.with_osd(home, |sh| sh.chunk_cache.contains(&fp)).unwrap(),
        "a quarantined chunk must not survive in the cache"
    );
    cluster.shutdown();
}

#[test]
fn recovery_rehome_invalidates_cached_chunk() {
    let cluster = boot(4, |c| {
        c.replication = 2;
        c.clock = ClockSource::Sim;
    });
    let client = cluster.client();
    let data = unique_payload(1);
    let fp = Fingerprint::of(&data);
    let old_home = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
        .unwrap();
    // keep the object's OMAP off the server we are about to remove
    let name = name_with_primary(&cluster, old_home, true);
    client.put_object(&name, &data).unwrap();

    // prime every survivor's cache: whoever becomes the new home must
    // invalidate before adopting the re-homed chunk
    for s in 0..4 {
        let id = ServerId(s);
        if id != old_home {
            cluster
                .with_osd(id, |sh| sh.chunk_cache.insert(fp, &data, false))
                .unwrap();
        }
    }
    cluster.kill_server(old_home).unwrap();
    cluster.remove_server(old_home).unwrap();
    cluster.recovery_wait().unwrap();

    let new_home = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
        .unwrap();
    assert_ne!(new_home, old_home, "the chunk must have re-homed");
    assert!(
        !cluster
            .with_osd(new_home, |sh| sh.chunk_cache.contains(&fp))
            .unwrap(),
        "the re-homed chunk must have been invalidated at its new home"
    );
    assert_eq!(client.get_object(&name).unwrap(), data);
    cluster.shutdown();
}

#[test]
fn kill_and_rejoin_wipe_clear_the_cache() {
    let cluster = boot(4, |c| c.clock = ClockSource::Sim);
    let data = unique_payload(1);
    let fp = Fingerprint::of(&data);
    let target = ServerId(2);

    // kill clears the cache like the span ring
    cluster
        .with_osd(target, |sh| sh.chunk_cache.insert(fp, &data, false))
        .unwrap();
    cluster.kill_server(target).unwrap();
    assert!(
        cluster.with_osd(target, |sh| sh.chunk_cache.is_empty()).unwrap(),
        "kill must clear the cache"
    );

    // and the rejoin wipe starts the new incarnation empty
    cluster.remove_server(target).unwrap();
    cluster
        .with_osd(target, |sh| sh.chunk_cache.insert(fp, &data, false))
        .unwrap();
    cluster.rejoin_server(target).unwrap();
    assert!(
        cluster.with_osd(target, |sh| sh.chunk_cache.is_empty()).unwrap(),
        "the rejoin wipe must clear the cache"
    );
    cluster.shutdown();
}

#[test]
fn selective_duplication_plants_a_locality_copy() {
    // cache off so repeat reads keep going over the fabric — exactly
    // the fragmentation signal selective duplication keys on
    let cluster = boot(4, |c| {
        c.cache = CacheConfig {
            capacity_bytes: 0,
            hot_band: 2,
        };
        c.selective_dup = Some(DupPolicy {
            fetch_threshold: 2,
            min_mean_amp_x100: 0,
            max_bytes: 16 << 20,
        });
    });
    let client = cluster.client();
    let data = unique_payload(1);
    let fp = Fingerprint::of(&data);
    let home = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
        .unwrap();
    // the reader must not be the chunk's home, or nothing is remote
    let name = name_with_primary(&cluster, home, true);
    let reader = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain(&name)[0])
        .unwrap();

    client.put_object(&name, &data).unwrap();
    for _ in 0..3 {
        assert_eq!(client.get_object(&name).unwrap(), data);
    }
    let planted = cluster.stats();
    assert!(
        planted.dup_chunks_planted >= 1,
        "a hot remote chunk must get a locality copy"
    );
    assert!(
        cluster
            .with_osd(reader, |sh| sh.chunk_cache.planted_contains(&fp))
            .unwrap(),
        "the reader must have planted the copy"
    );

    // after planting, the read is served from the local replica slot:
    // no further batch messages
    assert_eq!(client.get_object(&name).unwrap(), data);
    let after = cluster.stats();
    assert_eq!(
        after.read_batches, planted.read_batches,
        "a planted chunk must stop touching the fabric"
    );
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

#[test]
fn gc_reclaim_leaves_no_orphaned_plant() {
    // plant a locality copy on an off-chain reader, then delete the
    // object and GC: the reclaim broadcast must reach the plant holder,
    // whose invalidate_chunk choke point deletes the replica-slot copy
    // and deregisters the plant — no orphan bytes, no leaked budget
    let cluster = boot(4, |c| {
        c.clock = ClockSource::Sim;
        c.cache = CacheConfig {
            capacity_bytes: 0,
            hot_band: 2,
        };
        c.selective_dup = Some(DupPolicy {
            fetch_threshold: 2,
            min_mean_amp_x100: 0,
            max_bytes: 16 << 20,
        });
    });
    let client = cluster.client();
    let data = unique_payload(1);
    let fp = Fingerprint::of(&data);
    let home = cluster
        .with_osd(ServerId(0), |sh| sh.chunk_chain(fp.placement_key())[0])
        .unwrap();
    let name = name_with_primary(&cluster, home, true);
    let reader = cluster
        .with_osd(ServerId(0), |sh| sh.object_chain(&name)[0])
        .unwrap();

    client.put_object(&name, &data).unwrap();
    for _ in 0..3 {
        assert_eq!(client.get_object(&name).unwrap(), data);
    }
    assert!(
        cluster
            .with_osd(reader, |sh| sh.chunk_cache.planted_contains(&fp))
            .unwrap(),
        "precondition: the reader planted a locality copy"
    );

    client.delete_object(&name).unwrap();
    cluster.flush_consistency().unwrap();
    cluster.advance_clock(10).unwrap();
    let before = cluster.stats();
    cluster.run_gc(0).unwrap();
    let after = cluster.stats();
    assert!(after.gc_reclaimed > before.gc_reclaimed, "GC must reclaim");
    assert!(
        after.dup_plants_reclaimed > before.dup_plants_reclaimed,
        "the reclaim must be counted as a plant reclaim"
    );
    let (planted, orphan_bytes) = cluster
        .with_osd(reader, |sh| {
            (
                sh.chunk_cache.planted_contains(&fp),
                sh.chunk_cache.planted_bytes(),
            )
        })
        .unwrap();
    assert!(!planted, "the plant registration must be gone");
    assert_eq!(orphan_bytes, 0, "the plant budget must be released");
    assert!(
        !cluster
            .with_osd(reader, |sh| sh
                .replica_store
                .stat(&snss_dedup::dedup::engine::chunk_copy_key(&fp))
                .unwrap())
            .unwrap(),
        "the planted replica-slot copy must be deleted, not orphaned"
    );
    cluster.shutdown();
}

#[test]
fn raw_mode_reads_count_toward_read_amplification() {
    let cluster = boot(3, |c| {
        c.dedup = DedupMode::None;
        c.replication = 2;
    });
    let client = cluster.client();
    let data = unique_payload(2);
    client.put_object("raw-obj", &data).unwrap();
    let before = cluster.stats();
    assert_eq!(client.get_object("raw-obj").unwrap(), data);
    let after = cluster.stats();
    assert_eq!(
        after.read_amp_reads - before.read_amp_reads,
        1,
        "raw-mode reads must be counted"
    );
    assert_eq!(
        after.read_amp_homes - before.read_amp_homes,
        1,
        "a raw-mode read is answered by exactly one home"
    );
    cluster.shutdown();
}
