//! Failure detection & recovery backfill (`snss_dedup::recovery`).
//!
//! The deterministic MTTR path: a `kill_server` plus virtual-clock
//! advances — with no other admin calls — must end with the dead server
//! `Out`, every chunk and OMAP record back at `cfg.replication` copies
//! (clean audit, deep scrub with nothing left to repair), and the
//! `recovery_*` metrics accounting for the re-replicated bytes. Plus:
//! the admin `remove_server` path, typed admin errors, the
//! `BeforeRecoveryCopy`/`AfterRecoveryCopy` crash-point matrix, and the
//! central-mode deep scrub of raw chunks on non-metadata servers.

use snss_dedup::api::{
    ClockSource, Cluster, ClusterConfig, DedupMode, FailureDetection, ObserverVerdict,
    ScrubOptions,
};
use snss_dedup::cluster::{ServerId, ServerState};
use snss_dedup::dedup::Chunking;
use snss_dedup::failure::CrashPoint;
use snss_dedup::util::rng::XorShift128Plus;
use snss_dedup::Error;

const TICK: u64 = 10;
const PROBE: u64 = 10;
const GRACE: u64 = 40;
const OUT: u64 = 120;

fn payload(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = XorShift128Plus::new(seed);
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

fn sim_detector_config() -> ClusterConfig {
    ClusterConfig {
        servers: 4,
        replication: 2,
        chunking: Chunking::Fixed { size: 1024 },
        clock: ClockSource::Sim,
        failure_detection: Some(FailureDetection {
            probe_every_ticks: PROBE,
            grace_ticks: GRACE,
            out_ticks: OUT,
            observers: 3,
            out_quorum: 2,
        }),
        ..Default::default()
    }
}

fn populate(cluster: &Cluster, objects: u64) {
    let client = cluster.client();
    for i in 0..objects {
        client
            .put_object(&format!("obj-{i}"), &payload(i + 1, 8 * 1024))
            .unwrap();
    }
    cluster.flush_consistency().unwrap();
}

fn assert_all_readable(cluster: &Cluster, objects: u64) {
    let client = cluster.client();
    for i in 0..objects {
        assert_eq!(
            client.get_object(&format!("obj-{i}")).unwrap(),
            payload(i + 1, 8 * 1024),
            "obj-{i} must survive the failure"
        );
    }
}

/// Advance the virtual clock until `pred` holds, with a step cap.
fn advance_until(cluster: &Cluster, max_steps: u64, mut pred: impl FnMut() -> bool) -> bool {
    for _ in 0..max_steps {
        if pred() {
            return true;
        }
        cluster.advance_clock(TICK).unwrap();
    }
    pred()
}

/// The acceptance path: kill + clock advances only — the detector walks
/// the victim Up → Down → Out, recovery re-replicates everything, and
/// the cluster ends at full replication with clean accounting.
#[test]
fn detector_heals_a_killed_server_to_full_replication() {
    let objects = 24;
    let cluster = Cluster::new(sim_detector_config()).unwrap();
    populate(&cluster, objects);
    assert!(cluster.audit().unwrap().is_ok(), "baseline audit");

    let victim = ServerId(1);
    cluster.kill_server(victim).unwrap();

    // silent past the grace window: Down (placement skips the victim)
    assert!(
        advance_until(&cluster, GRACE / TICK + 2, || {
            cluster.server_state(victim).unwrap() == ServerState::Down
        }),
        "victim not marked Down within the grace window"
    );
    // silent past the out window: Out — sticky, fenced, recovery starts
    assert!(
        advance_until(&cluster, OUT / TICK + 2, || {
            cluster.server_state(victim).unwrap() == ServerState::Out
        }),
        "victim not marked Out within the out window"
    );
    let stats = cluster.stats();
    assert_eq!(stats.detector_marked_down, 1);
    assert_eq!(stats.detector_marked_out, 1);

    // recovery backfill converges (default budget is unlimited, so the
    // workers run free of the virtual clock)
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "{report:?}");
    assert!(report.chunks_restored > 0, "victim-homed chunks re-homed");
    assert!(report.copies_pushed > 0, "lost replica copies re-pushed");
    assert!(report.omap_recovered > 0, "victim-primaried records adopted");
    assert!(report.bytes_recovered > 0);

    // metrics account for the re-replicated bytes (the cluster-wide
    // counter also covers receiver-side adoption pushes)
    let stats = cluster.stats();
    assert!(stats.recovery_runs >= 3, "one job per survivor");
    assert!(stats.recovery_bytes >= report.bytes_recovered);
    assert_eq!(stats.recovery_lost, 0, "replication 2 loses nothing");

    // full replication, via the subsystem that can disprove it: the
    // audit is clean and a deep scrub finds zero missing/corrupt copies
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let scrub = cluster.scrub_wait().unwrap();
    assert!(scrub.all_done(), "{:?}", scrub.first_failure());
    assert_eq!(scrub.repaired, 0, "recovery already restored every copy");
    assert_eq!(scrub.lost, 0);
    assert_eq!(scrub.corruptions_found, 0);
    assert!(cluster.audit().unwrap().is_ok());

    assert_all_readable(&cluster, objects);
    // an Out server is permanently removed: restart is a typed error
    assert!(matches!(
        cluster.restart_server(victim),
        Err(Error::ServerRemoved(1))
    ));
    cluster.shutdown();
}

/// A kill + restart inside the grace window never escalates: the victim
/// stays Up (no Down/Out transition, no recovery) once heartbeats
/// resume; past the grace window it dips to Down and comes back Up.
#[test]
fn detector_tolerates_restarts_within_windows() {
    let cluster = Cluster::new(sim_detector_config()).unwrap();
    populate(&cluster, 6);
    let victim = ServerId(2);

    // within grace: no transition at all
    cluster.kill_server(victim).unwrap();
    cluster.advance_clock(TICK).unwrap(); // silent 10 < grace 40
    cluster.restart_server(victim).unwrap();
    cluster.advance_clock(2 * TICK).unwrap();
    assert_eq!(cluster.server_state(victim).unwrap(), ServerState::Up);
    let stats = cluster.stats();
    assert_eq!(stats.detector_marked_down, 0);
    assert_eq!(stats.recovery_runs, 0, "no out-transition, no recovery");

    // past grace but within out: Down, then Up again after the restart
    cluster.kill_server(victim).unwrap();
    assert!(
        advance_until(&cluster, GRACE / TICK + 2, || {
            cluster.server_state(victim).unwrap() == ServerState::Down
        }),
        "victim not marked Down"
    );
    cluster.restart_server(victim).unwrap();
    assert!(
        advance_until(&cluster, 4, || {
            cluster.server_state(victim).unwrap() == ServerState::Up
        }),
        "revived victim not marked Up again"
    );
    assert_eq!(cluster.stats().detector_marked_up, 1);
    assert!(cluster.audit().unwrap().is_ok());
    cluster.shutdown();
}

/// The wall-clock detector thread drives the same state machine without
/// virtual-clock ticks (poll-based assertions, generous bounds).
#[test]
fn wall_clock_detector_marks_out_and_recovers() {
    let objects = 8;
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        chunking: Chunking::Fixed { size: 1024 },
        failure_detection: Some(FailureDetection {
            probe_every_ticks: 20,
            grace_ticks: 80,
            out_ticks: 240,
            ..Default::default()
        }),
        ..Default::default()
    })
    .unwrap();
    populate(&cluster, objects);
    let victim = ServerId(3);
    cluster.kill_server(victim).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while cluster.server_state(victim).unwrap() != ServerState::Out {
        assert!(
            std::time::Instant::now() < deadline,
            "wall detector never marked the victim Out"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    // the Out mark becomes visible an instant before the detector's
    // recovery triggers land on the survivors' control lanes — wait for
    // every survivor to have started its job before waiting it out
    while cluster.stats().recovery_runs < 3 {
        assert!(
            std::time::Instant::now() < deadline,
            "recovery never triggered on every survivor"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "{report:?}");
    assert!(cluster.audit().unwrap().is_ok());
    assert_all_readable(&cluster, objects);
    cluster.shutdown();
}

/// The admin path: `remove_server` fences a live server, re-replicates
/// its data and leaves the cluster healthy — and the admin surface
/// rejects nonsense with typed errors instead of silent no-ops.
#[test]
fn remove_server_rereplicates_and_errors_are_typed() {
    let objects = 16;
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        chunking: Chunking::Fixed { size: 1024 },
        ..Default::default()
    })
    .unwrap();
    populate(&cluster, objects);

    // typed errors on unknown ids — the old silent no-ops are gone
    assert!(matches!(
        cluster.mark_down(ServerId(99)),
        Err(Error::UnknownServer(99))
    ));
    assert!(matches!(
        cluster.mark_up(ServerId(99)),
        Err(Error::UnknownServer(99))
    ));
    assert!(matches!(
        cluster.remove_server(ServerId(99)),
        Err(Error::UnknownServer(99))
    ));
    assert!(matches!(
        cluster.server_state(ServerId(99)),
        Err(Error::UnknownServer(99))
    ));
    // the known-id happy path still round-trips
    cluster.mark_down(ServerId(2)).unwrap();
    assert_eq!(cluster.server_state(ServerId(2)).unwrap(), ServerState::Down);
    cluster.mark_up(ServerId(2)).unwrap();

    // remove a live server: fenced + Out + recovered
    let victim = ServerId(1);
    cluster.remove_server(victim).unwrap();
    assert_eq!(cluster.server_state(victim).unwrap(), ServerState::Out);
    assert!(cluster.is_dead(victim), "removal fences the server");
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "{report:?}");
    assert!(cluster.audit().unwrap().is_ok());
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let scrub = cluster.scrub_wait().unwrap();
    assert_eq!(scrub.repaired + scrub.lost + scrub.corruptions_found, 0);
    assert_all_readable(&cluster, objects);

    // double removal and restart of a removed server: typed errors
    assert!(matches!(
        cluster.remove_server(victim),
        Err(Error::ServerRemoved(1))
    ));
    assert!(matches!(
        cluster.restart_server(victim),
        Err(Error::ServerRemoved(1))
    ));
    cluster.shutdown();
}

/// Detector-quorum matrix, liar side: with `observers: 3, out_quorum:
/// 2`, one observer that persistently swears a healthy server is dead
/// can never walk it Down — let alone Out — no matter how long the
/// campaign runs. The two honest `Alive` answers outvote it every round
/// and keep resetting the silence window.
#[test]
fn single_lying_observer_never_evicts_a_healthy_server() {
    let cluster = Cluster::new(sim_detector_config()).unwrap();
    populate(&cluster, 4);
    let target = ServerId(1);
    cluster
        .set_observer_hook(Some(Box::new(move |observer, id, verdict| {
            if observer == 0 && id == target {
                ObserverVerdict::Dead // a bad control path cries wolf
            } else {
                verdict
            }
        })))
        .unwrap();
    // far past grace + out: a lone dead vote below quorum is not evidence
    for _ in 0..(2 * (GRACE + OUT) / TICK) {
        cluster.advance_clock(TICK).unwrap();
    }
    assert_eq!(cluster.server_state(target).unwrap(), ServerState::Up);
    let stats = cluster.stats();
    assert_eq!(stats.detector_marked_down, 0, "liar outvoted every round");
    assert_eq!(stats.detector_marked_out, 0);
    assert!(
        stats.detector_probes > 0,
        "probe rounds must actually have run"
    );
    assert!(cluster.audit().unwrap().is_ok());
    cluster.shutdown();
}

/// Detector-quorum matrix, veto side: one observer that insists a dead
/// server is alive cannot keep it in the map — the two honest dropped-
/// envelope verdicts meet the quorum, and the victim walks Down → Out
/// within the usual grace + out windows.
#[test]
fn quorum_of_true_verdicts_evicts_a_dead_server_despite_a_liar() {
    let objects = 8;
    let cluster = Cluster::new(sim_detector_config()).unwrap();
    populate(&cluster, objects);
    let victim = ServerId(2);
    cluster
        .set_observer_hook(Some(Box::new(move |observer, id, verdict| {
            if observer == 0 && id == victim {
                ObserverVerdict::Alive // swears the corpse is fine
            } else {
                verdict
            }
        })))
        .unwrap();
    cluster.kill_server(victim).unwrap();
    assert!(
        advance_until(&cluster, (GRACE + OUT) / TICK + 4, || {
            cluster.server_state(victim).unwrap() == ServerState::Out
        }),
        "two true dead votes meet the quorum; the liar cannot veto"
    );
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "{report:?}");
    assert!(cluster.audit().unwrap().is_ok());
    assert_all_readable(&cluster, objects);
    cluster.shutdown();
}

/// Wipe-and-rejoin: an `Out` server stays fenced against `restart_server`
/// (the one-way door regression), comes back only through
/// `rejoin_server` — which wipes it empty — and the auto-enqueued
/// rebalance refills its share of the keyspace. Typed errors guard the
/// edges: unknown ids and not-Out servers are rejected.
#[test]
fn wipe_and_rejoin_readmits_an_out_server_empty() {
    let objects = 16;
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        chunking: Chunking::Fixed { size: 1024 },
        ..Default::default()
    })
    .unwrap();
    populate(&cluster, objects);

    // typed errors first: rejoin applies to Out servers only
    assert!(matches!(
        cluster.rejoin_server(ServerId(99)),
        Err(Error::UnknownServer(99))
    ));
    assert!(matches!(
        cluster.rejoin_server(ServerId(1)),
        Err(Error::NotRemoved(1))
    ));

    let victim = ServerId(1);
    cluster.remove_server(victim).unwrap();
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "{report:?}");

    // fenced-without-wipe regression: the Out server stays fenced — no
    // restart path may readmit its stale state
    assert!(matches!(
        cluster.restart_server(victim),
        Err(Error::ServerRemoved(1))
    ));
    assert!(cluster.is_dead(victim), "Out server must stay fenced");

    cluster.rejoin_server(victim).unwrap();
    assert_eq!(cluster.server_state(victim).unwrap(), ServerState::Up);
    assert!(!cluster.is_dead(victim), "rejoined server serves again");
    // double rejoin: it is Up now, so the same typed error applies
    assert!(matches!(
        cluster.rejoin_server(victim),
        Err(Error::NotRemoved(1))
    ));

    // the rejoin wiped it empty and auto-enqueued a rebalance; wait the
    // scans out, then heal-and-verify back to steady state
    cluster.rebalance_wait().unwrap();
    cluster.flush_consistency().unwrap();
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    cluster.scrub_wait().unwrap();
    cluster.run_gc(0).unwrap();
    let audit = cluster.audit().unwrap();
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let scrub = cluster.scrub_wait().unwrap();
    assert_eq!(
        scrub.repaired + scrub.lost + scrub.corruptions_found,
        0,
        "rejoin left degradation behind: {scrub:?}"
    );
    assert_all_readable(&cluster, objects);

    let stats = cluster.stats();
    assert_eq!(stats.membership_rejoins, 1);
    assert_eq!(stats.membership_wipes, 1);
    assert!(
        stats.membership_auto_rebalances >= 2,
        "remove + rejoin are both map changes: {stats:?}"
    );
    let back = stats
        .per_server
        .iter()
        .find(|p| p.server == victim.0)
        .expect("rejoined server reports stats");
    assert!(
        back.bytes_stored > 0,
        "rebalance re-homed chunks onto the rejoined server"
    );
    cluster.shutdown();
}

/// Crash-point matrix: a survivor dying right before / right after a
/// recovery copy write must never corrupt state — restart + the
/// re-queued job + one scrub pass converge back to a clean audit.
#[test]
fn recovery_crash_points_converge_after_restart() {
    for point in [CrashPoint::BeforeRecoveryCopy, CrashPoint::AfterRecoveryCopy] {
        let objects = 20;
        let cluster = Cluster::new(ClusterConfig {
            servers: 4,
            replication: 2,
            chunking: Chunking::Fixed { size: 1024 },
            ..Default::default()
        })
        .unwrap();
        populate(&cluster, objects);

        let victim = ServerId(1);
        let survivors = [ServerId(0), ServerId(2), ServerId(3)];
        for s in survivors {
            cluster.arm_crash(s, point).unwrap();
        }
        cluster.kill_server(victim).unwrap();
        cluster.remove_server(victim).unwrap();
        let _ = cluster.recovery_wait().unwrap();

        // recovery does copy work on at least one survivor, so at least
        // one armed point fired (placement is deterministic here)
        let crashed: Vec<ServerId> = survivors
            .iter()
            .copied()
            .filter(|s| cluster.is_dead(*s))
            .collect();
        assert!(!crashed.is_empty(), "{point:?} never fired");

        // restart the crashed survivors; each re-queues recovery for
        // the Out victim (its own job died with it)
        for s in crashed {
            cluster.restart_server(s).unwrap();
        }
        let report = cluster.recovery_wait().unwrap();
        assert!(report.first_failure().is_none(), "{point:?}: {report:?}");
        cluster.flush_consistency().unwrap();

        // heal-then-verify: one deep scrub sweeps up what the crashed
        // worker left behind, the next one must find nothing
        cluster.start_scrub(ScrubOptions::deep()).unwrap();
        cluster.scrub_wait().unwrap();
        cluster.run_gc(0).unwrap();
        let audit = cluster.audit().unwrap();
        assert!(audit.is_ok(), "{point:?}: {:?}", audit.violations);
        cluster.start_scrub(ScrubOptions::deep()).unwrap();
        let scrub = cluster.scrub_wait().unwrap();
        assert_eq!(
            scrub.repaired + scrub.lost + scrub.corruptions_found,
            0,
            "{point:?} left degradation behind"
        );
        assert_all_readable(&cluster, objects);
        cluster.shutdown();
    }
}

/// No-dedup mode: raw objects are re-homed *and* re-replicated after a
/// loss. Proof by double failure: after the first removal every object
/// must be back at 2 copies among the survivors, or the second removal
/// would lose data.
#[test]
fn nodedup_recovery_restores_raw_replication() {
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::None,
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    for i in 0..12u64 {
        client
            .put_object(&format!("obj-{i}"), &payload(i + 500, 4 * 1024))
            .unwrap();
    }
    cluster.remove_server(ServerId(1)).unwrap();
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "{report:?}");
    cluster.remove_server(ServerId(2)).unwrap();
    let report = cluster.recovery_wait().unwrap();
    assert!(report.first_failure().is_none(), "{report:?}");
    for i in 0..12u64 {
        assert_eq!(
            client.get_object(&format!("obj-{i}")).unwrap(),
            payload(i + 500, 4 * 1024),
            "obj-{i} lost after two sequential failures despite replication 2"
        );
    }
    cluster.shutdown();
}

/// Central-mode deep scrub now covers raw chunk data on non-metadata
/// servers (the old DESIGN.md §5 known limit): bit-rot planted on a
/// remote raw holder is found over `VerifyRaw` and — with no replica
/// copies to restore from in this comparator — quarantined behind an
/// invalid flag rather than silently served.
#[test]
fn central_mode_deep_scrub_covers_remote_raw_chunks() {
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 1,
        dedup: DedupMode::Central,
        chunking: Chunking::Fixed { size: 1024 },
        ..Default::default()
    })
    .unwrap();
    let client = cluster.client();
    for i in 0..8u64 {
        client
            .put_object(&format!("obj-{i}"), &payload(i + 100, 8 * 1024))
            .unwrap();
    }
    cluster.flush_consistency().unwrap();

    // plant rot in one raw chunk on a non-metadata server
    let mut planted = 0;
    for id in [ServerId(1), ServerId(2), ServerId(3)] {
        planted += cluster
            .with_osd(id, |sh| {
                let keys = sh.store.keys().unwrap();
                let Some(key) = keys.iter().find(|k| k.len() == 20) else {
                    return 0;
                };
                let mut data = sh.store.get(key).unwrap().unwrap();
                data[0] ^= 0xFF;
                sh.store.put(key, &data).unwrap();
                1
            })
            .unwrap();
        if planted > 0 {
            break;
        }
    }
    assert_eq!(planted, 1, "no raw chunk found on any non-metadata server");

    cluster.start_scrub(ScrubOptions::deep()).unwrap();
    let scrub = cluster.scrub_wait().unwrap();
    assert!(scrub.all_done(), "{:?}", scrub.first_failure());
    assert!(
        scrub.corruptions_found >= 1,
        "remote raw rot not detected: {scrub:?}"
    );
    assert!(
        scrub.lost >= 1,
        "unrecoverable remote rot must be quarantined: {scrub:?}"
    );
    // the quarantine keeps the audit clean: no valid flag points at rot
    assert!(cluster.audit().unwrap().is_ok());
    cluster.shutdown();
}
