//! Read-path macro-bench: per-home batched fetches
//! (`ReadBatching::PerHome`) vs the legacy per-chunk `FetchChunk`
//! fan-out, with the hot-chunk cache on and off, across dedup ratios.
//!
//! ```text
//! cargo bench --bench read_path                  # 5k + 20k objects
//! BENCH_SCALE=small cargo bench --bench read_path    # 5k only
//! ```
//!
//! Every configuration drives the *same* deterministic corpus; each
//! read is byte-compared against the generator **before** any number
//! is reported. The batched path must not send more backend read
//! messages than the legacy path at 0% dedup, and must cut them at
//! ≥50% dedup. Inline-valid consistency keeps commit flags
//! deterministic so read routing depends only on content. Results go
//! to stdout, to `bench_out/read_path.tsv`, and to
//! `BENCH_readpath.json` at the repository root.

use snss_dedup::api::{
    CacheConfig, Cluster, ClusterConfig, Consistency, ReadBatching,
};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

const SERVERS: usize = 4;
const THREADS: usize = 4;
const OBJECT_SIZE: usize = 8 << 10;
const CHUNK: usize = 2 << 10;
/// Read passes over the corpus — pass 2 is where the cache pays.
const PASSES: u64 = 2;

/// One configuration's outcome over the read phase.
struct Run {
    secs: f64,
    mib_per_s: f64,
    wire_bytes: u64,
    /// Backend read messages: `FetchChunkBatch` + legacy `FetchChunk`.
    read_msgs: u64,
    cache_hit_pct: f64,
    get_p50_us: u64,
    get_p99_us: u64,
}

fn run_one(objects: u64, dedup_pct: u8, batching: ReadBatching, cache_bytes: u64) -> Run {
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS,
        replication: 1,
        read_batching: batching,
        cache: CacheConfig {
            capacity_bytes: cache_bytes,
            ..CacheConfig::default()
        },
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        ..Default::default()
    })
    .expect("boot cluster");
    let gen = Arc::new(Generator::new(WorkloadSpec {
        object_size: OBJECT_SIZE,
        unit: CHUNK,
        dedup_pct,
        pool_blocks: 512,
        zipf_theta: 0.0,
        seed: 0x2EAD ^ objects,
    }));

    // write the corpus (not timed — this bench is about reads)
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = cluster.client();
        let gen = gen.clone();
        handles.push(std::thread::spawn(move || {
            let mut idx = t as u64;
            while idx < objects {
                let (name, data) = gen.named_object(idx);
                client.put_object(&name, &data).expect("bench put");
                idx += THREADS as u64;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    cluster.flush_consistency().ok();
    let before = cluster.stats();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = cluster.client();
        let gen = gen.clone();
        handles.push(std::thread::spawn(move || {
            for _pass in 0..PASSES {
                let mut idx = t as u64;
                while idx < objects {
                    let (name, data) = gen.named_object(idx);
                    // byte identity is a precondition for every number
                    // this bench reports
                    assert_eq!(
                        client.get_object(&name).expect("bench get"),
                        data,
                        "read diverged from the written corpus"
                    );
                    idx += THREADS as u64;
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let after = cluster.stats();

    let read_mib =
        (before.logical_bytes as f64 * PASSES as f64) / (1 << 20) as f64;
    let probes = after.read_cache_hits - before.read_cache_hits
        + (after.read_cache_misses - before.read_cache_misses);
    let get = cluster.metrics_snapshot().histogram_total("get_latency");
    let run = Run {
        secs,
        mib_per_s: read_mib / secs,
        wire_bytes: after.wire_bytes - before.wire_bytes,
        read_msgs: after.read_batches - before.read_batches + after.read_chunk_fetches
            - before.read_chunk_fetches,
        cache_hit_pct: 100.0 * (after.read_cache_hits - before.read_cache_hits) as f64
            / probes.max(1) as f64,
        get_p50_us: get.p50_us(),
        get_p99_us: get.p99_us(),
    };
    cluster.shutdown();
    run
}

fn main() {
    let sizes: &[u64] = match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => &[5_000],
        _ => &[5_000, 20_000],
    };
    let ratios: &[u8] = &[0, 50, 90];
    let default_cache = CacheConfig::default().capacity_bytes;
    // (label, batching, cache capacity): the full 2×2
    let configs: &[(&str, ReadBatching, u64)] = &[
        ("legacy", ReadBatching::Off, 0),
        ("legacy+cache", ReadBatching::Off, default_cache),
        ("batched", ReadBatching::PerHome, 0),
        ("batched+cache", ReadBatching::PerHome, default_cache),
    ];
    println!("== read path: per-home FetchChunkBatch vs per-chunk FetchChunk ==");
    println!(
        "{:<8} {:>6} {:<14} {:>10} {:>12} {:>12} {:>9} {:>9} {:>8}",
        "objects", "dedup%", "config", "MiB/s", "read msgs", "wireMB", "p50 µs", "p99 µs", "hit %"
    );
    let mut json_points = Vec::new();
    for &objects in sizes {
        for &pct in ratios {
            let mut msgs_nocache: Vec<(&str, u64)> = Vec::new();
            for &(label, batching, cache) in configs {
                let r = run_one(objects, pct, batching, cache);
                if cache == 0 {
                    msgs_nocache.push((label, r.read_msgs));
                }
                let mb = r.wire_bytes as f64 / (1 << 20) as f64;
                println!(
                    "{:<8} {:>6} {:<14} {:>10.1} {:>12} {:>12.1} {:>9} {:>9} {:>7.1}%",
                    objects,
                    pct,
                    label,
                    r.mib_per_s,
                    r.read_msgs,
                    mb,
                    r.get_p50_us,
                    r.get_p99_us,
                    r.cache_hit_pct
                );
                record(
                    "read_path",
                    "objects\tdedup_pct\tconfig\tsecs\tmib_per_s\tread_msgs\twire_bytes\t\
                     get_p50_us\tget_p99_us\tcache_hit_pct",
                    &format!(
                        "{objects}\t{pct}\t{label}\t{:.3}\t{:.1}\t{}\t{}\t{}\t{}\t{:.1}",
                        r.secs,
                        r.mib_per_s,
                        r.read_msgs,
                        r.wire_bytes,
                        r.get_p50_us,
                        r.get_p99_us,
                        r.cache_hit_pct
                    ),
                );
                json_points.push(format!(
                    "    {{\"objects\": {objects}, \"dedup_pct\": {pct}, \
                     \"config\": \"{label}\", \"secs\": {:.3}, \
                     \"mib_per_s\": {:.1}, \"read_msgs\": {}, \
                     \"wire_bytes\": {}, \"get_p50_us\": {}, \
                     \"get_p99_us\": {}, \"cache_hit_pct\": {:.1}}}",
                    r.secs,
                    r.mib_per_s,
                    r.read_msgs,
                    r.wire_bytes,
                    r.get_p50_us,
                    r.get_p99_us,
                    r.cache_hit_pct
                ));
            }
            // message-budget acceptance on the cache-off pair (message
            // counts are deterministic; wall time is not)
            let legacy = msgs_nocache.iter().find(|(l, _)| *l == "legacy").unwrap().1;
            let batched = msgs_nocache.iter().find(|(l, _)| *l == "batched").unwrap().1;
            assert!(
                batched <= legacy,
                "batched read path regressed message count at {pct}% dedup: \
                 {batched} > {legacy}"
            );
            if pct >= 50 {
                assert!(
                    batched < legacy,
                    "batched read path must cut backend messages at {pct}% dedup: \
                     {batched} vs {legacy}"
                );
            }
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"read_path\",\n  \"servers\": {SERVERS},\n  \
         \"object_size\": {OBJECT_SIZE},\n  \"chunk\": {CHUNK},\n  \
         \"read_passes\": {PASSES},\n  \"points\": [\n{}\n  ]\n}}\n",
        json_points.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_readpath.json");
    std::fs::write(path, json).expect("write BENCH_readpath.json");
    println!("summary written to BENCH_readpath.json");
}

/// Append one TSV row under `bench_out/` (same format as
/// `common::record`; duplicated so this driver stays self-contained).
fn record(bench: &str, header: &str, row: &str) {
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{bench}.tsv");
    let new = !std::path::Path::new(&path).exists();
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
    {
        if new {
            let _ = writeln!(f, "{header}");
        }
        let _ = writeln!(f, "{row}");
    }
}
