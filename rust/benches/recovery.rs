//! Recovery MTTR bench: time-to-full-replication after one server loss.
//!
//! Populates a 5-server cluster with small unique objects, removes one
//! server (`Cluster::remove_server` — the same detect→out→backfill path
//! the failure detector drives, minus the detection windows), and times
//! how long the surviving servers take to re-home OMAP records, restore
//! lost primaries and re-push replica copies back to the configured
//! replication factor. Health is asserted *after* timing: the audit
//! must be clean and a deep scrub must find nothing left to repair —
//! a fast-but-wrong recovery would fail here, not report a number.
//!
//! ```text
//! cargo bench --bench recovery                 # 10k + 100k objects
//! BENCH_SCALE=small cargo bench --bench recovery   # 10k only
//! ```
//!
//! Standalone driver (criterion is unavailable offline); rows are also
//! appended to `bench_out/recovery.tsv` and a JSON summary is written
//! to `BENCH_recovery.json` at the repository root.

use snss_dedup::api::{Cluster, ClusterConfig, RedundancyPolicy, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::util::rng::XorShift128Plus;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::io::Write as _;
use std::time::Instant;

const SERVERS: usize = 5;
/// One chunk per object keeps the focus on recovery fan-out, not
/// chunking.
const OBJECT_SIZE: usize = 1024;

struct Point {
    objects: u64,
    replication: usize,
    secs: f64,
    chunks_restored: u64,
    copies_pushed: u64,
    omap_recovered: u64,
    mib_recovered: f64,
    /// Cluster-merged recovery-stage latency quantiles (µs) from the
    /// per-server histogram registry.
    stage_p50_us: u64,
    stage_p99_us: u64,
}

fn run_point(objects: u64, replication: usize) -> Point {
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS,
        replication,
        chunking: Chunking::Fixed { size: OBJECT_SIZE },
        ..Default::default()
    })
    .expect("boot cluster");
    let client = cluster.client();
    let mut rng = XorShift128Plus::new(0xBACC_0FF5 ^ objects ^ replication as u64);
    let mut buf = vec![0u8; OBJECT_SIZE];
    for i in 0..objects {
        rng.fill_bytes(&mut buf);
        client
            .put_object(&format!("obj-{i}"), &buf)
            .expect("populate");
    }
    cluster.flush_consistency().expect("flush");

    let victim = ServerId(1);
    let t0 = Instant::now();
    cluster.remove_server(victim).expect("remove");
    let report = cluster.recovery_wait().expect("recovery");
    let secs = t0.elapsed().as_secs_f64();

    // health gate: a wrong recovery must fail loudly, not get timed
    assert!(
        report.first_failure().is_none(),
        "recovery failed: {report:?}"
    );
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "audit violations: {:?}", audit.violations);
    cluster.start_scrub(ScrubOptions::deep()).expect("scrub");
    let scrub = cluster.scrub_wait().expect("scrub_wait");
    assert_eq!(
        scrub.repaired + scrub.lost + scrub.corruptions_found,
        0,
        "recovery left degradation behind: {scrub:?}"
    );

    let stage = cluster
        .metrics_snapshot()
        .histogram_total("recovery_stage_latency");
    let point = Point {
        objects,
        replication,
        secs,
        chunks_restored: report.chunks_restored,
        copies_pushed: report.copies_pushed,
        omap_recovered: report.omap_recovered,
        mib_recovered: report.bytes_recovered as f64 / (1 << 20) as f64,
        stage_p50_us: stage.p50_us(),
        stage_p99_us: stage.p99_us(),
    };
    cluster.shutdown();
    point
}

/// One flat-vs-banded redundancy point: space overhead at steady state
/// and MTTR back to the full banded target after one server loss, with
/// the top refcount band tracked separately (those are the chunks whose
/// loss hurts the most objects — the banded policy exists to get *them*
/// back to full redundancy first and keep them there).
struct BandPoint {
    policy: &'static str,
    dedup_pct: u8,
    objects: u64,
    /// `copy_bytes / primary_bytes` at steady state, ×100.
    overhead_x100: u64,
    top_band_chunks: u64,
    /// Seconds from the loss until the top band (all chunks, for flat)
    /// is back at its full copy target.
    mttr_secs: f64,
    /// Scrub rounds the convergence loop needed after the backfill.
    scrub_rounds: u32,
}

fn run_band_point(
    policy: RedundancyPolicy,
    policy_name: &'static str,
    dedup_pct: u8,
    objects: u64,
) -> BandPoint {
    let banded = !policy.is_flat();
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS,
        replication: 2,
        redundancy: policy,
        chunking: Chunking::Fixed { size: OBJECT_SIZE },
        ..Default::default()
    })
    .expect("boot cluster");
    let client = cluster.client();
    // a small shared pool drives the hottest blocks far past the top
    // band threshold at high dedup ratios; at 0% nothing crosses
    let gen = Generator::new(WorkloadSpec {
        object_size: OBJECT_SIZE * 8,
        unit: OBJECT_SIZE,
        dedup_pct,
        pool_blocks: 16,
        zipf_theta: 0.0,
        seed: 0xBA4D ^ dedup_pct as u64,
    });
    for i in 0..objects {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).expect("populate");
    }
    cluster.flush_consistency().expect("flush");
    // settle stragglers the online hooks missed (dry budget, races)
    cluster.start_scrub(ScrubOptions::deep()).expect("scrub");
    cluster.scrub_wait().expect("scrub_wait");
    let steady = cluster.redundancy_report().expect("report");
    assert!(
        steady.is_converged(),
        "{policy_name}/{dedup_pct}%: not at target before the loss: {steady:?}"
    );
    let overhead_x100 = if steady.primary_bytes > 0 {
        steady.copy_bytes * 100 / steady.primary_bytes
    } else {
        0
    };

    let t0 = Instant::now();
    cluster.remove_server(ServerId(1)).expect("remove");
    let report = cluster.recovery_wait().expect("recovery");
    assert!(
        report.first_failure().is_none(),
        "recovery failed: {report:?}"
    );
    // MTTR-to-full-target: the refcount-descending work list plus the
    // repair-debt drain should leave little for the scrub rounds
    let mut scrub_rounds = 0u32;
    let mttr_secs = loop {
        let r = cluster.redundancy_report().expect("report");
        let healed = if banded {
            r.top_band_below == 0
        } else {
            r.below_target == 0
        };
        if healed {
            break t0.elapsed().as_secs_f64();
        }
        assert!(
            scrub_rounds < 6,
            "{policy_name}/{dedup_pct}%: top band never healed: {r:?}"
        );
        cluster.start_scrub(ScrubOptions::deep()).expect("scrub");
        cluster.scrub_wait().expect("scrub_wait");
        scrub_rounds += 1;
    };

    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "audit violations: {:?}", audit.violations);
    let point = BandPoint {
        policy: policy_name,
        dedup_pct,
        objects,
        overhead_x100,
        top_band_chunks: steady.top_band_chunks,
        mttr_secs,
        scrub_rounds,
    };
    cluster.shutdown();
    point
}

fn main() {
    let sizes: &[u64] = match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => &[10_000],
        _ => &[10_000, 100_000],
    };
    println!("== recovery: time-to-full-replication after one server loss ==");
    println!(
        "{:<10} {:>4} {:>10} {:>10} {:>10} {:>8} {:>10} {:>10}",
        "objects", "rep", "mttr s", "restored", "copies", "omap", "MiB", "MiB/s"
    );
    let mut json_points = Vec::new();
    for &objects in sizes {
        for replication in [2usize, 3] {
            let p = run_point(objects, replication);
            let rate = if p.secs > 0.0 {
                p.mib_recovered / p.secs
            } else {
                0.0
            };
            println!(
                "{:<10} {:>4} {:>10.3} {:>10} {:>10} {:>8} {:>10.1} {:>10.1}",
                p.objects,
                p.replication,
                p.secs,
                p.chunks_restored,
                p.copies_pushed,
                p.omap_recovered,
                p.mib_recovered,
                rate
            );
            record(
                "recovery",
                "objects\treplication\tmttr_secs\tchunks_restored\tcopies_pushed\t\
                 omap_recovered\tmib_recovered",
                &format!(
                    "{}\t{}\t{:.3}\t{}\t{}\t{}\t{:.2}",
                    p.objects,
                    p.replication,
                    p.secs,
                    p.chunks_restored,
                    p.copies_pushed,
                    p.omap_recovered,
                    p.mib_recovered
                ),
            );
            json_points.push(format!(
                "    {{\"objects\": {}, \"replication\": {}, \"mttr_secs\": {:.3}, \
                 \"chunks_restored\": {}, \"copies_pushed\": {}, \"omap_recovered\": {}, \
                 \"mib_recovered\": {:.2}, \
                 \"stage_p50_us\": {}, \"stage_p99_us\": {}}}",
                p.objects,
                p.replication,
                p.secs,
                p.chunks_restored,
                p.copies_pushed,
                p.omap_recovered,
                p.mib_recovered,
                p.stage_p50_us,
                p.stage_p99_us
            ));
        }
    }
    // ---- flat vs. banded redundancy: space overhead vs. MTTR ----
    let band_objects: u64 = match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => 400,
        _ => 1_200,
    };
    println!("== redundancy: space overhead vs. MTTR-to-full-target, flat vs. banded ==");
    println!(
        "{:<8} {:>6} {:>10} {:>12} {:>10} {:>7}",
        "policy", "dedup%", "overhead%", "top-band", "mttr s", "scrubs"
    );
    let mut band_json = Vec::new();
    for dedup_pct in [0u8, 50, 90] {
        for (policy, name) in [
            (RedundancyPolicy::flat(), "flat"),
            (RedundancyPolicy::banded(), "banded"),
        ] {
            let p = run_band_point(policy, name, dedup_pct, band_objects);
            println!(
                "{:<8} {:>6} {:>10} {:>12} {:>10.3} {:>7}",
                p.policy,
                p.dedup_pct,
                p.overhead_x100,
                p.top_band_chunks,
                p.mttr_secs,
                p.scrub_rounds
            );
            record(
                "recovery_banded",
                "policy\tdedup_pct\tobjects\toverhead_x100\ttop_band_chunks\tmttr_secs\t\
                 scrub_rounds",
                &format!(
                    "{}\t{}\t{}\t{}\t{}\t{:.3}\t{}",
                    p.policy,
                    p.dedup_pct,
                    p.objects,
                    p.overhead_x100,
                    p.top_band_chunks,
                    p.mttr_secs,
                    p.scrub_rounds
                ),
            );
            band_json.push(format!(
                "    {{\"policy\": \"{}\", \"dedup_pct\": {}, \"objects\": {}, \
                 \"overhead_x100\": {}, \"top_band_chunks\": {}, \"mttr_secs\": {:.3}, \
                 \"scrub_rounds\": {}}}",
                p.policy,
                p.dedup_pct,
                p.objects,
                p.overhead_x100,
                p.top_band_chunks,
                p.mttr_secs,
                p.scrub_rounds
            ));
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"recovery\",\n  \"servers\": {SERVERS},\n  \
         \"object_size\": {OBJECT_SIZE},\n  \"points\": [\n{}\n  ],\n  \
         \"band_points\": [\n{}\n  ]\n}}\n",
        json_points.join(",\n"),
        band_json.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_recovery.json");
    std::fs::write(path, json).expect("write BENCH_recovery.json");
    println!("summary written to BENCH_recovery.json");
}

/// Append one TSV row under `bench_out/` (same format as
/// `common::record`; duplicated so this driver stays self-contained).
fn record(bench: &str, header: &str, row: &str) {
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{bench}.tsv");
    let new = !std::path::Path::new(&path).exists();
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
    {
        if new {
            let _ = writeln!(f, "{header}");
        }
        let _ = writeln!(f, "{row}");
    }
}
