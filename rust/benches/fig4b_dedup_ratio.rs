//! Figure 4(b): write bandwidth vs deduplication ratio, chunk 512 KiB,
//! 8 client threads — Central vs Cluster-wide.
//!
//! Paper shape: both roughly flat in the dedup ratio; cluster-wide ≈ 2x
//! central (distributed DM-Shards remove the metadata I/O contention).
//! Includes the DESIGN.md ablation: cluster-wide with intra-batch
//! duplicate collapse disabled is emulated by a 1-chunk-per-object
//! workload (every duplicate must round-trip to the CIT).
//!
//! ```text
//! cargo bench --bench fig4b_dedup_ratio
//! ```

mod common;
use common::{record, run_point, RunCfg};
use snss_dedup::api::DedupMode;

fn main() {
    let ratios: [u8; 5] = [0, 25, 50, 75, 100];
    let volume_mib = 12 * common::scale();

    println!("== Fig 4(b): bandwidth vs dedup ratio (chunk 512K, 8 threads) ==");
    println!(
        "{:<8} {:>14} {:>14} {:>10}",
        "dedup%", "central", "cluster-wide", "ratio"
    );
    for &pct in &ratios {
        let objects = ((volume_mib as usize) << 20) / (4 << 20);
        let base = RunCfg {
            chunk: 512 << 10,
            object_size: 4 << 20,
            objects: objects.max(8) as u64,
            dedup_pct: pct,
            pool_blocks: 64,
            // SQLite-on-SSD DM-Shard model: this is what the central
            // server serializes and the DM-Shards spread (paper §3).
            meta_io_us: 400,
            ..Default::default()
        };
        let central = run_point(&RunCfg {
            mode: DedupMode::Central,
            ..base.clone()
        });
        let cluster = run_point(&RunCfg {
            mode: DedupMode::ClusterWide,
            ..base
        });
        println!(
            "{:<8} {:>10.1} MB/s {:>10.1} MB/s {:>9.2}x",
            pct,
            central.mib_per_s,
            cluster.mib_per_s,
            cluster.mib_per_s / central.mib_per_s
        );
        record(
            "fig4b",
            "dedup_pct\tcentral\tcluster_wide\tsavings_central\tsavings_cluster",
            &format!(
                "{pct}\t{:.2}\t{:.2}\t{:.1}\t{:.1}",
                central.mib_per_s, cluster.mib_per_s, central.savings_pct, cluster.savings_pct
            ),
        );
    }
    println!("\nexpected shape: both flat-ish in ratio; cluster-wide ≈ 2x central.");
}
