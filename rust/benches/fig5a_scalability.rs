//! Figure 5(a): write bandwidth vs number of client threads, chunk
//! 512 KiB — Central vs Cluster-wide.
//!
//! Paper shape: central *degrades* as threads grow (the single dedup
//! metadata server serializes all chunking/fingerprinting/lookup work;
//! at 32 threads it collapses), while cluster-wide *scales up* (CRUSH
//! spreads chunks and DM-Shards over all servers).
//!
//! ```text
//! cargo bench --bench fig5a_scalability
//! ```

mod common;
use common::{record, run_point, RunCfg};
use snss_dedup::api::DedupMode;

fn main() {
    let threads = [1usize, 2, 4, 8, 16, 32];
    let per_thread_mib = 8 * common::scale() / 2;

    println!("== Fig 5(a): bandwidth vs client threads (chunk 512K) ==");
    println!(
        "{:<9} {:>14} {:>14} {:>10}",
        "threads", "central", "cluster-wide", "ratio"
    );
    for &t in &threads {
        // volume scales with threads so each point saturates its clients
        let objects = ((per_thread_mib as usize * t) << 20) / (4 << 20);
        let base = RunCfg {
            threads: t,
            chunk: 512 << 10,
            object_size: 4 << 20,
            objects: objects.max(t) as u64,
            dedup_pct: 0,
            // SQLite-on-SSD DM-Shard model (see fig4b) — the central
            // server's serialized metadata I/O is the contended resource
            // the paper's Fig 5(a) exposes with rising thread counts.
            meta_io_us: 400,
            ..Default::default()
        };
        let central = run_point(&RunCfg {
            mode: DedupMode::Central,
            ..base.clone()
        });
        let cluster = run_point(&RunCfg {
            mode: DedupMode::ClusterWide,
            ..base
        });
        println!(
            "{:<9} {:>10.1} MB/s {:>10.1} MB/s {:>9.2}x",
            t,
            central.mib_per_s,
            cluster.mib_per_s,
            cluster.mib_per_s / central.mib_per_s
        );
        record(
            "fig5a",
            "threads\tcentral\tcluster_wide",
            &format!("{t}\t{:.2}\t{:.2}", central.mib_per_s, cluster.mib_per_s),
        );
    }
    println!("\nexpected shape: central flat/degrading with threads; cluster-wide scaling up.");
}
