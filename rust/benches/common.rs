//! Shared bench harness (criterion is unavailable offline; this is a
//! purpose-built workload driver that reports the same quantities the
//! paper's figures plot: aggregate client bandwidth and space savings).
//!
//! Every bench binary (`harness = false`) builds a fresh cluster per data
//! point, drives it with `threads` concurrent clients from the
//! deterministic FIO-substitute generator, and prints one table row per
//! point. Results are also appended to `bench_out/<bench>.tsv` for
//! plotting.

use snss_dedup::api::{Cluster, ClusterConfig, Consistency, DedupMode, FingerprintBackend};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

/// One bench data point's configuration.
#[derive(Clone)]
pub struct RunCfg {
    pub servers: usize,
    pub threads: usize,
    pub objects: u64,
    pub object_size: usize,
    pub chunk: usize,
    pub dedup_pct: u8,
    pub pool_blocks: u64,
    pub zipf_theta: f64,
    pub mode: DedupMode,
    pub consistency: Consistency,
    pub replication: usize,
    pub fingerprint_xla: bool,
    /// Modeled DM-Shard write latency in microseconds (0 = free). The
    /// paper's DM-Shard backend is SQLite on SSD; benches that measure
    /// consistency/metadata serialization set this to a few hundred µs.
    pub meta_io_us: u64,
    pub seed: u64,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            servers: 8,
            threads: 8,
            objects: 24,
            object_size: 4 << 20,
            chunk: 512 << 10,
            dedup_pct: 0,
            pool_blocks: 512,
            zipf_theta: 0.0,
            mode: DedupMode::ClusterWide,
            consistency: Consistency::AsyncTagged,
            replication: 1,
            fingerprint_xla: false,
            meta_io_us: 0,
            seed: 0xBEEF,
        }
    }
}

/// One bench data point's results.
#[derive(Clone, Copy, Debug)]
pub struct RunResult {
    pub mib_per_s: f64,
    pub savings_pct: f64,
    pub dedup_hits: u64,
    pub logical_mib: f64,
    pub secs: f64,
}

/// Execute one data point: boot, drive, quiesce, audit, tear down.
pub fn run_point(cfg: &RunCfg) -> RunResult {
    let fingerprint = if cfg.fingerprint_xla {
        FingerprintBackend::Xla {
            artifacts_dir: "artifacts".into(),
        }
    } else {
        FingerprintBackend::RustSha1
    };
    let cluster = Cluster::new(ClusterConfig {
        servers: cfg.servers,
        replication: cfg.replication,
        dedup: cfg.mode,
        consistency: cfg.consistency,
        chunking: Chunking::Fixed { size: cfg.chunk },
        fingerprint,
        meta_io: (cfg.meta_io_us > 0)
            .then(|| std::time::Duration::from_micros(cfg.meta_io_us)),
        ..Default::default()
    })
    .expect("boot cluster");

    let gen = Arc::new(Generator::new(WorkloadSpec {
        object_size: cfg.object_size,
        unit: cfg.chunk,
        dedup_pct: cfg.dedup_pct,
        pool_blocks: cfg.pool_blocks,
        zipf_theta: cfg.zipf_theta,
        seed: cfg.seed,
    }));

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..cfg.threads {
        let client = cluster.client();
        let gen = gen.clone();
        let objects = cfg.objects;
        let threads = cfg.threads as u64;
        handles.push(std::thread::spawn(move || {
            let mut written = 0u64;
            let mut idx = t as u64;
            while idx < objects {
                let (name, data) = gen.named_object(idx);
                client.put_object(&name, &data).expect("bench put");
                written += data.len() as u64;
                idx += threads;
            }
            written
        }));
    }
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let secs = t0.elapsed().as_secs_f64();
    cluster.flush_consistency().ok();
    let stats = cluster.stats();
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "bench audit violations: {:?}", audit.violations);
    let result = RunResult {
        mib_per_s: total as f64 / (1 << 20) as f64 / secs,
        savings_pct: stats.savings() * 100.0,
        dedup_hits: stats.dedup_hits,
        logical_mib: total as f64 / (1 << 20) as f64,
        secs,
    };
    cluster.shutdown();
    result
}

/// Append one TSV row under `bench_out/`.
pub fn record(bench: &str, header: &str, row: &str) {
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{bench}.tsv");
    let new = !std::path::Path::new(&path).exists();
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        if new {
            let _ = writeln!(f, "{header}");
        }
        let _ = writeln!(f, "{row}");
    }
}

/// Pretty size for labels.
pub fn fmt_size(bytes: usize) -> String {
    if bytes >= 1 << 20 {
        format!("{}M", bytes >> 20)
    } else {
        format!("{}K", bytes >> 10)
    }
}

/// Smoke-scale knob: `BENCH_SCALE=small cargo bench` shrinks the volume
/// ~8x for CI-style runs; default reproduces the figure shapes.
pub fn scale() -> u64 {
    match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => 1,
        _ => 8,
    }
}

#[allow(dead_code)]
fn main() {}
