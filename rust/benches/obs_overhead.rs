//! Tracing overhead bench: put throughput with tracing enabled but no
//! span sink (`span_ring_capacity = 0` — contexts propagate, nothing is
//! timed or recorded) vs tracing fully off.
//!
//! ```text
//! cargo bench --bench obs_overhead                 # full trials
//! BENCH_SCALE=small cargo bench --bench obs_overhead   # quick run
//! ```
//!
//! The two modes run **interleaved** (A/B/A/B…) so drift in machine
//! load hits both equally, and the reported figure is the per-mode
//! median. The run fails if the no-sink median falls more than 3%
//! below the tracing-off median — the "default-on, near-zero cost"
//! contract of the observability layer (DESIGN.md §12).

use snss_dedup::api::{Cluster, ClusterConfig, Consistency, WriteBatching};
use snss_dedup::dedup::Chunking;
use snss_dedup::obs::ObsConfig;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::sync::Arc;
use std::time::Instant;

const SERVERS: usize = 4;
const THREADS: usize = 4;
const OBJECT_SIZE: usize = 8 << 10;
const CHUNK: usize = 2 << 10;
const TOLERANCE_PCT: f64 = 3.0;

/// One trial: boot, drive `objects` puts, return MiB/s of logical data.
fn run_once(tracing: bool, objects: u64) -> f64 {
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS,
        replication: 1,
        write_batching: WriteBatching::TwoPhase,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        obs: ObsConfig {
            tracing,
            // the mode under test: propagate contexts, record nothing
            span_ring_capacity: 0,
            ..ObsConfig::default()
        },
        ..Default::default()
    })
    .expect("boot cluster");
    let gen = Arc::new(Generator::new(WorkloadSpec {
        object_size: OBJECT_SIZE,
        unit: CHUNK,
        dedup_pct: 25,
        pool_blocks: 512,
        zipf_theta: 0.0,
        seed: 0x0B5D ^ objects,
    }));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = cluster.client();
        let gen = gen.clone();
        handles.push(std::thread::spawn(move || {
            let mut idx = t as u64;
            while idx < objects {
                let (name, data) = gen.named_object(idx);
                client.put_object(&name, &data).expect("bench put");
                idx += THREADS as u64;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = cluster.stats();
    let mib = stats.logical_bytes as f64 / (1 << 20) as f64;
    cluster.shutdown();
    mib / secs
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    let (objects, trials) = match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => (1_000u64, 3usize),
        _ => (4_000, 5),
    };
    println!("== tracing overhead: no-sink vs tracing-off put throughput ==");
    // warm-up trial per mode (allocator + thread pools), then the
    // interleaved measured trials
    run_once(false, objects);
    run_once(true, objects);
    let mut off = Vec::with_capacity(trials);
    let mut on = Vec::with_capacity(trials);
    for trial in 0..trials {
        let a = run_once(false, objects);
        let b = run_once(true, objects);
        println!("trial {trial}: off {a:>8.1} MiB/s   no-sink {b:>8.1} MiB/s");
        off.push(a);
        on.push(b);
    }
    let (off_med, on_med) = (median(off), median(on));
    let overhead_pct = (100.0 * (off_med - on_med) / off_med).max(0.0);
    println!(
        "median: off {off_med:.1} MiB/s, no-sink {on_med:.1} MiB/s, \
         overhead {overhead_pct:.2}% (tolerance {TOLERANCE_PCT}%)"
    );
    assert!(
        overhead_pct <= TOLERANCE_PCT,
        "tracing without a sink costs {overhead_pct:.2}% put throughput \
         (> {TOLERANCE_PCT}% tolerance)"
    );
}
