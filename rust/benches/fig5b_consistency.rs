//! Figure 5(b): write bandwidth of the consistency variants vs chunk
//! size — async tagged (the paper) vs synchronous chunk-granularity vs
//! synchronous object-granularity vs no-consistency baseline.
//!
//! Paper shape: sync-chunk is worst (a serialized extra flag I/O + lock
//! per chunk), sync-object costs >15% vs baseline (one flag I/O but the
//! object transaction lock serializes a server's writers), async tagged
//! is within noise of the no-consistency baseline.
//!
//! ```text
//! cargo bench --bench fig5b_consistency
//! ```

mod common;
use common::{fmt_size, record, run_point, RunCfg};
use snss_dedup::api::Consistency;

fn main() {
    // skew toward small chunks: flag-update I/O is per-chunk, so that is
    // where the three placements separate (as in the paper's figure).
    let chunk_sizes = [16 << 10, 64 << 10, 512 << 10];
    let variants = [
        ("none", Consistency::None),
        ("async-tagged", Consistency::AsyncTagged),
        ("sync-object", Consistency::SyncObject),
        ("sync-chunk", Consistency::SyncChunk),
    ];
    let volume_mib = 12 * common::scale();

    println!("== Fig 5(b): consistency variants vs chunk size (8 threads, 0% dedup) ==");
    println!(
        "{:<8} {:>13} {:>13} {:>13} {:>13}",
        "chunk", "none", "async-tagged", "sync-object", "sync-chunk"
    );
    for &chunk in &chunk_sizes {
        let mut row = format!("{:<8}", fmt_size(chunk));
        let mut tsv = format!("{chunk}");
        for (_, consistency) in variants {
            let object_size = (4 << 20).max(chunk);
            let objects = ((volume_mib as usize) << 20) / object_size;
            let r = run_point(&RunCfg {
                chunk,
                consistency,
                object_size,
                objects: objects.max(8) as u64,
                dedup_pct: 0,
                // DM-Shard writes modeled at SQLite-on-SSD cost; this is
                // what separates the flag-update placements (paper §3).
                meta_io_us: 400,
                ..Default::default()
            });
            row += &format!(" {:>8.1} MB/s", r.mib_per_s);
            tsv += &format!("\t{:.2}", r.mib_per_s);
        }
        println!("{row}");
        record(
            "fig5b",
            "chunk_bytes\tnone\tasync_tagged\tsync_object\tsync_chunk",
            &tsv,
        );
    }
    println!(
        "\nexpected shape: async-tagged ≈ none; sync-object noticeably slower\n\
         (object tx lock); sync-chunk slowest, worst at small chunks."
    );
}
