//! Figure 4(a): write bandwidth vs chunk size, dedup ratio 0%, 8 client
//! threads — Baseline (no dedup) vs Central dedup vs Cluster-wide dedup.
//!
//! Paper shape: baseline ≈ cluster-wide, both well above central; the
//! dedup overhead (fingerprinting + chunk redirection) is largest at
//! small chunk sizes and shrinks as chunks grow.
//!
//! ```text
//! cargo bench --bench fig4a_chunk_size        # full volume
//! BENCH_SCALE=small cargo bench --bench fig4a_chunk_size
//! ```

mod common;
use common::{fmt_size, record, run_point, RunCfg};
use snss_dedup::api::DedupMode;

fn main() {
    let chunk_sizes = [64 << 10, 256 << 10, 512 << 10, 1 << 20, 4 << 20];
    let systems = [
        ("baseline", DedupMode::None),
        ("central", DedupMode::Central),
        ("cluster-wide", DedupMode::ClusterWide),
    ];
    let volume_mib = 12 * common::scale(); // logical MiB per point

    println!("== Fig 4(a): bandwidth vs chunk size (dedup 0%, 8 threads) ==");
    println!("{:<10} {:>14} {:>14} {:>14}", "chunk", "baseline", "central", "cluster-wide");
    for &chunk in &chunk_sizes {
        let mut row = format!("{:<10}", fmt_size(chunk));
        let mut tsv = format!("{}", chunk);
        for (_, mode) in systems {
            let object_size = (4 << 20).max(chunk);
            let objects = ((volume_mib as usize) << 20) / object_size;
            let r = run_point(&RunCfg {
                chunk,
                mode,
                object_size,
                objects: objects.max(8) as u64,
                dedup_pct: 0,
                ..Default::default()
            });
            row += &format!(" {:>10.1} MB/s", r.mib_per_s);
            tsv += &format!("\t{:.2}", r.mib_per_s);
        }
        println!("{row}");
        record("fig4a", "chunk_bytes\tbaseline\tcentral\tcluster_wide", &tsv);
    }
    println!(
        "\nexpected shape: baseline ≈ cluster-wide >> central; dedup overhead\n\
         largest at 64K (fingerprint + redirection per small chunk)."
    );
}
