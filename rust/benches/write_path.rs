//! Write-path macro-bench: batched two-phase scatter
//! (`WriteBatching::TwoPhase`) vs the legacy per-chunk protocol
//! (`WriteBatching::Off`), across dedup ratios, at 10k and 100k
//! objects.
//!
//! ```text
//! cargo bench --bench write_path                 # 10k + 100k objects
//! BENCH_SCALE=small cargo bench --bench write_path   # 10k only
//! ```
//!
//! For every data point both protocols drive the *same* deterministic
//! workload; their end states are asserted byte-identical (placement,
//! chunk counts, stored bytes) **before** any number is reported, and
//! on the ≥50%-duplicate corpora the batched path must cut backend
//! wire bytes by at least 40%. Inline-valid consistency keeps commit
//! flags deterministic so probe hits depend only on content, not on
//! flag-manager timing. Results go to stdout, to
//! `bench_out/write_path.tsv`, and to `BENCH_writepath.json` at the
//! repository root.

use snss_dedup::api::{Cluster, ClusterConfig, Consistency, WriteBatching};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

const SERVERS: usize = 4;
const THREADS: usize = 4;
const OBJECT_SIZE: usize = 8 << 10;
const CHUNK: usize = 2 << 10;

/// One protocol run's outcome.
struct Run {
    secs: f64,
    mib_per_s: f64,
    wire_bytes: u64,
    probe_batches: u64,
    store_batches: u64,
    savings_pct: f64,
    /// Cluster-merged put-latency quantiles (µs) from the per-server
    /// histogram registry.
    put_p50_us: u64,
    put_p99_us: u64,
    /// State fingerprint compared across protocols: global uniques and
    /// bytes plus the per-server placement.
    state: (u64, u64, Vec<(u32, usize, u64, usize)>),
}

fn run_one(objects: u64, dedup_pct: u8, batching: WriteBatching) -> Run {
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS,
        replication: 1,
        write_batching: batching,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        ..Default::default()
    })
    .expect("boot cluster");
    let gen = Arc::new(Generator::new(WorkloadSpec {
        object_size: OBJECT_SIZE,
        unit: CHUNK,
        dedup_pct,
        pool_blocks: 512,
        zipf_theta: 0.0,
        seed: 0x11AB ^ objects,
    }));

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = cluster.client();
        let gen = gen.clone();
        handles.push(std::thread::spawn(move || {
            let mut idx = t as u64;
            while idx < objects {
                let (name, data) = gen.named_object(idx);
                client.put_object(&name, &data).expect("bench put");
                idx += THREADS as u64;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    cluster.flush_consistency().ok();
    let stats = cluster.stats();
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "bench audit violations: {:?}", audit.violations);
    let state = (
        stats.unique_chunks,
        stats.stored_bytes,
        stats
            .per_server
            .iter()
            .map(|p| (p.server, p.chunks_stored, p.bytes_stored, p.objects))
            .collect(),
    );
    let logical_mib = stats.logical_bytes as f64 / (1 << 20) as f64;
    let put = cluster.metrics_snapshot().histogram_total("put_latency");
    let run = Run {
        secs,
        mib_per_s: logical_mib / secs,
        wire_bytes: stats.wire_bytes,
        probe_batches: stats.probe_batches,
        store_batches: stats.store_batches,
        savings_pct: stats.savings() * 100.0,
        put_p50_us: put.p50_us(),
        put_p99_us: put.p99_us(),
        state,
    };
    cluster.shutdown();
    run
}

fn main() {
    let sizes: &[u64] = match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => &[10_000],
        _ => &[10_000, 100_000],
    };
    let ratios: &[u8] = &[0, 50, 90];
    println!("== write path: batched two-phase vs per-chunk StoreChunk ==");
    println!(
        "{:<8} {:>6} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "objects", "dedup%", "off MiB/s", "batch MiB/s", "off wireMB", "batch wireMB", "wire -%"
    );
    let mut json_points = Vec::new();
    for &objects in sizes {
        for &pct in ratios {
            let off = run_one(objects, pct, WriteBatching::Off);
            let bat = run_one(objects, pct, WriteBatching::TwoPhase);
            // byte-identical end state is a precondition for every
            // number below
            assert_eq!(
                off.state,
                bat.state,
                "protocols diverged at {objects} objects / {pct}% dedup"
            );
            let reduction = 100.0 * (1.0 - bat.wire_bytes as f64 / off.wire_bytes.max(1) as f64);
            if pct >= 50 {
                assert!(
                    reduction >= 40.0,
                    "batched path must cut wire bytes ≥40% at {pct}% dedup, got {reduction:.1}%"
                );
            }
            let mb = |b: u64| b as f64 / (1 << 20) as f64;
            println!(
                "{:<8} {:>6} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>9.1}%",
                objects,
                pct,
                off.mib_per_s,
                bat.mib_per_s,
                mb(off.wire_bytes),
                mb(bat.wire_bytes),
                reduction
            );
            record(
                "write_path",
                "objects\tdedup_pct\toff_secs\tbatch_secs\toff_wire\tbatch_wire\t\
                 reduction_pct\tprobe_batches\tstore_batches\tsavings_pct",
                &format!(
                    "{objects}\t{pct}\t{:.3}\t{:.3}\t{}\t{}\t{reduction:.1}\t{}\t{}\t{:.1}",
                    off.secs,
                    bat.secs,
                    off.wire_bytes,
                    bat.wire_bytes,
                    bat.probe_batches,
                    bat.store_batches,
                    bat.savings_pct
                ),
            );
            json_points.push(format!(
                "    {{\"objects\": {objects}, \"dedup_pct\": {pct}, \
                 \"off_secs\": {:.3}, \"batched_secs\": {:.3}, \
                 \"off_wire_bytes\": {}, \"batched_wire_bytes\": {}, \
                 \"wire_reduction_pct\": {reduction:.1}, \
                 \"probe_batches\": {}, \"store_batches\": {}, \
                 \"off_put_p50_us\": {}, \"off_put_p99_us\": {}, \
                 \"batched_put_p50_us\": {}, \"batched_put_p99_us\": {}}}",
                off.secs,
                bat.secs,
                off.wire_bytes,
                bat.wire_bytes,
                bat.probe_batches,
                bat.store_batches,
                off.put_p50_us,
                off.put_p99_us,
                bat.put_p50_us,
                bat.put_p99_us
            ));
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"write_path\",\n  \"servers\": {SERVERS},\n  \
         \"object_size\": {OBJECT_SIZE},\n  \"chunk\": {CHUNK},\n  \"points\": [\n{}\n  ]\n}}\n",
        json_points.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_writepath.json");
    std::fs::write(path, json).expect("write BENCH_writepath.json");
    println!("summary written to BENCH_writepath.json");
}

/// Append one TSV row under `bench_out/` (same format as
/// `common::record`; duplicated so this driver stays self-contained).
fn record(bench: &str, header: &str, row: &str) {
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{bench}.tsv");
    let new = !std::path::Path::new(&path).exists();
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
    {
        if new {
            let _ = writeln!(f, "{header}");
        }
        let _ = writeln!(f, "{row}");
    }
}
