//! Table 2: deduplication space savings (%) vs number of disks/servers at
//! 100% dedup ratio — Cluster-wide vs per-disk (BtrFS-style local) dedup.
//!
//! Paper numbers:
//! ```text
//!                     1     2     4     8   disks
//! cluster-wide       85    85    85    85
//! disk-based         85    77    65    61
//! ```
//!
//! The workload pool is sized so unique content is 15% of logical bytes
//! (⇒ ideal savings 85%). Cluster-wide finds every duplicate regardless
//! of server count; disk-local only finds duplicates that land on the
//! same server, so its savings fall as servers are added.
//!
//! ```text
//! cargo bench --bench table2_space_savings
//! ```

mod common;
use common::{record, run_point, RunCfg};
use snss_dedup::api::DedupMode;

fn main() {
    let server_counts = [1usize, 2, 4, 8];
    let chunk = 64 << 10;
    let object_size = 1 << 20; // 16 blocks/object
    let objects = 8 * common::scale(); // logical volume
    let total_blocks = objects * (object_size / chunk) as u64;
    let pool_blocks = (total_blocks * 15 / 100).max(1); // 15% unique → 85% savings

    println!("== Table 2: space savings (%) vs #servers (100% dedup ratio) ==");
    println!("{:<16} {:>6} {:>6} {:>6} {:>6}", "dedup", 1, 2, 4, 8);
    for (label, mode) in [
        ("cluster-wide", DedupMode::ClusterWide),
        ("disk-local", DedupMode::DiskLocal),
    ] {
        let mut row = format!("{label:<16}");
        let mut tsv = label.to_string();
        for &servers in &server_counts {
            let r = run_point(&RunCfg {
                servers,
                mode,
                chunk,
                object_size,
                objects,
                dedup_pct: 100,
                pool_blocks,
                zipf_theta: 1.1, // real dedup workloads are skewed; keeps
                // per-disk reuse high so the paper's gentle decay appears
                threads: 4,
                ..Default::default()
            });
            row += &format!(" {:>5.1}%", r.savings_pct);
            tsv += &format!("\t{:.1}", r.savings_pct);
        }
        println!("{row}");
        record("table2", "dedup\ts1\ts2\ts4\ts8", &tsv);
    }
    println!(
        "\npaper:            cluster-wide 85/85/85/85 | disk-based 85/77/65/61\n\
         expected shape: cluster-wide flat at the pool ratio; disk-local decaying."
    );
}
