//! Fingerprint-pipeline macro-bench: tiered (weak prefilter + deferred
//! batched strong hashing, `FpMode::Tiered`) vs inline strong hashing
//! (`FpMode::Inline`), across dedup ratios, at 10k and 100k objects.
//!
//! ```text
//! cargo bench --bench fp_tiered                  # 10k + 100k objects
//! BENCH_SCALE=small cargo bench --bench fp_tiered    # 10k only
//! ```
//!
//! For every data point both pipelines drive the *same* deterministic
//! workload; after the tiered side's pending queue is flushed their end
//! states are asserted byte-identical (per-server placement, chunk
//! counts, stored bytes, plus content spot-checks) and both audits must
//! be clean **before** any number is reported. On the 0%-dedup corpus
//! the tiered pipeline must spend *strictly fewer* inline strong-hash
//! invocations than the inline pipeline, and its deferred hashing must
//! batch (mean hash-batch size > 1). Reported per point: put
//! throughput and deep-scrub wall time (the scrub re-hash loop is
//! batched through the provider too). Results go to stdout, to
//! `bench_out/fp_tiered.tsv`, and to `BENCH_fptiered.json` at the
//! repository root.

use snss_dedup::api::{Cluster, ClusterConfig, Consistency, FpMode, ScrubOptions};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

const SERVERS: usize = 4;
const THREADS: usize = 4;
const OBJECT_SIZE: usize = 8 << 10;
const CHUNK: usize = 2 << 10;

/// One pipeline run's outcome.
struct Run {
    secs: f64,
    puts_per_s: f64,
    scrub_secs: f64,
    /// Inline strong-hash invocations on the write path.
    strong_hashes: u64,
    /// Deferred-resolution provider batches (tier 2).
    batch_calls: u64,
    batch_items: u64,
    savings_pct: f64,
    /// State fingerprint compared across pipelines: the per-server
    /// placement ground truth (the global `unique_chunks`/`bytes_stored`
    /// counters double-count pending→strong migration by design, so the
    /// comparison uses backend-derived per-server numbers only).
    state: Vec<(u32, usize, u64, usize)>,
}

fn run_one(objects: u64, dedup_pct: u8, fp_mode: FpMode) -> Run {
    let cluster = Cluster::new(ClusterConfig {
        servers: SERVERS,
        replication: 1,
        consistency: Consistency::None,
        chunking: Chunking::Fixed { size: CHUNK },
        fp_mode,
        ..Default::default()
    })
    .expect("boot cluster");
    let gen = Arc::new(Generator::new(WorkloadSpec {
        object_size: OBJECT_SIZE,
        unit: CHUNK,
        dedup_pct,
        pool_blocks: 512,
        zipf_theta: 0.0,
        seed: 0xF1BE ^ objects,
    }));

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let client = cluster.client();
        let gen = gen.clone();
        handles.push(std::thread::spawn(move || {
            let mut idx = t as u64;
            while idx < objects {
                let (name, data) = gen.named_object(idx);
                client.put_object(&name, &data).expect("bench put");
                idx += THREADS as u64;
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();

    // quiesce: drain the pending queue, settle flags, collect nothing
    // (the workload deletes nothing), then demand a clean audit before
    // any timing is trusted
    cluster.fp_flush().expect("fp_flush");
    cluster.flush_consistency().ok();
    cluster.run_gc(0).expect("gc");
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "bench audit violations: {:?}", audit.violations);

    // content spot-check against the generator (every 97th object), so
    // "byte-identical" means bytes, not just matching counters
    let client = cluster.client();
    for idx in (0..objects).step_by(97) {
        let (name, data) = gen.named_object(idx);
        assert_eq!(client.get_object(&name).expect("read"), data, "{name} diverged");
    }

    // deep scrub wall time: every stored chunk is re-read and re-hashed
    // (batched per window through the provider)
    let t1 = Instant::now();
    cluster.start_scrub(ScrubOptions::deep()).expect("scrub");
    let report = cluster.scrub_wait().expect("scrub wait");
    let scrub_secs = t1.elapsed().as_secs_f64();
    assert!(report.all_done(), "deep scrub failed: {report:?}");

    let stats = cluster.stats();
    let run = Run {
        secs,
        puts_per_s: objects as f64 / secs,
        scrub_secs,
        strong_hashes: stats.fp_strong_hashes,
        batch_calls: stats.fp_batch_calls,
        batch_items: stats.fp_batch_items,
        savings_pct: stats.savings() * 100.0,
        state: stats
            .per_server
            .iter()
            .map(|p| (p.server, p.chunks_stored, p.bytes_stored, p.objects))
            .collect(),
    };
    cluster.shutdown();
    run
}

fn main() {
    let sizes: &[u64] = match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => &[10_000],
        _ => &[10_000, 100_000],
    };
    let ratios: &[u8] = &[0, 50, 90];
    println!("== fingerprint pipeline: tiered (weak prefilter + deferred batch) vs inline ==");
    println!(
        "{:<8} {:>6} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "objects",
        "dedup%",
        "inl puts/s",
        "tier puts/s",
        "inl scrub s",
        "tier scrub s",
        "strong -%",
        "batch mean"
    );
    let mut json_points = Vec::new();
    for &objects in sizes {
        for &pct in ratios {
            let inl = run_one(objects, pct, FpMode::Inline);
            let tier = run_one(objects, pct, FpMode::tiered());
            // byte-identical end state is a precondition for every
            // number below
            assert_eq!(
                inl.state,
                tier.state,
                "pipelines diverged at {objects} objects / {pct}% dedup"
            );
            if pct == 0 {
                assert!(
                    tier.strong_hashes < inl.strong_hashes,
                    "tiered must spend strictly fewer inline strong hashes at 0% dedup: \
                     {} vs {}",
                    tier.strong_hashes,
                    inl.strong_hashes
                );
            }
            assert!(tier.batch_calls > 0, "tiered ran no deferred batches");
            let batch_mean = tier.batch_items as f64 / tier.batch_calls as f64;
            assert!(
                batch_mean > 1.0,
                "deferred hashing must batch: mean {batch_mean:.2} \
                 ({} items / {} calls)",
                tier.batch_items,
                tier.batch_calls
            );
            let hash_ratio = tier.strong_hashes as f64 / inl.strong_hashes.max(1) as f64;
            let strong_cut = 100.0 * (1.0 - hash_ratio);
            println!(
                "{:<8} {:>6} {:>12.0} {:>12.0} {:>12.2} {:>12.2} {:>11.1}% {:>10.1}",
                objects,
                pct,
                inl.puts_per_s,
                tier.puts_per_s,
                inl.scrub_secs,
                tier.scrub_secs,
                strong_cut,
                batch_mean
            );
            record(
                "fp_tiered",
                "objects\tdedup_pct\tinline_secs\ttiered_secs\tinline_scrub_secs\t\
                 tiered_scrub_secs\tinline_strong\ttiered_strong\tbatch_calls\t\
                 batch_items\tsavings_pct",
                &format!(
                    "{objects}\t{pct}\t{:.3}\t{:.3}\t{:.3}\t{:.3}\t{}\t{}\t{}\t{}\t{:.1}",
                    inl.secs,
                    tier.secs,
                    inl.scrub_secs,
                    tier.scrub_secs,
                    inl.strong_hashes,
                    tier.strong_hashes,
                    tier.batch_calls,
                    tier.batch_items,
                    tier.savings_pct
                ),
            );
            json_points.push(format!(
                "    {{\"objects\": {objects}, \"dedup_pct\": {pct}, \
                 \"inline_puts_per_s\": {:.0}, \"tiered_puts_per_s\": {:.0}, \
                 \"inline_scrub_secs\": {:.3}, \"tiered_scrub_secs\": {:.3}, \
                 \"inline_strong_hashes\": {}, \"tiered_strong_hashes\": {}, \
                 \"strong_hash_reduction_pct\": {strong_cut:.1}, \
                 \"batch_calls\": {}, \"batch_items\": {}, \
                 \"batch_mean\": {batch_mean:.2}}}",
                inl.puts_per_s,
                tier.puts_per_s,
                inl.scrub_secs,
                tier.scrub_secs,
                inl.strong_hashes,
                tier.strong_hashes,
                tier.batch_calls,
                tier.batch_items
            ));
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"fp_tiered\",\n  \"servers\": {SERVERS},\n  \
         \"object_size\": {OBJECT_SIZE},\n  \"chunk\": {CHUNK},\n  \"points\": [\n{}\n  ]\n}}\n",
        json_points.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fptiered.json");
    std::fs::write(path, json).expect("write BENCH_fptiered.json");
    println!("summary written to BENCH_fptiered.json");
}

/// Append one TSV row under `bench_out/` (same format as
/// `common::record`; duplicated so this driver stays self-contained).
fn record(bench: &str, header: &str, row: &str) {
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{bench}.tsv");
    let new = !std::path::Path::new(&path).exists();
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
    {
        if new {
            let _ = writeln!(f, "{header}");
        }
        let _ = writeln!(f, "{row}");
    }
}
