//! Backreference-index micro-bench: local `CountRefs` answered from the
//! index (`DmShard::backref_refs_many`, O(log n + referrers) per
//! fingerprint) vs the pre-index full OMAP table walk
//! (`DmShard::count_refs_scan`, O(objects × chunks) per call), at 10k and
//! 100k objects — one scrub window (256 fingerprints) per call, the shape
//! the light-scrub refcount reconcile issues.
//!
//! ```text
//! cargo bench --bench backref_countrefs           # 10k + 100k objects
//! BENCH_SCALE=small cargo bench --bench backref_countrefs   # 10k only
//! ```
//!
//! Standalone driver (criterion is unavailable offline); results are also
//! appended to `bench_out/backref_countrefs.tsv`.

use snss_dedup::dedup::dmshard::DmShard;
use snss_dedup::dedup::omap::OmapEntry;
use snss_dedup::kvstore::MemKv;
use snss_dedup::util::rng::SplitMix64;
use snss_dedup::Fingerprint;
use std::io::Write as _;
use std::time::Instant;

/// Chunks per object (the 4 MiB / 512 KiB shape of the paper's figures).
const CHUNKS_PER_OBJECT: usize = 8;
/// Fingerprints per `CountRefs` call (one scrub window).
const WINDOW: usize = 256;

/// Populate a shard with `objects` layouts drawing chunks from a shared
/// pool (~4 references per chunk on average), plus one query window.
fn build(objects: usize, rng: &mut SplitMix64) -> (DmShard, Vec<Fingerprint>) {
    let shard = DmShard::new(
        Box::new(MemKv::new()),
        Box::new(MemKv::new()),
        Box::new(MemKv::new()),
    );
    let pool: Vec<Fingerprint> = (0..(objects * CHUNKS_PER_OBJECT / 4).max(WINDOW))
        .map(|i| Fingerprint::of(format!("chunk-{i}").as_bytes()))
        .collect();
    for o in 0..objects {
        let chunks: Vec<(Fingerprint, u32)> = (0..CHUNKS_PER_OBJECT)
            .map(|_| (pool[rng.below(pool.len() as u64) as usize], 4096))
            .collect();
        let entry = OmapEntry::new(
            format!("obj-{o}"),
            Fingerprint::of(format!("obj-{o}").as_bytes()),
            chunks,
        );
        shard.omap_put(&entry).expect("bench omap_put");
    }
    let fps: Vec<Fingerprint> = (0..WINDOW)
        .map(|_| pool[rng.below(pool.len() as u64) as usize])
        .collect();
    (shard, fps)
}

/// Time `reps` calls of `f`; returns mean microseconds per call.
fn time_us(reps: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / reps as f64
}

fn main() {
    let sizes: &[usize] = match std::env::var("BENCH_SCALE").as_deref() {
        Ok("small") => &[10_000],
        _ => &[10_000, 100_000],
    };
    println!("== backref index: CountRefs window ({WINDOW} fps) — index vs full scan ==");
    println!(
        "{:<10} {:>14} {:>14} {:>10}",
        "objects", "scan µs/call", "index µs/call", "speedup"
    );
    for &objects in sizes {
        let mut rng = SplitMix64::new(0xBACC_0FF5 ^ objects as u64);
        let (shard, fps) = build(objects, &mut rng);
        // sanity: both paths must agree before either is timed
        let scanned = shard.count_refs_scan(&fps).expect("scan");
        let indexed = shard.backref_refs_many(&fps).expect("index");
        assert_eq!(scanned, indexed, "index diverges from scan at {objects}");

        let scan_reps = if objects >= 100_000 { 3 } else { 10 };
        let scan_us = time_us(scan_reps, || {
            shard.count_refs_scan(&fps).expect("scan");
        });
        let index_us = time_us(100, || {
            shard.backref_refs_many(&fps).expect("index");
        });
        println!(
            "{:<10} {:>14.1} {:>14.1} {:>9.1}x",
            objects,
            scan_us,
            index_us,
            scan_us / index_us
        );
        record(
            "backref_countrefs",
            "objects\twindow\tscan_us\tindex_us\tspeedup",
            &format!(
                "{objects}\t{WINDOW}\t{scan_us:.1}\t{index_us:.1}\t{:.1}",
                scan_us / index_us
            ),
        );
    }
}

/// Append one TSV row under `bench_out/` (same format as `common::record`;
/// duplicated so this driver stays free of the cluster-harness module).
fn record(bench: &str, header: &str, row: &str) {
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{bench}.tsv");
    let new = !std::path::Path::new(&path).exists();
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
    {
        if new {
            let _ = writeln!(f, "{header}");
        }
        let _ = writeln!(f, "{row}");
    }
}
