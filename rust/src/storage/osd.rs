//! The object storage server (OSS/OSD).
//!
//! One `Osd` runs ten threads over a shared per-server state
//! ([`OsdShared`], which models everything that survives a crash — the
//! chunk store, the replica store and the DM-Shard are "disk"; the
//! pending-flag queue and any in-flight scrub or recovery job are
//! "memory" and die with the process):
//!
//! * **frontend** — client object transactions (the dedup engine entry);
//! * **backend**  — chunk + dedup-metadata ops from peer frontends;
//! * **replica**  — replica copies (strictly local; see `net` lane order);
//! * **control**  — map updates, rebalance, GC, stats, audit, scrub admin;
//! * **consistency manager** — the asynchronous flag flipper (§2.4);
//! * **scrub worker** — the online integrity walker ([`crate::scrub`]);
//! * **maintenance scheduler** — fires the periodic scrub cadence
//!   ([`crate::sched`]);
//! * **recovery worker** — re-replicates after a server loss
//!   ([`crate::recovery`]);
//! * **rebalance worker** — migrates holdings after a map change
//!   ([`crate::storage::rebalance`]);
//! * **fingerprint-pipeline worker** — resolves tier-1 deferred chunks
//!   through batched strong hashing and migrates them into the
//!   content-addressed domain ([`crate::dedup::fpipe`]).
//!
//! Kill/crash semantics: lanes keep running but silently *drop* every
//! envelope while the injector reports dead — callers observe a closed
//! reply channel, i.e. [`crate::Error::ServerDown`], exactly like a
//! machine that stopped answering. Restart revives the injector, clears
//! volatile state and runs a recovery scan.

use crate::cluster::{ClusterMap, ServerId};
use crate::dedup::consistency::{ConsistencyMode, PendingFlags};
use crate::dedup::dmshard::DmShard;
use crate::dedup::cache::{CacheConfig, ChunkCache, DupPolicy};
use crate::dedup::engine::{self, DedupMode, ReadBatching, WriteBatching};
use crate::dedup::fingerprint::{Fingerprint, FingerprintProvider};
use crate::dedup::redundancy::RedundancyPolicy;
use crate::dedup::gc;
use crate::dedup::Chunker;
use crate::failure::FailureInjector;
use crate::metrics::Metrics;
use crate::net::{endpoint, Inbox, Lane, NetProfile};
use crate::obs::trace;
use crate::obs::{ServerObs, SpanRecord};
use crate::placement::pg::PgMap;
use crate::sched::backpressure::Gate;
use crate::sched::flow::{FlowController, MaintClass};
use crate::sched::SchedCtl;
use crate::storage::backend::StorageBackend;
use crate::storage::proto::{AuditDump, ChunkAck, Dir, OsdStats, Req, Resp};
use crate::storage::rebalance;
use crate::util::clock::Clock;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Per-server configuration (a slice of the cluster config).
#[derive(Clone)]
pub struct OsdConfig {
    /// Dedup architecture this server runs.
    pub dedup: DedupMode,
    /// Commit-flag consistency mode.
    pub consistency: ConsistencyMode,
    /// Write-path chunk scatter protocol (per-chunk `StoreChunk` vs
    /// per-home two-phase batches).
    pub write_batching: WriteBatching,
    /// Object chunking policy.
    pub chunker: Chunker,
    /// Replica count for chunk data + OMAP copies.
    pub replication: usize,
    /// Verify chunk digests on read (integrity checking extension).
    pub verify_read: bool,
    /// After replicating a freshly stored chunk, confirm each replica
    /// copy by content (`VerifyCopy` fan-out). Off by default: it adds
    /// one replica-lane round trip per unique chunk; tests use it to
    /// pin the write path's full cross-server span tree.
    pub verify_write: bool,
    /// Modeled latency of one synchronous DM-Shard write (the paper's
    /// backend is SQLite on SSD; a flag flip or CIT insert is a
    /// synchronous UPDATE). Charged on the thread issuing the write, so
    /// serialization effects (transaction locks, single metadata server)
    /// emerge exactly where the paper's do. `None` = free (unit tests).
    pub meta_io: Option<Duration>,
    /// Read-path chunk gather protocol (per-chunk `FetchChunk` vs
    /// per-home `FetchChunkBatch`).
    pub read_batching: ReadBatching,
    /// Hot-chunk cache sizing/admission (capacity 0 disables it).
    pub cache: CacheConfig,
    /// Fragmentation-aware selective duplication of hot remote chunks;
    /// `None` (the default) disables planting.
    pub selective_dup: Option<DupPolicy>,
    /// Refcount-banded redundancy: maps refcount bands to copy counts
    /// on top of `replication`. The default (flat) keeps every chunk at
    /// exactly `replication` copies.
    pub redundancy: RedundancyPolicy,
    /// Fingerprint pipeline mode: inline strong hashing (the default)
    /// or the tiered weak-prefilter/deferred scheme (DESIGN.md §16).
    pub fp_mode: crate::dedup::fpipe::FpMode,
}

/// Everything a server owns that survives kill+restart (disk-like), plus
/// handles to cluster-shared infrastructure.
pub struct OsdShared {
    /// This server's id.
    pub id: ServerId,
    /// Per-server configuration slice.
    pub cfg: OsdConfig,
    /// Shared cluster-map handle (epochs, membership).
    pub map: Arc<RwLock<ClusterMap>>,
    /// Placement-group table for chunk/object routing.
    pub pgmap: Arc<PgMap>,
    /// The local DM-Shard (OMAP + CIT + backreference index, "disk").
    pub shard: DmShard,
    /// Primary chunk/object data ("disk").
    pub store: Box<dyn StorageBackend>,
    /// Replica copies of peer data + OMAP record copies ("disk").
    pub replica_store: Box<dyn StorageBackend>,
    /// Volatile: the async-consistency registration queue.
    pub pending: PendingFlags,
    /// Volatile: hot-chunk payload cache + selective-duplication
    /// tracker (cleared on kill and on the rejoin wipe — a cached chunk
    /// never survives an event that could retire its CIT entry).
    pub chunk_cache: ChunkCache,
    /// Volatile: scrub-worker job hand-off and progress (a crash aborts
    /// the running pass).
    pub scrub: crate::scrub::ScrubCtl,
    /// Volatile: recovery-worker job queue, ensure-barrier flags and
    /// progress (a crash drops queued jobs; restart re-queues recovery
    /// for every `Out` server in the map).
    pub recovery: crate::recovery::RecoveryCtl,
    /// Volatile: rebalance-worker one-slot job queue and progress (a
    /// crash drops the pending scan; the next map change re-queues it).
    pub rebalance: rebalance::RebalanceCtl,
    /// Maintenance scheduler: the armed periodic-scrub cadence and its
    /// fire accounting (configuration-like — survives kill/restart).
    pub sched: SchedCtl,
    /// Shared maintenance budget: scrub windows, rebalance batches and
    /// GC reclaims draw weighted tokens from this one per-server bucket.
    pub flow: FlowController,
    /// Replica-lane admission gate shedding `VerifyCopy` storms.
    pub verify_gate: Gate,
    /// Crash-point/kill failure injector for this server.
    pub injector: FailureInjector,
    /// This server's metrics instance (its entry in the cluster's
    /// [`crate::obs::Registry`]; cluster totals are an aggregation).
    pub metrics: Arc<Metrics>,
    /// This server's observability entry: span ring, tracing switch and
    /// live queue-depth gauges (see [`crate::obs`]).
    pub obs: Arc<ServerObs>,
    /// Fabric directory (server id + lane → address).
    pub dir: Dir,
    /// Fingerprint computation provider (scalar SHA-1 or XLA-batched).
    pub provider: Arc<dyn FingerprintProvider>,
    /// Cluster-start-relative clock (wall or virtual; see
    /// [`crate::util::clock`]).
    pub clock: Arc<dyn Clock>,
    /// SyncObject-mode transaction lock (held across a whole object write).
    pub obj_lock: Mutex<()>,
    /// Volatile: fingerprints whose write-time replica fan-out failed
    /// (dead/Busy peer) — the repair debt the next scrub pass drains
    /// *first*, so a write-path durability gap closes at the next
    /// maintenance window instead of whenever the full walk reaches it.
    pub repair_debt: Mutex<std::collections::HashSet<Fingerprint>>,
    /// Test hook: runs once on the frontend thread in the gap between
    /// the batched write path's probe phase and its store phase, then
    /// clears itself. Lets tests force deterministic probe-hint
    /// staleness (e.g. run GC at a chunk home between the phases);
    /// always `None` in production.
    pub probe_gap_hook: Mutex<Option<Box<dyn FnOnce() + Send>>>,
    /// Tiered fingerprint pipeline state: the tier-1 weak filter plus
    /// the volatile tier-2 pending queue (cleared on kill; a restart
    /// re-queues from the CIT via [`crate::dedup::gc::recovery_scan`]).
    pub fpipe: crate::dedup::fpipe::FpipeCtl,
}

impl OsdShared {
    /// Replica chain for a chunk fingerprint placement key (primary first).
    pub fn chunk_chain(&self, key: u64) -> Vec<ServerId> {
        let map = self.map.read().unwrap();
        self.pgmap.select(&map, key)
    }

    /// Replica chain for an object name (primary first).
    pub fn object_chain(&self, name: &str) -> Vec<ServerId> {
        self.chunk_chain(crate::hash::fnv1a64(name.as_bytes()))
    }

    /// Current time in ms.
    pub fn now_ms(&self) -> u64 {
        self.clock.now_ms()
    }

    /// Banded target copy count (primary included) for a chunk with
    /// `refcount` references — the single answer every plant/repair
    /// path (write fan-out, scrub, recovery, rebalance, promote/demote)
    /// agrees on: `cfg.redundancy` applied over `cfg.replication`,
    /// capped by the number of Up servers.
    pub fn redundancy_target(&self, refcount: u64) -> usize {
        let live = self.map.read().unwrap().up_count();
        self.cfg
            .redundancy
            .target_copies(refcount, self.cfg.replication, live)
    }

    /// Record a fingerprint whose replica push failed (dead/Busy peer):
    /// the next scrub pass re-verifies and re-pushes it before the full
    /// walk (see [`crate::scrub`]).
    pub fn note_repair_debt(&self, fp: Fingerprint) {
        self.repair_debt.lock().unwrap().insert(fp);
    }

    /// Drain the accumulated repair debt (scrub pass start).
    pub fn take_repair_debt(&self) -> Vec<Fingerprint> {
        self.repair_debt.lock().unwrap().drain().collect()
    }

    /// Charge one synchronous DM-Shard write against the metadata I/O
    /// cost model (no-op when unset).
    pub fn charge_meta_io(&self) {
        if let Some(d) = self.cfg.meta_io {
            std::thread::sleep(d);
        }
    }

    /// Charge maintenance I/O to the shared per-server budget (blocks
    /// until the class's bucket covers it — that pacing *is* the
    /// throttle; on the control lane it deliberately slows GC/rebalance
    /// passes, mirroring backfill competing for real lanes) and account
    /// the grant in the cluster metrics. Virtual-clock tests with a
    /// finite budget must keep advancing the clock while maintenance
    /// runs, or size the budget so no draw ever waits.
    pub fn charge_maint(&self, class: MaintClass, cost: u64) {
        let out = self.flow.take(class, cost);
        let counter = match class {
            MaintClass::Scrub => &self.metrics.flow_granted_scrub,
            MaintClass::Rebalance => &self.metrics.flow_granted_rebalance,
            MaintClass::Gc => &self.metrics.flow_granted_gc,
            MaintClass::Recovery => &self.metrics.flow_granted_recovery,
        };
        Metrics::add(counter, out.granted);
        if out.waited {
            Metrics::add(&self.metrics.flow_waits, 1);
        }
    }

    /// Restart after a kill/crash: re-derive the backreference index
    /// from the OMAP (a crash can separate an OMAP write from its index
    /// update; the OMAP is the source of truth), revive, then run the
    /// recovery scan (re-registers stored-but-invalid chunks with the
    /// flag manager). The rebuild runs *before* the lanes come back up,
    /// so no peer can observe the index mid-derivation; a rebuild
    /// failure leaves the server down and propagates — running against
    /// a known-broken index would let GC reclaim live data. Lives on
    /// the shared state (not [`Osd`]) so callers can run the O(OMAP)
    /// rebuild without holding any cluster-wide registry lock.
    pub fn restart(&self) -> crate::error::Result<()> {
        self.shard.rebuild_backrefs()?;
        Metrics::add(&self.metrics.backref_rebuilds, 1);
        self.injector.revive();
        let _ = gc::recovery_scan(self);
        Ok(())
    }
}

/// A running server: shared state + lane threads.
pub struct Osd {
    /// The server's crash-surviving shared state.
    pub shared: Arc<OsdShared>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

const POLL: Duration = Duration::from_millis(50);

impl Osd {
    /// Spawn a server: creates its four lane endpoints, registers them in
    /// the directory and starts all threads.
    pub fn spawn(shared: Arc<OsdShared>, profile: Option<NetProfile>) -> Osd {
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        let lanes = [Lane::Frontend, Lane::Backend, Lane::Replica, Lane::Control];
        for lane in lanes {
            let (addr, inbox) = endpoint(shared.id, profile);
            shared.dir.register(shared.id, lane, addr);
            // live queue-depth gauge: the inbox's depth counter outlives
            // this loop iteration via the registered Arc handle.
            shared.obs.register_gauge(lane_name(lane), inbox.depth_handle());
            let sh = shared.clone();
            let sd = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-{:?}", shared.id, lane))
                    .spawn(move || lane_loop(sh, sd, lane, inbox))
                    .expect("spawn lane"),
            );
        }

        // consistency-manager thread (only flips flags in AsyncTagged mode,
        // but runs regardless so FlushConsistency is uniform).
        {
            let sh = shared.clone();
            let sd = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-flagmgr", shared.id))
                    .spawn(move || flag_manager_loop(sh, sd))
                    .expect("spawn flagmgr"),
            );
        }

        // scrub worker thread: runs queued integrity passes concurrently
        // with the foreground lanes (see `crate::scrub`).
        {
            let sh = shared.clone();
            let sd = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-scrub", shared.id))
                    .spawn(move || crate::scrub::scrub_loop(sh, sd))
                    .expect("spawn scrub"),
            );
        }

        // maintenance scheduler thread: fires the armed periodic-scrub
        // cadence (see `crate::sched`; virtual-clock tests tick the same
        // path explicitly through `SchedTick`).
        {
            let sh = shared.clone();
            let sd = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-sched", shared.id))
                    .spawn(move || crate::sched::sched_loop(sh, sd))
                    .expect("spawn sched"),
            );
        }

        // recovery worker thread: runs queued backfill jobs after a
        // server loss, concurrently with foreground I/O (see
        // `crate::recovery`).
        {
            let sh = shared.clone();
            let sd = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-recovery", shared.id))
                    .spawn(move || crate::recovery::recovery_loop(sh, sd))
                    .expect("spawn recovery"),
            );
        }

        // rebalance worker thread: runs queued migration scans after a
        // map change (auto-rebalance), concurrently with foreground I/O.
        {
            let sh = shared.clone();
            let sd = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-rebalance", shared.id))
                    .spawn(move || rebalance::rebalance_loop(sh, sd))
                    .expect("spawn rebalance"),
            );
        }

        // fingerprint-pipeline worker thread: batched strong-hash
        // resolution of tier-1 deferred chunks (see `crate::dedup::fpipe`;
        // only spawned in tiered mode — inline mode has no tier 2).
        if shared.cfg.fp_mode.is_tiered() {
            let sh = shared.clone();
            let sd = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{}-fpipe", shared.id))
                    .spawn(move || crate::dedup::fpipe::fpipe_loop(sh, sd))
                    .expect("spawn fpipe"),
            );
        }

        Osd {
            shared,
            shutdown,
            threads,
        }
    }

    /// Abrupt kill: server stops answering; volatile state is lost —
    /// including every span in the server's ring (traces must never
    /// leak across a restart).
    pub fn kill(&self) {
        self.shared.injector.kill();
        self.shared.pending.clear();
        self.shared.scrub.clear();
        self.shared.recovery.clear();
        self.shared.rebalance.clear();
        self.shared.obs.clear_spans();
        self.shared.chunk_cache.clear();
        self.shared.repair_debt.lock().unwrap().clear();
        self.shared.fpipe.clear();
    }

    /// Restart after a kill/crash — see [`OsdShared::restart`].
    pub fn restart(&self) -> crate::error::Result<()> {
        self.shared.restart()
    }

    /// Stop all threads and join them (graceful teardown).
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn lane_loop(sh: Arc<OsdShared>, sd: Arc<AtomicBool>, lane: Lane, inbox: Inbox<Req, Resp>) {
    while !sd.load(Ordering::SeqCst) {
        let Some(env) = inbox.recv_timeout(POLL) else {
            continue;
        };
        if sh.injector.is_dead() {
            // crashed/killed server: drop silently (no reply).
            continue;
        }
        let ctx = env.ctx;
        let (req, replier) = env.split();
        // Replica-side backpressure: a `VerifyCopy` storm past the lane's
        // in-flight cap is shed with a cheap typed NACK *before* any
        // hashing happens; scrub senders back off and retry (see
        // `crate::sched::backpressure`).
        if lane == Lane::Replica
            && matches!(req, Req::VerifyCopy { .. })
            && !sh.verify_gate.admit(inbox.backlog())
        {
            // same rule as after dispatch: a server killed meanwhile
            // must not reply — not even a NACK
            if sh.injector.is_dead() {
                continue;
            }
            Metrics::add(&sh.metrics.backpressure_busy, 1);
            replier.reply(Resp::Busy);
            continue;
        }
        // Tracing: run the handler under the envelope's context so any
        // messages it sends downstream inherit the trace. With a sink the
        // handler gets a fresh child span, timed and recorded on exit;
        // with tracing on but no sink (the near-zero-cost mode the
        // overhead bench pins) the parent context propagates unchanged —
        // no clock read, no allocation, no ring write.
        let traced = sh.obs.tracing() && !ctx.is_none();
        let mut span = None;
        if traced {
            if sh.obs.sink().is_some() {
                let child = ctx.child();
                trace::set_current(child);
                span = Some((child, span_name(lane, &req), sh.now_ms()));
            } else {
                trace::set_current(ctx);
            }
        }
        let resp = dispatch(&sh, lane, req);
        if let Some((child, name, start_ms)) = span {
            if let Some(sink) = sh.obs.sink() {
                sink.record(SpanRecord {
                    trace_id: child.trace_id,
                    span_id: child.span_id,
                    parent: child.parent,
                    server: sh.id.0,
                    name,
                    start_ms,
                    end_ms: sh.now_ms(),
                });
            }
        }
        if traced {
            trace::clear_current();
        }
        // A crash point may have fired mid-request: a dead server must not
        // reply (the caller sees ServerDown via the dropped channel).
        if sh.injector.is_dead() {
            continue;
        }
        replier.reply(resp);
    }
}

/// Static display name of a lane (gauge + span labels).
fn lane_name(lane: Lane) -> &'static str {
    match lane {
        Lane::Frontend => "Frontend",
        Lane::Backend => "Backend",
        Lane::Replica => "Replica",
        Lane::Control => "Control",
    }
}

/// Static span name for one dispatched request. Hot-path request types
/// get precise names; everything else falls back to `<Lane>/Other` so
/// the name stays `'static` without a per-request allocation.
fn span_name(lane: Lane, req: &Req) -> &'static str {
    match req {
        Req::PutObject { .. } => "Frontend/PutObject",
        Req::GetObject { .. } => "Frontend/GetObject",
        Req::DeleteObject { .. } => "Frontend/DeleteObject",
        Req::ProbeChunks { .. } => "Backend/ProbeChunks",
        Req::StoreChunkBatch { .. } => "Backend/StoreChunkBatch",
        Req::StoreChunk { .. } => "Backend/StoreChunk",
        Req::FetchChunk { .. } => "Backend/FetchChunk",
        Req::FetchChunkBatch { .. } => "Backend/FetchChunkBatch",
        Req::DecRef { .. } => "Backend/DecRef",
        Req::DecRefBatch { .. } => "Backend/DecRefBatch",
        Req::PutCopy { .. } => "Replica/PutCopy",
        Req::FetchCopy { .. } => "Replica/FetchCopy",
        Req::DeleteCopy { .. } => "Replica/DeleteCopy",
        Req::VerifyCopy { .. } => "Replica/VerifyCopy",
        _ => match lane {
            Lane::Frontend => "Frontend/Other",
            Lane::Backend => "Backend/Other",
            Lane::Replica => "Replica/Other",
            Lane::Control => "Control/Other",
        },
    }
}

fn err_str(e: crate::error::Error) -> Resp {
    Resp::Err(e.to_string())
}

fn dispatch(sh: &Arc<OsdShared>, lane: Lane, req: Req) -> Resp {
    crate::metrics::Metrics::add(&sh.metrics.messages, 1);
    match (lane, req) {
        // ---- frontend ----
        (Lane::Frontend, Req::PutObject { name, data }) => {
            let t0 = Instant::now();
            match engine::put_object(sh, &name, &data) {
                Ok((logical, unique)) => {
                    sh.metrics.put_latency.record(t0.elapsed());
                    Resp::PutAck { logical, unique }
                }
                Err(e) => err_str(e),
            }
        }
        (Lane::Frontend, Req::GetObject { name }) => {
            let t0 = Instant::now();
            match engine::get_object(sh, &name) {
                Ok(found) => {
                    sh.metrics.get_latency.record(t0.elapsed());
                    match found {
                        Some(data) => Resp::Object(data),
                        None => Resp::NotFound,
                    }
                }
                Err(e) => err_str(e),
            }
        }
        (Lane::Frontend, Req::DeleteObject { name }) => {
            let t0 = Instant::now();
            match engine::delete_object(sh, &name) {
                Ok(existed) => {
                    sh.metrics.delete_latency.record(t0.elapsed());
                    if existed {
                        Resp::Ok
                    } else {
                        Resp::NotFound
                    }
                }
                Err(e) => err_str(e),
            }
        }

        // ---- backend ----
        (Lane::Backend, Req::StoreChunk { fp, data, refs }) => {
            match engine::store_chunk_local(sh, &fp, std::borrow::Cow::Owned(data), refs) {
                Ok(hit) => Resp::StoreAck { dedup_hit: hit },
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::ProbeChunks { fps }) => {
            crate::metrics::Metrics::add(&sh.metrics.cit_lookups, fps.len() as u64);
            match sh.shard.cit_valid_many(&fps) {
                Ok(valid) => Resp::ProbeAck { valid },
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::StoreChunkBatch { items }) => {
            let mut acks = Vec::with_capacity(items.len());
            let mut err = None;
            for item in items {
                let ack = match item.data {
                    Some(data) => engine::store_chunk_local(
                        sh,
                        &item.fp,
                        std::borrow::Cow::Owned(data),
                        item.refs,
                    )
                    .map(|hit| ChunkAck::Stored { dedup_hit: hit }),
                    None => engine::grant_ref_local(sh, &item.fp, item.refs).map(|granted| {
                        if granted {
                            ChunkAck::Stored { dedup_hit: true }
                        } else {
                            ChunkAck::NeedData
                        }
                    }),
                };
                match ack {
                    Ok(a) => acks.push(a),
                    Err(e) => {
                        err = Some(e);
                        break;
                    }
                }
            }
            match err {
                // a failed item aborts the rest of the batch; grants
                // already applied stay — leaked refcounts are the scrub
                // light pass's job, exactly like un-acked StoreChunks
                Some(e) => err_str(e),
                None => Resp::StoreBatchAck { acks },
            }
        }
        (Lane::Backend, Req::FetchChunk { fp }) => match sh.store.get(&fp.to_bytes()) {
            Ok(Some(d)) => Resp::Data(d),
            Ok(None) => Resp::NotFound,
            Err(e) => err_str(e),
        },
        (Lane::Backend, Req::FetchChunkBatch { fps }) => {
            // per-item misses answer `None` (never a whole-message
            // error): the reader falls back chunk by chunk, so one
            // missing chunk can't degrade its batch-mates.
            let items = fps
                .iter()
                .map(|fp| sh.store.get(&fp.to_bytes()).ok().flatten())
                .collect();
            Resp::ChunkBatch { items }
        }
        (Lane::Backend, Req::DecRef { fp, refs }) => match engine::dec_ref_local(sh, &fp, refs) {
            Ok(()) => Resp::Ok,
            Err(e) => err_str(e),
        },
        (Lane::Backend, Req::DecRefBatch { items }) => {
            let mut out = Resp::Ok;
            for (fp, refs) in items {
                if let Err(e) = engine::dec_ref_local(sh, &fp, refs) {
                    out = err_str(e);
                    break;
                }
            }
            out
        }
        (Lane::Backend, Req::SetRef { fp, refs }) => {
            match sh.shard.cit_update(&fp, |cur| {
                cur.map(|mut e| {
                    e.refcount = refs;
                    e
                })
            }) {
                Ok(_) => Resp::Ok,
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::StatChunk { fp }) => {
            let exists = sh.store.stat(&fp.to_bytes()).unwrap_or(false);
            let cit = sh
                .shard
                .cit_get(&fp)
                .ok()
                .flatten()
                .map(|e| (e.refcount, e.flag));
            Resp::ChunkStat {
                exists_data: exists,
                cit,
            }
        }
        (Lane::Backend, Req::StoreRaw { key, data }) => {
            let len = data.len() as u64;
            match sh.store.put_owned(&key, data) {
                Ok(()) => {
                    crate::metrics::Metrics::add(&sh.metrics.bytes_stored, len);
                    Resp::Ok
                }
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::FetchRaw { key }) => match sh.store.get(&key) {
            Ok(Some(d)) => Resp::Data(d),
            Ok(None) => Resp::NotFound,
            Err(e) => err_str(e),
        },
        (Lane::Backend, Req::DeleteRaw { key }) => match sh.store.delete(&key) {
            Ok(true) => Resp::Ok,
            Ok(false) => Resp::NotFound,
            Err(e) => err_str(e),
        },
        (Lane::Backend, Req::MigrateChunk {
            fp,
            data,
            refcount,
            valid,
        }) => match engine::absorb_migrated_chunk(sh, &fp, &data, refcount, valid) {
            Ok(()) => Resp::Ok,
            Err(e) => err_str(e),
        },
        (Lane::Backend, Req::MigrateOmap { value }) => {
            match crate::dedup::omap::OmapEntry::decode(&value) {
                // omap_put also indexes the migrated layout's backrefs
                Ok(entry) => match sh.shard.omap_put(&entry) {
                    Ok(delta) => {
                        crate::metrics::Metrics::add(
                            &sh.metrics.backref_updates,
                            delta.total(),
                        );
                        Resp::Ok
                    }
                    Err(e) => err_str(e),
                },
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::CountRefs { fps }) => {
            match crate::scrub::count_refs_local(sh, &fps) {
                Ok(counts) => Resp::RefCounts(counts),
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::EnsureCit { fp, len }) => {
            match crate::scrub::ensure_cit_local(sh, &fp, len) {
                Ok(_) => Resp::Ok,
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::RecoverOmap { value }) => {
            match crate::recovery::recover_omap_local(sh, value) {
                Ok(()) => Resp::Ok,
                Err(e) => err_str(e),
            }
        }
        (Lane::Backend, Req::VerifyRaw { key, fp }) => match sh.store.get(&key) {
            // hash locally through the provider (pending-aware); only
            // the verdict crosses the wire
            Ok(Some(d)) => Resp::CopyState {
                present: true,
                matches: crate::dedup::fpipe::chunk_matches(sh, &fp, &d),
            },
            Ok(None) => Resp::CopyState {
                present: false,
                matches: false,
            },
            Err(e) => err_str(e),
        },
        (Lane::Backend, Req::ListRefs { fp }) => match sh.shard.backref_referrers(&fp) {
            Ok(referrers) => {
                crate::metrics::Metrics::add(&sh.metrics.backref_lookups, 1);
                Resp::Referrers(
                    referrers
                        .into_iter()
                        .map(|b| {
                            let refs = b.refs();
                            (b.object, refs)
                        })
                        .collect(),
                )
            }
            Err(e) => err_str(e),
        },

        // ---- replica ----
        (Lane::Replica, Req::PutCopy { key, data }) => {
            let len = data.len() as u64;
            match sh.replica_store.put_owned(&key, data) {
                Ok(()) => {
                    crate::metrics::Metrics::add(&sh.metrics.bytes_replica, len);
                    Resp::Ok
                }
                Err(e) => err_str(e),
            }
        }
        (Lane::Replica, Req::DeleteCopy { key }) => match sh.replica_store.delete(&key) {
            Ok(_) => {
                // a retired chunk copy routes through the invalidation
                // choke point: drop any cached payload and deregister a
                // locality plant under the same key, so a reclaim can
                // never leave an orphaned plant behind (DESIGN.md §14)
                if let Some(fp) = engine::chunk_copy_fp(&key) {
                    engine::invalidate_chunk(sh, &fp);
                }
                Resp::Ok
            }
            Err(e) => err_str(e),
        },
        (Lane::Replica, Req::DemoteCopy { fp }) => {
            if sh.chunk_cache.planted_contains(&fp) {
                // the slot holds a locality plant, not a redundancy
                // copy — it was never counted toward the banded target,
                // so a demotion must not drop it (or double-count it)
                Resp::NotFound
            } else {
                match sh.replica_store.delete(&engine::chunk_copy_key(&fp)) {
                    Ok(true) => Resp::Ok,
                    Ok(false) => Resp::NotFound,
                    Err(e) => err_str(e),
                }
            }
        }
        (Lane::Replica, Req::FetchCopy { key }) => match sh.replica_store.get(&key) {
            Ok(Some(d)) => Resp::Data(d),
            Ok(None) => Resp::NotFound,
            Err(e) => err_str(e),
        },
        (Lane::Replica, Req::VerifyCopy { key, fp }) => match sh.replica_store.get(&key) {
            // hash locally through the provider (pending-aware); only
            // the verdict crosses the wire
            Ok(Some(d)) => Resp::CopyState {
                present: true,
                matches: crate::dedup::fpipe::chunk_matches(sh, &fp, &d),
            },
            Ok(None) => Resp::CopyState {
                present: false,
                matches: false,
            },
            Err(e) => err_str(e),
        },

        // ---- control ----
        (Lane::Control, Req::ApplyMap(_)) => Resp::Ok, // map is a shared handle
        (Lane::Control, Req::Rebalance) => match rebalance::run(sh) {
            Ok(_) => Resp::Ok,
            Err(e) => err_str(e),
        },
        (Lane::Control, Req::FlushConsistency) => {
            for fp in sh.pending.drain() {
                let _ = gc::confirm_flag(sh, &fp);
            }
            Resp::Ok
        }
        (Lane::Control, Req::FpipeFlush) => match crate::dedup::fpipe::flush(sh) {
            Ok(()) => Resp::Ok,
            Err(e) => err_str(e),
        },
        (Lane::Control, Req::RunGc { threshold_ms }) => match gc::run(sh, threshold_ms) {
            Ok(_) => Resp::Ok,
            Err(e) => err_str(e),
        },
        (Lane::Control, Req::RecoveryScan) => match gc::recovery_scan(sh) {
            Ok(_) => Resp::Ok,
            Err(e) => err_str(e),
        },
        (Lane::Control, Req::GetStats) => Resp::Stats(stats(sh)),
        (Lane::Control, Req::Audit) => match audit(sh) {
            Ok(d) => Resp::Audit(d),
            Err(e) => err_str(e),
        },
        (Lane::Control, Req::ScrubEnsure) => match crate::scrub::ensure_referenced(sh) {
            Ok(_) => Resp::Ok,
            Err(e) => err_str(e),
        },
        (Lane::Control, Req::StartScrub { opts }) => match sh.scrub.start(opts) {
            Ok(()) => Resp::Ok,
            // typed NACK so callers can tell "already running" (re-arm,
            // retry later) from a real failure
            Err(crate::error::Error::ScrubBusy(_)) => Resp::Busy,
            Err(e) => err_str(e),
        },
        (Lane::Control, Req::ScrubStatus) => Resp::Scrub(sh.scrub.status()),
        (Lane::Control, Req::SetSchedule { schedule }) => {
            sh.sched.set(sh.id.0, sh.now_ms(), schedule);
            Resp::Ok
        }
        (Lane::Control, Req::SchedStatus) => Resp::Sched(sh.sched.status(sh.id.0, sh.now_ms())),
        (Lane::Control, Req::SchedTick) => {
            crate::sched::tick(sh);
            Resp::Ok
        }
        (Lane::Control, Req::Ping) => Resp::Ok,
        (Lane::Control, Req::StartRecovery { lost }) => {
            sh.recovery.enqueue(lost);
            Resp::Ok
        }
        (Lane::Control, Req::RecoveryStatus) => Resp::Recovery(sh.recovery.status()),
        (Lane::Control, Req::StartRebalance) => {
            sh.rebalance.enqueue();
            Resp::Ok
        }
        (Lane::Control, Req::RebalanceStatus) => Resp::Rebalance(sh.rebalance.status()),
        (Lane::Control, Req::RecoveryProbe { lost }) => Resp::RecoveryAck {
            ensure_done: sh.recovery.is_ensured(lost),
        },
        (Lane::Control, Req::RebuildBackrefs) => {
            // audit + re-derive under one shard lock acquisition, so the
            // reported drift is exactly what the rebuild repaired
            match sh.shard.audit_and_rebuild_backrefs() {
                Ok((records, problems)) => {
                    crate::metrics::Metrics::add(
                        &sh.metrics.backref_mismatches,
                        problems.len() as u64,
                    );
                    crate::metrics::Metrics::add(&sh.metrics.backref_rebuilds, 1);
                    Resp::BackrefReport {
                        records: records as u64,
                        mismatches: problems.len() as u64,
                    }
                }
                Err(e) => err_str(e),
            }
        }
        (Lane::Control, Req::Sync) => match sh.shard.sync() {
            Ok(()) => Resp::Ok,
            Err(e) => err_str(e),
        },

        // wrong lane
        (lane, req) => Resp::Err(format!("protocol violation: {req:?} on {lane:?} lane")),
    }
}

fn flag_manager_loop(sh: Arc<OsdShared>, sd: Arc<AtomicBool>) {
    while !sd.load(Ordering::SeqCst) {
        let Some(fp) = sh.pending.pop_timeout(POLL) else {
            continue;
        };
        if sh.injector.is_dead() {
            // crash wipes the queue; anything already popped is lost too.
            sh.pending.clear();
            continue;
        }
        let _ = gc::confirm_flag(&sh, &fp);
    }
}

fn stats(sh: &OsdShared) -> OsdStats {
    OsdStats {
        server: sh.id.0,
        map_epoch: sh.map.read().unwrap().epoch,
        objects: sh.shard.omap_len(),
        cit_entries: sh.shard.cit_len(),
        chunks_stored: sh.store.len(),
        bytes_stored: sh.store.stored_bytes(),
        replica_keys: sh.replica_store.len(),
        replica_bytes: sh.replica_store.stored_bytes(),
        pending_flags: sh.pending.len(),
        backref_entries: sh.shard.backref_len(),
    }
}

fn audit(sh: &OsdShared) -> crate::error::Result<AuditDump> {
    use crate::dedup::cit::CommitFlag;
    let mut dump = AuditDump {
        server: sh.id.0,
        ..Default::default()
    };
    for name in sh.shard.omap_names()? {
        if let Some(entry) = sh.shard.omap_get(&name)? {
            let mut counts = std::collections::HashMap::new();
            for (fp, _) in &entry.chunks {
                *counts.entry(*fp).or_insert(0u64) += 1;
            }
            for (fp, n) in counts {
                dump.omap_refs.push((fp, n));
            }
        }
    }
    for fp in sh.shard.cit_fingerprints()? {
        if let Some(e) = sh.shard.cit_get(&fp)? {
            dump.cit.push((fp, e.refcount, e.flag == CommitFlag::Valid));
        }
    }
    for key in sh.store.keys()? {
        if let Some(fp) = crate::dedup::fingerprint::Fingerprint::from_bytes(&key) {
            dump.data_fps.push(fp);
        }
    }
    // the backreference index must agree with the OMAP it inverts
    dump.backref_mismatches = sh.shard.backref_audit()?;
    crate::metrics::Metrics::add(
        &sh.metrics.backref_mismatches,
        dump.backref_mismatches.len() as u64,
    );
    Ok(dump)
}
