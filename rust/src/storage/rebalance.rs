//! Storage rebalancing (paper §2.3 / Figure 1(b)).
//!
//! When the cluster map changes (server added/removed/reweighted) every
//! server scans its local holdings and migrates whatever no longer maps to
//! it under the new epoch:
//!
//! * **chunks + CIT entries** move to the chunk's new content-derived
//!   home — because placement is a pure function of the fingerprint, *no
//!   deduplication metadata update is ever needed anywhere else* (the
//!   paper's key point: location is never stored, so relocation cannot
//!   stale it);
//! * **OMAP records** move to the object's new name-derived primary;
//! * replica copies are re-fanned-out by the receiving server.
//!
//! The migration itself uses the normal backend lane, so rebalancing
//! competes with foreground I/O exactly like Ceph backfill does.

use crate::dedup::engine::omap_copy_key;
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::net::Lane;
use crate::sched::flow::MaintClass;
use crate::storage::osd::OsdShared;
use crate::storage::proto::{Req, Resp};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Worker poll interval for new jobs / shutdown.
const POLL: Duration = Duration::from_millis(50);

/// Outcome of one server's rebalance scan.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RebalanceReport {
    /// Chunks (CIT entry + data) migrated to a new content home.
    pub chunks_moved: usize,
    /// Total bytes of migrated chunk data.
    pub chunk_bytes_moved: u64,
    /// OMAP records migrated to a new name-derived primary.
    pub omap_moved: usize,
    /// Entries whose new home was unreachable (dead or mid-restart):
    /// left in place for a later scan instead of aborting the whole
    /// pass — under failure detection the map can flap while servers
    /// are still reviving, and one dead home must not stall every other
    /// migration.
    pub skipped_unreachable: usize,
}

/// Lifecycle of a server's queued rebalance work.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum RebalanceState {
    /// No rebalance has run since boot (or the last crash wiped it).
    #[default]
    Idle,
    /// A scan is queued, waiting for the worker thread.
    Queued,
    /// A scan is in progress.
    Running,
    /// The last scan completed.
    Done,
    /// The last scan aborted (server died mid-pass, or an I/O error).
    Failed(String),
}

/// One server's rebalance progress snapshot. The move counters are
/// cumulative across scans since boot (a map change mid-scan re-queues
/// another scan; callers gating on "migrations drained" look at `state`
/// + `queued`, not the counters).
#[derive(Clone, Debug, Default)]
pub struct RebalanceStatus {
    /// Server id.
    pub server: u32,
    /// Worker lifecycle state.
    pub state: RebalanceState,
    /// Scans still queued behind the current one (0 or 1: queued scans
    /// collapse — one full scan covers every pending map change).
    pub queued: usize,
    /// Completed scans since boot.
    pub runs: u64,
    /// Chunks (CIT entry + data, or raw objects) migrated, cumulative.
    pub chunks_moved: u64,
    /// Bytes of migrated chunk data, cumulative.
    pub chunk_bytes_moved: u64,
    /// OMAP records migrated, cumulative.
    pub omap_moved: u64,
    /// Entries whose new home was unreachable, cumulative (left in
    /// place for a later scan).
    pub skipped_unreachable: u64,
    /// Current/last scan start (ms since cluster start).
    pub started_ms: u64,
    /// Current/last scan end (ms since cluster start; 0 while running).
    pub finished_ms: u64,
}

#[derive(Default)]
struct CtlInner {
    pending: bool,
    status: RebalanceStatus,
}

/// Per-server rebalance control block: a collapsing one-slot job queue
/// plus the externally visible status, mirroring
/// [`crate::recovery::RecoveryCtl`]. Volatile — a crash drops the
/// pending scan and fails the running one; the next map change (or
/// explicit [`crate::api::Cluster::rebalance`]) re-queues it.
#[derive(Default)]
pub struct RebalanceCtl {
    inner: Mutex<CtlInner>,
    cv: Condvar,
}

impl RebalanceCtl {
    /// Idle control block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Idle control block that already knows its server id.
    pub fn for_server(server: u32) -> Self {
        let ctl = Self::default();
        ctl.inner.lock().unwrap().status.server = server;
        ctl
    }

    /// Queue a rebalance scan (idempotent: triggers while one is already
    /// pending collapse; a trigger while a scan is *running* stays
    /// pending so the worker runs one more full scan afterwards — the
    /// running scan may have walked holdings before the newest map
    /// epoch landed).
    pub fn enqueue(&self) {
        let mut g = self.inner.lock().unwrap();
        g.pending = true;
        if !matches!(g.status.state, RebalanceState::Running) {
            g.status.state = RebalanceState::Queued;
        }
        self.cv.notify_one();
    }

    /// Current status snapshot (with the live queue depth).
    pub fn status(&self) -> RebalanceStatus {
        let g = self.inner.lock().unwrap();
        let mut st = g.status.clone();
        st.queued = usize::from(g.pending);
        st
    }

    fn take_job(&self, timeout: Duration) -> bool {
        let mut g = self.inner.lock().unwrap();
        if !g.pending {
            g = self.cv.wait_timeout(g, timeout).unwrap().0;
        }
        std::mem::take(&mut g.pending)
    }

    fn update(&self, f: impl FnOnce(&mut RebalanceStatus)) {
        f(&mut self.inner.lock().unwrap().status);
    }

    /// Crash semantics (called from `Osd::kill`): the pending scan is
    /// volatile and dies with the process.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.pending = false;
        if matches!(
            g.status.state,
            RebalanceState::Queued | RebalanceState::Running
        ) {
            g.status = RebalanceStatus {
                server: g.status.server,
                state: RebalanceState::Failed("server crashed".into()),
                ..Default::default()
            };
        }
    }
}

/// The per-server rebalance worker thread body (spawned by
/// [`crate::storage::osd::Osd::spawn`]). Waits for queued scans and
/// runs one full [`run`] pass per job.
pub fn rebalance_loop(sh: Arc<OsdShared>, sd: Arc<AtomicBool>) {
    while !sd.load(Ordering::SeqCst) {
        if !sh.rebalance.take_job(POLL) {
            continue;
        }
        if sh.injector.is_dead() {
            continue; // the kill-time clear() already failed the status
        }
        let started = sh.now_ms();
        sh.rebalance.update(|st| {
            st.state = RebalanceState::Running;
            st.started_ms = started;
            st.finished_ms = 0;
        });
        let outcome = run(&sh);
        let finished = sh.now_ms();
        sh.rebalance.update(|st| {
            st.finished_ms = finished;
            match &outcome {
                Ok(report) => {
                    st.state = RebalanceState::Done;
                    st.runs += 1;
                    st.chunks_moved += report.chunks_moved as u64;
                    st.chunk_bytes_moved += report.chunk_bytes_moved;
                    st.omap_moved += report.omap_moved as u64;
                    st.skipped_unreachable += report.skipped_unreachable as u64;
                }
                Err(e) => st.state = RebalanceState::Failed(e.to_string()),
            }
        });
    }
}

/// Scan local holdings and migrate what no longer belongs here.
pub fn run(sh: &OsdShared) -> Result<RebalanceReport> {
    let mut report = RebalanceReport::default();

    // ---- chunks (CIT + data) ----
    for fp in sh.shard.cit_fingerprints()? {
        let chain = sh.chunk_chain(fp.placement_key());
        let new_home = match chain.first() {
            Some(id) => *id,
            None => continue,
        };
        if new_home == sh.id {
            continue;
        }
        let Some(entry) = sh.shard.cit_get(&fp)? else {
            continue;
        };
        let Ok(addr) = sh.dir.lookup(new_home, Lane::Backend) else {
            report.skipped_unreachable += 1;
            continue; // dead home: this entry waits for a later scan
        };
        let Some(data) = sh.store.get(&fp.to_bytes())? else {
            // metadata-only remnant; move the entry anyway so repair can
            // happen at the new home (replica copies still exist).
            let req = Req::MigrateChunk {
                fp,
                data: Vec::new(),
                refcount: entry.refcount,
                valid: false,
            };
            let size = req.wire_size();
            sh.charge_maint(MaintClass::Rebalance, size as u64);
            let t0 = Instant::now();
            let outcome = addr.call(req, size);
            sh.metrics.rebalance_migration_latency.record(t0.elapsed());
            match outcome {
                Ok(Resp::Ok) => {
                    sh.shard.cit_delete(&fp)?;
                    // coherence: the CIT entry left this server
                    crate::dedup::engine::invalidate_chunk(sh, &fp);
                }
                Ok(_) => {}
                Err(Error::ServerDown(_)) => report.skipped_unreachable += 1,
                Err(e) => return Err(e),
            }
            continue;
        };
        let req = Req::MigrateChunk {
            fp,
            data: data.clone(),
            refcount: entry.refcount,
            valid: entry.flag == crate::dedup::cit::CommitFlag::Valid,
        };
        // migration batches draw from the same per-server maintenance
        // budget as scrub windows — the two no longer collide blindly
        let size = req.wire_size();
        sh.charge_maint(MaintClass::Rebalance, size as u64);
        let t0 = Instant::now();
        let outcome = addr.call(req, size);
        sh.metrics.rebalance_migration_latency.record(t0.elapsed());
        match outcome {
            Ok(Resp::Ok) => {
                sh.shard.cit_delete(&fp)?;
                sh.store.delete(&fp.to_bytes())?;
                // coherence: chunk + CIT entry migrated away
                crate::dedup::engine::invalidate_chunk(sh, &fp);
                report.chunks_moved += 1;
                report.chunk_bytes_moved += data.len() as u64;
            }
            Ok(other) => {
                return Err(Error::TxAborted(format!("migrate {fp} refused: {other:?}")))
            }
            Err(Error::ServerDown(_)) => report.skipped_unreachable += 1,
            Err(e) => return Err(e),
        }
    }

    // ---- OMAP records ----
    for name in sh.shard.omap_names()? {
        let chain = sh.object_chain(&name);
        let new_primary = match chain.first() {
            Some(id) => *id,
            None => continue,
        };
        if new_primary == sh.id {
            continue;
        }
        let Some(entry) = sh.shard.omap_get(&name)? else {
            continue;
        };
        let value = entry.encode();
        let Ok(addr) = sh.dir.lookup(new_primary, Lane::Backend) else {
            report.skipped_unreachable += 1;
            continue;
        };
        let req = Req::MigrateOmap {
            value: value.clone(),
        };
        let size = req.wire_size();
        sh.charge_maint(MaintClass::Rebalance, size as u64);
        match addr.call(req, size) {
            Err(Error::ServerDown(_)) => {
                report.skipped_unreachable += 1;
                continue;
            }
            Err(e) => return Err(e),
            Ok(Resp::Ok) => {
                if let Some(delta) = sh.shard.omap_delete(&name)? {
                    Metrics::add(&sh.metrics.backref_updates, delta.removed);
                }
                // refresh the read-availability copy placement as well
                for peer in chain.iter().skip(1).take(sh.cfg.replication.saturating_sub(1)) {
                    if *peer == sh.id {
                        sh.replica_store.put(&omap_copy_key(&name), &value)?;
                        continue;
                    }
                    // a dead peer or failed push leaves the record's
                    // copy placement degraded — count it instead of
                    // shrugging (the next scrub pass re-fans it)
                    let pushed = sh.dir.lookup(*peer, Lane::Replica).is_ok_and(|r| {
                        matches!(
                            r.call(
                                Req::PutCopy {
                                    key: omap_copy_key(&name),
                                    data: value.clone(),
                                },
                                value.len() + 64,
                            ),
                            Ok(Resp::Ok)
                        )
                    });
                    if !pushed {
                        Metrics::add(&sh.metrics.replica_push_failures, 1);
                    }
                }
                report.omap_moved += 1;
            }
            Ok(other) => {
                return Err(Error::TxAborted(format!(
                    "migrate omap {name} refused: {other:?}"
                )))
            }
        }
    }

    // ---- raw objects (no-dedup mode) ----
    for key in sh.store.keys()? {
        if !key.starts_with(b"obj:") {
            continue;
        }
        let name = String::from_utf8_lossy(&key[4..]).to_string();
        let chain = sh.object_chain(&name);
        let new_primary = match chain.first() {
            Some(id) => *id,
            None => continue,
        };
        if new_primary == sh.id {
            continue;
        }
        if let Some(data) = sh.store.get(&key)? {
            let Ok(addr) = sh.dir.lookup(new_primary, Lane::Backend) else {
                report.skipped_unreachable += 1;
                continue;
            };
            let req = Req::StoreRaw {
                key: key.clone(),
                data,
            };
            let size = req.wire_size();
            sh.charge_maint(MaintClass::Rebalance, size as u64);
            match addr.call(req, size) {
                Ok(Resp::Ok) => {
                    sh.store.delete(&key)?;
                    report.chunks_moved += 1;
                }
                Ok(_) => {}
                Err(Error::ServerDown(_)) => report.skipped_unreachable += 1,
                Err(e) => return Err(e),
            }
        }
    }

    Ok(report)
}
