//! Object storage servers (OSS/OSD) — the shared-nothing substrate.
//!
//! * [`backend`] — byte-addressed chunk/object stores (memory + file).
//! * [`proto`] — the typed request/response protocol between lanes.
//! * [`osd`] — the server: four lanes (frontend / backend / replica /
//!   control) over shared per-server state, plus the consistency-manager
//!   and GC threads.
//! * [`rebalance`] — map-change-driven migration of chunks and OMAP
//!   entries to their recomputed homes.

pub mod backend;
pub mod osd;
pub mod proto;
pub mod rebalance;

pub use backend::{FileStore, MemStore, StorageBackend};
pub use osd::{Osd, OsdShared};
