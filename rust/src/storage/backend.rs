//! Chunk/object data stores (the "disk" of each storage server).

use crate::error::Result;
use crate::util::hex;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Byte store keyed by opaque keys (chunk fingerprints / object names).
/// Internally synchronized; data survives server kill+restart (it models
/// the disk, not the process).
pub trait StorageBackend: Send + Sync {
    /// Store (overwrite) `key`.
    fn put(&self, key: &[u8], data: &[u8]) -> Result<()>;
    /// Store (overwrite) `key`, taking ownership — implementations that
    /// keep data in memory avoid the copy (hot write path).
    fn put_owned(&self, key: &[u8], data: Vec<u8>) -> Result<()> {
        self.put(key, &data)
    }
    /// Fetch a value.
    fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>>;
    /// Delete; true if present.
    fn delete(&self, key: &[u8]) -> Result<bool>;
    /// Does the key exist (the `stat` used by consistency checks)?
    fn stat(&self, key: &[u8]) -> Result<bool>;
    /// All keys (for rebalance scans).
    fn keys(&self) -> Result<Vec<Vec<u8>>>;
    /// Total live payload bytes.
    fn stored_bytes(&self) -> u64;
    /// Number of stored values.
    fn len(&self) -> usize;
    /// True if nothing is stored.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Delete every stored value (wipe-and-rejoin support). The default
    /// walks `keys` and deletes one at a time so the byte/count
    /// accounting stays exact for any implementation.
    fn clear(&self) -> Result<()> {
        for key in self.keys()? {
            self.delete(&key)?;
        }
        Ok(())
    }
}

/// In-memory backend.
#[derive(Default)]
pub struct MemStore {
    map: Mutex<HashMap<Vec<u8>, Vec<u8>>>,
    bytes: AtomicU64,
}

impl MemStore {
    /// New empty store.
    pub fn new() -> Self {
        Self::default()
    }
}

impl StorageBackend for MemStore {
    fn put(&self, key: &[u8], data: &[u8]) -> Result<()> {
        self.put_owned(key, data.to_vec())
    }

    fn put_owned(&self, key: &[u8], data: Vec<u8>) -> Result<()> {
        let len = data.len() as u64;
        let mut m = self.map.lock().unwrap();
        if let Some(old) = m.insert(key.to_vec(), data) {
            self.bytes.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
        self.bytes.fetch_add(len, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        Ok(self.map.lock().unwrap().get(key).cloned())
    }

    fn delete(&self, key: &[u8]) -> Result<bool> {
        let mut m = self.map.lock().unwrap();
        if let Some(old) = m.remove(key) {
            self.bytes.fetch_sub(old.len() as u64, Ordering::Relaxed);
            Ok(true)
        } else {
            Ok(false)
        }
    }

    fn stat(&self, key: &[u8]) -> Result<bool> {
        Ok(self.map.lock().unwrap().contains_key(key))
    }

    fn keys(&self) -> Result<Vec<Vec<u8>>> {
        Ok(self.map.lock().unwrap().keys().cloned().collect())
    }

    fn stored_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

/// File-per-key backend under a directory (keys hex-encoded, two-level
/// fan-out to keep directories small).
pub struct FileStore {
    dir: PathBuf,
    bytes: AtomicU64,
    count: AtomicU64,
    // serialize directory mutations; reads go straight to the fs
    lock: Mutex<()>,
}

impl FileStore {
    /// Open (creating) a store rooted at `dir`; scans existing content to
    /// rebuild the byte/count accounting (restart path).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let mut bytes = 0u64;
        let mut count = 0u64;
        for sub in std::fs::read_dir(&dir)? {
            let sub = sub?;
            if sub.file_type()?.is_dir() {
                for f in std::fs::read_dir(sub.path())? {
                    let md = f?.metadata()?;
                    bytes += md.len();
                    count += 1;
                }
            }
        }
        Ok(FileStore {
            dir,
            bytes: AtomicU64::new(bytes),
            count: AtomicU64::new(count),
            lock: Mutex::new(()),
        })
    }

    fn path_of(&self, key: &[u8]) -> PathBuf {
        let h = hex::encode(key);
        let (fan, rest) = if h.len() >= 2 {
            (&h[..2], &h[..])
        } else {
            ("00", &h[..])
        };
        self.dir.join(fan).join(rest)
    }
}

impl StorageBackend for FileStore {
    fn put(&self, key: &[u8], data: &[u8]) -> Result<()> {
        let p = self.path_of(key);
        let _g = self.lock.lock().unwrap();
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let old = std::fs::metadata(&p).map(|m| m.len()).ok();
        std::fs::write(&p, data)?;
        if let Some(old) = old {
            self.bytes.fetch_sub(old, Ordering::Relaxed);
        } else {
            self.count.fetch_add(1, Ordering::Relaxed);
        }
        self.bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        match std::fs::read(self.path_of(key)) {
            Ok(v) => Ok(Some(v)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    fn delete(&self, key: &[u8]) -> Result<bool> {
        let p = self.path_of(key);
        let _g = self.lock.lock().unwrap();
        match std::fs::metadata(&p) {
            Ok(md) => {
                std::fs::remove_file(&p)?;
                self.bytes.fetch_sub(md.len(), Ordering::Relaxed);
                self.count.fetch_sub(1, Ordering::Relaxed);
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    fn stat(&self, key: &[u8]) -> Result<bool> {
        Ok(self.path_of(key).exists())
    }

    fn keys(&self) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::new();
        for sub in std::fs::read_dir(&self.dir)? {
            let sub = sub?;
            if sub.file_type()?.is_dir() {
                for f in std::fs::read_dir(sub.path())? {
                    let name = f?.file_name();
                    if let Some(k) = name.to_str().and_then(hex::decode) {
                        out.push(k);
                    }
                }
            }
        }
        Ok(out)
    }

    fn stored_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn len(&self) -> usize {
        self.count.load(Ordering::Relaxed) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conformance(store: &dyn StorageBackend) {
        assert!(store.is_empty());
        store.put(b"k1", b"hello").unwrap();
        store.put(b"k2", &vec![7u8; 1000]).unwrap();
        assert_eq!(store.stored_bytes(), 1005);
        assert_eq!(store.len(), 2);
        assert!(store.stat(b"k1").unwrap());
        assert!(!store.stat(b"nope").unwrap());
        assert_eq!(store.get(b"k1").unwrap().unwrap(), b"hello");
        // overwrite adjusts accounting
        store.put(b"k1", b"hi").unwrap();
        assert_eq!(store.stored_bytes(), 1002);
        assert!(store.delete(b"k1").unwrap());
        assert!(!store.delete(b"k1").unwrap());
        assert_eq!(store.stored_bytes(), 1000);
        let keys = store.keys().unwrap();
        assert_eq!(keys, vec![b"k2".to_vec()]);
        store.clear().unwrap();
        assert!(store.is_empty(), "clear removes every stored value");
        assert_eq!(store.stored_bytes(), 0, "clear keeps accounting exact");
    }

    #[test]
    fn memstore_conformance() {
        conformance(&MemStore::new());
    }

    #[test]
    fn filestore_conformance() {
        let d = std::env::temp_dir().join(format!("snss-fs-conf-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        conformance(&FileStore::open(&d).unwrap());
    }

    #[test]
    fn filestore_survives_reopen() {
        let d = std::env::temp_dir().join(format!("snss-fs-reopen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        {
            let fs = FileStore::open(&d).unwrap();
            fs.put(b"\xaa\xbb", &vec![1u8; 128]).unwrap();
            fs.put(b"\xcc", b"x").unwrap();
        }
        let fs = FileStore::open(&d).unwrap();
        assert_eq!(fs.len(), 2);
        assert_eq!(fs.stored_bytes(), 129);
        assert_eq!(fs.get(b"\xaa\xbb").unwrap().unwrap(), vec![1u8; 128]);
        let mut keys = fs.keys().unwrap();
        keys.sort();
        assert_eq!(keys, vec![b"\xaa\xbb".to_vec(), b"\xcc".to_vec()]);
    }
}
