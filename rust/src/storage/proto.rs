//! The request/response protocol spoken over the fabric.
//!
//! One `Req`/`Resp` pair covers all four lanes (the [`crate::net::Lane`]
//! key in the directory selects the handler); the lane-ordering rules in
//! `net` still apply. `wire_size` feeds the optional [`NetProfile`]
//! cost model.

use crate::cluster::ClusterMap;
use crate::dedup::cit::CommitFlag;
use crate::dedup::fingerprint::Fingerprint;
use crate::recovery::RecoveryStatus;
use crate::sched::{SchedStatus, ScrubSchedule};
use crate::scrub::{ScrubOptions, ScrubStatus};
use crate::storage::rebalance::RebalanceStatus;

/// All messages a server can receive.
#[derive(Debug)]
pub enum Req {
    // ---- frontend lane (clients → object primary) ----
    /// Write a whole object through the dedup engine.
    PutObject { name: String, data: Vec<u8> },
    /// Read a whole object.
    GetObject { name: String },
    /// Delete an object (decrements chunk references).
    DeleteObject { name: String },

    // ---- backend lane (frontends → chunk home) ----
    /// Dedup-aware chunk store: CIT lookup, refcount/flag logic, data
    /// store + replication. `refs` is the intra-batch multiplicity.
    StoreChunk {
        fp: Fingerprint,
        data: Vec<u8>,
        refs: u64,
    },
    /// Phase A of the batched write path: a read-only CIT probe for one
    /// object's fingerprints homed here. The reply says which are already
    /// Valid, so Phase B can elide their payloads.
    ProbeChunks { fps: Vec<Fingerprint> },
    /// Phase B of the batched write path: one message per chunk home
    /// carrying refcount grants for every item, payloads only for probe
    /// misses (and NeedData resends). Each item runs the same
    /// `store_chunk_local` transaction a single `StoreChunk` would.
    StoreChunkBatch { items: Vec<ChunkPut> },
    /// Fetch chunk data by fingerprint.
    FetchChunk { fp: Fingerprint },
    /// Batched read path: fetch every listed chunk homed here in one
    /// message (the read-side mirror of [`Req::ProbeChunks`]). Misses
    /// come back as `None` so the reader can fall back per item.
    FetchChunkBatch { fps: Vec<Fingerprint> },
    /// Decrement a chunk's refcount by `refs` (delete / tx rollback).
    DecRef { fp: Fingerprint, refs: u64 },
    /// Batched [`Req::DecRef`]: all of one object's refcount releases
    /// homed on this server, in one message (delete and abort paths).
    DecRefBatch { items: Vec<(Fingerprint, u64)> },
    /// Existence + CIT state probe (consistency checks, tests).
    StatChunk { fp: Fingerprint },
    /// Raw keyed store (no-dedup + central-data paths).
    StoreRaw { key: Vec<u8>, data: Vec<u8> },
    /// Raw keyed fetch.
    FetchRaw { key: Vec<u8> },
    /// Raw keyed delete.
    DeleteRaw { key: Vec<u8> },
    /// Scrub repair: force a CIT entry's refcount to the cluster-wide
    /// OMAP-derived reference count (the paper's GC "cross-match" applied
    /// to reference leaks from unrolled-back failed transactions).
    SetRef { fp: Fingerprint, refs: u64 },
    /// Rebalance transfer: a chunk plus its CIT entry moving to its new
    /// content-derived home.
    MigrateChunk {
        fp: Fingerprint,
        data: Vec<u8>,
        refcount: u64,
        valid: bool,
    },
    /// Rebalance transfer: an OMAP record moving to its new name-derived
    /// home.
    MigrateOmap { value: Vec<u8> },
    /// Scrub: count this server's local OMAP references for each
    /// fingerprint (replaces the old full-dump cross-match: only the
    /// window's counts cross the wire, never whole tables).
    CountRefs { fps: Vec<Fingerprint> },
    /// Scrub ensure-phase: create a zero-ref invalid CIT entry at the
    /// fingerprint's home if none exists (a reference with no CIT entry
    /// cannot be seen, reconciled or repaired by the home's walk).
    EnsureCit { fp: Fingerprint, len: u32 },
    /// Backreference-index lookup: which of this server's objects
    /// reference `fp`, and how many times each (an indexed range read;
    /// diagnostics and the "who holds this chunk?" admin question).
    ListRefs { fp: Fingerprint },
    /// Recovery: adopt this encoded OMAP record if the name is unknown
    /// here (the receiver is the record's new primary after its old one
    /// left; a racing fresh write always wins), then refresh its replica
    /// copies under the current chain.
    RecoverOmap {
        /// The encoded [`crate::dedup::omap::OmapEntry`].
        value: Vec<u8>,
    },
    /// Central-mode deep scrub: verify a raw chunk in this server's
    /// *primary* store against its expected fingerprint. Like
    /// [`Req::VerifyCopy`] the holder hashes locally — only the verdict
    /// crosses the wire.
    VerifyRaw {
        /// Primary-store key of the raw chunk.
        key: Vec<u8>,
        /// Expected content fingerprint.
        fp: Fingerprint,
    },

    // ---- replica lane (backends → replica holders; strictly local) ----
    /// Store a replica copy of a chunk / OMAP record.
    PutCopy { key: Vec<u8>, data: Vec<u8> },
    /// Delete a replica copy.
    DeleteCopy { key: Vec<u8> },
    /// Redundancy demotion: drop the chunk's replica-slot copy *iff* it
    /// is a redundancy copy. A locality plant under the same key (see
    /// [`crate::dedup::cache::ChunkCache`]) was never counted toward
    /// the banded target, so the holder consults its plant registry and
    /// keeps a planted copy — unlike [`Req::DeleteCopy`], which retires
    /// the key unconditionally (GC reclaim, object delete).
    DemoteCopy { fp: Fingerprint },
    /// Fetch a replica copy (degraded reads, repair).
    FetchCopy { key: Vec<u8> },
    /// Deep scrub: verify a replica copy against its expected
    /// fingerprint. The holder hashes locally — only the verdict crosses
    /// the wire, not the data.
    VerifyCopy { key: Vec<u8>, fp: Fingerprint },

    // ---- control lane (admin) ----
    /// Push a new cluster map epoch.
    ApplyMap(ClusterMap),
    /// Scan and migrate data that no longer belongs here, synchronously
    /// (the reply waits for the whole scan; see [`Req::StartRebalance`]
    /// for the queued form).
    Rebalance,
    /// Queue a rebalance scan on this server's rebalance worker
    /// (map-change auto-rebalance path; the handler only enqueues).
    StartRebalance,
    /// Snapshot this server's rebalance worker progress.
    RebalanceStatus,
    /// Drain the async consistency queue (tests/benches quiesce).
    FlushConsistency,
    /// Run a GC pass; entries invalid for longer than `threshold_ms` are
    /// candidates.
    RunGc { threshold_ms: u64 },
    /// Post-restart recovery scan (re-registers stored-but-invalid chunks).
    RecoveryScan,
    /// Per-server stats snapshot.
    GetStats,
    /// Dump for cluster-wide invariant checks.
    Audit,
    /// Run the scrub ensure-phase: every locally referenced fingerprint
    /// gets a CIT entry at its home (see [`crate::scrub`]).
    ScrubEnsure,
    /// Queue an online scrub pass on this server's scrub worker.
    StartScrub { opts: ScrubOptions },
    /// Snapshot the scrub worker's progress.
    ScrubStatus,
    /// Arm (or disarm with `None`) this server's periodic scrub
    /// schedule (see [`crate::sched`]).
    SetSchedule {
        /// The cadence to arm; `None` disarms.
        schedule: Option<ScrubSchedule>,
    },
    /// Snapshot this server's maintenance-scheduler state.
    SchedStatus,
    /// Evaluate this server's schedule now (fires due passes). Sent by
    /// [`crate::api::Cluster::advance_clock`] after moving the virtual
    /// clock; idempotent per due time.
    SchedTick,
    /// One-shot backreference-index migration/repair: audit the index
    /// against the OMAP, then re-derive it (pre-index stores, suspected
    /// divergence after an unclean recovery).
    RebuildBackrefs,
    /// Failure-detector heartbeat: a live control lane answers
    /// [`Resp::Ok`]; a killed/crashed server drops the envelope, which
    /// the detector reads as evidence of death (see
    /// [`crate::recovery::detector`]).
    Ping,
    /// Queue a recovery-backfill job for the departed server `lost` on
    /// this server's recovery worker (see [`crate::recovery`]).
    StartRecovery {
        /// The server whose out-transition is being recovered from.
        lost: u32,
    },
    /// Snapshot this server's recovery worker progress.
    RecoveryStatus,
    /// Ensure-barrier probe: has this server completed the OMAP +
    /// ensure stage of its recovery job for `lost`? Peers gate their
    /// chunk backfill on every survivor answering yes (bounded wait).
    RecoveryProbe {
        /// The lost server the barrier synchronizes on.
        lost: u32,
    },
    /// Drain the tiered fingerprint pipeline: synchronously resolve and
    /// migrate every queued pending identity (tests/benches quiesce;
    /// see [`crate::dedup::fpipe`]). A no-op under `FpMode::Inline`.
    FpipeFlush,
    /// Flush persistent stores.
    Sync,
}

/// All responses.
#[derive(Debug)]
pub enum Resp {
    /// Generic success.
    Ok,
    /// Object write accepted: (logical bytes, unique bytes this op).
    PutAck { logical: u64, unique: u64 },
    /// Object payload.
    Object(Vec<u8>),
    /// Chunk/raw payload.
    Data(Vec<u8>),
    /// Store-chunk outcome.
    StoreAck {
        /// True when the chunk was already present (refcount bumped).
        dedup_hit: bool,
    },
    /// `ProbeChunks` answer: for each requested fingerprint (same
    /// order), does a Valid CIT entry exist at this home?
    ProbeAck {
        /// One flag per probed fingerprint; true = payload not needed.
        valid: Vec<bool>,
    },
    /// `StoreChunkBatch` answer: one outcome per item, same order.
    StoreBatchAck {
        /// Per-item outcome (grant, store, or NeedData NACK).
        acks: Vec<ChunkAck>,
    },
    /// `FetchChunkBatch` answer: one payload per requested fingerprint
    /// (same order); `None` = not stored here (degraded fallback).
    ChunkBatch {
        /// Per-item payload or miss marker.
        items: Vec<Option<Vec<u8>>>,
    },
    /// Stat outcome.
    ChunkStat {
        exists_data: bool,
        cit: Option<(u64, CommitFlag)>,
    },
    /// Per-fingerprint local OMAP reference counts (same order as the
    /// requested fingerprints).
    RefCounts(Vec<u64>),
    /// `ListRefs` answer: (object name, reference multiplicity) for every
    /// local referrer of the requested fingerprint.
    Referrers(Vec<(String, u64)>),
    /// `RebuildBackrefs` answer.
    BackrefReport {
        /// Index records after the rebuild.
        records: u64,
        /// Index ↔ OMAP discrepancies the pre-rebuild audit found.
        mismatches: u64,
    },
    /// Replica-copy verification verdict.
    CopyState { present: bool, matches: bool },
    /// Scrub worker progress snapshot.
    Scrub(ScrubStatus),
    /// Recovery worker progress snapshot.
    Recovery(RecoveryStatus),
    /// Rebalance worker progress snapshot.
    Rebalance(RebalanceStatus),
    /// Ensure-barrier answer (see [`Req::RecoveryProbe`]).
    RecoveryAck {
        /// True when the OMAP + ensure stage for the probed job is done
        /// (durably — a finished job keeps answering true).
        ensure_done: bool,
    },
    /// Maintenance-scheduler snapshot.
    Sched(SchedStatus),
    /// Typed busy NACK: the receiver shed the request without doing its
    /// work (replica `VerifyCopy` lane over its in-flight cap, or a
    /// scrub start racing a pass already queued/running). Retry later;
    /// nothing happened.
    Busy,
    /// Requested key/object/chunk is unknown.
    NotFound,
    /// Per-server statistics.
    Stats(OsdStats),
    /// Audit dump.
    Audit(AuditDump),
    /// Error string (errors must cross threads; `crate::Error` is not
    /// `Clone` and carries io errors, so the wire form is a string).
    Err(String),
}

/// One chunk inside a [`Req::StoreChunkBatch`]: the refcount grant
/// always travels; the payload only when the Phase-A probe reported the
/// chunk absent/invalid at its home (or on a NeedData resend).
#[derive(Clone, Debug)]
pub struct ChunkPut {
    /// Content fingerprint (routing key and CIT key).
    pub fp: Fingerprint,
    /// Intra-object reference multiplicity to grant.
    pub refs: u64,
    /// Chunk payload; `None` when the probe said the home already holds
    /// a Valid copy.
    pub data: Option<Vec<u8>>,
}

/// Per-item outcome of a [`Req::StoreChunkBatch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkAck {
    /// The grant (and store, when a payload was shipped) landed.
    Stored {
        /// True when the chunk was already present (refcount bumped).
        dedup_hit: bool,
    },
    /// The probe hint went stale (entry reclaimed or invalid and no
    /// payload was shipped): nothing was granted — re-send this item
    /// with its payload.
    NeedData,
}

/// Per-server statistics snapshot.
#[derive(Clone, Debug, Default)]
pub struct OsdStats {
    /// Server id.
    pub server: u32,
    /// Cluster-map epoch this server has applied.
    pub map_epoch: u64,
    /// Objects in the local OMAP.
    pub objects: usize,
    /// Entries in the local CIT.
    pub cit_entries: usize,
    /// Chunks in the local primary store.
    pub chunks_stored: usize,
    /// Bytes in the local primary store.
    pub bytes_stored: u64,
    /// Keys in the local replica store.
    pub replica_keys: usize,
    /// Bytes in the local replica store.
    pub replica_bytes: u64,
    /// Async-consistency registrations not yet confirmed.
    pub pending_flags: usize,
    /// Records in the local backreference index.
    pub backref_entries: usize,
}

/// Audit dump for cluster-wide invariant checking: every OMAP reference
/// and every CIT entry on this server.
#[derive(Clone, Debug, Default)]
pub struct AuditDump {
    /// Server id.
    pub server: u32,
    /// (chunk fp, multiplicity) summed over all local OMAP entries.
    pub omap_refs: Vec<(Fingerprint, u64)>,
    /// (fp, refcount, valid) for every CIT entry.
    pub cit: Vec<(Fingerprint, u64, bool)>,
    /// Fingerprints whose chunk data is present in the local store
    /// (presence is resolved cluster-wide by the auditor: in central mode
    /// the metadata owner and the data holder are different servers).
    pub data_fps: Vec<Fingerprint>,
    /// Local backreference-index ↔ OMAP discrepancies (one line each;
    /// empty when the index is exact).
    pub backref_mismatches: Vec<String>,
}

impl Req {
    /// Approximate wire size (payload + small header) for the net model.
    pub fn wire_size(&self) -> usize {
        const HDR: usize = 64;
        HDR + match self {
            Req::PutObject { name, data } => name.len() + data.len(),
            Req::GetObject { name } | Req::DeleteObject { name } => name.len(),
            Req::StoreChunk { data, .. } => 20 + data.len(),
            Req::ProbeChunks { fps } | Req::FetchChunkBatch { fps } => 20 * fps.len(),
            Req::StoreChunkBatch { items } => items
                .iter()
                .map(|i| 29 + i.data.as_ref().map_or(0, Vec::len))
                .sum(),
            Req::DecRefBatch { items } => 28 * items.len(),
            Req::FetchChunk { .. } | Req::DecRef { .. } | Req::StatChunk { .. } => 20,
            Req::StoreRaw { key, data } => key.len() + data.len(),
            Req::FetchRaw { key } | Req::DeleteRaw { key } => key.len(),
            Req::MigrateChunk { data, .. } => 20 + 16 + data.len(),
            Req::MigrateOmap { value } => value.len(),
            Req::CountRefs { fps } => 20 * fps.len(),
            Req::EnsureCit { .. } => 24,
            Req::ListRefs { .. } => 20,
            Req::RecoverOmap { value } => value.len(),
            Req::VerifyRaw { key, .. } => key.len() + 20,
            Req::StartRecovery { .. } | Req::RecoveryProbe { .. } => 8,
            Req::VerifyCopy { key, .. } => key.len() + 20,
            Req::StartScrub { .. } => 24,
            Req::SetSchedule { .. } => 24,
            Req::PutCopy { key, data } => key.len() + data.len(),
            Req::DeleteCopy { key } | Req::FetchCopy { key } => key.len(),
            Req::DemoteCopy { .. } => 20,
            Req::ApplyMap(m) => 16 * m.servers.len(),
            _ => 0,
        }
    }
}

/// Convenience alias for this protocol's directory.
pub type Dir = crate::net::Directory<Req, Resp>;
/// Convenience alias for addresses.
pub type OsdAddr = crate::net::Addr<Req, Resp>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_size_scales_with_payload() {
        let small = Req::StoreChunk {
            fp: Fingerprint::of(b"x"),
            data: vec![0; 10],
            refs: 1,
        };
        let big = Req::StoreChunk {
            fp: Fingerprint::of(b"x"),
            data: vec![0; 10_000],
            refs: 1,
        };
        assert!(big.wire_size() > small.wire_size() + 9_000);
        assert!(Req::GetObject { name: "a".into() }.wire_size() < 100);
    }

    #[test]
    fn batch_wire_sizes_elide_hit_payloads() {
        let fp = Fingerprint::of(b"x");
        let hit = Req::StoreChunkBatch {
            items: vec![ChunkPut {
                fp,
                refs: 3,
                data: None,
            }],
        };
        let miss = Req::StoreChunkBatch {
            items: vec![ChunkPut {
                fp,
                refs: 1,
                data: Some(vec![0; 4096]),
            }],
        };
        assert!(miss.wire_size() > hit.wire_size() + 4_000);
        assert_eq!(Req::ProbeChunks { fps: vec![fp; 8] }.wire_size(), 64 + 160);
        let dec = Req::DecRefBatch {
            items: vec![(fp, 2); 4],
        };
        assert_eq!(dec.wire_size(), 64 + 112);
    }
}
