//! Rendezvous (highest-random-weight) hashing — the ablation alternative
//! to straw2. Weighted via the same logarithmic trick; kept to compare
//! balance quality and movement behaviour in the placement ablation.

use super::PlacementPolicy;
use crate::cluster::{ClusterMap, ServerId};
use crate::hash::fnv::fnv1a64_pair;

/// The HRW policy (stateless).
pub struct Rendezvous;

impl PlacementPolicy for Rendezvous {
    fn select(&self, map: &ClusterMap, key: u64, n: usize) -> Vec<ServerId> {
        let mut scored: Vec<(f64, ServerId)> = map
            .up_servers()
            .map(|s| {
                let h = fnv1a64_pair(key ^ 0xA5A5_5A5A_DEAD_BEEF, s.id.0 as u64);
                let u = ((h >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64);
                (-s.weight / u.ln(), s.id)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        scored.truncate(n);
        scored.into_iter().map(|(_, id)| id).collect()
    }

    fn name(&self) -> &'static str {
        "rendezvous"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::conformance;

    #[test]
    fn conformance_basic() {
        conformance::basic(&Rendezvous);
    }

    #[test]
    fn conformance_balance() {
        conformance::balance(&Rendezvous);
    }

    #[test]
    fn conformance_minimal_movement() {
        conformance::minimal_movement(&Rendezvous, 0.04);
    }

    #[test]
    fn conformance_weighted() {
        conformance::weighted(&Rendezvous);
    }

    #[test]
    fn conformance_prop_distinct() {
        conformance::prop_distinct(&Rendezvous);
    }

    #[test]
    fn differs_from_straw2() {
        // sanity: it is actually a different mapping
        use crate::placement::straw2::Straw2;
        let map = ClusterMap::new(8);
        let diff = (0..500u64)
            .filter(|&k| {
                Rendezvous.select(&map, k, 1) != Straw2.select(&map, k, 1)
            })
            .count();
        assert!(diff > 100);
    }
}
