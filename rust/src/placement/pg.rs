//! Placement groups: keys fold onto a fixed ring of PGs; the policy places
//! PGs onto servers once per map epoch, and the per-key hot path is a mask
//! plus a table lookup (this is Ceph's PG layer, which the paper inherits
//! by passing fingerprints to CRUSH).

use super::PlacementPolicy;
use crate::cluster::{ClusterMap, ServerId};
use std::sync::RwLock;

/// Salt mixed into each PG id before it is handed to the policy, so pg 0
/// and key 0 never collide trivially.
const PG_SALT: u64 = 0x5047_5047;

/// Cached PG→replica-chain table for one map epoch.
pub struct PgMap {
    policy: Box<dyn PlacementPolicy>,
    pg_count: u32,
    replicas: usize,
    cache: RwLock<Cached>,
}

struct Cached {
    epoch: u64,
    table: Vec<Vec<ServerId>>,
}

impl PgMap {
    /// Build over a policy; `pg_count` must be a power of two.
    pub fn new(policy: Box<dyn PlacementPolicy>, pg_count: u32, replicas: usize) -> Self {
        assert!(pg_count.is_power_of_two(), "pg_count must be a power of two");
        PgMap {
            policy,
            pg_count,
            replicas,
            cache: RwLock::new(Cached {
                epoch: 0,
                table: Vec::new(),
            }),
        }
    }

    /// PG id for a key.
    #[inline]
    pub fn pg_of(&self, key: u64) -> u32 {
        (key & (self.pg_count as u64 - 1)) as u32
    }

    /// Number of PGs.
    pub fn pg_count(&self) -> u32 {
        self.pg_count
    }

    /// Replica chain for `key` under `map` (primary first). Rebuilds the
    /// cached table when the epoch changed.
    pub fn select(&self, map: &ClusterMap, key: u64) -> Vec<ServerId> {
        self.ensure(map);
        let cache = self.cache.read().unwrap();
        cache.table[self.pg_of(key) as usize].clone()
    }

    /// Primary server for `key`.
    pub fn primary(&self, map: &ClusterMap, key: u64) -> Option<ServerId> {
        self.ensure(map);
        let cache = self.cache.read().unwrap();
        cache.table[self.pg_of(key) as usize].first().copied()
    }

    /// Full chain for a PG id (used by rebalance scans).
    pub fn chain_of_pg(&self, map: &ClusterMap, pg: u32) -> Vec<ServerId> {
        self.ensure(map);
        self.cache.read().unwrap().table[pg as usize].clone()
    }

    /// Compute the full PG→chain table for an arbitrary map *without*
    /// touching the per-epoch cache. Recovery planning uses this to
    /// reconstruct placement as it was before a server left, while
    /// foreground I/O keeps reading the live table — the synthetic map
    /// must never thrash the cache the hot path depends on.
    pub fn table_for(&self, map: &ClusterMap) -> Vec<Vec<ServerId>> {
        (0..self.pg_count)
            .map(|pg| {
                let key = crate::hash::fnv::fnv1a64_pair(pg as u64, PG_SALT);
                self.policy.select(map, key, self.replicas)
            })
            .collect()
    }

    fn ensure(&self, map: &ClusterMap) {
        {
            let cache = self.cache.read().unwrap();
            if cache.epoch == map.epoch {
                return;
            }
        }
        let mut table = Vec::with_capacity(self.pg_count as usize);
        for pg in 0..self.pg_count {
            // salt the pg id so pg 0 and key 0 don't collide trivially
            let key = crate::hash::fnv::fnv1a64_pair(pg as u64, PG_SALT);
            table.push(self.policy.select(map, key, self.replicas));
        }
        let mut cache = self.cache.write().unwrap();
        if cache.epoch != map.epoch {
            *cache = Cached {
                epoch: map.epoch,
                table,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::straw2::Straw2;

    fn pgmap(replicas: usize) -> PgMap {
        PgMap::new(Box::new(Straw2), 128, replicas)
    }

    #[test]
    fn select_is_stable_within_epoch() {
        let map = ClusterMap::new(5);
        let pm = pgmap(2);
        for k in 0..100u64 {
            assert_eq!(pm.select(&map, k), pm.select(&map, k));
        }
    }

    #[test]
    fn cache_refreshes_on_epoch_change() {
        let mut map = ClusterMap::new(3);
        let pm = pgmap(1);
        let before: Vec<_> = (0..1000u64).map(|k| pm.select(&map, k)[0]).collect();
        map.add_server(1.0);
        let after: Vec<_> = (0..1000u64).map(|k| pm.select(&map, k)[0]).collect();
        let moved = before.iter().zip(&after).filter(|(a, b)| a != b).count();
        assert!(moved > 0, "nothing moved after adding a server");
        assert!(moved < 600, "too much moved: {moved}/1000");
        // everything that moved went to the new server
        for (a, b) in before.iter().zip(&after) {
            if a != b {
                assert_eq!(*b, ServerId(3));
            }
        }
    }

    #[test]
    fn pg_of_masks() {
        let pm = pgmap(1);
        assert_eq!(pm.pg_of(0), 0);
        assert_eq!(pm.pg_of(127), 127);
        assert_eq!(pm.pg_of(128), 0);
        assert_eq!(pm.pg_of(u64::MAX), 127);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        PgMap::new(Box::new(Straw2), 100, 1);
    }

    #[test]
    fn replica_chain_length() {
        let map = ClusterMap::new(4);
        let pm = pgmap(3);
        for k in 0..50u64 {
            assert_eq!(pm.select(&map, k).len(), 3);
        }
    }
}
