//! CRUSH straw2 bucket selection.
//!
//! Each Up server draws a "straw" `ln(u) / weight` where `u` is a uniform
//! (0,1] hash of (key, server); the longest straws win. straw2's defining
//! property (Weil et al., and what the paper relies on for rebalancing):
//! changing one server's weight only moves keys to/from *that* server.

use super::PlacementPolicy;
use crate::cluster::{ClusterMap, ServerId};
use crate::hash::fnv::fnv1a64_pair;

/// The straw2 policy (stateless).
pub struct Straw2;

#[inline]
fn draw(key: u64, server: u32, weight: f64) -> f64 {
    // u in (0, 1]: take 53 bits, avoid 0.
    let h = fnv1a64_pair(key, server as u64);
    let u = ((h >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64);
    // ln(u) <= 0; dividing by weight shrinks the penalty for heavy servers.
    u.ln() / weight
}

impl PlacementPolicy for Straw2 {
    fn select(&self, map: &ClusterMap, key: u64, n: usize) -> Vec<ServerId> {
        // Collect (draw, id) for Up servers and take the top-n.
        let mut straws: Vec<(f64, ServerId)> = map
            .up_servers()
            .map(|s| (draw(key, s.id.0, s.weight), s.id))
            .collect();
        straws.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        straws.truncate(n);
        straws.into_iter().map(|(_, id)| id).collect()
    }

    fn name(&self) -> &'static str {
        "straw2"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::conformance;

    #[test]
    fn conformance_basic() {
        conformance::basic(&Straw2);
    }

    #[test]
    fn conformance_balance() {
        conformance::balance(&Straw2);
    }

    #[test]
    fn conformance_minimal_movement() {
        conformance::minimal_movement(&Straw2, 0.04);
    }

    #[test]
    fn conformance_weighted() {
        conformance::weighted(&Straw2);
    }

    #[test]
    fn conformance_prop_distinct() {
        conformance::prop_distinct(&Straw2);
    }

    #[test]
    fn down_server_only_moves_its_own_keys() {
        use crate::cluster::ServerState;
        let before = ClusterMap::new(5);
        let mut after = before.clone();
        after.set_state(ServerId(2), ServerState::Down);
        for key in 0..2000u64 {
            let k = fnv1a64_pair(key, 1);
            let a = Straw2.select(&before, k, 1)[0];
            let b = Straw2.select(&after, k, 1)[0];
            if a != ServerId(2) {
                assert_eq!(a, b, "key not on the failed server moved");
            } else {
                assert_ne!(b, ServerId(2));
            }
        }
    }
}
