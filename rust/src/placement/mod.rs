//! Content-based data placement (the paper's §2.3).
//!
//! The paper passes the chunk's SHA-1 fingerprint to CRUSH so that (a) a
//! fingerprint lookup is a single message to one server, and (b) storage
//! rebalancing never stales the dedup metadata — the chunk's location is
//! recomputable from its content under the current map epoch.
//!
//! Two interchangeable policies are provided:
//!
//! * [`straw2`] — CRUSH's straw2 bucket selection (weighted, minimal
//!   movement on weight/membership change). The default, as in Ceph.
//! * [`rendezvous`] — highest-random-weight hashing, the ablation
//!   comparator for the placement-policy design choice in DESIGN.md.
//!
//! Keys are first folded onto a fixed ring of **placement groups**
//! ([`pg::PgMap`]); policies place PGs, and per-epoch PG→servers tables
//! are cached so the per-chunk hot path is one hash + one table lookup.

pub mod pg;
pub mod rendezvous;
pub mod straw2;

use crate::cluster::{ClusterMap, ServerId};

/// A placement policy maps (map, key, n) → ordered replica chain.
pub trait PlacementPolicy: Send + Sync {
    /// Select up to `n` distinct Up servers for `key`; the first entry is
    /// the primary. Fewer than `n` are returned if the map is too small.
    fn select(&self, map: &ClusterMap, key: u64, n: usize) -> Vec<ServerId>;

    /// Policy name (for configs / reports).
    fn name(&self) -> &'static str;
}

/// The default policy used by the cluster.
pub fn default_policy() -> Box<dyn PlacementPolicy> {
    Box::new(straw2::Straw2)
}

#[cfg(test)]
pub(crate) mod conformance {
    //! Properties every placement policy must satisfy.
    use super::*;
    use crate::cluster::ServerState;
    use crate::util::prop;

    /// Determinism + distinctness + up-only.
    pub fn basic(policy: &dyn PlacementPolicy) {
        let mut map = ClusterMap::new(6);
        map.set_state(ServerId(3), ServerState::Down);
        for key in 0..200u64 {
            let a = policy.select(&map, key, 3);
            let b = policy.select(&map, key, 3);
            assert_eq!(a, b, "non-deterministic at key {key}");
            assert_eq!(a.len(), 3);
            let mut uniq = a.clone();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "duplicate replicas at key {key}");
            assert!(!a.contains(&ServerId(3)), "placed on Down server");
        }
    }

    /// Load balance: over many keys, primary counts are within ±40% of
    /// fair share for equal weights.
    pub fn balance(policy: &dyn PlacementPolicy) {
        let map = ClusterMap::new(8);
        let mut counts = vec![0usize; 8];
        let keys = 20_000u64;
        for key in 0..keys {
            let sel = policy.select(&map, crate::hash::fnv::fnv1a64_pair(key, 99), 1);
            counts[sel[0].0 as usize] += 1;
        }
        let fair = keys as f64 / 8.0;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > fair * 0.6 && (c as f64) < fair * 1.4,
                "server {i} got {c} of fair {fair}"
            );
        }
    }

    /// Minimal movement: adding one server moves ≈ 1/(n+1) of keys.
    pub fn minimal_movement(policy: &dyn PlacementPolicy, tolerance: f64) {
        let map_before = ClusterMap::new(7);
        let mut map_after = map_before.clone();
        map_after.add_server(1.0);
        let keys = 20_000u64;
        let mut moved = 0usize;
        for key in 0..keys {
            let k = crate::hash::fnv::fnv1a64_pair(key, 7);
            let a = policy.select(&map_before, k, 1)[0];
            let b = policy.select(&map_after, k, 1)[0];
            if a != b {
                moved += 1;
                // anything that moves must move TO the new server
                assert_eq!(b, ServerId(7), "moved to an old server");
            }
        }
        let frac = moved as f64 / keys as f64;
        let expected = 1.0 / 8.0;
        assert!(
            (frac - expected).abs() < tolerance,
            "moved {frac:.3}, expected ~{expected:.3}"
        );
    }

    /// Weighted balance: a 2x-weight server gets ~2x the primaries.
    pub fn weighted(policy: &dyn PlacementPolicy) {
        let mut map = ClusterMap::new(4);
        map.set_weight(ServerId(0), 2.0);
        let keys = 30_000u64;
        let mut counts = vec![0usize; 4];
        for key in 0..keys {
            let sel = policy.select(&map, crate::hash::fnv::fnv1a64_pair(key, 3), 1);
            counts[sel[0].0 as usize] += 1;
        }
        let heavy = counts[0] as f64;
        let light = counts[1..].iter().sum::<usize>() as f64 / 3.0;
        let ratio = heavy / light;
        assert!(ratio > 1.6 && ratio < 2.4, "weight ratio {ratio}");
    }

    /// Property: replica chains never repeat a server, any map.
    pub fn prop_distinct(policy: &dyn PlacementPolicy) {
        prop::check(
            prop::Config { cases: 48, ..Default::default() },
            |rng, size| {
                let n = 1 + rng.below(9) as usize;
                let mut map = ClusterMap::new(n);
                // random downs
                for i in 0..n {
                    if rng.unit_f64() < 0.2 {
                        map.set_state(ServerId(i as u32), ServerState::Down);
                    }
                }
                let key = rng.next_u64();
                let r = 1 + (size as usize % 4);
                (map, key, r)
            },
            |(map, key, r)| {
                let sel = policy.select(map, *key, *r);
                let mut uniq = sel.clone();
                uniq.sort();
                uniq.dedup();
                if uniq.len() != sel.len() {
                    return Err("duplicate server in chain".into());
                }
                if sel.len() > map.up_count() {
                    return Err("selected more than up_count".into());
                }
                if sel.len() < (*r).min(map.up_count()) {
                    return Err("under-selected".into());
                }
                Ok(())
            },
        );
    }
}
