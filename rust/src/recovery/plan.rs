//! Recovery planning: which of this server's keys lost a copy when a
//! server left the map.
//!
//! Placement is a pure function of (map, key), so the set of keys a
//! departed server held is *recomputable* from lightweight metadata —
//! no data rescan, no central manifest. [`LossView`] reconstructs the
//! pre-failure placement table by cloning the current map with the lost
//! server restored to `Up` (straw2's minimal-movement property makes
//! this exact as long as no other membership change raced the failure;
//! a racing change simply widens the affected set the next job sees).
//! A key is **affected** iff its old chain contained the lost server —
//! or, for non-minimal policies, iff its chain changed at all.
//!
//! Two walks produce the work-list, both over indexed local state:
//!
//! * [`omap_plan`] — the replica store's `o:` (and no-dedup `obj:`)
//!   copies plus the local OMAP names: records whose primary was lost
//!   are adopted by (or pushed to) their new primary, and affected
//!   records are re-fanned-out to the new chain.
//! * [`chunk_plan`] — the local CIT plus the replica store's `c:`
//!   copies: every affected chunk this server now homes, prioritized by
//!   refcount (most-shared first — the largest blast-radius chunks heal
//!   first), plus entries that must be re-created because their old
//!   home died with them.

use crate::cluster::{ServerId, ServerState};
use crate::dedup::engine::DedupMode;
use crate::dedup::fingerprint::Fingerprint;
use crate::error::Result;
use crate::storage::osd::OsdShared;
use std::collections::HashSet;

/// Pre-failure placement view for one lost server (see module docs).
pub(crate) struct LossView {
    lost: ServerId,
    /// PG → replica chain under the reconstructed pre-failure map.
    old_table: Vec<Vec<ServerId>>,
}

impl LossView {
    /// Reconstruct placement as it was before `lost` left: the current
    /// map with `lost` forced back to `Up`.
    pub fn capture(sh: &OsdShared, lost: ServerId) -> Self {
        let mut old_map = sh.map.read().unwrap().clone();
        old_map.set_state(lost, ServerState::Up);
        LossView {
            lost,
            old_table: sh.pgmap.table_for(&old_map),
        }
    }

    /// The pre-failure replica chain for a placement key.
    pub fn old_chain(&self, sh: &OsdShared, key: u64) -> &[ServerId] {
        &self.old_table[sh.pgmap.pg_of(key) as usize]
    }

    /// Did this key lose a copy (or move) when the server left?
    pub fn affected(&self, sh: &OsdShared, key: u64) -> bool {
        let old = self.old_chain(sh, key);
        if old.contains(&self.lost) {
            return true;
        }
        // paranoia for non-minimal placement policies: any chain change
        // counts as affected, even without the lost member in it
        let new = sh.chunk_chain(key);
        old != new.as_slice()
    }
}

/// Stage-1 work-list: OMAP records (and no-dedup raw objects) to
/// re-home and re-fan-out.
#[derive(Default)]
pub(crate) struct OmapPlan {
    /// Records whose new primary is this server and whose OMAP entry is
    /// missing: (name, encoded record from the local replica copy).
    pub adopt: Vec<(String, Vec<u8>)>,
    /// Records whose new primary is another survivor: (primary, encoded
    /// record) — pushed with `RecoverOmap` (adopt-if-absent there).
    pub push: Vec<(ServerId, Vec<u8>)>,
    /// Locally-owned affected records whose replica copies must be
    /// re-fanned-out under the new chain.
    pub refan: Vec<String>,
    /// No-dedup raw objects to adopt into the local primary store:
    /// (store key, data from the local replica copy).
    pub raw_adopt: Vec<(Vec<u8>, Vec<u8>)>,
    /// Locally-primaried affected raw objects whose replica copies must
    /// be re-fanned-out under the new chain (store keys).
    pub raw_refan: Vec<Vec<u8>>,
}

/// Build the stage-1 (OMAP re-homing) work-list from the local replica
/// store and OMAP.
pub(crate) fn omap_plan(sh: &OsdShared, view: &LossView) -> Result<OmapPlan> {
    let mut plan = OmapPlan::default();
    if sh.cfg.dedup == DedupMode::Central {
        // central keeps every OMAP record on the metadata owner and
        // fans no copies out; there is nothing to re-home.
        return Ok(plan);
    }
    for key in sh.replica_store.keys()? {
        if let Some(name) = key.strip_prefix(b"o:").and_then(|n| std::str::from_utf8(n).ok()) {
            let pkey = crate::hash::fnv1a64(name.as_bytes());
            if !view.affected(sh, pkey) {
                continue;
            }
            let chain = sh.object_chain(name);
            let Some(primary) = chain.first().copied() else {
                continue;
            };
            let Some(value) = sh.replica_store.get(&key)? else {
                continue;
            };
            if primary == sh.id {
                if sh.shard.omap_get(name)?.is_none() {
                    plan.adopt.push((name.to_string(), value));
                }
            } else {
                plan.push.push((primary, value));
            }
        } else if let Some(name) = key
            .strip_prefix(b"obj:")
            .and_then(|n| std::str::from_utf8(n).ok())
        {
            let pkey = crate::hash::fnv1a64(name.as_bytes());
            if !view.affected(sh, pkey) {
                continue;
            }
            if sh.object_chain(name).first() == Some(&sh.id) && sh.store.get(&key)?.is_none() {
                if let Some(data) = sh.replica_store.get(&key)? {
                    plan.raw_adopt.push((key, data));
                }
            }
        }
    }
    for name in sh.shard.omap_names()? {
        if view.affected(sh, crate::hash::fnv1a64(name.as_bytes()))
            && sh.object_chain(&name).first() == Some(&sh.id)
        {
            plan.refan.push(name);
        }
    }
    if sh.cfg.dedup == DedupMode::None {
        // raw objects this server primaries whose replica set named the
        // lost server: their copies must be re-fanned-out like OMAP
        // records (there is no chunk phase to do it in this mode)
        for key in sh.store.keys()? {
            let Some(name) = key
                .strip_prefix(b"obj:")
                .and_then(|n| std::str::from_utf8(n).ok())
            else {
                continue;
            };
            if view.affected(sh, crate::hash::fnv1a64(name.as_bytes()))
                && sh.object_chain(name).first() == Some(&sh.id)
            {
                plan.raw_refan.push(key);
            }
        }
    }
    Ok(plan)
}

/// One chunk the stage-2 backfill must look at.
pub(crate) struct ChunkTask {
    /// Content fingerprint.
    pub fp: Fingerprint,
    /// Chunk length (CIT entry or surviving copy).
    pub len: u32,
    /// Refcount at plan time (0 for entries that must be re-created).
    pub refcount: u64,
    /// False when the entry died with its old home and must be
    /// re-created from a surviving copy before repair.
    pub have_entry: bool,
}

/// Build the stage-2 (chunk backfill) work-list: every affected chunk
/// this server is responsible for, most-referenced first.
pub(crate) fn chunk_plan(sh: &OsdShared, view: &LossView) -> Result<Vec<ChunkTask>> {
    let mut tasks: Vec<ChunkTask> = Vec::new();
    let mut seen: HashSet<Fingerprint> = HashSet::new();
    match sh.cfg.dedup {
        DedupMode::None => return Ok(tasks),
        DedupMode::ClusterWide | DedupMode::DiskLocal | DedupMode::Central => {}
    }
    for fp in sh.shard.cit_fingerprints()? {
        let Some(entry) = sh.shard.cit_get(&fp)? else {
            continue;
        };
        let key = fp.placement_key();
        if sh.cfg.dedup == DedupMode::ClusterWide && sh.chunk_chain(key).first() != Some(&sh.id) {
            continue; // the map moved this home; rebalance owns the move
        }
        if !view.affected(sh, key) {
            continue;
        }
        seen.insert(fp);
        tasks.push(ChunkTask {
            fp,
            len: entry.len,
            refcount: entry.refcount,
            have_entry: true,
        });
    }
    if sh.cfg.dedup == DedupMode::ClusterWide {
        // chunks whose CIT entry died with the lost home, known here
        // only through a surviving replica copy
        for key in sh.replica_store.keys()? {
            let Some(fp) = key.strip_prefix(b"c:").and_then(Fingerprint::from_bytes) else {
                continue;
            };
            if seen.contains(&fp) {
                continue;
            }
            let pkey = fp.placement_key();
            if sh.chunk_chain(pkey).first() != Some(&sh.id) || !view.affected(sh, pkey) {
                continue;
            }
            if sh.shard.cit_get(&fp)?.is_some() {
                continue; // created since the CIT walk (ensure phase)
            }
            let len = sh
                .replica_store
                .get(&key)?
                .map(|d| d.len() as u32)
                .unwrap_or(0);
            seen.insert(fp);
            tasks.push(ChunkTask {
                fp,
                len,
                refcount: 0,
                have_entry: false,
            });
        }
    }
    // most-shared chunks first: losing a copy of a high-refcount chunk
    // is the largest blast-radius event in a dedup cluster
    tasks.sort_by(|a, b| b.refcount.cmp(&a.refcount).then(a.fp.cmp(&b.fp)));
    Ok(tasks)
}
