//! Clock-driven failure detection over control-lane heartbeats.
//!
//! The cluster-level [`Detector`] probes every mapped server with a
//! [`Req::Ping`] on the control lane and keys its verdicts on the
//! fabric's crash semantics: a live lane answers within microseconds, a
//! killed/crashed lane *drops* the envelope (the sender observes a
//! disconnected reply channel — hard evidence of death), and a merely
//! busy lane simply hasn't answered yet (no evidence either way, so the
//! detector never punishes slowness with an out-transition).
//!
//! State machine per server, driven by the crate-internal `run_tick`:
//!
//! ```text
//!            ping fails, silent ≥ grace_ticks        silent ≥ out_ticks
//!   Up ────────────────────────────────────▶ Down ───────────────────▶ Out
//!    ▲                                        │                        │
//!    └────────── ping answers ────────────────┘          (sticky; fence + recovery)
//! ```
//!
//! **Quorum.** Each due probe round runs
//! [`FailureDetection::observers`] independent heartbeats and the
//! verdicts vote: the round only counts as evidence of death when at
//! least [`FailureDetection::out_quorum`] observers report a dropped
//! envelope. One flaky or lying observer (a bad control path, a
//! partitioned prober) can therefore never walk a healthy server down
//! the Down→Out path as long as `out_quorum ≥ 2` — a single dissenting
//! `Alive` answer is proof of life and resets the silence window. A
//! genuinely dead lane drops every observer's envelope, so the quorum is
//! met on the same tick it would have been without voting.
//!
//! *Silence* is measured from the last proof of life (`last_ok_ms`,
//! seeded at registration time), so a single large
//! [`crate::api::Cluster::advance_clock`] jump past `grace + out` marks a
//! dead server straight `Out` — exactly the deterministic acceptance
//! path — while a live server always re-proves itself on the same tick.
//! An out-transition is **sticky**: the server is fenced (killed, so a
//! fail-slow zombie can never serve stale state again), the map epoch
//! bumps, and every surviving server is told to start recovery backfill
//! ([`crate::recovery`]). Down is transient: a Down server whose
//! heartbeats resume is marked Up again.
//!
//! Ticks come from two sources, mirroring the maintenance scheduler: a
//! wall-clock thread (production) or `Cluster::advance_clock` (the
//! deterministic virtual-clock path). Both funnel through `run_tick`.

use crate::cluster::{Monitor, ServerId, ServerState};
use crate::error::Result;
use crate::metrics::Metrics;
use crate::net::Lane;
use crate::storage::osd::Osd;
use crate::storage::proto::{Dir, Req};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// Wall poll interval of the cluster-level detector thread (wall-clock
/// mode only; virtual-clock tests tick explicitly).
pub(crate) const DETECTOR_POLL: Duration = Duration::from_millis(10);

/// Wall-time bound on waiting for one heartbeat reply. Live lanes answer
/// in microseconds and dead lanes drop the envelope just as fast, so
/// this only bites when a lane is busy with a long control operation —
/// which yields the inconclusive verdict, never a death sentence.
const PING_WAIT: Duration = Duration::from_millis(20);

/// Failure-detection configuration
/// ([`crate::api::ClusterConfig::failure_detection`]). All windows are
/// clock ticks (ms of cluster time — wall or virtual).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FailureDetection {
    /// Heartbeat cadence: at most one probe per server per this many
    /// ticks.
    pub probe_every_ticks: u64,
    /// A server silent (failed probes) for at least this long is marked
    /// `Down` — placement skips it, degraded reads take over.
    pub grace_ticks: u64,
    /// A server silent for at least this long is marked `Out`: fenced,
    /// removed from placement, and recovery backfill re-replicates its
    /// data from surviving copies. Must be ≥ `grace_ticks`.
    pub out_ticks: u64,
    /// Independent heartbeat observers per probe round. Each runs its
    /// own ping; their verdicts vote (see the module docs).
    pub observers: u32,
    /// Dead votes required before a probe round counts as evidence of
    /// death. With `out_quorum ≥ 2` a single flaky observer can never
    /// evict a healthy server. Must be in `1..=observers`.
    pub out_quorum: u32,
}

impl Default for FailureDetection {
    fn default() -> Self {
        FailureDetection {
            probe_every_ticks: 250,
            grace_ticks: 1_000,
            out_ticks: 5_000,
            observers: 3,
            out_quorum: 2,
        }
    }
}

impl FailureDetection {
    /// Reject degenerate windows (zero grace, out shorter than grace)
    /// and unsatisfiable quorums (zero observers, quorum > observers).
    pub fn validate(&self) -> Result<()> {
        if self.probe_every_ticks == 0 || self.grace_ticks == 0 {
            return Err(crate::error::Error::Invalid(
                "failure_detection windows must be > 0".into(),
            ));
        }
        if self.out_ticks < self.grace_ticks {
            return Err(crate::error::Error::Invalid(
                "failure_detection out_ticks must be >= grace_ticks".into(),
            ));
        }
        if self.observers == 0 || self.out_quorum == 0 {
            return Err(crate::error::Error::Invalid(
                "failure_detection observers and out_quorum must be > 0".into(),
            ));
        }
        if self.out_quorum > self.observers {
            return Err(crate::error::Error::Invalid(
                "failure_detection out_quorum must be <= observers".into(),
            ));
        }
        Ok(())
    }
}

/// Per-server health bookkeeping.
struct Health {
    /// Last proof of life (registration or an answered heartbeat).
    last_ok_ms: u64,
    /// Last probe send time (cadence limiter).
    last_probe_ms: Option<u64>,
}

/// A fault-injection hook mapping one observer's raw heartbeat verdict
/// to the verdict the vote actually counts: `(observer index, probed
/// server, raw verdict) → counted verdict`. Tests use it to model a
/// lying or flaky observer without breaking a real control lane.
pub type ObserverHook =
    Box<dyn Fn(usize, ServerId, ObserverVerdict) -> ObserverVerdict + Send + Sync>;

/// Cluster-level failure detector state (one per cluster, shared by the
/// wall-clock thread and the virtual-clock tick path).
pub struct Detector {
    cfg: FailureDetection,
    inner: Mutex<HashMap<u32, Health>>,
    observer_hook: Mutex<Option<ObserverHook>>,
}

impl Detector {
    /// A detector with no servers registered yet.
    pub fn new(cfg: FailureDetection) -> Self {
        Detector {
            cfg,
            inner: Mutex::new(HashMap::new()),
            observer_hook: Mutex::new(None),
        }
    }

    /// The configured windows.
    pub fn config(&self) -> &FailureDetection {
        &self.cfg
    }

    /// Install (or with `None` remove) the per-observer fault-injection
    /// hook — see [`ObserverHook`].
    pub fn set_observer_hook(&self, hook: Option<ObserverHook>) {
        *self.observer_hook.lock().unwrap() = hook;
    }

    /// (Re-)register a server with a fresh proof of life at `now`.
    /// Called for every server at cluster boot, for servers added later,
    /// and on admin restart — a revived server must not be judged on the
    /// silence of its previous life.
    pub fn register(&self, id: ServerId, now: u64) {
        self.inner.lock().unwrap().insert(
            id.0,
            Health {
                last_ok_ms: now,
                last_probe_ms: None,
            },
        );
    }
}

/// One heartbeat observer's three-way verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObserverVerdict {
    /// The control lane answered: proof of life.
    Alive,
    /// The envelope was dropped without a reply: crash-semantics
    /// evidence of death.
    Dead,
    /// No answer within the wall bound (busy lane): no evidence.
    Unknown,
}

fn ping(dir: &Dir, id: ServerId) -> ObserverVerdict {
    let Ok(addr) = dir.lookup(id, Lane::Control) else {
        return ObserverVerdict::Dead; // deregistered: permanently gone
    };
    let req = Req::Ping;
    let size = req.wire_size();
    match addr.send(req, size) {
        Err(_) => ObserverVerdict::Dead,
        Ok(pending) => match pending.wait_for(PING_WAIT) {
            Ok(Some(_)) => ObserverVerdict::Alive,
            Ok(None) => ObserverVerdict::Unknown,
            Err(_) => ObserverVerdict::Dead,
        },
    }
}

/// Run one quorum probe round against `id`: every observer pings, the
/// hook (if any) rewrites each raw verdict, and the votes aggregate. A
/// round is `Dead` only when at least `out_quorum` observers saw a
/// dropped envelope; any surviving `Alive` answer below that bar is
/// proof of life; all-inconclusive stays inconclusive.
fn probe_round(det: &Detector, dir: &Dir, id: ServerId, metrics: &Metrics) -> ObserverVerdict {
    let hook = det.observer_hook.lock().unwrap();
    let mut alive = 0u32;
    let mut dead = 0u32;
    for observer in 0..det.cfg.observers {
        Metrics::add(&metrics.detector_probes, 1);
        let mut verdict = ping(dir, id);
        if let Some(h) = hook.as_ref() {
            verdict = h(observer as usize, id, verdict);
        }
        match verdict {
            ObserverVerdict::Alive => alive += 1,
            ObserverVerdict::Dead => dead += 1,
            ObserverVerdict::Unknown => {}
        }
    }
    if dead >= det.cfg.out_quorum {
        ObserverVerdict::Dead
    } else if alive > 0 {
        ObserverVerdict::Alive
    } else {
        ObserverVerdict::Unknown
    }
}

/// One detector evaluation at time `now`: probe due servers, apply the
/// Down/Out state machine, fence new Out servers and fan recovery
/// backfill out to the survivors. Called from
/// [`crate::api::Cluster::advance_clock`] (virtual clock) and from the
/// cluster's detector thread (wall clock); all sends are bounded-wait or
/// fire-and-forget, so a busy control lane can never stall the caller's
/// clock.
pub(crate) fn run_tick(
    det: &Detector,
    monitor: &Monitor,
    dir: &Dir,
    osds: &Mutex<HashMap<ServerId, Osd>>,
    metrics: &Metrics,
    now: u64,
) {
    let map = monitor.map();
    let mut outs: Vec<ServerId> = Vec::new();
    for s in &map.servers {
        if s.state == ServerState::Out {
            continue; // sticky: an out server is never probed again
        }
        let (due, last_ok) = {
            let mut g = det.inner.lock().unwrap();
            let h = g.entry(s.id.0).or_insert_with(|| Health {
                last_ok_ms: now,
                last_probe_ms: None,
            });
            let due = match h.last_probe_ms {
                Some(t) => now >= t + det.cfg.probe_every_ticks,
                None => true,
            };
            if due {
                h.last_probe_ms = Some(now);
            }
            (due, h.last_ok_ms)
        };
        if !due {
            continue;
        }
        let verdict = probe_round(det, dir, s.id, metrics);
        // Transitions are decided against a *fresh* state read, not the
        // snapshot the probe loop iterates (the probe itself waits up to
        // PING_WAIT, and an admin remove_server may have marked the
        // server Out meanwhile): an Out server is never transitioned
        // away from — un-fencing a removed server would let its stale
        // state back into the cluster.
        let fresh = monitor.map().server(s.id).map(|i| i.state);
        if fresh.is_none() || fresh == Some(ServerState::Out) {
            continue;
        }
        match verdict {
            ObserverVerdict::Alive => {
                det.inner.lock().unwrap().get_mut(&s.id.0).unwrap().last_ok_ms = now;
                if fresh == Some(ServerState::Down) {
                    // heartbeats resumed: transient failure over
                    let _ = monitor.mark_up(s.id);
                    Metrics::add(&metrics.detector_marked_up, 1);
                }
            }
            ObserverVerdict::Unknown => {}
            ObserverVerdict::Dead => {
                let silent = now.saturating_sub(last_ok);
                if silent >= det.cfg.out_ticks {
                    let _ = monitor.mark_out(s.id);
                    Metrics::add(&metrics.detector_marked_out, 1);
                    outs.push(s.id);
                } else if silent >= det.cfg.grace_ticks && fresh == Some(ServerState::Up) {
                    let _ = monitor.mark_down(s.id);
                    Metrics::add(&metrics.detector_marked_down, 1);
                }
            }
        }
    }
    for lost in outs {
        // Fence: the server may be fail-slow rather than dead; once its
        // data is re-homed it must never serve stale state again.
        if let Some(osd) = osds.lock().unwrap().get(&lost) {
            osd.kill();
        }
        trigger_recovery(monitor, dir, lost);
        // the out-transition changed the map: survivors whose PGs
        // re-primaried must migrate, same as any other map change
        crate::membership::auto_rebalance(monitor, dir, metrics);
    }
}

/// Tell every Up server to start recovery backfill for `lost`
/// (fire-and-forget: the handler only enqueues on the recovery worker).
pub(crate) fn trigger_recovery(monitor: &Monitor, dir: &Dir, lost: ServerId) {
    let map = monitor.map();
    for s in map.servers.iter().filter(|s| s.state == ServerState::Up) {
        if let Ok(addr) = dir.lookup(s.id, Lane::Control) {
            let req = Req::StartRecovery { lost: lost.0 };
            let size = req.wire_size();
            let _ = addr.send(req, size);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        assert!(FailureDetection::default().validate().is_ok());
        assert!(FailureDetection {
            probe_every_ticks: 0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(FailureDetection {
            grace_ticks: 100,
            out_ticks: 50,
            probe_every_ticks: 10,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(FailureDetection {
            observers: 0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(FailureDetection {
            out_quorum: 0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(FailureDetection {
            observers: 2,
            out_quorum: 3,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(FailureDetection {
            observers: 1,
            out_quorum: 1,
            ..Default::default()
        }
        .validate()
        .is_ok());
    }

    #[test]
    fn registration_seeds_proof_of_life() {
        let det = Detector::new(FailureDetection::default());
        det.register(ServerId(3), 42);
        let g = det.inner.lock().unwrap();
        assert_eq!(g.get(&3).unwrap().last_ok_ms, 42);
        assert!(g.get(&3).unwrap().last_probe_ms.is_none());
    }
}
