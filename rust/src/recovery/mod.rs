//! Autonomous failure detection & dedup-aware recovery backfill.
//!
//! The paper's robustness story so far was *reactive and manual*:
//! `kill_server` left the map untouched, `ServerState::Out` existed but
//! nothing drove it, and a chunk that lost a replica stayed degraded
//! until a deep scrub happened to walk over it. This module closes the
//! loop — **detect → mark out → re-replicate** — with no operator in
//! it:
//!
//! * **Detection** ([`detector`]) — the cluster-level [`Detector`]
//!   heartbeats every server over the control lane ([`Req::Ping`]),
//!   marks a silent server `Down` after `grace_ticks` and `Out` after
//!   `out_ticks`, fences the out server and bumps the map epoch so
//!   placement and degraded reads react. Fully deterministic under
//!   [`crate::api::Cluster::advance_clock`].
//! * **Planning** (`plan.rs`) — on any out-transition (or an explicit
//!   [`crate::api::Cluster::remove_server`]), every surviving server
//!   recomputes, from its own CIT / backreference index / replica
//!   store, exactly which chunks and OMAP records had the lost server
//!   in their placement chain. No data rescan: placement is a pure
//!   function of (map, key), so the affected set falls out of
//!   lightweight metadata.
//! * **Backfill** (this file) — a per-server **recovery worker** thread
//!   (a pure client of the lane graph, like the scrub worker) executes
//!   the plan in two stages. Stage 1 re-homes OMAP records: the new
//!   primary adopts the record from a surviving replica copy
//!   (adopt-if-absent, so a racing fresh write always wins) and
//!   re-fans-out copies under the new chain. After a cluster-wide
//!   **ensure barrier** — each worker waits (bounded) until every
//!   surviving peer has finished stage 1, so every referenced
//!   fingerprint has a CIT entry at its new home — stage 2 walks the
//!   chunk work-list **most-referenced first**: restore the primary
//!   from any surviving copy, re-synchronize the refcount (the scrub
//!   reconcile's double-read + CAS), and re-push replica copies until
//!   the chain is back at `cfg.replication`.
//!
//! **Flow control & backpressure** — every scanned entry and
//! re-replicated byte is charged to [`MaintClass::Recovery`] in the
//! shared per-server budget, and replica-presence probes honor the
//! `VerifyCopy` gate's [`Resp::Busy`] NACKs with backoff — recovery
//! competes politely with foreground I/O and the other maintenance
//! classes.
//!
//! **Crash consistency** — the flag-based argument extends to recovery
//! writes: [`CrashPoint::BeforeRecoveryCopy`] dies before anything
//! lands (the degradation persists; a re-queued job heals it), and
//! [`CrashPoint::AfterRecoveryCopy`] dies between the data write and
//! the flag flip / remaining pushes — the stored-but-invalid state GC
//! and scrub already know how to re-validate or reclaim. A crashed
//! worker's job is volatile; [`crate::api::Cluster::restart_server`]
//! re-queues recovery for every `Out` server in the map.

pub mod detector;
mod plan;

pub use self::detector::{Detector, FailureDetection, ObserverHook, ObserverVerdict};

use crate::cluster::{ServerId, ServerState};
use crate::dedup::cit::CommitFlag;
use crate::dedup::engine::{chunk_copy_key, omap_copy_key, DedupMode};
use crate::dedup::fingerprint::Fingerprint;
use crate::dedup::omap::OmapEntry;
use crate::error::{Error, Result};
use crate::failure::CrashPoint;
use crate::metrics::Metrics;
use crate::net::Lane;
use crate::sched::flow::MaintClass;
use crate::scrub::{self, ReconcileVerdict};
use crate::storage::osd::OsdShared;
use crate::storage::proto::{Req, Resp};
use self::plan::{ChunkTask, LossView};
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Worker poll interval for new jobs / shutdown.
const POLL: Duration = Duration::from_millis(50);
/// Byte-equivalent cost charged per scanned work item.
const ITEM_COST: u64 = 64;
/// Refcount-reconcile window (entries per batched `CountRefs` round).
const RECONCILE_WINDOW: usize = 256;
/// Wall bound on the cluster-wide ensure barrier. Dead peers are
/// skipped instantly (their probes answer `ServerDown`), a live peer
/// answering "not yet" is making progress toward its ensure stage, so
/// this cap only bites when a live peer's job *failed* before marking —
/// generous, because giving up early risks walking the CIT before
/// peers re-created entries in it; residual gaps then fall to the next
/// scrub's ensure phase.
const BARRIER_WAIT: Duration = Duration::from_secs(30);
/// Poll interval while waiting on the ensure barrier.
const BARRIER_POLL: Duration = Duration::from_millis(5);
/// Retry budget per `Busy`-NACKed replica-presence probe.
const PROBE_MAX_ATTEMPTS: u32 = 100;
/// Base wall backoff after a `Busy` NACK (doubles per attempt, capped).
const PROBE_BACKOFF_BASE_US: u64 = 200;

/// Lifecycle of a server's recovery job.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum RecoveryState {
    /// No recovery has run since boot (or the last crash wiped it).
    #[default]
    Idle,
    /// A job is queued, waiting for the worker thread.
    Queued,
    /// The backfill is in progress.
    Running,
    /// The last job completed.
    Done,
    /// The last job aborted (server died mid-pass, or an I/O error).
    Failed(String),
}

/// One server's recovery progress snapshot.
#[derive(Clone, Debug, Default)]
pub struct RecoveryStatus {
    /// Server id.
    pub server: u32,
    /// Job lifecycle state.
    pub state: RecoveryState,
    /// The lost server the current/last job recovers from.
    pub lost: Option<u32>,
    /// Jobs still queued behind the current one.
    pub queued: usize,
    /// Work items examined (CIT entries + re-created entries).
    pub chunks_scanned: u64,
    /// Primary chunks (and no-dedup objects) restored from a surviving
    /// copy.
    pub chunks_restored: u64,
    /// Replica copies (chunk + OMAP record) re-pushed.
    pub copies_pushed: u64,
    /// Bytes re-replicated by this job.
    pub bytes_recovered: u64,
    /// OMAP records adopted onto this server as their new primary.
    pub omap_recovered: u64,
    /// CIT refcounts re-synchronized by the reconcile step.
    pub refs_fixed: u64,
    /// Referenced chunks with no surviving copy anywhere (quarantined).
    pub lost_chunks: u64,
    /// Job start (ms since cluster start).
    pub started_ms: u64,
    /// Job end (ms since cluster start; 0 while running).
    pub finished_ms: u64,
}

#[derive(Default)]
struct CtlInner {
    queue: VecDeque<u32>,
    ensured: HashSet<u32>,
    status: RecoveryStatus,
}

/// Per-server recovery control block: job queue, ensure-barrier flags
/// and the externally visible status. Volatile — a crash drops queued
/// jobs and aborts the running one ([`crate::api::Cluster::restart_server`]
/// re-queues recovery for every `Out` server).
#[derive(Default)]
pub struct RecoveryCtl {
    inner: Mutex<CtlInner>,
    cv: Condvar,
}

impl RecoveryCtl {
    /// Idle control block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Idle control block that already knows its server id.
    pub fn for_server(server: u32) -> Self {
        let ctl = Self::default();
        ctl.inner.lock().unwrap().status.server = server;
        ctl
    }

    /// Queue a recovery job for `lost` (idempotent against the pending
    /// queue — duplicate triggers for the same failure collapse).
    pub fn enqueue(&self, lost: u32) {
        let mut g = self.inner.lock().unwrap();
        if !g.queue.contains(&lost) {
            g.queue.push_back(lost);
        }
        if !matches!(g.status.state, RecoveryState::Running) {
            g.status.state = RecoveryState::Queued;
        }
        self.cv.notify_one();
    }

    /// Current status snapshot (with the live queue depth).
    pub fn status(&self) -> RecoveryStatus {
        let g = self.inner.lock().unwrap();
        let mut st = g.status.clone();
        st.queued = g.queue.len();
        st
    }

    /// Has this server completed the OMAP + ensure stage for a job
    /// recovering `lost`? The ensure effects are durable, so a finished
    /// job keeps answering true — peers barrier on exactly this.
    pub fn is_ensured(&self, lost: u32) -> bool {
        self.inner.lock().unwrap().ensured.contains(&lost)
    }

    fn mark_ensured(&self, lost: u32) {
        self.inner.lock().unwrap().ensured.insert(lost);
    }

    fn take_job(&self, timeout: Duration) -> Option<u32> {
        let mut g = self.inner.lock().unwrap();
        if g.queue.is_empty() {
            g = self.cv.wait_timeout(g, timeout).unwrap().0;
        }
        g.queue.pop_front()
    }

    fn update(&self, f: impl FnOnce(&mut RecoveryStatus)) {
        f(&mut self.inner.lock().unwrap().status);
    }

    /// Crash semantics (called from `Osd::kill`): queued jobs and the
    /// barrier memory are volatile and die with the process.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.queue.clear();
        g.ensured.clear();
        if matches!(g.status.state, RecoveryState::Queued | RecoveryState::Running) {
            g.status = RecoveryStatus {
                server: g.status.server,
                state: RecoveryState::Failed("server crashed".into()),
                ..Default::default()
            };
        }
    }
}

/// The per-server recovery worker thread body (spawned by
/// [`crate::storage::osd::Osd::spawn`]). Waits for queued jobs and runs
/// one full backfill per job.
pub fn recovery_loop(sh: Arc<OsdShared>, sd: Arc<AtomicBool>) {
    while !sd.load(Ordering::SeqCst) {
        let Some(lost) = sh.recovery.take_job(POLL) else {
            continue;
        };
        if sh.injector.is_dead() {
            continue; // the kill-time clear() already failed the status
        }
        let started = sh.now_ms();
        sh.recovery.update(|st| {
            *st = RecoveryStatus {
                server: sh.id.0,
                state: RecoveryState::Running,
                lost: Some(lost),
                started_ms: started,
                ..Default::default()
            };
        });
        Metrics::add(&sh.metrics.recovery_runs, 1);
        let outcome = run_recovery(&sh, ServerId(lost));
        let finished = sh.now_ms();
        sh.recovery.update(|st| {
            st.finished_ms = finished;
            st.state = match &outcome {
                Ok(()) => RecoveryState::Done,
                Err(e) => RecoveryState::Failed(e.to_string()),
            };
        });
    }
}

/// A killed/crashed server must stop recovering at once (checked per
/// item, matching the lanes' crash model).
fn ensure_alive(sh: &OsdShared) -> Result<()> {
    if sh.injector.is_dead() {
        Err(Error::ServerDown(sh.id.0))
    } else {
        Ok(())
    }
}

/// One full backfill for the departure of `lost` (see module docs).
fn run_recovery(sh: &OsdShared, lost: ServerId) -> Result<()> {
    let view = LossView::capture(sh, lost);
    let epoch0 = sh.map.read().unwrap().epoch;

    // ---- stage 1: re-home OMAP records, then ensure CIT entries ----
    let stage1 = Instant::now();
    recover_omap_records(sh, &view)?;
    ensure_affected(sh, &view)?;
    sh.recovery.mark_ensured(lost.0);
    barrier_wait(sh, lost)?;
    sh.metrics.recovery_stage_latency.record(stage1.elapsed());

    // ---- stage 2: chunk backfill, most-referenced first ----
    let stage2 = Instant::now();
    let tasks = plan::chunk_plan(sh, &view)?;
    for window in tasks.chunks(RECONCILE_WINDOW) {
        let mut fps: Vec<Fingerprint> = Vec::with_capacity(window.len());
        for task in window {
            ensure_alive(sh)?;
            sh.charge_maint(MaintClass::Recovery, ITEM_COST);
            sh.recovery.update(|st| st.chunks_scanned += 1);
            Metrics::add(&sh.metrics.recovery_chunks_scanned, 1);
            if sh.cfg.dedup == DedupMode::Central
                && sh.chunk_chain(task.fp.placement_key()).first() != Some(&sh.id)
            {
                central_restore(sh, task)?;
            } else {
                if !task.have_entry {
                    scrub::ensure_cit_local(sh, &task.fp, task.len)?;
                }
                restore_primary(sh, task)?;
                re_replicate(sh, task)?;
            }
            fps.push(task.fp);
        }
        if sh.cfg.dedup != DedupMode::None && !fps.is_empty() {
            // same double-read + CAS reconcile the scrub light pass uses
            // (counts exclude Out servers — their references left scope)
            if let ReconcileVerdict::Done { fixed } = scrub::reconcile_refcounts(sh, epoch0, &fps)?
            {
                sh.recovery.update(|st| st.refs_fixed += fixed);
                Metrics::add(&sh.metrics.recovery_refs_fixed, fixed);
            }
        }
    }
    sh.metrics.recovery_stage_latency.record(stage2.elapsed());
    Ok(())
}

/// Stage 1a: adopt / push / re-fan-out OMAP records (and no-dedup raw
/// objects) whose chain included the lost server.
fn recover_omap_records(sh: &OsdShared, view: &LossView) -> Result<()> {
    let plan = plan::omap_plan(sh, view)?;
    let mut refan: HashSet<String> = plan.refan.into_iter().collect();

    for (name, value) in plan.adopt {
        ensure_alive(sh)?;
        sh.charge_maint(MaintClass::Recovery, (value.len() as u64).max(ITEM_COST));
        let entry = OmapEntry::decode(&value)?;
        sh.charge_meta_io();
        if let Some(delta) = sh.shard.omap_put_if_absent(&entry)? {
            Metrics::add(&sh.metrics.backref_updates, delta.total());
            Metrics::add(&sh.metrics.recovery_omap_recovered, 1);
            Metrics::add(&sh.metrics.recovery_bytes, value.len() as u64);
            sh.recovery.update(|st| {
                st.omap_recovered += 1;
                st.bytes_recovered += value.len() as u64;
            });
        }
        refan.insert(name);
    }

    for (key, data) in plan.raw_adopt {
        ensure_alive(sh)?;
        sh.charge_maint(MaintClass::Recovery, (data.len() as u64).max(ITEM_COST));
        if sh.injector.maybe_crash(CrashPoint::BeforeRecoveryCopy) {
            return Err(Error::ServerDown(sh.id.0));
        }
        sh.store.put(&key, &data)?;
        Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
        if sh.injector.maybe_crash(CrashPoint::AfterRecoveryCopy) {
            return Err(Error::ServerDown(sh.id.0));
        }
        Metrics::add(&sh.metrics.recovery_chunks_restored, 1);
        Metrics::add(&sh.metrics.recovery_bytes, data.len() as u64);
        sh.recovery.update(|st| {
            st.chunks_restored += 1;
            st.bytes_recovered += data.len() as u64;
        });
        let name = String::from_utf8_lossy(&key[4..]).to_string();
        for peer in replica_slots(sh, &sh.object_chain(&name), sh.cfg.replication) {
            push_copy(sh, peer, key.clone(), &data)?;
        }
    }

    for key in plan.raw_refan {
        ensure_alive(sh)?;
        let Some(data) = sh.store.get(&key)? else {
            continue;
        };
        let name = String::from_utf8_lossy(&key[4..]).to_string();
        for peer in replica_slots(sh, &sh.object_chain(&name), sh.cfg.replication) {
            push_copy(sh, peer, key.clone(), &data)?;
        }
    }

    for (target, value) in plan.push {
        ensure_alive(sh)?;
        sh.charge_maint(MaintClass::Recovery, (value.len() as u64).max(ITEM_COST));
        let Ok(addr) = sh.dir.lookup(target, Lane::Backend) else {
            continue; // dead target: its own restart re-converges
        };
        let req = Req::RecoverOmap { value };
        let size = req.wire_size();
        let _ = addr.call(req, size); // best-effort; next pass settles
    }

    for name in refan {
        ensure_alive(sh)?;
        let Some(entry) = sh.shard.omap_get(&name)? else {
            continue;
        };
        let value = entry.encode();
        for peer in replica_slots(sh, &sh.object_chain(&name), sh.cfg.replication) {
            push_copy(sh, peer, omap_copy_key(&name), &value)?;
        }
    }
    Ok(())
}

/// Stage 1b: every *affected* fingerprint referenced by the local OMAP
/// gets a CIT entry at its (new) home — the scrub ensure phase filtered
/// to the loss's blast radius.
fn ensure_affected(sh: &OsdShared, view: &LossView) -> Result<()> {
    if sh.cfg.dedup == DedupMode::None {
        return Ok(());
    }
    for (fp, len) in sh.shard.backref_referenced()? {
        ensure_alive(sh)?;
        if !view.affected(sh, fp.placement_key()) {
            continue;
        }
        let home = match sh.cfg.dedup {
            DedupMode::ClusterWide => match sh.chunk_chain(fp.placement_key()).first() {
                Some(id) => *id,
                None => continue,
            },
            DedupMode::DiskLocal | DedupMode::Central => sh.id,
            DedupMode::None => continue,
        };
        if home == sh.id {
            scrub::ensure_cit_local(sh, &fp, len)?;
            continue;
        }
        let Ok(addr) = sh.dir.lookup(home, Lane::Backend) else {
            continue;
        };
        let req = Req::EnsureCit { fp, len };
        let size = req.wire_size();
        match addr.call(req, size) {
            Ok(_) => {}
            Err(Error::ServerDown(_)) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Bounded wait until every surviving peer reports its ensure stage
/// done for this job, so the stage-2 CIT walk sees every entry peers
/// re-created here. A peer that never answers (dead, or its trigger
/// never arrived) cannot stall recovery — the next scrub's ensure phase
/// closes any residual gap.
fn barrier_wait(sh: &OsdShared, lost: ServerId) -> Result<()> {
    let deadline = Instant::now() + BARRIER_WAIT;
    loop {
        ensure_alive(sh)?;
        let peers: Vec<ServerId> = sh
            .map
            .read()
            .unwrap()
            .servers
            .iter()
            .filter(|s| s.state == ServerState::Up && s.id != sh.id && s.id != lost)
            .map(|s| s.id)
            .collect();
        let mut all = true;
        for peer in peers {
            let Ok(addr) = sh.dir.lookup(peer, Lane::Control) else {
                continue;
            };
            let req = Req::RecoveryProbe { lost: lost.0 };
            let size = req.wire_size();
            match addr.call(req, size) {
                Ok(Resp::RecoveryAck { ensure_done }) => {
                    if !ensure_done {
                        all = false;
                    }
                }
                _ => {} // dead / unreachable peer: skipped
            }
        }
        if all || Instant::now() >= deadline {
            return Ok(());
        }
        std::thread::sleep(BARRIER_POLL);
    }
}

/// The replica slots of a chain under the given copy count (`copies`
/// total including the primary), excluding ourselves. Object records
/// (OMAP, raw) always heal to the flat `replication` factor; chunk
/// healing passes the refcount-banded target instead
/// ([`OsdShared::redundancy_target`]).
fn replica_slots(sh: &OsdShared, chain: &[ServerId], copies: usize) -> Vec<ServerId> {
    chain
        .iter()
        .skip(1)
        .take(copies.saturating_sub(1))
        .filter(|id| **id != sh.id)
        .copied()
        .collect()
}

/// Restore a missing primary chunk from any surviving copy; quarantine
/// (invalid flag) when none exists anywhere.
fn restore_primary(sh: &OsdShared, task: &ChunkTask) -> Result<()> {
    let key = task.fp.to_bytes();
    if sh.store.stat(&key)? {
        return Ok(());
    }
    let (good, from_self) = match own_copy(sh, &task.fp)? {
        Some(d) => (Some(d), true),
        None => (fetch_any_copy(sh, &task.fp)?, false),
    };
    let Some(data) = good else {
        // no surviving copy anywhere: never leave a valid flag pointing
        // at missing data (the audit invariant)
        sh.charge_meta_io();
        sh.shard
            .cit_set_flag(&task.fp, CommitFlag::Invalid, sh.now_ms())?;
        crate::dedup::engine::invalidate_chunk(sh, &task.fp);
        if task.refcount > 0 {
            sh.recovery.update(|st| st.lost_chunks += 1);
            Metrics::add(&sh.metrics.recovery_lost, 1);
        }
        return Ok(());
    };
    if sh.injector.maybe_crash(CrashPoint::BeforeRecoveryCopy) {
        return Err(Error::ServerDown(sh.id.0));
    }
    // coherence: this server just became (or re-became) the chunk's
    // home — drop any cached payload before the re-homed write
    crate::dedup::engine::invalidate_chunk(sh, &task.fp);
    sh.store.put(&key, &data)?;
    Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
    if sh.injector.maybe_crash(CrashPoint::AfterRecoveryCopy) {
        return Err(Error::ServerDown(sh.id.0));
    }
    sh.charge_meta_io();
    let flag = if crate::dedup::fpipe::is_pending(&task.fp) {
        // a pending identity stays pending: its strong digest is still
        // unresolved, so recovery must not admit it to the dedup domain
        // — put it back on the migration queue instead
        sh.fpipe.enqueue(task.fp);
        CommitFlag::Pending
    } else {
        CommitFlag::Valid
    };
    sh.shard.cit_set_flag(&task.fp, flag, sh.now_ms())?;
    sh.charge_maint(MaintClass::Recovery, data.len() as u64);
    sh.recovery.update(|st| {
        st.chunks_restored += 1;
        st.bytes_recovered += data.len() as u64;
    });
    Metrics::add(&sh.metrics.recovery_chunks_restored, 1);
    Metrics::add(&sh.metrics.recovery_bytes, data.len() as u64);
    if from_self {
        // we were a replica holder and are the primary now: the local
        // copy slot is no longer on the chain — drop the orphan
        sh.replica_store.delete(&chunk_copy_key(&task.fp))?;
    }
    Ok(())
}

/// Verdict of one replica-presence probe.
enum Probe {
    /// The peer holds a digest-matching copy.
    Healthy,
    /// The peer is missing the copy (or holds rot): push one.
    NeedPush,
    /// The peer is unreachable (dead): nothing to fix right now.
    Unreachable,
    /// The probe retry budget ran out under sustained backpressure;
    /// left for the next scrub pass.
    GaveUp,
}

/// Probe one peer for a digest-matching replica copy, honoring the
/// replica lane's `Busy` backpressure gate with backoff.
fn probe_copy(sh: &OsdShared, peer: ServerId, fp: &Fingerprint) -> Probe {
    let Ok(addr) = sh.dir.lookup(peer, Lane::Replica) else {
        return Probe::Unreachable;
    };
    let mut attempts = 0u32;
    loop {
        let req = Req::VerifyCopy {
            key: chunk_copy_key(fp),
            fp: *fp,
        };
        let size = req.wire_size();
        match addr.call(req, size) {
            Ok(Resp::CopyState { present, matches }) => {
                return if present && matches {
                    Probe::Healthy
                } else {
                    Probe::NeedPush
                };
            }
            Ok(Resp::Busy) => {
                attempts += 1;
                if attempts >= PROBE_MAX_ATTEMPTS {
                    Metrics::add(&sh.metrics.backpressure_gave_up, 1);
                    return Probe::GaveUp;
                }
                Metrics::add(&sh.metrics.backpressure_retries, 1);
                std::thread::sleep(Duration::from_micros(
                    PROBE_BACKOFF_BASE_US << attempts.min(6),
                ));
            }
            Ok(_) | Err(_) => return Probe::Unreachable,
        }
    }
}

/// Push one replica copy to a peer, bracketed by the recovery crash
/// points and charged to the recovery budget.
fn push_copy(sh: &OsdShared, peer: ServerId, key: Vec<u8>, data: &[u8]) -> Result<bool> {
    if sh.injector.maybe_crash(CrashPoint::BeforeRecoveryCopy) {
        return Err(Error::ServerDown(sh.id.0));
    }
    let Ok(addr) = sh.dir.lookup(peer, Lane::Replica) else {
        return Ok(false);
    };
    sh.charge_maint(MaintClass::Recovery, (data.len() as u64).max(ITEM_COST));
    let req = Req::PutCopy {
        key,
        data: data.to_vec(),
    };
    let size = req.wire_size();
    let pushed = matches!(addr.call(req, size), Ok(Resp::Ok));
    if sh.injector.maybe_crash(CrashPoint::AfterRecoveryCopy) {
        return Err(Error::ServerDown(sh.id.0));
    }
    if pushed {
        sh.recovery.update(|st| {
            st.copies_pushed += 1;
            st.bytes_recovered += data.len() as u64;
        });
        Metrics::add(&sh.metrics.recovery_copies_pushed, 1);
        Metrics::add(&sh.metrics.recovery_bytes, data.len() as u64);
    }
    Ok(pushed)
}

/// Re-push replica copies for one chunk until its chain is back at the
/// chunk's banded copy target (the redundancy policy applied to the
/// refcount the plan recorded — the work list is refcount-descending,
/// so the highest bands heal first).
fn re_replicate(sh: &OsdShared, task: &ChunkTask) -> Result<()> {
    if sh.cfg.dedup == DedupMode::Central {
        return Ok(()); // central fans no copies out
    }
    let target = sh.redundancy_target(task.refcount);
    if target <= 1 {
        return Ok(());
    }
    let chain = sh.chunk_chain(task.fp.placement_key());
    let mut data: Option<Vec<u8>> = None;
    for peer in replica_slots(sh, &chain, target) {
        ensure_alive(sh)?;
        match probe_copy(sh, peer, &task.fp) {
            Probe::Healthy | Probe::Unreachable | Probe::GaveUp => {}
            Probe::NeedPush => {
                if data.is_none() {
                    data = sh.store.get(&task.fp.to_bytes())?;
                }
                let Some(d) = &data else {
                    return Ok(()); // primary unrecoverable: quarantined
                };
                push_copy(sh, peer, chunk_copy_key(&task.fp), d)?;
            }
        }
    }
    Ok(())
}

/// Central-mode restore: the metadata owner re-checks a raw chunk on its
/// (possibly new) data home and re-ships surviving bytes there.
fn central_restore(sh: &OsdShared, task: &ChunkTask) -> Result<()> {
    let chain = sh.chunk_chain(task.fp.placement_key());
    let Some(home) = chain.first().copied() else {
        return Ok(());
    };
    let Ok(addr) = sh.dir.lookup(home, Lane::Backend) else {
        return Ok(()); // dead home: nothing to restore onto yet
    };
    let req = Req::StatChunk { fp: task.fp };
    let size = req.wire_size();
    match addr.call(req, size) {
        Ok(Resp::ChunkStat {
            exists_data: true, ..
        }) => return Ok(()),
        Ok(Resp::ChunkStat { .. }) => {}
        _ => return Ok(()),
    }
    match fetch_any_copy(sh, &task.fp)? {
        Some(data) => {
            if sh.injector.maybe_crash(CrashPoint::BeforeRecoveryCopy) {
                return Err(Error::ServerDown(sh.id.0));
            }
            sh.charge_maint(MaintClass::Recovery, data.len() as u64);
            let req = Req::StoreRaw {
                key: task.fp.to_bytes().to_vec(),
                data: data.clone(),
            };
            let size = req.wire_size();
            let stored = matches!(addr.call(req, size), Ok(Resp::Ok));
            if sh.injector.maybe_crash(CrashPoint::AfterRecoveryCopy) {
                return Err(Error::ServerDown(sh.id.0));
            }
            if stored {
                sh.recovery.update(|st| {
                    st.chunks_restored += 1;
                    st.bytes_recovered += data.len() as u64;
                });
                Metrics::add(&sh.metrics.recovery_chunks_restored, 1);
                Metrics::add(&sh.metrics.recovery_bytes, data.len() as u64);
            }
        }
        None => {
            // central replicates nothing; data on a lost home is gone —
            // quarantine so reads fail loudly instead of serving holes
            sh.charge_meta_io();
            sh.shard
                .cit_set_flag(&task.fp, CommitFlag::Invalid, sh.now_ms())?;
            crate::dedup::engine::invalidate_chunk(sh, &task.fp);
            if task.refcount > 0 {
                sh.recovery.update(|st| st.lost_chunks += 1);
                Metrics::add(&sh.metrics.recovery_lost, 1);
            }
        }
    }
    Ok(())
}

/// Our own replica slot for a chunk, content-verified (strong digest,
/// or the weak identity for a pending chunk — see
/// [`crate::dedup::fpipe::chunk_matches`]).
fn own_copy(sh: &OsdShared, fp: &Fingerprint) -> Result<Option<Vec<u8>>> {
    Ok(sh
        .replica_store
        .get(&chunk_copy_key(fp))?
        .filter(|d| crate::dedup::fpipe::chunk_matches(sh, fp, d)))
}

/// Fetch a digest-verified copy of a chunk from *anywhere*: our own
/// replica slot, the placement chain, then a sweep of every other live
/// server — after an out-transition the surviving copies may sit on
/// servers the new chain no longer names. Shared with the scrub
/// repair path (DESIGN.md §11).
pub(crate) fn fetch_any_copy(sh: &OsdShared, fp: &Fingerprint) -> Result<Option<Vec<u8>>> {
    if let Some(d) = own_copy(sh, fp)? {
        return Ok(Some(d));
    }
    if let Some(d) = scrub::fetch_healthy_copy(sh, fp)? {
        return Ok(Some(d));
    }
    let chain: HashSet<ServerId> = sh.chunk_chain(fp.placement_key()).into_iter().collect();
    let peers: Vec<ServerId> = sh
        .map
        .read()
        .unwrap()
        .servers
        .iter()
        .filter(|s| s.state == ServerState::Up && s.id != sh.id && !chain.contains(&s.id))
        .map(|s| s.id)
        .collect();
    for peer in peers {
        let Ok(addr) = sh.dir.lookup(peer, Lane::Replica) else {
            continue;
        };
        let req = Req::FetchCopy {
            key: chunk_copy_key(fp),
        };
        let size = req.wire_size();
        if let Ok(Resp::Data(d)) = addr.call(req, size) {
            if crate::dedup::fpipe::chunk_matches(sh, fp, &d) {
                return Ok(Some(d));
            }
        }
    }
    Ok(None)
}

/// The [`Req::RecoverOmap`] handler: adopt a pushed OMAP record if the
/// name is unknown here (a racing fresh write always wins), then
/// refresh the record's replica copies under the current chain.
pub(crate) fn recover_omap_local(sh: &OsdShared, value: Vec<u8>) -> Result<()> {
    let entry = OmapEntry::decode(&value)?;
    sh.charge_meta_io();
    if let Some(delta) = sh.shard.omap_put_if_absent(&entry)? {
        Metrics::add(&sh.metrics.backref_updates, delta.total());
        Metrics::add(&sh.metrics.recovery_omap_recovered, 1);
        Metrics::add(&sh.metrics.recovery_bytes, value.len() as u64);
    }
    let current = match sh.shard.omap_get(&entry.name)? {
        Some(e) => e.encode(),
        None => value,
    };
    let chain = sh.object_chain(&entry.name);
    for peer in replica_slots(sh, &chain, sh.cfg.replication) {
        let Ok(addr) = sh.dir.lookup(peer, Lane::Replica) else {
            continue;
        };
        let req = Req::PutCopy {
            key: omap_copy_key(&entry.name),
            data: current.clone(),
        };
        let size = req.wire_size();
        if matches!(addr.call(req, size), Ok(Resp::Ok)) {
            Metrics::add(&sh.metrics.recovery_copies_pushed, 1);
            Metrics::add(&sh.metrics.recovery_bytes, current.len() as u64);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctl_queue_dedups_and_tracks_state() {
        let ctl = RecoveryCtl::for_server(7);
        assert_eq!(ctl.status().state, RecoveryState::Idle);
        ctl.enqueue(3);
        ctl.enqueue(3); // duplicate trigger collapses
        ctl.enqueue(5);
        let st = ctl.status();
        assert_eq!(st.state, RecoveryState::Queued);
        assert_eq!(st.queued, 2);
        assert_eq!(ctl.take_job(Duration::from_millis(1)), Some(3));
        assert_eq!(ctl.take_job(Duration::from_millis(1)), Some(5));
        assert_eq!(ctl.take_job(Duration::from_millis(1)), None);
    }

    #[test]
    fn ctl_ensure_barrier_memory_survives_jobs_not_crashes() {
        let ctl = RecoveryCtl::for_server(1);
        assert!(!ctl.is_ensured(3));
        ctl.mark_ensured(3);
        assert!(ctl.is_ensured(3));
        ctl.clear(); // crash wipes volatile barrier memory
        assert!(!ctl.is_ensured(3));
    }

    #[test]
    fn ctl_clear_fails_inflight_job() {
        let ctl = RecoveryCtl::for_server(2);
        ctl.enqueue(0);
        ctl.clear();
        assert!(matches!(ctl.status().state, RecoveryState::Failed(_)));
        assert_eq!(ctl.status().queued, 0);
    }
}
