//! Workload generation — the FIO-substitute (paper §3 uses FIO with a
//! dedup-percentage knob, varying chunk size and client threads).
//!
//! * [`generator`] — synthetic objects with an exact duplicate-chunk
//!   ratio, deterministic from a seed.
//! * [`zipf`] — Zipf-distributed duplicate-pool sampling (real dedup
//!   workloads are skewed; uniform is also available).
//! * [`corpus`] — objects from a real directory tree (the e2e example).

pub mod corpus;
pub mod generator;
pub mod zipf;

pub use generator::{Generator, WorkloadSpec};
