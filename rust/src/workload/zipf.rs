//! Zipf sampler over `{0, …, n-1}` with skew `theta` (CDF table + binary
//! search; exact, no rejection).

use crate::util::rng::SplitMix64;

/// Precomputed Zipf distribution.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build for `n` items with skew `theta > 0` (larger = more skewed).
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta > 0.0);
        let mut cdf = Vec::with_capacity(n as usize);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(theta);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw one sample in `[0, n)`; rank 0 is the most popular.
    pub fn sample(&self, rng: &mut SplitMix64) -> u64 {
        let u = rng.unit_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i as u64,
            Err(i) => (i as u64).min(self.cdf.len() as u64 - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank0_most_popular() {
        let z = Zipf::new(100, 1.0);
        let mut rng = SplitMix64::new(1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[99] * 5);
    }

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(7, 0.9);
        let mut rng = SplitMix64::new(2);
        for _ in 0..1000 {
            assert!(z.sample(&mut rng) < 7);
        }
    }

    #[test]
    fn single_item() {
        let z = Zipf::new(1, 1.0);
        let mut rng = SplitMix64::new(3);
        assert_eq!(z.sample(&mut rng), 0);
    }
}
