//! Dedup-ratio-controlled synthetic workload (the FIO substitute).
//!
//! An object is a sequence of `unit` -byte blocks. Each block is a
//! duplicate (drawn from a shared pool of `pool_blocks` well-known blocks)
//! with probability `dedup_pct`%, otherwise globally unique. Everything is
//! deterministic in (`seed`, object index), so concurrent client threads
//! can generate disjoint slices of one workload without coordination, and
//! reruns are reproducible.

use crate::util::rng::{SplitMix64, XorShift128Plus};
use crate::workload::zipf::Zipf;

/// Workload shape parameters.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    /// Bytes per object.
    pub object_size: usize,
    /// Duplication granularity — should equal the cluster's chunk size so
    /// "dedup_pct" translates directly into duplicate chunks.
    pub unit: usize,
    /// Percentage [0, 100] of blocks drawn from the duplicate pool.
    pub dedup_pct: u8,
    /// Number of distinct blocks in the duplicate pool.
    pub pool_blocks: u64,
    /// Zipf skew for pool sampling (0.0 = uniform).
    pub zipf_theta: f64,
    /// Base seed.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            object_size: 4 << 20,
            unit: 64 << 10,
            dedup_pct: 0,
            pool_blocks: 1024,
            zipf_theta: 0.0,
            seed: 0x5EED,
        }
    }
}

/// Deterministic workload generator.
pub struct Generator {
    spec: WorkloadSpec,
    zipf: Option<Zipf>,
}

impl Generator {
    /// Build a generator (precomputes the Zipf table if skewed).
    pub fn new(spec: WorkloadSpec) -> Self {
        assert!(spec.object_size > 0 && spec.unit > 0);
        assert!(spec.dedup_pct <= 100);
        let zipf = if spec.zipf_theta > 0.0 && spec.pool_blocks > 1 {
            Some(Zipf::new(spec.pool_blocks, spec.zipf_theta))
        } else {
            None
        };
        Generator { spec, zipf }
    }

    /// The spec in effect.
    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    /// Object name for index `idx`.
    pub fn name(&self, idx: u64) -> String {
        format!("wl-{:08x}-{idx}", self.spec.seed)
    }

    /// Generate object `idx`'s payload.
    pub fn object(&self, idx: u64) -> Vec<u8> {
        let spec = &self.spec;
        let mut out = vec![0u8; spec.object_size];
        let mut decide = SplitMix64::new(spec.seed ^ idx.wrapping_mul(0x9E37_79B9));
        for (b, block) in out.chunks_mut(spec.unit).enumerate() {
            let dup = (decide.below(100) as u8) < spec.dedup_pct;
            let block_seed = if dup {
                let pool_id = match &self.zipf {
                    Some(z) => z.sample(&mut decide),
                    None => decide.below(spec.pool_blocks.max(1)),
                };
                // pool blocks share seeds across ALL objects — these are
                // the cluster-wide duplicates.
                spec.seed ^ 0xD00D_0000_0000_0000 ^ pool_id
            } else {
                // unique everywhere
                spec.seed
                    ^ 0x0101_0000_0000_0000
                    ^ idx.wrapping_mul(1_000_003)
                    ^ (b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            };
            XorShift128Plus::new(block_seed).fill_bytes(block);
        }
        out
    }

    /// (name, payload) convenience.
    pub fn named_object(&self, idx: u64) -> (String, Vec<u8>) {
        (self.name(idx), self.object(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn unique_blocks(gen: &Generator, objects: u64) -> (usize, usize) {
        let mut set = HashSet::new();
        let mut total = 0usize;
        for i in 0..objects {
            let data = gen.object(i);
            for block in data.chunks(gen.spec().unit) {
                set.insert(crate::hash::sha1::sha1(block));
                total += 1;
            }
        }
        (set.len(), total)
    }

    #[test]
    fn deterministic() {
        let g1 = Generator::new(WorkloadSpec::default());
        let g2 = Generator::new(WorkloadSpec::default());
        assert_eq!(g1.object(3), g2.object(3));
        assert_eq!(g1.name(3), g2.name(3));
    }

    #[test]
    fn zero_pct_all_unique() {
        let g = Generator::new(WorkloadSpec {
            object_size: 64 * 1024,
            unit: 4096,
            dedup_pct: 0,
            ..Default::default()
        });
        let (uniq, total) = unique_blocks(&g, 8);
        assert_eq!(uniq, total);
    }

    #[test]
    fn hundred_pct_only_pool_blocks() {
        let g = Generator::new(WorkloadSpec {
            object_size: 64 * 1024,
            unit: 4096,
            dedup_pct: 100,
            pool_blocks: 10,
            ..Default::default()
        });
        let (uniq, total) = unique_blocks(&g, 8);
        assert!(uniq <= 10, "{uniq} unique of {total}");
        assert_eq!(total, 8 * 16);
    }

    #[test]
    fn fifty_pct_in_between() {
        let g = Generator::new(WorkloadSpec {
            object_size: 256 * 1024,
            unit: 4096,
            dedup_pct: 50,
            pool_blocks: 4,
            ..Default::default()
        });
        let (uniq, total) = unique_blocks(&g, 8);
        let ratio = uniq as f64 / total as f64;
        assert!(ratio > 0.35 && ratio < 0.65, "unique ratio {ratio}");
    }

    #[test]
    fn different_objects_differ() {
        let g = Generator::new(WorkloadSpec {
            dedup_pct: 0,
            object_size: 8192,
            unit: 4096,
            ..Default::default()
        });
        assert_ne!(g.object(0), g.object(1));
    }

    #[test]
    fn zipf_skews_pool_usage() {
        let g = Generator::new(WorkloadSpec {
            object_size: 512 * 1024,
            unit: 4096,
            dedup_pct: 100,
            pool_blocks: 64,
            zipf_theta: 4.0,
            ..Default::default()
        });
        // with heavy skew, far fewer distinct pool blocks appear
        let (uniq, _) = unique_blocks(&g, 4);
        assert!(uniq < 20, "zipf should concentrate: {uniq}");
    }
}
