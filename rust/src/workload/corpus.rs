//! Real-directory corpus loader (used by `examples/e2e_cluster.rs` to run
//! the full stack over actual files rather than synthetic data).

use crate::error::Result;
use std::path::Path;

/// One corpus object.
#[derive(Clone, Debug)]
pub struct CorpusObject {
    /// Root-relative file path, used as the object name.
    pub name: String,
    /// File contents.
    pub data: Vec<u8>,
}

/// Recursively load files under `root` (skipping files larger than
/// `max_file_bytes` and empty files). Names are root-relative paths.
pub fn load_dir(root: impl AsRef<Path>, max_file_bytes: u64) -> Result<Vec<CorpusObject>> {
    let root = root.as_ref();
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&dir) else {
            continue;
        };
        for entry in entries.flatten() {
            let path = entry.path();
            let Ok(ft) = entry.file_type() else { continue };
            if ft.is_dir() {
                stack.push(path);
            } else if ft.is_file() {
                let Ok(md) = entry.metadata() else { continue };
                if md.len() == 0 || md.len() > max_file_bytes {
                    continue;
                }
                if let Ok(data) = std::fs::read(&path) {
                    let name = path
                        .strip_prefix(root)
                        .unwrap_or(&path)
                        .to_string_lossy()
                        .into_owned();
                    out.push(CorpusObject { name, data });
                }
            }
        }
    }
    out.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_this_crate_sources() {
        // the repo's own rust sources are a guaranteed-present corpus
        let objs = load_dir("rust/src", 1 << 20).unwrap();
        assert!(objs.len() > 10, "found {}", objs.len());
        assert!(objs.iter().any(|o| o.name.ends_with("lib.rs")));
        // deterministic ordering
        let again = load_dir("rust/src", 1 << 20).unwrap();
        assert_eq!(
            objs.iter().map(|o| &o.name).collect::<Vec<_>>(),
            again.iter().map(|o| &o.name).collect::<Vec<_>>()
        );
    }

    #[test]
    fn size_filter() {
        let objs = load_dir("rust/src", 10).unwrap();
        assert!(objs.is_empty(), "no source file is under 10 bytes");
    }
}
