//! Message-passing fabric between cluster nodes.
//!
//! The paper's testbed is 4 OSS machines on 10 GbE; here every server is a
//! group of OS threads and the "network" is typed channels with an optional
//! cost model ([`NetProfile`]) that charges per-message latency and
//! per-byte wire time at the sender — concurrent senders overlap, exactly
//! like independent NICs.
//!
//! ## Lanes and deadlock freedom
//!
//! Every OSD exposes several **lanes** (frontend / backend / replica /
//! control), each a [`Inbox`] drained by its own thread. Request flow is
//! constrained to the strict order *frontend → backend → replica* (control
//! is orthogonal and never blocks on data lanes), which makes the wait-for
//! graph acyclic: a frontend may block on any backend, a backend only on
//! replica lanes, a replica lane never issues outbound calls.
//!
//! The per-server **scrub worker** ([`crate::scrub`]) is a pure client of
//! this graph: it calls peer backend lanes (`CountRefs`, `EnsureCit`) and
//! replica lanes (`VerifyCopy`, `FetchCopy`, `PutCopy`) but serves no
//! inbound requests itself, so it can never appear in a wait cycle. The
//! **recovery worker** ([`crate::recovery`]) and the cluster-level
//! **failure detector** hold the same position: pure clients whose
//! handlers (`RecoverOmap`, `VerifyRaw`, `RecoveryProbe`, `Ping`) do
//! strictly local work (plus backend→replica fan-out, which the order
//! already allows), and whose heartbeats are bounded-wait — the graph
//! stays acyclic with them in it. Its
//! handlers on the backend/replica lanes do strictly local work (a
//! backreference-index range read, a CIT upsert, a local hash),
//! preserving the lane order above. A replica lane may shed a
//! `VerifyCopy` over its in-flight cap with an inline `Busy` NACK
//! ([`crate::sched::backpressure`]) — still strictly local, so the
//! wait-for graph stays acyclic. Each endpoint tracks its queued-request
//! depth ([`Inbox::backlog`]) to make that cap observable.

pub mod fabric;

pub use fabric::{endpoint, Addr, Directory, Envelope, Inbox, Lane, NetProfile, Pending};
