//! Typed endpoints, RPC envelopes and the cluster-wide address directory.

use crate::cluster::ServerId;
use crate::error::{Error, Result};
use crate::obs::trace::{self, TraceCtx};
use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Safety net against protocol bugs: no RPC should ever take this long in
/// an in-process cluster; hitting it means a lane deadlocked or a reply
/// was dropped without closing the channel.
pub const RPC_TIMEOUT: Duration = Duration::from_secs(30);

/// Service lanes exposed by every OSD (see module docs for the ordering).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Client object ops; may fan out to any backend.
    Frontend,
    /// Chunk + dedup-metadata ops; may call replica lanes only.
    Backend,
    /// Replica copies; strictly local, never calls out.
    Replica,
    /// Admin: map updates, rebalance, GC, stats, audit.
    Control,
}

/// One request plus its reply channel and the sender's trace context.
pub struct Envelope<Req, Resp> {
    /// The request payload.
    pub req: Req,
    /// The sender's span context, stamped by [`Addr::send`] from the
    /// sending thread's current span ([`crate::obs::trace::current`]) —
    /// [`TraceCtx::NONE`] for untraced traffic. Receivers parent their
    /// handler spans under it (DESIGN.md §12).
    pub ctx: TraceCtx,
    reply: Sender<Resp>,
}

impl<Req, Resp> Envelope<Req, Resp> {
    /// Answer the caller (ignores a vanished caller).
    pub fn reply(self, resp: Resp) {
        let _ = self.reply.send(resp);
    }

    /// Split into the owned request and a replier, letting handlers move
    /// payloads out of the message instead of copying them (hot path:
    /// chunk stores move their data straight into the backend).
    pub fn split(self) -> (Req, Replier<Resp>) {
        (self.req, Replier(self.reply))
    }
}

/// The reply half of a split envelope.
pub struct Replier<Resp>(Sender<Resp>);

impl<Resp> Replier<Resp> {
    /// Answer the caller (ignores a vanished caller).
    pub fn reply(self, resp: Resp) {
        let _ = self.0.send(resp);
    }
}

/// Receiving side of a lane.
pub struct Inbox<Req, Resp> {
    rx: Receiver<Envelope<Req, Resp>>,
    depth: Arc<AtomicI64>,
}

impl<Req, Resp> Inbox<Req, Resp> {
    /// Block for the next envelope; `None` when all senders are gone.
    pub fn recv(&self) -> Option<Envelope<Req, Resp>> {
        let env = self.rx.recv().ok();
        if env.is_some() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
        }
        env
    }

    /// Non-blocking receive with timeout (used by lanes that also poll
    /// shutdown flags).
    pub fn recv_timeout(&self, d: Duration) -> Option<Envelope<Req, Resp>> {
        let env = self.rx.recv_timeout(d).ok();
        if env.is_some() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
        }
        env
    }

    /// Requests still queued on this lane *behind* the ones already
    /// received — the in-flight count backpressure gates key on
    /// ([`crate::sched::backpressure::Gate`]). Senders increment before
    /// the channel send, so the reading never under-counts.
    pub fn backlog(&self) -> usize {
        self.depth.load(Ordering::Relaxed).max(0) as usize
    }

    /// A shared handle on this lane's live depth counter, registered as
    /// a queue-depth gauge with the observability layer (the inbox
    /// itself moves into its lane thread; the gauge stays behind).
    pub fn depth_handle(&self) -> Arc<AtomicI64> {
        self.depth.clone()
    }
}

/// In-flight RPC; `wait` blocks for the response.
pub struct Pending<Resp> {
    rx: Receiver<Resp>,
    target: ServerId,
}

impl<Resp> Pending<Resp> {
    /// Await the reply; a dropped envelope (dead server) maps to
    /// [`Error::ServerDown`].
    pub fn wait(self) -> Result<Resp> {
        match self.rx.recv_timeout(RPC_TIMEOUT) {
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Disconnected) => Err(Error::ServerDown(self.target.0)),
            Err(RecvTimeoutError::Timeout) => Err(Error::ServerDown(self.target.0)),
        }
    }

    /// Await the reply for at most `d` of wall time. `Ok(Some)` — the
    /// reply arrived; `Ok(None)` — still in flight (inconclusive: the
    /// receiver may merely be busy); `Err(ServerDown)` — the receiver
    /// dropped the envelope without replying (crash semantics). The
    /// failure detector keys on this three-way verdict: only the hard
    /// `Err` counts as evidence of death.
    pub fn wait_for(self, d: Duration) -> Result<Option<Resp>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => Ok(Some(r)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(Error::ServerDown(self.target.0)),
        }
    }
}

/// Wire-cost model: per-message latency plus per-byte time, charged at the
/// sender (concurrent senders overlap, like independent NICs on a switch).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetProfile {
    /// One-way per-message latency.
    pub latency: Duration,
    /// Link bandwidth in bytes/second (0 = infinite).
    pub bytes_per_sec: u64,
}

impl NetProfile {
    /// 10 GbE-ish profile scaled for an in-process simulation.
    pub fn lan_10g() -> Self {
        NetProfile {
            latency: Duration::from_micros(50),
            bytes_per_sec: 1_250_000_000,
        }
    }

    fn charge(&self, bytes: usize) {
        let wire = if self.bytes_per_sec > 0 {
            Duration::from_nanos((bytes as u64).saturating_mul(1_000_000_000) / self.bytes_per_sec)
        } else {
            Duration::ZERO
        };
        let total = self.latency + wire;
        if !total.is_zero() {
            std::thread::sleep(total);
        }
    }
}

/// Sending side of a lane.
pub struct Addr<Req, Resp> {
    tx: Sender<Envelope<Req, Resp>>,
    target: ServerId,
    profile: Option<NetProfile>,
    depth: Arc<AtomicI64>,
}

impl<Req, Resp> Clone for Addr<Req, Resp> {
    fn clone(&self) -> Self {
        Addr {
            tx: self.tx.clone(),
            target: self.target,
            profile: self.profile,
            depth: self.depth.clone(),
        }
    }
}

impl<Req, Resp> Addr<Req, Resp> {
    /// Fire a request without blocking on the reply. The envelope is
    /// stamped with the sending thread's current trace context — the
    /// single place contexts enter the fabric, so propagation needs no
    /// call-site changes anywhere.
    pub fn send(&self, req: Req, wire_bytes: usize) -> Result<Pending<Resp>> {
        if let Some(p) = &self.profile {
            p.charge(wire_bytes);
        }
        let ctx = trace::current();
        let (rtx, rrx) = channel();
        // count before the send so the receiver's backlog() never
        // under-reports what is queued
        self.depth.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(Envelope { req, ctx, reply: rtx }).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(Error::ServerDown(self.target.0));
        }
        Ok(Pending {
            rx: rrx,
            target: self.target,
        })
    }

    /// Synchronous RPC.
    pub fn call(&self, req: Req, wire_bytes: usize) -> Result<Resp> {
        self.send(req, wire_bytes)?.wait()
    }
}

/// Create a connected (addr, inbox) endpoint pair for `server`.
pub fn endpoint<Req, Resp>(
    server: ServerId,
    profile: Option<NetProfile>,
) -> (Addr<Req, Resp>, Inbox<Req, Resp>) {
    let (tx, rx) = channel();
    let depth = Arc::new(AtomicI64::new(0));
    (
        Addr {
            tx,
            target: server,
            profile,
            depth: depth.clone(),
        },
        Inbox { rx, depth },
    )
}

/// Cluster-wide address book, keyed by (server, lane). Entries are
/// replaced on server restart (new channels), so stale addresses fail fast
/// with [`Error::ServerDown`] instead of hanging.
pub struct Directory<Req, Resp> {
    entries: Arc<RwLock<HashMap<(ServerId, Lane), Addr<Req, Resp>>>>,
}

impl<Req, Resp> Clone for Directory<Req, Resp> {
    fn clone(&self) -> Self {
        Directory {
            entries: self.entries.clone(),
        }
    }
}

impl<Req, Resp> Default for Directory<Req, Resp> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Req, Resp> Directory<Req, Resp> {
    /// Empty directory.
    pub fn new() -> Self {
        Directory {
            entries: Arc::new(RwLock::new(HashMap::new())),
        }
    }

    /// Register (or replace) a lane address.
    pub fn register(&self, server: ServerId, lane: Lane, addr: Addr<Req, Resp>) {
        self.entries.write().unwrap().insert((server, lane), addr);
    }

    /// Remove all lanes of a server (final removal, not restart).
    pub fn deregister(&self, server: ServerId) {
        self.entries
            .write()
            .unwrap()
            .retain(|(s, _), _| *s != server);
    }

    /// Look up a lane address.
    pub fn lookup(&self, server: ServerId, lane: Lane) -> Result<Addr<Req, Resp>> {
        self.entries
            .read()
            .unwrap()
            .get(&(server, lane))
            .cloned()
            .ok_or(Error::ServerDown(server.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpc_roundtrip() {
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(0), None);
        let t = std::thread::spawn(move || {
            while let Some(env) = inbox.recv() {
                let v = env.req;
                env.reply(v * 2);
            }
        });
        assert_eq!(addr.call(21, 4).unwrap(), 42);
        drop(addr);
        t.join().unwrap();
    }

    #[test]
    fn dead_receiver_is_server_down() {
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(3), None);
        drop(inbox);
        match addr.call(1, 4) {
            Err(Error::ServerDown(3)) => {}
            other => panic!("expected ServerDown, got {other:?}"),
        }
    }

    #[test]
    fn dropped_envelope_is_server_down() {
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(5), None);
        let pending = addr.send(1, 4).unwrap();
        let env = inbox.recv().unwrap();
        drop(env); // server died mid-request
        match pending.wait() {
            Err(Error::ServerDown(5)) => {}
            other => panic!("expected ServerDown, got {other:?}"),
        }
    }

    #[test]
    fn wait_for_three_way_verdict() {
        // reply arrived
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(0), None);
        let p = addr.send(1, 4).unwrap();
        inbox.recv().unwrap().reply(2);
        assert_eq!(p.wait_for(Duration::from_millis(100)).unwrap(), Some(2));
        // still in flight (nobody served it yet): inconclusive
        let p = addr.send(1, 4).unwrap();
        assert_eq!(p.wait_for(Duration::from_millis(1)).unwrap(), None);
        inbox.recv().unwrap().reply(0); // drain the abandoned probe
        // dropped envelope: hard evidence of death
        let p = addr.send(1, 4).unwrap();
        drop(inbox.recv().unwrap());
        match p.wait_for(Duration::from_millis(100)) {
            Err(Error::ServerDown(0)) => {}
            other => panic!("expected ServerDown, got {other:?}"),
        }
    }

    #[test]
    fn scatter_gather() {
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(0), None);
        let t = std::thread::spawn(move || {
            while let Some(env) = inbox.recv() {
                let v = env.req;
                env.reply(v + 1);
            }
        });
        let pendings: Vec<_> = (0..16).map(|i| addr.send(i, 4).unwrap()).collect();
        let sum: u32 = pendings.into_iter().map(|p| p.wait().unwrap()).sum();
        assert_eq!(sum, (1..=16).sum::<u32>());
        drop(addr);
        t.join().unwrap();
    }

    #[test]
    fn directory_register_lookup_replace() {
        let dir = Directory::<u32, u32>::new();
        let (a1, _i1) = endpoint(ServerId(1), None);
        dir.register(ServerId(1), Lane::Backend, a1);
        assert!(dir.lookup(ServerId(1), Lane::Backend).is_ok());
        assert!(matches!(
            dir.lookup(ServerId(1), Lane::Frontend),
            Err(Error::ServerDown(1))
        ));
        // replace with a live endpoint (restart)
        let (a2, i2) = endpoint(ServerId(1), None);
        dir.register(ServerId(1), Lane::Backend, a2);
        let t = std::thread::spawn(move || {
            if let Some(env) = i2.recv() {
                let v = env.req;
                env.reply(v);
            }
        });
        assert_eq!(dir.lookup(ServerId(1), Lane::Backend).unwrap().call(9, 4).unwrap(), 9);
        t.join().unwrap();
        dir.deregister(ServerId(1));
        assert!(dir.lookup(ServerId(1), Lane::Backend).is_err());
    }

    #[test]
    fn backlog_counts_queued_envelopes() {
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(0), None);
        assert_eq!(inbox.backlog(), 0);
        let _p1 = addr.send(1, 4).unwrap();
        let _p2 = addr.send(2, 4).unwrap();
        let _p3 = addr.send(3, 4).unwrap();
        assert_eq!(inbox.backlog(), 3);
        let env = inbox.recv().unwrap();
        assert_eq!(inbox.backlog(), 2, "the received envelope left the queue");
        env.reply(0);
    }

    #[test]
    fn send_stamps_the_senders_trace_context() {
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(0), None);
        // untraced thread → NONE
        let _p = addr.send(1, 4).unwrap();
        let env = inbox.recv().unwrap();
        assert!(env.ctx.is_none());
        env.reply(0);
        // traced thread → the current span rides along
        let ctx = TraceCtx::root();
        trace::set_current(ctx);
        let _p = addr.send(2, 4).unwrap();
        trace::clear_current();
        let env = inbox.recv().unwrap();
        assert_eq!(env.ctx, ctx);
        env.reply(0);
    }

    #[test]
    fn depth_handle_tracks_backlog() {
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(0), None);
        let gauge = inbox.depth_handle();
        let _p = addr.send(1, 4).unwrap();
        assert_eq!(gauge.load(Ordering::Relaxed), 1);
        inbox.recv().unwrap().reply(0);
        assert_eq!(gauge.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn net_profile_charges_time() {
        let profile = NetProfile {
            latency: Duration::from_millis(5),
            bytes_per_sec: 0,
        };
        let (addr, inbox) = endpoint::<u32, u32>(ServerId(0), Some(profile));
        let t = std::thread::spawn(move || {
            while let Some(env) = inbox.recv() {
                let v = env.req;
                env.reply(v);
            }
        });
        let t0 = std::time::Instant::now();
        addr.call(1, 0).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(5));
        drop(addr);
        t.join().unwrap();
    }
}
