//! Crash-point failure injection.
//!
//! The paper's robustness claim is about *sudden server failure in the
//! middle of a write transaction* (§2.4). [`FailureInjector`] lets tests
//! and examples arm a named point inside the transaction; when execution
//! reaches it the server flips to dead **at exactly that point** — the
//! remaining steps never run, in-flight requests never get replies, and
//! only state already persisted survives (the backing stores model disk).

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Named instants inside the dedup write transaction where a server can
/// be made to die.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CrashPoint {
    /// Chunk server: after the CIT entry (flag=0) is inserted, before the
    /// chunk data is stored — leaves a dangling invalid CIT entry.
    AfterCitInsert,
    /// Chunk server: after the chunk data is stored, before the commit
    /// flag is flipped — leaves a stored-but-invalid chunk (the classic
    /// tagged-consistency case).
    AfterDataStore,
    /// Chunk server: after local store, before replication fan-out.
    BeforeReplicate,
    /// Primary frontend: after all chunk stores succeeded, before the
    /// OMAP entry is written — whole-object transaction failure.
    BeforeOmapWrite,
    /// Primary frontend: after the OMAP write, before replying to the
    /// client — committed but unacknowledged.
    AfterOmapWrite,
    /// Scrub worker: a defect (bit-rot, missing primary, bad replica
    /// copy) was detected, but the server dies before the repair write
    /// lands — the defect must survive for the next scrub to fix.
    BeforeScrubRepair,
    /// Scrub worker: the repaired primary data was written, but the
    /// server dies before replica copies are refreshed.
    AfterScrubRepair,
    /// Recovery worker: a lost primary or replica copy is about to be
    /// re-written from a surviving copy, but the server dies first —
    /// nothing lands, the degradation persists for the next recovery
    /// pass (or scrub) to heal.
    BeforeRecoveryCopy,
    /// Recovery worker: the recovered data was written, but the server
    /// dies before the commit flag flips / the remaining copies are
    /// pushed — the stored-but-invalid state the flag-based consistency
    /// argument already covers (GC/scrub re-validate or reclaim it).
    AfterRecoveryCopy,
    /// Fingerprint-pipeline worker: a pending chunk's strong
    /// fingerprint was resolved, but the server dies before the
    /// strong-fingerprint chunk is stored — nothing changed; the
    /// pending identity survives and a restart re-queues it.
    BeforeFpMigrateStore,
    /// Fingerprint-pipeline worker: the strong-fingerprint chunk was
    /// stored with the full reference count, but the server dies
    /// before the referencing OMAP entries are rewritten — the OMAP
    /// still references the pending identity; re-migration
    /// double-grants the strong chunk's refcount and scrub's
    /// reconcile settles it.
    AfterFpMigrateStore,
    /// Fingerprint-pipeline worker: OMAP entries now reference the
    /// strong fingerprint, but the server dies before the pending
    /// identity is reclaimed — it lingers with zero references and
    /// ages into GC reclaim.
    AfterFpMigrateOmap,
}

/// Per-server failure injector.
#[derive(Default)]
pub struct FailureInjector {
    armed: Mutex<HashSet<CrashPoint>>,
    /// Set when a crash fired; the OSD lanes watch this and go silent.
    dead: AtomicBool,
}

impl FailureInjector {
    /// No failures armed.
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm a crash point (fires once).
    pub fn arm(&self, p: CrashPoint) {
        self.armed.lock().unwrap().insert(p);
    }

    /// Called from transaction code at each named point. Returns `true`
    /// (and marks the server dead) when the point was armed.
    pub fn maybe_crash(&self, p: CrashPoint) -> bool {
        if self.dead.load(Ordering::SeqCst) {
            return true;
        }
        let fired = self.armed.lock().unwrap().remove(&p);
        if fired {
            self.dead.store(true, Ordering::SeqCst);
        }
        fired
    }

    /// Is the server dead (crashed or killed)?
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Kill unconditionally (admin kill / `Cluster::kill_server`).
    pub fn kill(&self) {
        self.dead.store(true, Ordering::SeqCst);
    }

    /// Revive (admin restart); disarms nothing — unfired points stay armed.
    pub fn revive(&self) {
        self.dead.store(false, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_once_and_marks_dead() {
        let f = FailureInjector::new();
        assert!(!f.maybe_crash(CrashPoint::AfterDataStore));
        f.arm(CrashPoint::AfterDataStore);
        assert!(f.maybe_crash(CrashPoint::AfterDataStore));
        assert!(f.is_dead());
        // once dead, every point reports dead
        assert!(f.maybe_crash(CrashPoint::BeforeOmapWrite));
    }

    #[test]
    fn revive_clears_death_not_armed_points() {
        let f = FailureInjector::new();
        f.arm(CrashPoint::AfterCitInsert);
        f.arm(CrashPoint::BeforeOmapWrite);
        assert!(f.maybe_crash(CrashPoint::AfterCitInsert));
        f.revive();
        assert!(!f.is_dead());
        // the other armed point still fires after revival
        assert!(f.maybe_crash(CrashPoint::BeforeOmapWrite));
    }

    #[test]
    fn kill_and_revive() {
        let f = FailureInjector::new();
        f.kill();
        assert!(f.is_dead());
        f.revive();
        assert!(!f.is_dead());
    }
}
