//! Hashing substrates: SHA-1 (content fingerprints), the gear rolling hash
//! (CDC chunk boundaries) and FNV-1a (object-name hashing / placement
//! draws).
//!
//! SHA-1 and the gear table are implemented from scratch and are
//! bit-identical to the Pallas kernels in `python/compile/kernels/`
//! (cross-checked in tests, and against the RustCrypto `sha1` crate).

pub mod fnv;
pub mod gear;
pub mod sha1;

pub use fnv::fnv1a64;
pub use sha1::sha1;
