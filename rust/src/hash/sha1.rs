//! SHA-1, from scratch.
//!
//! This is the scalar CPU fingerprint path of the deduplication engine
//! (the paper's §2.1: "computes the fingerprint for each chunk's
//! content"). The batched hot path runs the same function as a Pallas
//! kernel through XLA (see `runtime::BatchFingerprinter`); both are
//! asserted bit-identical in tests, and this implementation is further
//! cross-checked against the RustCrypto `sha1` crate.

const H0: [u32; 5] = [0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476, 0xC3D2E1F0];
const K: [u32; 4] = [0x5A827999, 0x6ED9EBA1, 0x8F1BBCDC, 0xCA62C1D6];

/// Streaming SHA-1 state.
#[derive(Clone)]
pub struct Sha1 {
    state: [u32; 5],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Default for Sha1 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha1 {
    /// Fresh hasher.
    pub fn new() -> Self {
        Sha1 {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }

    /// Absorb bytes.
    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len += data.len() as u64;
        if self.buf_len > 0 {
            let need = 64 - self.buf_len;
            let take = need.min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                compress(&mut self.state, &block);
                self.buf_len = 0;
            }
            if data.is_empty() {
                // everything fit in the buffer; don't fall through (the
                // remainder logic below would reset buf_len).
                return;
            }
        }
        let mut blocks = data.chunks_exact(64);
        for b in &mut blocks {
            compress(&mut self.state, b.try_into().unwrap());
        }
        let rem = blocks.remainder();
        self.buf[..rem.len()].copy_from_slice(rem);
        self.buf_len = rem.len();
    }

    /// Finish and return the 20-byte digest.
    pub fn finalize(mut self) -> [u8; 20] {
        let bitlen = self.total_len * 8;
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // length goes straight into the buffer (no total_len update needed
        // but update() is simplest and padding already accounted for).
        self.buf[56..64].copy_from_slice(&bitlen.to_be_bytes());
        let block = self.buf;
        compress(&mut self.state, &block);
        let mut out = [0u8; 20];
        for (i, w) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }
}

/// One-shot SHA-1 digest.
pub fn sha1(data: &[u8]) -> [u8; 20] {
    let mut h = Sha1::new();
    h.update(data);
    h.finalize()
}

/// One-shot SHA-1 digest as 5 big-endian u32 words (the kernel layout).
pub fn sha1_words(data: &[u8]) -> [u32; 5] {
    let d = sha1(data);
    let mut w = [0u32; 5];
    for i in 0..5 {
        w[i] = u32::from_be_bytes([d[i * 4], d[i * 4 + 1], d[i * 4 + 2], d[i * 4 + 3]]);
    }
    w
}

#[inline]
fn compress(state: &mut [u32; 5], block: &[u8; 64]) {
    let mut w = [0u32; 16];
    for (i, wi) in w.iter_mut().enumerate() {
        *wi = u32::from_be_bytes([
            block[i * 4],
            block[i * 4 + 1],
            block[i * 4 + 2],
            block[i * 4 + 3],
        ]);
    }
    let [mut a, mut b, mut c, mut d, mut e] = *state;
    for t in 0..80 {
        let wt = if t < 16 {
            w[t]
        } else {
            let v = (w[(t - 3) % 16] ^ w[(t - 8) % 16] ^ w[(t - 14) % 16] ^ w[t % 16]).rotate_left(1);
            w[t % 16] = v;
            v
        };
        let f = match t / 20 {
            0 => (b & c) | (!b & d),
            1 | 3 => b ^ c ^ d,
            _ => (b & c) | (b & d) | (c & d),
        };
        let tmp = a
            .rotate_left(5)
            .wrapping_add(f)
            .wrapping_add(e)
            .wrapping_add(K[t / 20])
            .wrapping_add(wt);
        e = d;
        d = c;
        c = b.rotate_left(30);
        b = a;
        a = tmp;
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;
    use sha1 as rc_sha1;
    use sha1::Digest as _;

    fn rustcrypto(data: &[u8]) -> [u8; 20] {
        let mut h = rc_sha1::Sha1::new();
        h.update(data);
        h.finalize().into()
    }

    #[test]
    fn fips_vectors() {
        assert_eq!(
            hex::encode(&sha1(b"abc")),
            "a9993e364706816aba3e25717850c26c9cd0d89d"
        );
        assert_eq!(
            hex::encode(&sha1(b"")),
            "da39a3ee5e6b4b0d3255bfef95601890afd80709"
        );
        assert_eq!(
            hex::encode(&sha1(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "84983e441c3bd26ebaae4aa1f95129e5e54670f1"
        );
    }

    #[test]
    fn matches_rustcrypto_across_sizes() {
        for n in [0usize, 1, 55, 56, 57, 63, 64, 65, 127, 128, 1000, 4096] {
            let data: Vec<u8> = (0..n).map(|i| (i * 37 % 251) as u8).collect();
            assert_eq!(sha1(&data), rustcrypto(&data), "size {n}");
        }
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..5000).map(|i| (i % 256) as u8).collect();
        for split in [0usize, 1, 63, 64, 65, 2500, 4999] {
            let mut h = Sha1::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), sha1(&data), "split {split}");
        }
    }

    #[test]
    fn words_layout_big_endian() {
        let w = sha1_words(b"abc");
        assert_eq!(w[0], 0xa9993e36);
        assert_eq!(w[4], 0x9cd0d89d);
    }

    #[test]
    fn property_matches_rustcrypto() {
        use crate::util::prop;
        prop::check(
            prop::Config::default(),
            |rng, size| prop::bytes(rng, size as usize * 40),
            |data| {
                if sha1(data) == rustcrypto(data) {
                    Ok(())
                } else {
                    Err(format!("mismatch at len {}", data.len()))
                }
            },
        );
    }
}
