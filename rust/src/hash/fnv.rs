//! FNV-1a 64-bit — cheap stable hash for object names and placement draws.

const OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const PRIME: u64 = 0x100_0000_01B3;

/// FNV-1a over a byte slice.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Hash two 64-bit values together (used for per-(key, server) placement
/// draws — a cheap keyed hash with good avalanche via an extra mix).
pub fn fnv1a64_pair(a: u64, b: u64) -> u64 {
    let mut buf = [0u8; 16];
    buf[..8].copy_from_slice(&a.to_le_bytes());
    buf[8..].copy_from_slice(&b.to_le_bytes());
    let h = fnv1a64(&buf);
    // finalize with a splitmix-style mix: raw FNV has weak high bits.
    let mut z = h;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn pair_is_deterministic_and_spread() {
        assert_eq!(fnv1a64_pair(1, 2), fnv1a64_pair(1, 2));
        assert_ne!(fnv1a64_pair(1, 2), fnv1a64_pair(2, 1));
        // avalanche sanity: flipping one input bit flips ~half the output
        let base = fnv1a64_pair(0x1234, 7);
        let flip = fnv1a64_pair(0x1235, 7);
        let dist = (base ^ flip).count_ones();
        assert!(dist > 16 && dist < 48, "poor avalanche: {dist}");
    }
}
