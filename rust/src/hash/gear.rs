//! Gear rolling hash for content-defined chunking.
//!
//! The table derivation mirrors `python/compile/kernels/ref.py::gear_table`
//! byte-for-byte (splitmix64 from the golden-ratio seed), so the Rust
//! chunker and the Pallas kernel find identical cut points.

use crate::util::rng::SplitMix64;
use std::sync::OnceLock;

/// The 256-entry gear table (lazily derived, deterministic).
pub fn gear_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut sm = SplitMix64::new(0x9E37_79B9_7F4A_7C15);
        let mut t = [0u32; 256];
        for e in t.iter_mut() {
            *e = (sm.next_u64() & 0xFFFF_FFFF) as u32;
        }
        t
    })
}

/// Incremental gear state: `h = (h << 1) + GEAR[b]`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Gear {
    h: u32,
}

impl Gear {
    /// Fresh state (h = 0).
    pub fn new() -> Self {
        Gear { h: 0 }
    }

    /// Absorb one byte, returning the updated hash.
    #[inline]
    pub fn roll(&mut self, b: u8) -> u32 {
        self.h = (self.h << 1).wrapping_add(gear_table()[b as usize]);
        self.h
    }

    /// Current hash value.
    pub fn value(&self) -> u32 {
        self.h
    }
}

/// Dense candidate bitmap over `data`: 1 where `h & mask == 0`.
/// Matches `kernels.gearhash.gearhash_pallas` bit-for-bit.
pub fn boundaries(data: &[u8], mask: u32) -> Vec<bool> {
    let mut g = Gear::new();
    data.iter().map(|&b| g.roll(b) & mask == 0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_pinned_to_python() {
        // Pinned in python/tests/test_gearhash_kernel.py as well.
        let t = gear_table();
        assert_eq!(t[0], 0xA1B9_65F4);
        assert_eq!(t[255], 0xB7C7_534D);
    }

    #[test]
    fn table_has_no_collisions() {
        let mut v: Vec<u32> = gear_table().to_vec();
        v.sort_unstable();
        v.dedup();
        assert_eq!(v.len(), 256);
    }

    #[test]
    fn roll_is_shift_add() {
        let mut g = Gear::new();
        let h1 = g.roll(0);
        assert_eq!(h1, gear_table()[0]);
        let h2 = g.roll(1);
        assert_eq!(h2, (h1 << 1).wrapping_add(gear_table()[1]));
    }

    #[test]
    fn boundary_density_tracks_mask() {
        let data: Vec<u8> = (0..65536u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8).collect();
        let hits = boundaries(&data, 0x3F).iter().filter(|&&b| b).count();
        let density = hits as f64 / data.len() as f64;
        assert!(density > 0.5 / 64.0 && density < 2.0 / 64.0, "density {density}");
    }

    #[test]
    fn only_trailing_32_bytes_matter() {
        // h_i depends on at most the 32 trailing bytes (u32 shift-out).
        let a: Vec<u8> = (0..100).map(|i| i as u8).collect();
        let mut b = a.clone();
        b[0] = 0xFF; // differs only at position 0
        let ba = boundaries(&a, 0x07);
        let bb = boundaries(&b, 0x07);
        assert_eq!(&ba[32..], &bb[32..]);
    }
}
