//! # snss-dedup — cluster-wide deduplication for shared-nothing storage
//!
//! A from-scratch reproduction of *"A Robust Fault-Tolerant and Scalable
//! Cluster-wide Deduplication for Shared-Nothing Storage Systems"*
//! (Khan, Lee, Hamandawana, Park, Kim — 2018).
//!
//! The crate implements the full stack the paper builds on:
//!
//! * a **shared-nothing storage cluster** — one OS thread-group per object
//!   storage server (OSS), a message-passing fabric, CRUSH-like straw2
//!   placement over placement groups, primary-copy replication, cluster-map
//!   epochs and storage rebalancing ([`storage`], [`net`], [`placement`],
//!   [`cluster`]);
//! * the paper's **cluster-wide deduplication**: per-server DM-Shards
//!   (OMAP + CIT over an embedded KV store), content-fingerprint-based
//!   chunk + metadata placement, asynchronous tagged consistency and
//!   garbage collection ([`dedup`], [`kvstore`]);
//! * the **comparators** used in the paper's evaluation: baseline
//!   no-dedup, a central dedup-metadata server, and per-disk local dedup
//!   (wired through [`api::DedupMode`]);
//! * an **accelerated fingerprint engine**: a Pallas batched SHA-1 kernel,
//!   AOT-lowered by `python/compile/aot.py` to HLO text and executed from
//!   the request path through the PJRT CPU client ([`runtime`]);
//! * an **online scrub & repair subsystem**: per-server, rate-limited,
//!   epoch-aware integrity walks that verify and heal refcounts, commit
//!   flags, chunk data and replica copies while foreground I/O continues
//!   ([`scrub`]);
//! * a **backreference index** per DM-Shard — the inverted OMAP
//!   (`chunk fingerprint → referring objects`) maintained transactionally
//!   with object writes, so reference counting for GC, scrub and audits
//!   is an indexed range read instead of a full OMAP scan
//!   ([`dedup::dmshard`], DESIGN.md §6);
//! * a **batched two-phase write path**: per-home `ProbeChunks` +
//!   `StoreChunkBatch` fan-out with fingerprint-first dedup hints —
//!   payloads ship only for probe misses, stale hints are NACKed with
//!   `NeedData` and resent ([`dedup::engine::WriteBatching`],
//!   DESIGN.md §7);
//! * a **maintenance scheduler with cluster-wide flow control**:
//!   cron-style per-OSD scrub cadence under an injectable (virtual or
//!   wall) clock, one shared weighted token budget for scrub, rebalance,
//!   GC and recovery, and replica-side `VerifyCopy` backpressure with
//!   AIMD sender windows ([`sched`], [`util::clock`], DESIGN.md §10);
//! * **autonomous failure detection & recovery backfill**: clock-driven
//!   heartbeats mark silent servers `Down` then `Out`, fence them, and
//!   every survivor re-replicates the lost chunks and OMAP records from
//!   surviving copies — most-referenced chunks first — until the cluster
//!   is back at full replication ([`recovery`], DESIGN.md §11);
//! * **elastic membership**: wipe-and-rejoin re-admits an `Out` server
//!   only after erasing its stale state, a quorum of independent
//!   heartbeat observers gates every eviction, and map changes
//!   auto-enqueue flow-controlled rebalance scans — no operator call
//!   ([`membership`], DESIGN.md §13);
//! * an **observability layer**: trace contexts in every fabric envelope
//!   with per-server span rings and tail-based slow-op sampling
//!   (`Cluster::trace_dump` reassembles cross-server trees), a per-server
//!   metrics registry whose cluster view is an aggregation (skew and
//!   hot-shard detection), per-op-class latency histograms with
//!   p50/p90/p99 readout, and std-only Prometheus-text/JSON exposition
//!   ([`obs`], DESIGN.md §12);
//! * a **tiered fingerprint pipeline**: a weak-hash prefilter at chunk
//!   boundaries so unique-looking chunks skip the inline strong hash,
//!   deferred batched strong hashing on a per-OSD background worker,
//!   and verify-before-merge collision safety — a weak match never
//!   grants a refcount without byte-compare or strong-digest
//!   verification ([`dedup::fpipe`], DESIGN.md §16);
//! * evaluation machinery: an FIO-like workload generator ([`workload`]),
//!   crash-point failure injection ([`failure`]) and metrics ([`metrics`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use snss_dedup::api::{Cluster, ClusterConfig, DedupMode};
//!
//! let cluster = Cluster::new(ClusterConfig {
//!     servers: 4,
//!     dedup: DedupMode::ClusterWide,
//!     ..ClusterConfig::default()
//! }).unwrap();
//! let client = cluster.client();
//! client.put_object("vm-image-1", &vec![0u8; 1 << 20]).unwrap();
//! let back = client.get_object("vm-image-1").unwrap();
//! assert_eq!(back.len(), 1 << 20);
//! println!("{:?}", cluster.stats());
//! cluster.shutdown();
//! ```
//!
//! See `examples/` for the end-to-end drivers and `DESIGN.md` for the
//! paper-to-module map.

// Every public item carries rustdoc; CI builds the docs with warnings
// denied (`cargo doc --no-deps`), so a missing doc fails the build there
// while staying a warning locally.
#![warn(missing_docs)]
#![warn(rustdoc::broken_intra_doc_links)]

pub mod api;
pub mod cluster;
pub mod dedup;
pub mod error;
pub mod failure;
pub mod hash;
pub mod kvstore;
pub mod membership;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod placement;
pub mod recovery;
pub mod runtime;
pub mod sched;
pub mod scrub;
pub mod storage;
pub mod util;
pub mod workload;

pub use api::{Cluster, ClusterConfig, DedupMode};
pub use dedup::fingerprint::Fingerprint;
pub use error::{Error, Result};
