//! The monitor: owns the authoritative cluster map and pushes updates to
//! subscribers (OSDs and clients hold an `Arc<RwLock<ClusterMap>>` that the
//! monitor refreshes — standing in for Ceph's map-gossip).

use super::map::{ClusterMap, ServerId, ServerState};
use crate::error::{Error, Result};
use std::sync::{Arc, Mutex, RwLock};

/// Callback invoked after every map mutation with the new map.
pub type MapListener = Box<dyn Fn(&ClusterMap) + Send + Sync>;

/// Authoritative map owner.
pub struct Monitor {
    map: Arc<RwLock<ClusterMap>>,
    listeners: Mutex<Vec<MapListener>>,
}

impl Monitor {
    /// Start a monitor over a fresh `n`-server map.
    pub fn new(n: usize) -> Self {
        Monitor {
            map: Arc::new(RwLock::new(ClusterMap::new(n))),
            listeners: Mutex::new(Vec::new()),
        }
    }

    /// Shared handle to the live map (readers see updates immediately —
    /// the in-process analogue of OSDs fetching the latest epoch).
    pub fn map_handle(&self) -> Arc<RwLock<ClusterMap>> {
        self.map.clone()
    }

    /// Snapshot of the current map.
    pub fn map(&self) -> ClusterMap {
        self.map.read().unwrap().clone()
    }

    /// Register a listener fired on every mutation.
    pub fn subscribe(&self, l: MapListener) {
        self.listeners.lock().unwrap().push(l);
    }

    fn mutate(&self, f: impl FnOnce(&mut ClusterMap)) -> ClusterMap {
        let snapshot = {
            let mut m = self.map.write().unwrap();
            f(&mut m);
            m.clone()
        };
        for l in self.listeners.lock().unwrap().iter() {
            l(&snapshot);
        }
        snapshot
    }

    /// Add a server with the given weight; returns (id, new map).
    pub fn add_server(&self, weight: f64) -> (ServerId, ClusterMap) {
        let mut id = ServerId(0);
        let m = self.mutate(|m| id = m.add_server(weight));
        (id, m)
    }

    /// Transition a server's state; [`Error::UnknownServer`] when the id
    /// names no map entry (no epoch bump, no listeners fired).
    fn set_state(&self, id: ServerId, state: ServerState) -> Result<ClusterMap> {
        let snapshot = {
            let mut m = self.map.write().unwrap();
            if !m.set_state(id, state) {
                return Err(Error::UnknownServer(id.0));
            }
            m.clone()
        };
        for l in self.listeners.lock().unwrap().iter() {
            l(&snapshot);
        }
        Ok(snapshot)
    }

    /// Mark a server Down (crash detected) — placement immediately skips it.
    pub fn mark_down(&self, id: ServerId) -> Result<ClusterMap> {
        self.set_state(id, ServerState::Down)
    }

    /// Mark a server Up again (recovered).
    pub fn mark_up(&self, id: ServerId) -> Result<ClusterMap> {
        self.set_state(id, ServerState::Up)
    }

    /// Remove a server from placement (failure-detector out-transition or
    /// administrative removal; data should re-replicate off of it).
    pub fn mark_out(&self, id: ServerId) -> Result<ClusterMap> {
        self.set_state(id, ServerState::Out)
    }

    /// Reweight a server.
    pub fn reweight(&self, id: ServerId, weight: f64) -> ClusterMap {
        self.mutate(|m| m.set_weight(id, weight))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn listeners_fire_on_mutation() {
        let mon = Monitor::new(2);
        let fired = Arc::new(AtomicU64::new(0));
        let f = fired.clone();
        mon.subscribe(Box::new(move |m| {
            f.store(m.epoch, Ordering::SeqCst);
        }));
        let (id, m) = mon.add_server(1.0);
        assert_eq!(id, ServerId(2));
        assert_eq!(m.epoch, 2);
        assert_eq!(fired.load(Ordering::SeqCst), 2);
        mon.mark_down(id).unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 3);
        // unknown ids are a typed error; no listener fires, no epoch bump
        assert!(mon.mark_down(ServerId(99)).is_err());
        assert_eq!(fired.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn map_handle_sees_updates() {
        let mon = Monitor::new(1);
        let h = mon.map_handle();
        mon.add_server(1.0);
        assert_eq!(h.read().unwrap().up_count(), 2);
    }
}
