//! Cluster membership: the epoch-versioned cluster map and the monitor
//! service that mutates and distributes it.
//!
//! This is the substrate role Ceph's monitor quorum plays for the paper's
//! testbed (Table 1 lists 3 monitors); a single in-process [`Monitor`] is
//! sufficient because monitor consensus is orthogonal to the paper's
//! mechanisms (the dedup metadata never lives on the monitor — that is the
//! whole point of the DM-Shard design).

pub mod map;
pub mod monitor;

pub use map::{ClusterMap, ServerId, ServerInfo, ServerState};
pub use monitor::Monitor;
