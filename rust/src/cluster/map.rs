//! The epoch-versioned cluster map.

/// Identifier of an object storage server (OSS/OSD).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ServerId(pub u32);

impl std::fmt::Display for ServerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "osd.{}", self.0)
    }
}

/// Liveness / membership state of a server in the map.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerState {
    /// In the map and serving I/O.
    Up,
    /// In the map but not responding (crashed / killed); placement skips it.
    Down,
    /// Administratively removed; pending data migration off of it.
    Out,
}

/// Per-server entry in the cluster map.
#[derive(Clone, Debug)]
pub struct ServerInfo {
    /// The server's id.
    pub id: ServerId,
    /// CRUSH-style weight (relative capacity); straw2 draws scale with it.
    pub weight: f64,
    /// Up/Down/Out membership state.
    pub state: ServerState,
}

/// The shared-nothing cluster's view of membership, versioned by epoch.
/// Placement is a pure function of (map, key), so any holder of the same
/// epoch computes identical locations — no central lookup table exists.
#[derive(Clone, Debug)]
pub struct ClusterMap {
    /// Monotonic version; bumped by every membership/weight change.
    pub epoch: u64,
    /// All known servers (any state).
    pub servers: Vec<ServerInfo>,
}

impl ClusterMap {
    /// A fresh map with `n` up servers of equal weight.
    pub fn new(n: usize) -> Self {
        ClusterMap {
            epoch: 1,
            servers: (0..n as u32)
                .map(|i| ServerInfo {
                    id: ServerId(i),
                    weight: 1.0,
                    state: ServerState::Up,
                })
                .collect(),
        }
    }

    /// Servers eligible for placement (Up only).
    pub fn up_servers(&self) -> impl Iterator<Item = &ServerInfo> {
        self.servers
            .iter()
            .filter(|s| s.state == ServerState::Up && s.weight > 0.0)
    }

    /// Number of Up servers.
    pub fn up_count(&self) -> usize {
        self.up_servers().count()
    }

    /// Look up a server entry.
    pub fn server(&self, id: ServerId) -> Option<&ServerInfo> {
        self.servers.iter().find(|s| s.id == id)
    }

    /// Next unused server id.
    pub fn next_id(&self) -> ServerId {
        ServerId(self.servers.iter().map(|s| s.id.0 + 1).max().unwrap_or(0))
    }

    /// Add a server (epoch bump); returns its id.
    pub fn add_server(&mut self, weight: f64) -> ServerId {
        let id = self.next_id();
        self.servers.push(ServerInfo {
            id,
            weight,
            state: ServerState::Up,
        });
        self.epoch += 1;
        id
    }

    /// Transition a server's state (epoch bump). Returns false (and
    /// leaves the map untouched) when the id names no entry, so callers
    /// can surface a typed error instead of silently no-opping.
    pub fn set_state(&mut self, id: ServerId, state: ServerState) -> bool {
        if let Some(s) = self.servers.iter_mut().find(|s| s.id == id) {
            s.state = state;
            self.epoch += 1;
            true
        } else {
            false
        }
    }

    /// Change a server's weight (epoch bump).
    pub fn set_weight(&mut self, id: ServerId, weight: f64) {
        if let Some(s) = self.servers.iter_mut().find(|s| s.id == id) {
            s.weight = weight;
            self.epoch += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_map_all_up() {
        let m = ClusterMap::new(4);
        assert_eq!(m.epoch, 1);
        assert_eq!(m.up_count(), 4);
        assert_eq!(m.next_id(), ServerId(4));
    }

    #[test]
    fn add_and_down() {
        let mut m = ClusterMap::new(2);
        let id = m.add_server(2.0);
        assert_eq!(id, ServerId(2));
        assert_eq!(m.epoch, 2);
        assert_eq!(m.up_count(), 3);
        assert!(m.set_state(ServerId(0), ServerState::Down));
        assert_eq!(m.epoch, 3);
        assert!(!m.set_state(ServerId(99), ServerState::Down), "unknown id");
        assert_eq!(m.epoch, 3, "failed transition must not bump the epoch");
        assert_eq!(m.up_count(), 2);
        assert_eq!(m.server(ServerId(0)).unwrap().state, ServerState::Down);
    }

    #[test]
    fn zero_weight_excluded_from_placement() {
        let mut m = ClusterMap::new(3);
        m.set_weight(ServerId(1), 0.0);
        assert_eq!(m.up_count(), 2);
    }

    #[test]
    fn display_format() {
        assert_eq!(ServerId(7).to_string(), "osd.7");
    }
}
