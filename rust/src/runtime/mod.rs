//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and serves batched fingerprint requests on the
//! request path (Python never runs here).
//!
//! The PJRT client and compiled executables live on one dedicated service
//! thread (the `xla` crate's handles wrap raw pointers, and a single
//! device is the honest model of the accelerator the paper proposes for
//! fingerprint offload); OSD frontends submit jobs over a channel.
//!
//! Chunks whose size matches a compiled `(batch, chunk_bytes)` variant are
//! packed big-endian into `u32[batch, words]` literals and digested by the
//! Pallas SHA-1 kernel; everything else (tail chunks, odd sizes) falls
//! back to the scalar Rust SHA-1 — both paths are bit-identical, which
//! `rust/tests/xla_runtime.rs` asserts.

use crate::dedup::fingerprint::{Fingerprint, FingerprintProvider};
use crate::error::{Error, Result};
use crate::hash::sha1::sha1_words;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

/// One artifact listed in `artifacts/manifest.tsv`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Artifact name (kernel + shape variant).
    pub name: String,
    /// Artifact kind (e.g. `hlo`).
    pub kind: String,
    /// Batch dimension the kernel was lowered for.
    pub batch: usize,
    /// Chunk size in bytes the kernel was lowered for.
    pub chunk_bytes: usize,
    /// Pallas tile size.
    pub tile: usize,
    /// Lane mask baked into the lowering.
    pub mask: u32,
    /// Path to the compiled artifact file.
    pub file: PathBuf,
}

/// Parse `manifest.tsv` (written by `python/compile/aot.py`).
pub fn parse_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let text = std::fs::read_to_string(dir.join("manifest.tsv"))?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() != 7 {
            return Err(Error::Corrupt(format!("manifest line: {line}")));
        }
        out.push(ArtifactSpec {
            name: f[0].to_string(),
            kind: f[1].to_string(),
            batch: f[2].parse().map_err(|_| Error::Corrupt("batch".into()))?,
            chunk_bytes: f[3].parse().map_err(|_| Error::Corrupt("chunk".into()))?,
            tile: f[4].parse().map_err(|_| Error::Corrupt("tile".into()))?,
            mask: f[5].parse().map_err(|_| Error::Corrupt("mask".into()))?,
            file: dir.join(f[6]),
        });
    }
    Ok(out)
}

/// Pack chunks (all exactly `chunk_bytes` long) big-endian into a flat
/// u32 buffer of `batch * chunk_bytes/4` words, zero-padding missing rows.
pub fn pack_batch(chunks: &[&[u8]], batch: usize, chunk_bytes: usize) -> Vec<u32> {
    let words = chunk_bytes / 4;
    let mut out = vec![0u32; batch * words];
    for (r, c) in chunks.iter().enumerate() {
        debug_assert_eq!(c.len(), chunk_bytes);
        for w in 0..words {
            let o = w * 4;
            out[r * words + w] = u32::from_be_bytes([c[o], c[o + 1], c[o + 2], c[o + 3]]);
        }
    }
    out
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
enum Job {
    /// Digest chunks of exactly `chunk_bytes` (one variant).
    Digest {
        variant: usize,
        packed: Vec<u32>,
        rows: usize,
        reply: Sender<Result<Vec<Fingerprint>>>,
    },
    Shutdown,
}

/// The accelerator service: a thread owning the PJRT client + compiled
/// fingerprint executables. Implements [`FingerprintProvider`].
pub struct XlaFingerprintService {
    tx: Mutex<Sender<Job>>,
    variants: Vec<ArtifactSpec>,
    /// Chunks digested via the accelerator (for perf reporting).
    pub accel_chunks: AtomicU64,
    /// Chunks digested via the scalar fallback.
    pub scalar_chunks: AtomicU64,
}

impl XlaFingerprintService {
    /// Load the manifest, compile all fingerprint variants on a service
    /// thread, and return the provider handle.
    pub fn start(artifacts_dir: impl AsRef<Path>) -> Result<XlaFingerprintService> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let variants: Vec<ArtifactSpec> = parse_manifest(&dir)?
            .into_iter()
            .filter(|a| a.kind == "fingerprint")
            .collect();
        if variants.is_empty() {
            return Err(Error::Xla("no fingerprint artifacts in manifest".into()));
        }
        let tx = Self::spawn_service(variants.clone())?;
        Ok(XlaFingerprintService {
            tx: Mutex::new(tx),
            variants,
            accel_chunks: AtomicU64::new(0),
            scalar_chunks: AtomicU64::new(0),
        })
    }

    /// Spawn the service thread owning the PJRT client and compiled
    /// executables (requires the vendored `xla` crate — see the `xla`
    /// cargo feature).
    #[cfg(feature = "xla")]
    fn spawn_service(specs: Vec<ArtifactSpec>) -> Result<Sender<Job>> {
        let (tx, rx) = channel::<Job>();
        let (boot_tx, boot_rx) = channel::<Result<()>>();
        std::thread::Builder::new()
            .name("xla-fp-service".into())
            .spawn(move || {
                // Build client + executables on the service thread; report
                // boot status, then serve jobs forever.
                let built = (|| -> Result<(xla::PjRtClient, Vec<xla::PjRtLoadedExecutable>)> {
                    let client = xla::PjRtClient::cpu().map_err(|e| Error::Xla(e.to_string()))?;
                    let mut execs = Vec::new();
                    for spec in &specs {
                        let proto = xla::HloModuleProto::from_text_file(
                            spec.file.to_str().unwrap_or_default(),
                        )
                        .map_err(|e| Error::Xla(format!("{}: {e}", spec.name)))?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        let exe = client
                            .compile(&comp)
                            .map_err(|e| Error::Xla(format!("compile {}: {e}", spec.name)))?;
                        execs.push(exe);
                    }
                    Ok((client, execs))
                })();
                let (_client, execs) = match built {
                    Ok(ok) => {
                        let _ = boot_tx.send(Ok(()));
                        ok
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Shutdown => break,
                        Job::Digest {
                            variant,
                            packed,
                            rows,
                            reply,
                        } => {
                            let spec = &specs[variant];
                            let result = run_digest(&execs[variant], spec, &packed, rows);
                            let _ = reply.send(result);
                        }
                    }
                }
            })
            .map_err(|e| Error::Xla(format!("spawn service: {e}")))?;
        boot_rx
            .recv()
            .map_err(|_| Error::Xla("service thread died during boot".into()))??;
        Ok(tx)
    }

    /// Built without the `xla` feature: no PJRT service exists. The
    /// returned sender dangles (its receiver is dropped), digest jobs are
    /// never submitted ([`Self::digest_via_xla`] short-circuits) and the
    /// provider serves every chunk through the scalar fallback.
    #[cfg(not(feature = "xla"))]
    fn spawn_service(_specs: Vec<ArtifactSpec>) -> Result<Sender<Job>> {
        let (tx, _rx) = channel::<Job>();
        Ok(tx)
    }

    /// The compiled variants (for reports and tests).
    pub fn variants(&self) -> &[ArtifactSpec] {
        &self.variants
    }

    fn variant_for(&self, len: usize) -> Option<usize> {
        self.variants.iter().position(|v| v.chunk_bytes == len)
    }

    /// Digest `chunks` (all exactly the variant's chunk size) through the
    /// accelerator, splitting into batches as needed.
    #[cfg(feature = "xla")]
    fn digest_via_xla(&self, variant: usize, chunks: &[&[u8]]) -> Result<Vec<Fingerprint>> {
        let spec = &self.variants[variant];
        let mut out = Vec::with_capacity(chunks.len());
        for group in chunks.chunks(spec.batch) {
            let packed = pack_batch(group, spec.batch, spec.chunk_bytes);
            let (rtx, rrx) = channel();
            self.tx
                .lock()
                .unwrap()
                .send(Job::Digest {
                    variant,
                    packed,
                    rows: group.len(),
                    reply: rtx,
                })
                .map_err(|_| Error::Xla("service gone".into()))?;
            let digests = rrx.recv().map_err(|_| Error::Xla("service died".into()))??;
            out.extend(digests);
        }
        Ok(out)
    }

    /// Without the `xla` feature there is no accelerator; report the
    /// miss so [`FingerprintProvider::digests`] takes the scalar path.
    #[cfg(not(feature = "xla"))]
    fn digest_via_xla(&self, _variant: usize, _chunks: &[&[u8]]) -> Result<Vec<Fingerprint>> {
        Err(Error::Xla("built without the `xla` feature".into()))
    }
}

#[cfg(feature = "xla")]
fn run_digest(
    exe: &xla::PjRtLoadedExecutable,
    spec: &ArtifactSpec,
    packed: &[u32],
    rows: usize,
) -> Result<Vec<Fingerprint>> {
    let words = spec.chunk_bytes / 4;
    let lit = xla::Literal::vec1(packed)
        .reshape(&[spec.batch as i64, words as i64])
        .map_err(|e| Error::Xla(e.to_string()))?;
    let result = exe
        .execute::<xla::Literal>(&[lit])
        .map_err(|e| Error::Xla(e.to_string()))?[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Xla(e.to_string()))?;
    let mut tuple = result;
    let parts = tuple
        .decompose_tuple()
        .map_err(|e| Error::Xla(e.to_string()))?;
    let digests = parts[0]
        .to_vec::<u32>()
        .map_err(|e| Error::Xla(e.to_string()))?;
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let mut w = [0u32; 5];
        w.copy_from_slice(&digests[r * 5..r * 5 + 5]);
        out.push(Fingerprint(w));
    }
    Ok(out)
}

impl FingerprintProvider for XlaFingerprintService {
    fn digests(&self, chunks: &[&[u8]]) -> Vec<Fingerprint> {
        // Group indices by matching variant; scalar-fallback the rest.
        let mut out = vec![Fingerprint([0; 5]); chunks.len()];
        let mut by_variant: std::collections::HashMap<usize, Vec<usize>> =
            std::collections::HashMap::new();
        for (i, c) in chunks.iter().enumerate() {
            match self.variant_for(c.len()) {
                Some(v) => by_variant.entry(v).or_default().push(i),
                None => {
                    out[i] = Fingerprint(sha1_words(c));
                    self.scalar_chunks.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        for (variant, idxs) in by_variant {
            let group: Vec<&[u8]> = idxs.iter().map(|&i| chunks[i]).collect();
            match self.digest_via_xla(variant, &group) {
                Ok(ds) => {
                    self.accel_chunks
                        .fetch_add(idxs.len() as u64, Ordering::Relaxed);
                    for (k, i) in idxs.into_iter().enumerate() {
                        out[i] = ds[k];
                    }
                }
                Err(_) => {
                    // accelerator trouble: stay correct via the scalar path
                    for i in idxs {
                        out[i] = Fingerprint(sha1_words(chunks[i]));
                        self.scalar_chunks.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla-pallas-sha1"
    }
}

impl Drop for XlaFingerprintService {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Job::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_batch_layout() {
        let a = [0x01u8, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08];
        let packed = pack_batch(&[&a], 2, 8);
        assert_eq!(packed, vec![0x01020304, 0x05060708, 0, 0]);
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join(format!("snss-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# header\nfp_b2_c64\tfingerprint\t2\t64\t1\t0\tfp_b2_c64.hlo.txt\n",
        )
        .unwrap();
        let specs = parse_manifest(&dir).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].batch, 2);
        assert_eq!(specs[0].chunk_bytes, 64);
        assert_eq!(specs[0].kind, "fingerprint");
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("snss-manifest-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), "not\ta\tmanifest\n").unwrap();
        assert!(parse_manifest(&dir).is_err());
    }
}
