//! Observability: distributed tracing, the per-server metrics registry
//! and metric/trace exposition (DESIGN.md §12).
//!
//! Three pillars:
//!
//! * **Distributed tracing** ([`trace`]) — a [`TraceCtx`] rides in
//!   every fabric envelope; OSD lane loops open one handler span per
//!   dispatched request; [`crate::api::Client`] opens a root span per
//!   `put`/`get`/`delete`. Completed spans land in a per-server
//!   lock-free ring ([`SpanSink`]) and
//!   [`crate::api::Cluster::trace_dump`] reassembles cross-server trees
//!   by span id.
//! * **Tail-based sampling** — every op is traced, but full trees are
//!   *retained* only for ops whose root exceeded
//!   [`ObsConfig::slow_op_threshold_ms`] (slow-op forensics), plus a
//!   head-sampled 1-in-N exemplar stream
//!   ([`ObsConfig::head_sample_every`]). The retention decision lives
//!   at the client root, the span data in per-server rings — a crashed
//!   server merely truncates a tree, it can never corrupt or stall the
//!   sampler (the rings are volatile and cleared on kill, like every
//!   other in-memory state).
//! * **Per-server metrics registry** ([`Registry`]) — each server owns
//!   its own [`crate::metrics::Metrics`]; the cluster view is an
//!   aggregation ([`crate::api::Cluster::metrics_snapshot`]), which
//!   makes skew/hot-shard detection ([`MetricsSnapshot::skew`],
//!   [`MetricsSnapshot::hot_servers`]) possible at all.
//!
//! Tracing is **default-on and near-zero cost without a sink**: context
//! propagation is a 24-byte copy plus a thread-local read per message,
//! and span timing/recording happens only behind the
//! per-server sink presence check (`benches/obs_overhead.rs` holds the
//! put path within a few percent of a tracing-off build).

pub mod snapshot;
pub mod trace;

pub use self::snapshot::{FlowClassUtil, MetricsSnapshot, ServerSnapshot};
pub use self::trace::{SpanRecord, TraceCtx};

use crate::metrics::Metrics;
use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Pseudo server id for the cluster-scope registry entry: client root
/// spans, client-side counters and the failure detector's activity.
pub const CLIENT_SCOPE: u32 = u32::MAX;

/// Observability configuration ([`crate::api::ClusterConfig::obs`]).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Propagate trace contexts and open spans (default on; turning it
    /// off removes even the per-message context copy).
    pub tracing: bool,
    /// Capacity of each server's span ring. 0 detaches the sink
    /// entirely: contexts still propagate but nothing is timed or
    /// recorded (the "near-zero cost" mode the overhead bench pins).
    pub span_ring_capacity: usize,
    /// Tail-sampling threshold: a client op whose root span runs at
    /// least this long has its full tree retained for [`TraceDump`].
    pub slow_op_threshold_ms: u64,
    /// Head sampling: additionally retain every Nth client op as an
    /// exemplar (0 = off).
    pub head_sample_every: u64,
    /// Bound on distinct retained traces (oldest evicted first).
    pub retained_traces: usize,
    /// Period of the clock-driven snapshot sampler in ms (0 = off):
    /// [`crate::api::Cluster::advance_clock`] captures one
    /// [`MetricsSnapshot`] per crossed period boundary, so deterministic
    /// tests can assert metric *trajectories*.
    pub sample_every_ms: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            tracing: true,
            span_ring_capacity: 256,
            slow_op_threshold_ms: 500,
            head_sample_every: 0,
            retained_traces: 64,
            sample_every_ms: 0,
        }
    }
}

/// A bounded, lock-free-indexed ring of completed spans (one per
/// server). Writers claim a slot with one relaxed `fetch_add` — no
/// shared lock, no allocation on the hot path beyond the slot write;
/// under overflow the oldest spans are overwritten (tail sampling makes
/// that loss benign: retention is decided at the client root, not
/// here).
pub struct SpanSink {
    slots: Vec<Mutex<Option<SpanRecord>>>,
    head: AtomicUsize,
}

impl SpanSink {
    /// A ring with `capacity` slots (callers guarantee `capacity > 0`).
    pub fn new(capacity: usize) -> SpanSink {
        SpanSink {
            slots: (0..capacity.max(1)).map(|_| Mutex::new(None)).collect(),
            head: AtomicUsize::new(0),
        }
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Record one completed span (untraced records are dropped).
    pub fn record(&self, span: SpanRecord) {
        if span.trace_id == 0 {
            return;
        }
        let i = self.head.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        *self.slots[i].lock().unwrap() = Some(span);
    }

    /// All currently retained spans (unordered).
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.slots
            .iter()
            .filter_map(|s| *s.lock().unwrap())
            .collect()
    }

    /// Crash semantics: a killed server's spans are volatile and die
    /// with it (called from the OSD kill path so no spans leak across
    /// `restart_server`).
    pub fn clear(&self) {
        for slot in &self.slots {
            *slot.lock().unwrap() = None;
        }
    }
}

/// One server's observability entry: its metrics instance, its span
/// ring, and its registered live gauges (per-lane queue depths).
pub struct ServerObs {
    metrics: Arc<Metrics>,
    tracing: bool,
    sink: Option<SpanSink>,
    gauges: Mutex<Vec<(&'static str, Arc<AtomicI64>)>>,
}

impl ServerObs {
    fn new(cfg: &ObsConfig) -> ServerObs {
        ServerObs {
            metrics: Arc::new(Metrics::new()),
            tracing: cfg.tracing,
            sink: (cfg.tracing && cfg.span_ring_capacity > 0)
                .then(|| SpanSink::new(cfg.span_ring_capacity)),
            gauges: Mutex::new(Vec::new()),
        }
    }

    /// This server's metrics instance (the registry entry the OSD bumps
    /// directly).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Is context propagation enabled?
    pub fn tracing(&self) -> bool {
        self.tracing
    }

    /// This server's span ring (`None` ⇒ the near-zero-cost no-sink
    /// mode: propagate contexts, record nothing).
    pub fn sink(&self) -> Option<&SpanSink> {
        self.sink.as_ref()
    }

    /// Register a live gauge (e.g. a fabric inbox's queued-request
    /// depth) under a static name. Re-registering a name replaces the
    /// old handle, so a respawned server never double-reports.
    pub fn register_gauge(&self, name: &'static str, handle: Arc<AtomicI64>) {
        let mut gauges = self.gauges.lock().unwrap();
        if let Some(slot) = gauges.iter_mut().find(|(n, _)| *n == name) {
            slot.1 = handle;
        } else {
            gauges.push((name, handle));
        }
    }

    /// Current value of every registered gauge.
    pub fn gauge_values(&self) -> Vec<(&'static str, i64)> {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(name, h)| (*name, h.load(Ordering::Relaxed)))
            .collect()
    }

    /// Drop all retained spans (kill-path crash semantics).
    pub fn clear_spans(&self) {
        if let Some(sink) = &self.sink {
            sink.clear();
        }
    }
}

/// The cluster's observability registry: per-server entries (metrics +
/// span ring + gauges), the tail/head sampling state, and the sampled
/// snapshot history. One instance per [`crate::api::Cluster`], shared
/// with every [`crate::api::Client`].
pub struct Registry {
    cfg: ObsConfig,
    entries: Mutex<BTreeMap<u32, Arc<ServerObs>>>,
    retained: Mutex<VecDeque<u64>>,
    roots_started: AtomicU64,
    samples: Mutex<Vec<MetricsSnapshot>>,
    last_sample_ms: AtomicU64,
}

impl Registry {
    /// Fresh registry under `cfg`.
    pub fn new(cfg: ObsConfig) -> Arc<Registry> {
        Arc::new(Registry {
            cfg,
            entries: Mutex::new(BTreeMap::new()),
            retained: Mutex::new(VecDeque::new()),
            roots_started: AtomicU64::new(0),
            samples: Mutex::new(Vec::new()),
            last_sample_ms: AtomicU64::new(0),
        })
    }

    /// The configuration this registry was built with.
    pub fn cfg(&self) -> &ObsConfig {
        &self.cfg
    }

    /// Get-or-create the entry for server `id` (use [`CLIENT_SCOPE`]
    /// for the cluster-scope entry).
    pub fn server(&self, id: u32) -> Arc<ServerObs> {
        self.entries
            .lock()
            .unwrap()
            .entry(id)
            .or_insert_with(|| Arc::new(ServerObs::new(&self.cfg)))
            .clone()
    }

    /// All registered entries, ordered by id.
    pub fn entries(&self) -> Vec<(u32, Arc<ServerObs>)> {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .map(|(id, e)| (*id, e.clone()))
            .collect()
    }

    /// Mark a trace retained (idempotent; oldest retained trace evicted
    /// past [`ObsConfig::retained_traces`]).
    pub fn mark_retained(&self, trace_id: u64) {
        let mut g = self.retained.lock().unwrap();
        if g.contains(&trace_id) {
            return;
        }
        g.push_back(trace_id);
        while g.len() > self.cfg.retained_traces.max(1) {
            g.pop_front();
        }
    }

    /// Trace ids currently retained (oldest first).
    pub fn retained_ids(&self) -> Vec<u64> {
        self.retained.lock().unwrap().iter().copied().collect()
    }

    /// Run `f` inside a fresh client root span named `name`, applying
    /// the head- and tail-sampling policy on exit. `now_ms` reads the
    /// cluster's injected clock.
    pub fn with_root<R>(
        &self,
        name: &'static str,
        now_ms: impl Fn() -> u64,
        f: impl FnOnce() -> R,
    ) -> R {
        if !self.cfg.tracing {
            return f();
        }
        let ctx = TraceCtx::root();
        let nth = self.roots_started.fetch_add(1, Ordering::Relaxed) + 1;
        if self.cfg.head_sample_every > 0 && nth % self.cfg.head_sample_every == 0 {
            self.mark_retained(ctx.trace_id);
        }
        let start_ms = now_ms();
        trace::set_current(ctx);
        let out = f();
        trace::clear_current();
        let end_ms = now_ms();
        let entry = self.server(CLIENT_SCOPE);
        if let Some(sink) = entry.sink() {
            sink.record(SpanRecord {
                trace_id: ctx.trace_id,
                span_id: ctx.span_id,
                parent: 0,
                server: CLIENT_SCOPE,
                name,
                start_ms,
                end_ms,
            });
        }
        if end_ms.saturating_sub(start_ms) >= self.cfg.slow_op_threshold_ms {
            self.mark_retained(ctx.trace_id);
        }
        out
    }

    /// Reassemble the retained traces from every server's span ring.
    pub fn trace_dump(&self) -> TraceDump {
        let retained: HashSet<u64> = self.retained.lock().unwrap().iter().copied().collect();
        let mut by_trace: BTreeMap<u64, Vec<SpanRecord>> = BTreeMap::new();
        for (_, entry) in self.entries() {
            if let Some(sink) = entry.sink() {
                for span in sink.snapshot() {
                    if retained.contains(&span.trace_id) {
                        by_trace.entry(span.trace_id).or_default().push(span);
                    }
                }
            }
        }
        TraceDump {
            traces: by_trace
                .into_iter()
                .map(|(trace_id, mut spans)| {
                    spans.sort_by_key(|s| (s.start_ms, s.span_id));
                    TraceTree { trace_id, spans }
                })
                .collect(),
        }
    }

    /// Clock-driven sampler: capture one snapshot (via `make`) per
    /// crossed [`ObsConfig::sample_every_ms`] boundary.
    pub fn maybe_sample(&self, now_ms: u64, make: impl FnOnce() -> MetricsSnapshot) {
        let period = self.cfg.sample_every_ms;
        if period == 0 {
            return;
        }
        let last = self.last_sample_ms.load(Ordering::Relaxed);
        if now_ms / period > last / period {
            self.last_sample_ms.store(now_ms, Ordering::Relaxed);
            self.samples.lock().unwrap().push(make());
        }
    }

    /// The sampled snapshot history (oldest first).
    pub fn samples(&self) -> Vec<MetricsSnapshot> {
        self.samples.lock().unwrap().clone()
    }
}

/// One reassembled trace: every retained span of one client operation,
/// across all servers, ordered by start time.
#[derive(Clone, Debug)]
pub struct TraceTree {
    /// The trace id all spans share.
    pub trace_id: u64,
    /// The spans (root first when the root survived its ring).
    pub spans: Vec<SpanRecord>,
}

impl TraceTree {
    /// The client root span (parent 0), if it survived.
    pub fn root(&self) -> Option<&SpanRecord> {
        self.spans.iter().find(|s| s.parent == 0)
    }

    /// Direct children of `span_id`, in start order.
    pub fn children(&self, span_id: u64) -> Vec<&SpanRecord> {
        self.spans.iter().filter(|s| s.parent == span_id).collect()
    }

    /// First span with the given name.
    pub fn find(&self, name: &str) -> Option<&SpanRecord> {
        self.spans.iter().find(|s| s.name == name)
    }

    /// Is `span_id` connected to the client root by parent links within
    /// this tree?
    pub fn reachable_from_root(&self, span_id: u64) -> bool {
        let mut cur = span_id;
        for _ in 0..=self.spans.len() {
            let Some(span) = self.spans.iter().find(|s| s.span_id == cur) else {
                return false;
            };
            if span.parent == 0 {
                return true;
            }
            cur = span.parent;
        }
        false // parent cycle (cannot happen with unique ids)
    }

    /// Indented text rendering of the tree (orphaned subtrees — spans
    /// whose parent rotated out of its ring or died with its server —
    /// are listed beneath the tree).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let dur = self.root().map(|r| r.duration_ms()).unwrap_or(0);
        let count = self.spans.len();
        let _ = writeln!(out, "trace {} ({} ms, {} spans)", self.trace_id, dur, count);
        let mut seen: HashSet<u64> = HashSet::new();
        if let Some(root) = self.root() {
            self.render_span(&mut out, root, 1, &mut seen);
        }
        for span in &self.spans {
            if !seen.contains(&span.span_id) && !self.reachable_from_root(span.span_id) {
                let _ = writeln!(out, "  (orphan) {}", Self::line(span));
                seen.insert(span.span_id);
                self.render_span_children(&mut out, span.span_id, 2, &mut seen);
            }
        }
        out
    }

    fn line(span: &SpanRecord) -> String {
        let server = if span.server == CLIENT_SCOPE {
            "client".to_string()
        } else {
            format!("osd.{}", span.server)
        };
        format!(
            "{} [{}] {}..{} ms",
            span.name, server, span.start_ms, span.end_ms
        )
    }

    fn render_span(
        &self,
        out: &mut String,
        span: &SpanRecord,
        depth: usize,
        seen: &mut HashSet<u64>,
    ) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "{}{}", "  ".repeat(depth), Self::line(span));
        seen.insert(span.span_id);
        self.render_span_children(out, span.span_id, depth + 1, seen);
    }

    fn render_span_children(
        &self,
        out: &mut String,
        span_id: u64,
        depth: usize,
        seen: &mut HashSet<u64>,
    ) {
        for child in self.children(span_id) {
            if seen.insert(child.span_id) {
                use std::fmt::Write as _;
                let _ = writeln!(out, "{}{}", "  ".repeat(depth), Self::line(child));
                self.render_span_children(out, child.span_id, depth + 1, seen);
            }
        }
    }
}

/// Every retained trace, reassembled ([`crate::api::Cluster::trace_dump`]).
#[derive(Clone, Debug, Default)]
pub struct TraceDump {
    /// Retained traces, ordered by trace id (creation order).
    pub traces: Vec<TraceTree>,
}

impl TraceDump {
    /// Look up one trace by id.
    pub fn trace(&self, trace_id: u64) -> Option<&TraceTree> {
        self.traces.iter().find(|t| t.trace_id == trace_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, id: u64, parent: u64, name: &'static str) -> SpanRecord {
        SpanRecord {
            trace_id: trace,
            span_id: id,
            parent,
            server: 0,
            name,
            start_ms: id,
            end_ms: id + 1,
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let sink = SpanSink::new(2);
        sink.record(span(1, 1, 0, "a"));
        sink.record(span(1, 2, 1, "b"));
        sink.record(span(1, 3, 1, "c"));
        let mut names: Vec<&str> = sink.snapshot().iter().map(|s| s.name).collect();
        names.sort_unstable();
        assert_eq!(names, vec!["b", "c"]);
        sink.clear();
        assert!(sink.snapshot().is_empty());
    }

    #[test]
    fn untraced_spans_are_dropped() {
        let sink = SpanSink::new(4);
        sink.record(span(0, 9, 0, "untraced"));
        assert!(sink.snapshot().is_empty());
    }

    #[test]
    fn retention_is_bounded_and_idempotent() {
        let reg = Registry::new(ObsConfig {
            retained_traces: 2,
            ..ObsConfig::default()
        });
        reg.mark_retained(1);
        reg.mark_retained(1);
        reg.mark_retained(2);
        reg.mark_retained(3);
        assert_eq!(reg.retained_ids(), vec![2, 3]);
    }

    #[test]
    fn tree_reassembly_and_reachability() {
        let reg = Registry::new(ObsConfig::default());
        let sink_a = reg.server(0);
        let sink_b = reg.server(1);
        sink_a.sink().unwrap().record(span(7, 10, 0, "client/put"));
        sink_a.sink().unwrap().record(span(7, 11, 10, "Frontend/PutObject"));
        sink_b.sink().unwrap().record(span(7, 12, 11, "Backend/StoreChunkBatch"));
        sink_b.sink().unwrap().record(span(7, 99, 55, "orphan"));
        reg.mark_retained(7);
        let dump = reg.trace_dump();
        let tree = dump.trace(7).expect("retained trace");
        assert_eq!(tree.root().unwrap().name, "client/put");
        assert!(tree.reachable_from_root(12));
        assert!(!tree.reachable_from_root(99));
        assert_eq!(tree.children(10).len(), 1);
        let text = tree.render();
        assert!(text.contains("Backend/StoreChunkBatch"));
        assert!(text.contains("(orphan)"));
    }

    #[test]
    fn with_root_applies_tail_and_head_sampling() {
        let reg = Registry::new(ObsConfig {
            slow_op_threshold_ms: 10,
            head_sample_every: 4,
            ..ObsConfig::default()
        });
        let clock = AtomicU64::new(0);
        // ops 1..=3: fast, not retained; op 4: head-sampled; op 5: slow
        for i in 1..=5u64 {
            let body = || {
                if i == 5 {
                    clock.fetch_add(50, Ordering::Relaxed);
                }
            };
            reg.with_root("client/put", || clock.load(Ordering::Relaxed), body);
        }
        assert_eq!(reg.retained_ids().len(), 2);
        let dump = reg.trace_dump();
        assert_eq!(dump.traces.len(), 2);
        // the slow op's root span really ran ≥ threshold
        let mut roots = dump.traces.iter().filter_map(|t| t.root());
        assert!(roots.any(|r| r.duration_ms() >= 10));
    }

    #[test]
    fn sampler_fires_once_per_period_boundary() {
        let reg = Registry::new(ObsConfig {
            sample_every_ms: 100,
            ..ObsConfig::default()
        });
        reg.maybe_sample(50, MetricsSnapshot::default);
        assert_eq!(reg.samples().len(), 0);
        reg.maybe_sample(120, MetricsSnapshot::default);
        reg.maybe_sample(130, MetricsSnapshot::default);
        reg.maybe_sample(250, MetricsSnapshot::default);
        assert_eq!(reg.samples().len(), 2);
    }

    #[test]
    fn tracing_off_disables_roots_and_sinks() {
        let reg = Registry::new(ObsConfig {
            tracing: false,
            ..ObsConfig::default()
        });
        assert!(reg.server(0).sink().is_none());
        reg.with_root("client/put", || 0, || ());
        assert!(reg.retained_ids().is_empty());
        assert!(trace::current().is_none());
    }
}
