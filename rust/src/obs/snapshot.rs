//! Typed, serializable metrics snapshots and their renderers.
//!
//! [`crate::api::Cluster::metrics_snapshot`] materializes one
//! [`MetricsSnapshot`]: per-server counter values, histogram snapshots
//! (with p50/p90/p99 readout), per-lane queue-depth gauges and
//! flow-budget utilization per maintenance class. The cluster view is
//! *derived* — [`MetricsSnapshot::counter_total`] /
//! [`MetricsSnapshot::histogram_total`] aggregate, and
//! [`MetricsSnapshot::skew`] / [`MetricsSnapshot::hot_servers`] surface
//! per-server imbalance, the signal the old single global counter block
//! erased by construction.
//!
//! Both renderers are hand-rolled over `std` only: a Prometheus-style
//! text exposition ([`MetricsSnapshot::to_prometheus`]) and a JSON
//! document ([`MetricsSnapshot::to_json`]). All metric names are static
//! identifiers, so neither format needs an escaping pass.

use crate::metrics::HistogramSnapshot;
use std::fmt::Write as _;

/// Server label used for the cluster-scope entry (client roots, the
/// failure detector) in rendered output.
const CLUSTER_LABEL: &str = "cluster";

/// Flow-budget utilization of one maintenance class on one server.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlowClassUtil {
    /// Maintenance class name (`scrub` / `rebalance` / `gc` / `recovery`).
    pub class: &'static str,
    /// Tokens granted to this class since boot.
    pub granted: u64,
    /// Configured refill weight of this class.
    pub weight: u32,
    /// This class's share of all tokens granted on the server (0 when
    /// nothing was granted yet).
    pub share: f64,
}

/// One server's slice of a [`MetricsSnapshot`].
#[derive(Clone, Debug, Default)]
pub struct ServerSnapshot {
    /// Server id ([`crate::obs::CLIENT_SCOPE`] for the cluster-scope
    /// entry).
    pub server: u32,
    /// Counter name → value (from [`crate::metrics::Metrics::counters`]).
    pub counters: Vec<(&'static str, u64)>,
    /// Histogram name → point-in-time snapshot with quantile readout.
    pub histograms: Vec<(&'static str, HistogramSnapshot)>,
    /// Lane name → queued-request depth (live gauge, fed by the fabric's
    /// inbox depth counters).
    pub queue_depths: Vec<(&'static str, i64)>,
    /// Flow-budget utilization per maintenance class.
    pub flow: Vec<FlowClassUtil>,
}

impl ServerSnapshot {
    fn label(&self) -> String {
        if self.server == crate::obs::CLIENT_SCOPE {
            CLUSTER_LABEL.to_string()
        } else {
            self.server.to_string()
        }
    }
}

/// A typed point-in-time view of every metric in the cluster.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Capture time (ms since cluster start, from the injected clock).
    pub now_ms: u64,
    /// One entry per registered server, plus the cluster-scope entry,
    /// ordered by id.
    pub servers: Vec<ServerSnapshot>,
}

impl MetricsSnapshot {
    /// Sum of one counter across every entry (per-server sums ≡ the old
    /// cluster-global counter, because each increment lands on exactly
    /// one server's registry entry).
    pub fn counter_total(&self, name: &str) -> u64 {
        self.servers
            .iter()
            .flat_map(|s| s.counters.iter())
            .filter(|(n, _)| *n == name)
            .map(|(_, v)| v)
            .sum()
    }

    /// Cluster-level histogram: bucket-wise merge of one histogram
    /// across every server, with the usual quantile readout.
    pub fn histogram_total(&self, name: &str) -> HistogramSnapshot {
        let mut total = HistogramSnapshot::default();
        for s in &self.servers {
            for (n, h) in &s.histograms {
                if *n == name {
                    total.merge(h);
                }
            }
        }
        total
    }

    fn per_server_values(&self, name: &str) -> Vec<(u32, u64)> {
        self.servers
            .iter()
            .filter(|s| s.server != crate::obs::CLIENT_SCOPE)
            .map(|s| {
                let v = s
                    .counters
                    .iter()
                    .find(|(n, _)| *n == name)
                    .map(|(_, v)| *v)
                    .unwrap_or(0);
                (s.server, v)
            })
            .collect()
    }

    /// Skew of one counter across real servers: `max / mean` (1.0 means
    /// perfectly balanced; 0.0 when the counter is zero everywhere).
    pub fn skew(&self, name: &str) -> f64 {
        let values = self.per_server_values(name);
        if values.is_empty() {
            return 0.0;
        }
        let sum: u64 = values.iter().map(|(_, v)| v).sum();
        if sum == 0 {
            return 0.0;
        }
        let mean = sum as f64 / values.len() as f64;
        let max = values.iter().map(|(_, v)| *v).max().unwrap_or(0);
        max as f64 / mean
    }

    /// Servers whose value of `name` exceeds `factor ×` the per-server
    /// mean — the hot-shard detector the per-server registry exists for.
    pub fn hot_servers(&self, name: &str, factor: f64) -> Vec<u32> {
        let values = self.per_server_values(name);
        if values.is_empty() {
            return Vec::new();
        }
        let mean = values.iter().map(|(_, v)| v).sum::<u64>() as f64 / values.len() as f64;
        values
            .into_iter()
            .filter(|(_, v)| *v as f64 > factor * mean && *v > 0)
            .map(|(id, _)| id)
            .collect()
    }

    /// Prometheus-style text exposition (`snss_`-prefixed metric names,
    /// a `server` label per entry, histograms expanded to
    /// `_count`/`_mean_us`/`_p50_us`/`_p90_us`/`_p99_us` readouts).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "snss_snapshot_ms {}", self.now_ms);
        for s in &self.servers {
            let label = s.label();
            for (name, v) in &s.counters {
                let _ = writeln!(out, "snss_{name}{{server=\"{label}\"}} {v}");
            }
            for (name, h) in &s.histograms {
                let _ = writeln!(out, "snss_{name}_count{{server=\"{label}\"}} {}", h.count);
                let _ = writeln!(
                    out,
                    "snss_{name}_mean_us{{server=\"{label}\"}} {:.1}",
                    h.mean_us()
                );
                let _ = writeln!(
                    out,
                    "snss_{name}_p50_us{{server=\"{label}\"}} {}",
                    h.p50_us()
                );
                let _ = writeln!(
                    out,
                    "snss_{name}_p90_us{{server=\"{label}\"}} {}",
                    h.p90_us()
                );
                let _ = writeln!(
                    out,
                    "snss_{name}_p99_us{{server=\"{label}\"}} {}",
                    h.p99_us()
                );
            }
            for (lane, depth) in &s.queue_depths {
                let _ = writeln!(
                    out,
                    "snss_queue_depth{{server=\"{label}\",lane=\"{lane}\"}} {depth}"
                );
            }
            for f in &s.flow {
                let _ = writeln!(
                    out,
                    "snss_flow_granted{{server=\"{label}\",class=\"{}\"}} {}",
                    f.class, f.granted
                );
                let _ = writeln!(
                    out,
                    "snss_flow_share{{server=\"{label}\",class=\"{}\"}} {:.3}",
                    f.class, f.share
                );
            }
        }
        out
    }

    /// JSON document (hand-rolled, std-only). All keys are static
    /// identifiers and all values numeric, so no escaping is needed.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{{\"now_ms\":{},\"servers\":[", self.now_ms);
        for (i, s) in self.servers.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{{\"server\":\"{}\",\"counters\":{{", s.label());
            for (j, (name, v)) in s.counters.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                let _ = write!(out, "\"{name}\":{v}");
            }
            out.push_str("},\"histograms\":{");
            for (j, (name, h)) in s.histograms.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                let _ = write!(
                    out,
                    "\"{name}\":{{\"count\":{},\"mean_us\":{:.1},\"p50_us\":{},\"p90_us\":{},\"p99_us\":{}}}",
                    h.count,
                    h.mean_us(),
                    h.p50_us(),
                    h.p90_us(),
                    h.p99_us()
                );
            }
            out.push_str("},\"queue_depths\":{");
            for (j, (lane, depth)) in s.queue_depths.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                let _ = write!(out, "\"{lane}\":{depth}");
            }
            out.push_str("},\"flow\":{");
            for (j, f) in s.flow.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                let _ = write!(
                    out,
                    "\"{}\":{{\"granted\":{},\"weight\":{},\"share\":{:.3}}}",
                    f.class, f.granted, f.weight, f.share
                );
            }
            out.push_str("}}");
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap_with(counts: &[(u32, u64)]) -> MetricsSnapshot {
        MetricsSnapshot {
            now_ms: 42,
            servers: counts
                .iter()
                .map(|(id, v)| ServerSnapshot {
                    server: *id,
                    counters: vec![("messages", *v)],
                    ..Default::default()
                })
                .collect(),
        }
    }

    #[test]
    fn totals_skew_and_hot_servers() {
        let snap = snap_with(&[(0, 10), (1, 10), (2, 100), (crate::obs::CLIENT_SCOPE, 5)]);
        assert_eq!(snap.counter_total("messages"), 125);
        assert_eq!(snap.counter_total("missing"), 0);
        // mean over real servers = 40, max = 100 → skew 2.5
        assert!((snap.skew("messages") - 2.5).abs() < 1e-9);
        assert_eq!(snap.hot_servers("messages", 2.0), vec![2]);
        assert!(snap.hot_servers("messages", 3.0).is_empty());
    }

    #[test]
    fn renderers_cover_every_metric() {
        let mut snap = snap_with(&[(0, 7)]);
        snap.servers[0]
            .histograms
            .push(("put_latency", HistogramSnapshot::default()));
        snap.servers[0].queue_depths.push(("Frontend", 3));
        snap.servers[0].flow.push(FlowClassUtil {
            class: "scrub",
            granted: 9,
            weight: 1,
            share: 1.0,
        });
        let text = snap.to_prometheus();
        assert!(text.contains("snss_messages{server=\"0\"} 7"));
        assert!(text.contains("snss_put_latency_p99_us{server=\"0\"} 0"));
        assert!(text.contains("snss_queue_depth{server=\"0\",lane=\"Frontend\"} 3"));
        assert!(text.contains("snss_flow_granted{server=\"0\",class=\"scrub\"} 9"));
        let json = snap.to_json();
        assert!(json.contains("\"messages\":7"));
        assert!(json.contains("\"put_latency\""));
        assert!(json.contains("\"Frontend\":3"));
        assert!(json.contains("\"scrub\":{\"granted\":9"));
        assert!(json.starts_with('{') && json.ends_with('}'));
    }
}
