//! Trace-context propagation primitives.
//!
//! A [`TraceCtx`] is 24 bytes of plain data — `{trace_id, span_id,
//! parent}` — stamped into every fabric [`crate::net::Envelope`] at the
//! single construction site ([`crate::net::Addr::send`]). The sender
//! does not pass it explicitly: `send` reads the **thread-local current
//! span** ([`current`]), which the OSD lane loop sets to its handler
//! span before dispatching, so any nested fabric call made while
//! serving a request is automatically parented under that request's
//! span. Crossing a thread boundary *is* crossing a server boundary in
//! this simulator, which makes the thread-local exactly the right
//! carrier: context flows along the lane graph (frontend → backend →
//! replica) with zero signature changes anywhere.
//!
//! Span ids are drawn from one process-wide relaxed atomic counter —
//! unique across every simulated server, so cross-server trees can be
//! reassembled by id alone ([`crate::api::Cluster::trace_dump`]).
//! `trace_id == 0` is the reserved "not traced" value ([`TraceCtx::NONE`]).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Trace context carried in every fabric envelope: which trace this
/// message belongs to, the sender-side span it was issued from, and
/// that span's parent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    /// Trace identifier — shared by every span of one client operation.
    pub trace_id: u64,
    /// The span this message was sent from (the receiver's parent).
    pub span_id: u64,
    /// The sending span's own parent (0 for a client root).
    pub parent: u64,
}

impl TraceCtx {
    /// The "not traced" context (all zeros). Messages sent outside any
    /// span — admin calls, maintenance workers, heartbeats — carry this
    /// and produce no spans downstream.
    pub const NONE: TraceCtx = TraceCtx {
        trace_id: 0,
        span_id: 0,
        parent: 0,
    };

    /// True for the reserved untraced context.
    pub fn is_none(&self) -> bool {
        self.trace_id == 0
    }

    /// Open a fresh root context (new trace, new root span, parent 0).
    pub fn root() -> TraceCtx {
        TraceCtx {
            trace_id: next_id(),
            span_id: next_id(),
            parent: 0,
        }
    }

    /// Open a child context of `self`: same trace, fresh span id,
    /// parented under `self`'s span.
    pub fn child(&self) -> TraceCtx {
        TraceCtx {
            trace_id: self.trace_id,
            span_id: next_id(),
            parent: self.span_id,
        }
    }
}

/// One completed span, as retained by a [`crate::obs::SpanSink`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Trace this span belongs to.
    pub trace_id: u64,
    /// This span's id (unique process-wide).
    pub span_id: u64,
    /// Parent span id (0 for a client root).
    pub parent: u64,
    /// Server that executed the span ([`crate::obs::CLIENT_SCOPE`] for
    /// client roots).
    pub server: u32,
    /// Static operation name, e.g. `"Backend/StoreChunkBatch"`.
    pub name: &'static str,
    /// Span start (ms since cluster start, from the injected clock).
    pub start_ms: u64,
    /// Span end (ms since cluster start).
    pub end_ms: u64,
}

impl SpanRecord {
    /// Wall (or simulated) duration of the span.
    pub fn duration_ms(&self) -> u64 {
        self.end_ms.saturating_sub(self.start_ms)
    }
}

/// Process-wide span/trace id allocator. Starts at 1 so 0 stays the
/// reserved "untraced" value.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Draw a fresh process-unique id (relaxed — only uniqueness matters).
pub fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    /// The span the current thread is executing inside, stamped into
    /// every envelope this thread sends.
    static CURRENT: Cell<TraceCtx> = const { Cell::new(TraceCtx::NONE) };
}

/// The current thread's active span context ([`TraceCtx::NONE`] when
/// the thread is not serving a traced request).
pub fn current() -> TraceCtx {
    CURRENT.with(|c| c.get())
}

/// Set the current thread's active span context (lane loops call this
/// before dispatching a handler; clients call it around an op root).
pub fn set_current(ctx: TraceCtx) {
    CURRENT.with(|c| c.set(ctx));
}

/// Reset the current thread to untraced.
pub fn clear_current() {
    set_current(TraceCtx::NONE);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_nonzero() {
        let a = next_id();
        let b = next_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn child_links_to_parent() {
        let root = TraceCtx::root();
        assert_eq!(root.parent, 0);
        assert!(!root.is_none());
        let child = root.child();
        assert_eq!(child.trace_id, root.trace_id);
        assert_eq!(child.parent, root.span_id);
        assert_ne!(child.span_id, root.span_id);
    }

    #[test]
    fn thread_local_roundtrip() {
        assert!(current().is_none());
        let ctx = TraceCtx::root();
        set_current(ctx);
        assert_eq!(current(), ctx);
        clear_current();
        assert!(current().is_none());
    }

    #[test]
    fn thread_locals_are_independent() {
        let ctx = TraceCtx::root();
        set_current(ctx);
        let seen = std::thread::spawn(current).join().unwrap();
        assert!(seen.is_none());
        clear_current();
    }
}
