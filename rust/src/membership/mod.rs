//! Elastic membership: wipe-and-rejoin and map-change auto-rebalance.
//!
//! The paper's shared-nothing design assumes the cluster map can change
//! — servers join, fail, and return — while content-addressed placement
//! and dedup metadata stay consistent. This module closes the loop that
//! [`crate::recovery`] opened:
//!
//! * **Wipe-and-rejoin** ([`crate::api::Cluster::rejoin_server`]) — an
//!   `Out` server is re-admitted only after its *entire* local state
//!   (OMAP, CIT, backreference index, chunk store, replica store) is
//!   erased. The old identity was fenced on the out-transition and
//!   recovery re-homed its holdings onto the survivors, so every
//!   refcount in its CIT and every reference in its OMAP is stale by
//!   construction: re-admitting them would double-count shared chunks
//!   (corrupting GC's reclaim decisions) or resurrect deleted objects.
//!   An empty server re-admitted Up is merely *underweighted* — exactly
//!   the state `add_server` creates — and the normal rebalance/recovery
//!   machinery backfills it from authoritative copies.
//! * **Auto-rebalance** ([`auto_rebalance`]) — every map-change event
//!   (add, detector out, rejoin) fans a [`Req::StartRebalance`] to every
//!   `Up` server's control lane, fire-and-forget. The per-server
//!   rebalance workers ([`crate::storage::rebalance`]) run the scans,
//!   charging [`crate::sched::flow::MaintClass::Rebalance`] from the
//!   shared maintenance budget — no operator call, no unthrottled burst.
//! * **Detector quorum** lives in [`crate::recovery::detector`]: the
//!   Down→Out path that makes rejoin necessary now requires a
//!   configurable quorum of independent heartbeat observers, so one
//!   flaky control path cannot evict a healthy server.
//!
//! Observability: [`crate::metrics::Metrics::membership_rejoins`],
//! [`crate::metrics::Metrics::membership_wipes`] and
//! [`crate::metrics::Metrics::membership_auto_rebalances`] count the
//! three events; the join/evict paths run under `membership/*` root
//! trace spans.

use crate::cluster::{Monitor, ServerState};
use crate::metrics::Metrics;
use crate::net::Lane;
use crate::storage::osd::OsdShared;
use crate::storage::proto::{Dir, Req};

/// Fan a queued rebalance scan to every `Up` server (fire-and-forget:
/// the control-lane handler only enqueues on the rebalance worker) and
/// count one auto-rebalance event. Called on every map-change event —
/// server added, detector out-transition, admin removal, rejoin.
pub fn auto_rebalance(monitor: &Monitor, dir: &Dir, metrics: &Metrics) {
    Metrics::add(&metrics.membership_auto_rebalances, 1);
    let map = monitor.map();
    for s in map.servers.iter().filter(|s| s.state == ServerState::Up) {
        if let Ok(addr) = dir.lookup(s.id, Lane::Control) {
            let req = Req::StartRebalance;
            let size = req.wire_size();
            let _ = addr.send(req, size);
        }
    }
}

/// Erase one server's entire local state — DM-Shard (OMAP + CIT +
/// backreference index), primary chunk store and replica store — and
/// count the wipe. The caller must have fenced the server first (lanes
/// dead, workers cleared): this is the "wipe" half of wipe-and-rejoin,
/// never valid on a live identity.
pub(crate) fn wipe_local_state(sh: &OsdShared) -> crate::error::Result<()> {
    sh.shard.wipe()?;
    sh.store.clear()?;
    sh.replica_store.clear()?;
    // coherence: no cached payload (or planted-copy bookkeeping) may
    // survive the wipe — the rejoined server starts empty
    sh.chunk_cache.clear();
    Metrics::add(&sh.metrics.membership_wipes, 1);
    Ok(())
}
