//! Public entry point: [`ClusterConfig`] → [`Cluster`] → [`Client`].
//!
//! A `Cluster` assembles the monitor, the placement layer, one OSD
//! thread-group per server, the optional failure detector and the shared
//! metrics, then hands out cheap clonable [`Client`] handles. Admin
//! operations (add/kill/restart/remove/rejoin server, rebalance, GC,
//! audit, scrub, recovery) live on the cluster object; data operations
//! live on clients.

use crate::cluster::{Monitor, ServerId, ServerState};
use crate::dedup::consistency::ConsistencyMode;
use crate::dedup::dmshard::DmShard;
use crate::dedup::fingerprint::{FingerprintProvider, RustSha1Provider};
use crate::dedup::{Chunker, Chunking};
use crate::error::{Error, Result};
use crate::failure::{CrashPoint, FailureInjector};
use crate::kvstore::{LogKv, MemKv};
use crate::metrics::Metrics;
use crate::net::{Lane, NetProfile};
use crate::obs::{
    FlowClassUtil, MetricsSnapshot, ObsConfig, Registry, ServerSnapshot, TraceDump, CLIENT_SCOPE,
};
use crate::placement::pg::PgMap;
use crate::placement::{rendezvous::Rendezvous, straw2::Straw2, PlacementPolicy};
use crate::recovery::detector::{self, Detector};
use crate::sched::backpressure::Gate;
use crate::sched::flow::FlowController;
use crate::sched::SchedCtl;
use crate::storage::backend::{FileStore, MemStore};
use crate::storage::osd::{Osd, OsdConfig, OsdShared};
use crate::storage::proto::{AuditDump, Dir, OsdStats, Req, Resp};
use crate::util::clock::{Clock, SimClock, WallClock};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

pub use crate::dedup::cache::{CacheConfig, DupPolicy};
pub use crate::dedup::consistency::ConsistencyMode as Consistency;
pub use crate::dedup::engine::{DedupMode, ReadBatching, WriteBatching};
pub use crate::dedup::fpipe::FpMode;
pub use crate::dedup::redundancy::{RedundancyBand, RedundancyPolicy};
pub use crate::recovery::{
    FailureDetection, ObserverHook, ObserverVerdict, RecoveryState, RecoveryStatus,
};
pub use crate::storage::rebalance::{RebalanceState, RebalanceStatus};
pub use crate::sched::flow::{FlowConfig, MaintClass};
pub use crate::sched::{SchedStatus, ScrubSchedule};
pub use crate::scrub::{ScrubKind, ScrubOptions, ScrubState, ScrubStatus};

/// Placement policy choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// CRUSH-like straw2 (default, as in Ceph).
    Straw2,
    /// Rendezvous/HRW (ablation).
    Rendezvous,
}

/// Durable-storage backends for chunk data and DM-Shards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Durability {
    /// Everything in memory (fast; still survives *simulated* crashes —
    /// kill/restart models a process crash, not power loss).
    Memory,
    /// Chunk data and DM-Shards persisted under this directory
    /// (file-per-chunk + bitcask logs) — survives real process restarts.
    Disk(PathBuf),
}

/// Time source driving CIT timestamps, GC age thresholds and the
/// maintenance scheduler (see [`crate::util::clock`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ClockSource {
    /// Monotonic wall time relative to cluster start (production).
    #[default]
    Wall,
    /// A deterministic virtual clock that only moves when
    /// [`Cluster::advance_clock`] is called (tests).
    Sim,
}

/// Fingerprint engine choice.
#[derive(Clone, Debug)]
pub enum FingerprintBackend {
    /// From-scratch scalar SHA-1 on each frontend thread (default).
    RustSha1,
    /// The AOT Pallas batched kernel through PJRT; falls back to scalar
    /// SHA-1 for shapes without a compiled variant.
    Xla { artifacts_dir: PathBuf },
}

/// Full cluster configuration.
#[derive(Clone)]
pub struct ClusterConfig {
    /// Number of storage servers.
    pub servers: usize,
    /// Replica count for chunk data + OMAP copies (1 = no replication).
    pub replication: usize,
    /// Refcount-banded redundancy policy layered on `replication`: the
    /// more objects share a chunk, the more copies it gets (capped by
    /// the live-server count). The default flat policy keeps every
    /// chunk at exactly `replication` copies; see
    /// [`RedundancyPolicy::banded`] and DESIGN.md §15.
    pub redundancy: RedundancyPolicy,
    /// Placement groups (power of two).
    pub pg_count: u32,
    /// Dedup architecture.
    pub dedup: DedupMode,
    /// Commit-flag consistency mode.
    pub consistency: ConsistencyMode,
    /// Write-path chunk scatter protocol: per-home two-phase batches
    /// (the default) or the legacy per-chunk `StoreChunk` fan-out.
    pub write_batching: WriteBatching,
    /// Read-path chunk gather protocol: per-home `FetchChunkBatch`
    /// messages (the default) or the legacy per-chunk `FetchChunk`
    /// fan-out (DESIGN.md §14).
    pub read_batching: ReadBatching,
    /// Per-server hot-chunk cache sizing/admission (capacity 0
    /// disables caching).
    pub cache: CacheConfig,
    /// Fragmentation-aware selective duplication of hot remote chunks
    /// (`None` = off, the default): plant extra locality copies of
    /// chunks this server keeps fetching over the fabric, under the
    /// rebalance class of the maintenance flow budget.
    pub selective_dup: Option<DupPolicy>,
    /// Chunking policy.
    pub chunking: Chunking,
    /// Placement policy.
    pub placement: Placement,
    /// Storage durability.
    pub durability: Durability,
    /// Fingerprint engine.
    pub fingerprint: FingerprintBackend,
    /// Optional wire-cost model.
    pub net: Option<NetProfile>,
    /// Optional modeled latency per synchronous DM-Shard write (the
    /// paper's SQLite-on-SSD backend; see `OsdConfig::meta_io`).
    pub meta_io: Option<std::time::Duration>,
    /// Verify chunk digests on read.
    pub verify_read: bool,
    /// Confirm freshly replicated chunk copies by content with a
    /// `VerifyCopy` fan-out (off by default; one extra replica-lane
    /// round trip per unique chunk).
    pub verify_write: bool,
    /// Observability: tracing, span sampling and the metrics sampler
    /// (see [`crate::obs::ObsConfig`]; tracing defaults on).
    pub obs: ObsConfig,
    /// Time source (wall for production, virtual for deterministic
    /// scheduler/throttling tests).
    pub clock: ClockSource,
    /// Per-server shared maintenance budget (scrub + rebalance + GC
    /// weighted classes); default unlimited.
    pub maint_flow: FlowConfig,
    /// Replica-lane `VerifyCopy` in-flight cap (0 = unlimited): past it
    /// the lane sheds probes with `Busy` NACKs that scrub senders honor
    /// with window shrink + backoff.
    pub verify_inflight_cap: usize,
    /// Autonomous failure detection (`None` = off, the default): the
    /// cluster heartbeats every server, marks silent ones `Down` after
    /// the grace window and `Out` after the out window, and triggers
    /// recovery backfill on every out-transition — see
    /// [`crate::recovery`]. Deterministic under [`ClockSource::Sim`]
    /// (the detector evaluates on every [`Cluster::advance_clock`]).
    pub failure_detection: Option<FailureDetection>,
    /// Fingerprint pipeline mode (DESIGN.md §16): [`FpMode::Inline`]
    /// (the default — every chunk strong-hashed on the write path,
    /// bit-for-bit today's behavior) or [`FpMode::Tiered`] — a weak-hash
    /// prefilter inline, deferred batched strong hashing in the
    /// background, and verify-before-merge collision safety. Effective
    /// for [`DedupMode::ClusterWide`] writes only.
    pub fp_mode: FpMode,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            servers: 4,
            replication: 2,
            redundancy: RedundancyPolicy::flat(),
            pg_count: 128,
            dedup: DedupMode::ClusterWide,
            consistency: ConsistencyMode::AsyncTagged,
            write_batching: WriteBatching::TwoPhase,
            read_batching: ReadBatching::PerHome,
            cache: CacheConfig::default(),
            selective_dup: None,
            chunking: Chunking::Fixed { size: 64 * 1024 },
            placement: Placement::Straw2,
            durability: Durability::Memory,
            fingerprint: FingerprintBackend::RustSha1,
            net: None,
            meta_io: None,
            verify_read: false,
            verify_write: false,
            obs: ObsConfig::default(),
            clock: ClockSource::Wall,
            maint_flow: FlowConfig::default(),
            verify_inflight_cap: 64,
            failure_detection: None,
            fp_mode: FpMode::Inline,
        }
    }
}

/// Aggregated cluster statistics.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    /// Logical bytes accepted from clients (pre-dedup).
    pub logical_bytes: u64,
    /// Unique chunk bytes stored (primary copies).
    pub stored_bytes: u64,
    /// Replica chunk bytes stored.
    pub replica_bytes: u64,
    /// Duplicate hits (refcount increments granted).
    pub dedup_hits: u64,
    /// Unique chunks written.
    pub unique_chunks: u64,
    /// CIT lookups served.
    pub cit_lookups: u64,
    /// Repair events across all subsystems.
    pub repairs: u64,
    /// Chunks reclaimed by GC.
    pub gc_reclaimed: u64,
    /// Write transactions aborted.
    pub tx_aborts: u64,
    /// CIT entries examined by scrub passes.
    pub scrub_chunks_checked: u64,
    /// Chunk bytes re-read and re-fingerprinted by deep scrub.
    pub scrub_bytes_verified: u64,
    /// Primary-chunk digest mismatches (bit-rot) found by deep scrub.
    pub scrub_corruptions_found: u64,
    /// Scrub repairs applied (primaries and replica copies).
    pub scrub_repaired: u64,
    /// Backreference-index records written/deleted by OMAP mutations.
    pub backref_updates: u64,
    /// Reference counts answered from the backreference index.
    pub backref_lookups: u64,
    /// Full index re-derivations (recovery + migration).
    pub backref_rebuilds: u64,
    /// Index ↔ OMAP discrepancies found by audits.
    pub backref_mismatches: u64,
    /// `ProbeChunks` messages sent (batched write path, Phase A).
    pub probe_batches: u64,
    /// Fingerprints a Phase-A probe reported already Valid (payload
    /// elided from Phase B).
    pub probe_hits: u64,
    /// `StoreChunkBatch` messages sent (Phase B + NeedData resends).
    pub store_batches: u64,
    /// Chunk items carried by all `StoreChunkBatch` messages.
    pub batch_items: u64,
    /// Fingerprints re-shipped with payload after a `NeedData` NACK.
    pub need_data_resends: u64,
    /// Backend-lane bytes the dedup engine put on the wire (request
    /// sizes of chunk scatter, probes, batches, refcount releases).
    pub wire_bytes: u64,
    /// Scheduled scrub passes fired by the maintenance scheduler.
    pub sched_fires: u64,
    /// Scheduled due times skipped because a pass was still running.
    pub sched_skipped_busy: u64,
    /// Maintenance tokens granted to scrub by the shared budget.
    pub flow_granted_scrub: u64,
    /// Maintenance tokens granted to rebalance by the shared budget.
    pub flow_granted_rebalance: u64,
    /// Maintenance tokens granted to GC by the shared budget.
    pub flow_granted_gc: u64,
    /// Times a maintenance consumer waited for budget refill.
    pub flow_waits: u64,
    /// `Busy` NACKs sent by replica lanes shedding `VerifyCopy` storms.
    pub backpressure_busy: u64,
    /// `VerifyCopy` probes re-sent after a `Busy` NACK.
    pub backpressure_retries: u64,
    /// Sender AIMD window halvings triggered by `Busy` NACKs.
    pub backpressure_window_shrinks: u64,
    /// Probes abandoned after the retry budget (0 in steady state).
    pub backpressure_gave_up: u64,
    /// Maintenance tokens granted to recovery backfill by the budget.
    pub flow_granted_recovery: u64,
    /// Heartbeat probes sent by the failure detector.
    pub detector_probes: u64,
    /// Servers the detector marked Down (silent past the grace window).
    pub detector_marked_down: u64,
    /// Down servers the detector marked Up again (heartbeats resumed).
    pub detector_marked_up: u64,
    /// Servers the detector marked Out (each triggers recovery).
    pub detector_marked_out: u64,
    /// Recovery jobs started by workers.
    pub recovery_runs: u64,
    /// Work items examined by recovery backfill.
    pub recovery_chunks_scanned: u64,
    /// Primary chunks/objects restored from a surviving copy.
    pub recovery_chunks_restored: u64,
    /// Replica copies (chunk + OMAP record) re-pushed by recovery.
    pub recovery_copies_pushed: u64,
    /// Bytes re-replicated by recovery.
    pub recovery_bytes: u64,
    /// OMAP records re-homed onto new primaries by recovery.
    pub recovery_omap_recovered: u64,
    /// CIT refcounts re-synchronized by recovery's reconcile step.
    pub recovery_refs_fixed: u64,
    /// Referenced chunks with no surviving copy anywhere (quarantined;
    /// 0 unless more copies were lost than replication covers).
    pub recovery_lost: u64,
    /// Object reads counted by the read-amplification accounting.
    pub read_amp_reads: u64,
    /// Distinct chunk homes touched across all counted object reads
    /// (`read_amp_homes / read_amp_reads` = mean fan-out per read).
    pub read_amp_homes: u64,
    /// `FetchChunkBatch` messages sent (batched read path; ≤ 1 per
    /// distinct live chunk home per read, plus Busy retries).
    pub read_batches: u64,
    /// Chunk fetches carried inside `FetchChunkBatch` messages.
    pub read_batch_items: u64,
    /// Single-chunk `FetchChunk` messages sent (legacy path + degraded
    /// fallback; 0 on a healthy batched cluster).
    pub read_chunk_fetches: u64,
    /// Chunks the batched read path degraded to the per-item path.
    pub read_fallbacks: u64,
    /// Chunk fetches that fell back after a home stayed `Busy` through
    /// its granted retry.
    pub read_degraded_busy: u64,
    /// Chunk fetches that fell back on a dead/unreachable/missing home.
    pub read_degraded_dead: u64,
    /// Hot-chunk cache hits.
    pub read_cache_hits: u64,
    /// Hot-chunk cache misses.
    pub read_cache_misses: u64,
    /// Payloads admitted to hot-chunk caches.
    pub read_cache_insertions: u64,
    /// Cache entries evicted by capacity pressure.
    pub read_cache_evictions: u64,
    /// Cache entries dropped by coherence invalidation hooks.
    pub read_cache_invalidations: u64,
    /// Locality copies planted by selective duplication.
    pub dup_chunks_planted: u64,
    /// Planted locality copies evicted to respect the byte budget.
    pub dup_chunks_evicted: u64,
    /// `Out` servers wiped and re-admitted by [`Cluster::rejoin_server`].
    pub membership_rejoins: u64,
    /// Local-state wipes performed on the rejoin path.
    pub membership_wipes: u64,
    /// Map-change events that auto-enqueued a cluster-wide rebalance.
    pub membership_auto_rebalances: u64,
    /// Replica-copy pushes that failed at any fan-out site (dead peer,
    /// `Busy` shed, error reply) instead of being silently shrugged off.
    pub replica_push_failures: u64,
    /// Copy-adds applied by the online redundancy promotion hook.
    pub redundancy_promotions: u64,
    /// Copy-drops applied by the online demotion hook and the scrub's
    /// excess sweep (plant-registry-aware).
    pub redundancy_demotions: u64,
    /// Sum of banded copy targets computed at write-time fan-out
    /// (divide by `unique_chunks` for the mean target).
    pub redundancy_target_copies: u64,
    /// Orphaned locality plants reclaimed through the
    /// `invalidate_chunk` choke point.
    pub dup_plants_reclaimed: u64,
    /// Tiered-pipeline writes whose weak hash hit the candidacy filter
    /// (probable duplicates, strong-hashed inline).
    pub fp_weak_hits: u64,
    /// Tiered-pipeline writes whose weak hash missed the filter
    /// (unique-looking; inline strong hash skipped).
    pub fp_weak_misses: u64,
    /// Chunks strong-hashed inline on the write path (every chunk under
    /// [`FpMode::Inline`]; only filter hits and verify rejects under
    /// [`FpMode::Tiered`]).
    pub fp_strong_hashes: u64,
    /// Chunks deferred with a pending identity for background hashing.
    pub fp_deferred: u64,
    /// Batched `digests` calls issued by the tier-2 migrator.
    pub fp_batch_calls: u64,
    /// Chunks hashed across all tier-2 batches (`fp_batch_items /
    /// fp_batch_calls` = mean batch size).
    pub fp_batch_items: u64,
    /// Weak-hash matches rejected by byte-compare verification — the
    /// verify-before-merge guard refusing a refcount merge.
    pub fp_verify_rejects: u64,
    /// Pending chunks migrated into the content-addressed dedup domain.
    pub fp_migrations: u64,
    /// Per-server snapshots.
    pub per_server: Vec<OsdStats>,
}

impl ClusterStats {
    /// Space savings: 1 - stored/logical.
    pub fn savings(&self) -> f64 {
        if self.logical_bytes == 0 {
            0.0
        } else {
            1.0 - self.stored_bytes as f64 / self.logical_bytes as f64
        }
    }
}

/// Cluster-wide redundancy census (see DESIGN.md §15): for every
/// referenced chunk, the banded copy target (the [`RedundancyPolicy`]
/// applied to its refcount) is compared against the copies actually on
/// live servers — the primary plus the chain's replica-slot copies,
/// *excluding* selective-duplication locality plants, which were never
/// counted toward the target. Produced by
/// [`Cluster::redundancy_report`]; tests and the recovery bench use it
/// to assert exact convergence and measure space overhead.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RedundancyReport {
    /// Referenced chunks examined (refcount > 0, home alive).
    pub chunks: u64,
    /// Chunks holding exactly their banded copy target.
    pub at_target: u64,
    /// Chunks with fewer live copies than their target (degraded).
    pub below_target: u64,
    /// Chunks with more live copies than their target (a missed
    /// demotion; scrub's excess sweep drains these).
    pub above_target: u64,
    /// Chunks whose refcount is in the policy's top band.
    pub top_band_chunks: u64,
    /// Top-band chunks below their target (the MTTR numerator the
    /// recovery bench drives to zero).
    pub top_band_below: u64,
    /// Bytes held as primary copies across examined chunks.
    pub primary_bytes: u64,
    /// Bytes held as redundancy copies across examined chunks.
    pub copy_bytes: u64,
}

impl RedundancyReport {
    /// Every examined chunk sits exactly at its banded target.
    pub fn is_converged(&self) -> bool {
        self.below_target == 0 && self.above_target == 0
    }
}

/// Cluster-wide invariant-check report (see DESIGN.md §5).
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    /// Human-readable violations; empty = healthy.
    pub violations: Vec<String>,
    /// Fingerprints audited.
    pub fingerprints: usize,
    /// Total OMAP references seen.
    pub references: u64,
}

impl AuditReport {
    /// No violations found.
    pub fn is_ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Cluster-wide scrub report: per-server worker snapshots plus their
/// aggregate (see [`crate::scrub`] for field semantics).
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    /// One status per live server polled.
    pub per_server: Vec<ScrubStatus>,
    /// CIT entries examined.
    pub chunks_checked: u64,
    /// Bytes re-read and re-fingerprinted (deep only).
    pub bytes_verified: u64,
    /// Digest mismatches found on primary chunk data (deep only).
    pub corruptions_found: u64,
    /// Data repairs applied.
    pub repaired: u64,
    /// Commit flags confirmed valid against present data.
    pub flags_confirmed: u64,
    /// CIT refcounts re-synchronized to the cluster-wide count.
    pub refs_fixed: u64,
    /// Entries skipped because their home moved (rebalancer's job).
    pub misplaced: u64,
    /// Referenced chunks with no healthy copy anywhere.
    pub lost: u64,
    /// Replica-copy probes abandoned under backpressure (left for the
    /// next pass; 0 in steady state).
    pub copies_unverified: u64,
}

impl ScrubReport {
    /// Is any server's pass still queued or running?
    pub fn is_running(&self) -> bool {
        self.per_server
            .iter()
            .any(|s| matches!(s.state, ScrubState::Queued | ScrubState::Running))
    }

    /// Did every polled server finish its pass cleanly?
    pub fn all_done(&self) -> bool {
        self.per_server
            .iter()
            .all(|s| s.state == ScrubState::Done)
    }

    /// First per-server failure, if any pass aborted.
    pub fn first_failure(&self) -> Option<String> {
        self.per_server.iter().find_map(|s| match &s.state {
            ScrubState::Failed(e) => Some(format!("osd.{}: {e}", s.server)),
            _ => None,
        })
    }
}

/// Cluster-wide recovery report: per-server worker snapshots plus their
/// aggregate (see [`crate::recovery`] for field semantics).
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// One status per live server polled.
    pub per_server: Vec<RecoveryStatus>,
    /// Work items examined.
    pub chunks_scanned: u64,
    /// Primary chunks/objects restored from a surviving copy.
    pub chunks_restored: u64,
    /// Replica copies re-pushed.
    pub copies_pushed: u64,
    /// Bytes re-replicated.
    pub bytes_recovered: u64,
    /// OMAP records re-homed.
    pub omap_recovered: u64,
    /// CIT refcounts re-synchronized.
    pub refs_fixed: u64,
    /// Referenced chunks with no surviving copy anywhere.
    pub lost_chunks: u64,
}

impl RecoveryReport {
    /// Is any server's recovery job still queued or running?
    pub fn is_running(&self) -> bool {
        self.per_server.iter().any(|s| {
            s.queued > 0 || matches!(s.state, RecoveryState::Queued | RecoveryState::Running)
        })
    }

    /// First per-server failure, if any job aborted.
    pub fn first_failure(&self) -> Option<String> {
        self.per_server.iter().find_map(|s| match &s.state {
            RecoveryState::Failed(e) => Some(format!("osd.{}: {e}", s.server)),
            _ => None,
        })
    }
}

/// Cluster-wide rebalance report: per-server worker snapshots plus
/// their aggregate (see [`crate::storage::rebalance`] for field
/// semantics). Named distinctly from the per-scan
/// [`crate::storage::rebalance::RebalanceReport`].
#[derive(Clone, Debug, Default)]
pub struct RebalanceProgress {
    /// One status per live server polled.
    pub per_server: Vec<RebalanceStatus>,
    /// Completed scans across all servers.
    pub runs: u64,
    /// Chunks migrated to their new primary.
    pub chunks_moved: u64,
    /// Bytes of chunk data migrated.
    pub chunk_bytes_moved: u64,
    /// OMAP records re-homed.
    pub omap_moved: u64,
    /// Moves skipped because the destination was unreachable.
    pub skipped_unreachable: u64,
}

impl RebalanceProgress {
    /// Is any server's rebalance scan still queued or running?
    pub fn is_running(&self) -> bool {
        self.per_server.iter().any(|s| {
            s.queued > 0 || matches!(s.state, RebalanceState::Queued | RebalanceState::Running)
        })
    }

    /// First per-server failure, if any scan aborted.
    pub fn first_failure(&self) -> Option<String> {
        self.per_server.iter().find_map(|s| match &s.state {
            RebalanceState::Failed(e) => Some(format!("osd.{}: {e}", s.server)),
            _ => None,
        })
    }
}

/// A running cluster.
pub struct Cluster {
    cfg: ClusterConfig,
    monitor: Arc<Monitor>,
    pgmap: Arc<PgMap>,
    dir: Dir,
    /// The cluster-scope metrics entry ([`crate::obs::CLIENT_SCOPE`]):
    /// client-side and failure-detector activity. Per-server counters
    /// live on each server's own registry entry.
    metrics: Arc<Metrics>,
    /// Observability registry: per-server metrics entries, span rings
    /// and the tail-sampling state.
    obs: Arc<Registry>,
    clock: Arc<dyn Clock>,
    /// The virtual clock handle when `cfg.clock == ClockSource::Sim`.
    sim: Option<Arc<SimClock>>,
    provider: Arc<dyn FingerprintProvider>,
    osds: Arc<Mutex<HashMap<ServerId, Osd>>>,
    /// Failure detector (when `cfg.failure_detection` is on).
    detector: Option<Arc<Detector>>,
    /// Shutdown flag + handle of the wall-clock detector thread.
    det_shutdown: Arc<AtomicBool>,
    det_thread: Option<std::thread::JoinHandle<()>>,
}

impl Cluster {
    /// Boot a cluster.
    pub fn new(cfg: ClusterConfig) -> Result<Cluster> {
        if cfg.servers == 0 {
            return Err(Error::Invalid("servers must be > 0".into()));
        }
        if cfg.replication == 0 {
            return Err(Error::Invalid("replication must be >= 1".into()));
        }
        if let Some(fd) = &cfg.failure_detection {
            fd.validate()?;
        }
        let monitor = Arc::new(Monitor::new(cfg.servers));
        let policy: Box<dyn PlacementPolicy> = match cfg.placement {
            Placement::Straw2 => Box::new(Straw2),
            Placement::Rendezvous => Box::new(Rendezvous),
        };
        // chains must be wide enough for the top redundancy band, not
        // just the flat replication factor — promotion needs the slots
        let chain_width = cfg.redundancy.max_copies(cfg.replication).max(2);
        let pgmap = Arc::new(PgMap::new(policy, cfg.pg_count, chain_width));
        let dir: Dir = Dir::new();
        let obs = Registry::new(cfg.obs.clone());
        // the cluster-scope registry entry doubles as the old "shared"
        // metrics handle: client + detector increments land here, while
        // every OSD bumps its own per-server entry.
        let metrics = obs.server(CLIENT_SCOPE).metrics().clone();
        let sim = match cfg.clock {
            ClockSource::Sim => Some(Arc::new(SimClock::new())),
            ClockSource::Wall => None,
        };
        let clock: Arc<dyn Clock> = match &sim {
            Some(s) => s.clone(),
            None => Arc::new(WallClock::new()),
        };
        let provider: Arc<dyn FingerprintProvider> = match &cfg.fingerprint {
            FingerprintBackend::RustSha1 => Arc::new(RustSha1Provider),
            FingerprintBackend::Xla { artifacts_dir } => {
                Arc::new(crate::runtime::XlaFingerprintService::start(artifacts_dir)?)
            }
        };
        let detector = cfg
            .failure_detection
            .as_ref()
            .map(|fd| Arc::new(Detector::new(*fd)));
        let mut cluster = Cluster {
            cfg,
            monitor,
            pgmap,
            dir,
            metrics,
            obs,
            clock,
            sim,
            provider,
            osds: Arc::new(Mutex::new(HashMap::new())),
            detector,
            det_shutdown: Arc::new(AtomicBool::new(false)),
            det_thread: None,
        };
        let ids: Vec<ServerId> = cluster.monitor.map().servers.iter().map(|s| s.id).collect();
        for id in ids {
            cluster.spawn_osd(id)?;
        }
        if let Some(det) = &cluster.detector {
            let now = cluster.clock.now_ms();
            for s in &cluster.monitor.map().servers {
                det.register(s.id, now);
            }
            if cluster.sim.is_none() {
                // wall-clock mode: a cluster-level thread drives the
                // detector; virtual-clock tests tick it from advance_clock
                let det = det.clone();
                let monitor = cluster.monitor.clone();
                let dir = cluster.dir.clone();
                let osds = cluster.osds.clone();
                let metrics = cluster.metrics.clone();
                let clock = cluster.clock.clone();
                let sd = cluster.det_shutdown.clone();
                cluster.det_thread = Some(
                    std::thread::Builder::new()
                        .name("cluster-detector".into())
                        .spawn(move || {
                            while !sd.load(Ordering::SeqCst) {
                                std::thread::sleep(detector::DETECTOR_POLL);
                                detector::run_tick(
                                    &det,
                                    &monitor,
                                    &dir,
                                    &osds,
                                    &metrics,
                                    clock.now_ms(),
                                );
                            }
                        })
                        .expect("spawn detector"),
                );
            }
        }
        Ok(cluster)
    }

    fn spawn_osd(&self, id: ServerId) -> Result<()> {
        let (omap, cit, backref, store, replica): (
            Box<dyn crate::kvstore::KvStore>,
            Box<dyn crate::kvstore::KvStore>,
            Box<dyn crate::kvstore::KvStore>,
            Box<dyn crate::storage::backend::StorageBackend>,
            Box<dyn crate::storage::backend::StorageBackend>,
        ) = match &self.cfg.durability {
            Durability::Memory => (
                Box::new(MemKv::new()),
                Box::new(MemKv::new()),
                Box::new(MemKv::new()),
                Box::new(MemStore::new()),
                Box::new(MemStore::new()),
            ),
            Durability::Disk(root) => {
                let base = root.join(format!("osd{}", id.0));
                (
                    Box::new(LogKv::open(base.join("omap.log"))?),
                    Box::new(LogKv::open(base.join("cit.log"))?),
                    Box::new(LogKv::open(base.join("backref.log"))?),
                    Box::new(FileStore::open(base.join("data"))?),
                    Box::new(FileStore::open(base.join("replica"))?),
                )
            }
        };
        let entry = self.obs.server(id.0);
        let metrics = entry.metrics().clone();
        let shard = DmShard::new(omap, cit, backref);
        if shard.omap_len() > 0 {
            // cold open with existing layouts: a pre-index store has no
            // backref records at all, and a store from an unclean process
            // death may hold a torn index (an OMAP write separated from
            // its index write) that is *non-empty* but wrong. Either way
            // the OMAP is the source of truth — re-derive before any lane
            // can consult the index.
            shard.rebuild_backrefs()?;
            Metrics::add(&metrics.backref_rebuilds, 1);
        }
        let shared = Arc::new(OsdShared {
            id,
            cfg: OsdConfig {
                dedup: self.cfg.dedup,
                consistency: self.cfg.consistency,
                write_batching: self.cfg.write_batching,
                chunker: Chunker::new(self.cfg.chunking),
                replication: self.cfg.replication,
                redundancy: self.cfg.redundancy.clone(),
                verify_read: self.cfg.verify_read,
                verify_write: self.cfg.verify_write,
                meta_io: self.cfg.meta_io,
                read_batching: self.cfg.read_batching,
                cache: self.cfg.cache,
                selective_dup: self.cfg.selective_dup,
                fp_mode: self.cfg.fp_mode,
            },
            map: self.monitor.map_handle(),
            pgmap: self.pgmap.clone(),
            shard,
            store,
            replica_store: replica,
            pending: crate::dedup::consistency::PendingFlags::new(),
            chunk_cache: crate::dedup::cache::ChunkCache::new(self.cfg.cache),
            scrub: crate::scrub::ScrubCtl::for_server(id.0),
            recovery: crate::recovery::RecoveryCtl::for_server(id.0),
            rebalance: crate::storage::rebalance::RebalanceCtl::for_server(id.0),
            sched: SchedCtl::new(),
            flow: FlowController::new(self.cfg.maint_flow.clone(), self.clock.clone()),
            verify_gate: Gate::new(self.cfg.verify_inflight_cap),
            injector: FailureInjector::new(),
            metrics,
            obs: entry,
            dir: self.dir.clone(),
            provider: self.provider.clone(),
            clock: self.clock.clone(),
            obj_lock: Mutex::new(()),
            probe_gap_hook: Mutex::new(None),
            repair_debt: Mutex::new(std::collections::HashSet::new()),
            fpipe: crate::dedup::fpipe::FpipeCtl::for_mode(self.cfg.fp_mode),
        });
        let osd = Osd::spawn(shared, self.cfg.net);
        self.osds.lock().unwrap().insert(id, osd);
        Ok(())
    }

    /// A clonable data-path handle.
    pub fn client(&self) -> Client {
        Client {
            dedup: self.cfg.dedup,
            map: self.monitor.map_handle(),
            pgmap: self.pgmap.clone(),
            dir: self.dir.clone(),
            clock: self.clock.clone(),
            obs: self.obs.clone(),
        }
    }

    /// The cluster configuration in effect.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// The cluster-scope metrics entry (client + detector activity).
    /// Per-server counters live on each server's registry entry; use
    /// [`Cluster::stats`] or [`Cluster::metrics_snapshot`] for totals.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The observability registry (per-server metrics entries, span
    /// rings and sampling state).
    pub fn obs(&self) -> &Arc<Registry> {
        &self.obs
    }

    /// The virtual clock handle (`Some` only under [`ClockSource::Sim`]).
    /// Test hooks that run on OSD threads use this to advance time
    /// without borrowing the cluster.
    pub fn sim_clock(&self) -> Option<Arc<SimClock>> {
        self.sim.clone()
    }

    /// Current map epoch.
    pub fn epoch(&self) -> u64 {
        self.monitor.map().epoch
    }

    // ---- membership / failure admin ----

    /// Add a server and rebalance the whole cluster onto the new map.
    /// The map change auto-enqueues a rebalance scan on every server
    /// ([`crate::membership::auto_rebalance`]); this call then blocks
    /// until the scans drain so the newcomer holds its share on return.
    pub fn add_server(&self) -> Result<ServerId> {
        let body = || {
            let (id, _) = self.monitor.add_server(1.0);
            self.spawn_osd(id)?;
            if let Some(det) = &self.detector {
                det.register(id, self.clock.now_ms());
            }
            crate::membership::auto_rebalance(&self.monitor, &self.dir, &self.metrics);
            self.rebalance_wait()?;
            Ok(id)
        };
        self.obs.with_root("membership/join", || self.clock.now_ms(), body)
    }

    /// Abrupt, silent crash of a server. The map is not touched here:
    /// with [`ClusterConfig::failure_detection`] armed, the detector
    /// notices the silence, walks the server Down → Out and triggers
    /// recovery backfill ([`crate::recovery`]); without it, the crash
    /// stays invisible to placement until an admin reacts — exactly a
    /// machine that stopped answering.
    pub fn kill_server(&self, id: ServerId) -> Result<()> {
        let osds = self.osds.lock().unwrap();
        let osd = osds.get(&id).ok_or(Error::ServerDown(id.0))?;
        osd.kill();
        Ok(())
    }

    /// Arm a crash point on a server (fires once, then the server is dead).
    pub fn arm_crash(&self, id: ServerId, point: CrashPoint) -> Result<()> {
        let osds = self.osds.lock().unwrap();
        let osd = osds.get(&id).ok_or(Error::ServerDown(id.0))?;
        osd.shared.injector.arm(point);
        Ok(())
    }

    /// Is this server currently dead (killed or crashed via a fired
    /// crash point)?
    pub fn is_dead(&self, id: ServerId) -> bool {
        self.osds
            .lock()
            .unwrap()
            .get(&id)
            .map(|o| o.shared.injector.is_dead())
            .unwrap_or(true)
    }

    /// Restart a killed/crashed server (backref-index re-derivation +
    /// revive + recovery scan). Errors if the index could not be rebuilt
    /// — the server then stays down rather than serving wrong counts.
    /// The O(OMAP) rebuild runs after the registry lock is dropped, so
    /// one recovering server never stalls unrelated admin operations.
    /// A server marked `Out` is refused with [`Error::ServerRemoved`]:
    /// its data was re-homed, so its local state is stale by
    /// construction. A restarted server re-queues recovery backfill for
    /// every `Out` server in the map (its own crashed/missed jobs).
    pub fn restart_server(&self, id: ServerId) -> Result<()> {
        match self.monitor.map().server(id) {
            None => return Err(Error::UnknownServer(id.0)),
            Some(s) if s.state == ServerState::Out => {
                return Err(Error::ServerRemoved(id.0));
            }
            Some(_) => {}
        }
        let shared = {
            let osds = self.osds.lock().unwrap();
            osds.get(&id).ok_or(Error::ServerDown(id.0))?.shared.clone()
        };
        shared.restart()?;
        if let Some(det) = &self.detector {
            // fresh proof of life: a revived server must not be judged
            // on the silence of its previous incarnation
            det.register(id, self.clock.now_ms());
        }
        for s in &self.monitor.map().servers {
            if s.state == ServerState::Out {
                shared.recovery.enqueue(s.id.0);
            }
        }
        Ok(())
    }

    /// Mark a server Down in the map (placement skips it; rebalance moves
    /// its PGs' primaries). [`Error::UnknownServer`] for ids the map has
    /// never seen — admin typos fail loudly like every sibling op.
    pub fn mark_down(&self, id: ServerId) -> Result<()> {
        self.monitor.mark_down(id).map(|_| ())
    }

    /// Mark a server Up again. [`Error::UnknownServer`] on unknown ids.
    pub fn mark_up(&self, id: ServerId) -> Result<()> {
        self.monitor.mark_up(id).map(|_| ())
    }

    /// A server's current membership state in the map.
    pub fn server_state(&self, id: ServerId) -> Result<ServerState> {
        self.monitor
            .map()
            .server(id)
            .map(|s| s.state)
            .ok_or(Error::UnknownServer(id.0))
    }

    /// Permanently remove a server: fence it (kill — a fail-slow zombie
    /// must never serve stale state again), mark it `Out` (epoch bump;
    /// placement skips it) and trigger recovery backfill on every
    /// surviving server — the admin counterpart of the failure
    /// detector's out-transition. [`Error::ServerRemoved`] when already
    /// out, [`Error::UnknownServer`] for ids the map has never seen.
    pub fn remove_server(&self, id: ServerId) -> Result<()> {
        let body = || {
            match self.monitor.map().server(id) {
                None => return Err(Error::UnknownServer(id.0)),
                Some(s) if s.state == ServerState::Out => {
                    return Err(Error::ServerRemoved(id.0));
                }
                Some(_) => {}
            }
            if let Some(osd) = self.osds.lock().unwrap().get(&id) {
                osd.kill();
            }
            self.monitor.mark_out(id)?;
            detector::trigger_recovery(&self.monitor, &self.dir, id);
            crate::membership::auto_rebalance(&self.monitor, &self.dir, &self.metrics);
            Ok(())
        };
        self.obs.with_root("membership/evict", || self.clock.now_ms(), body)
    }

    /// Wipe-and-rejoin an `Out` server: fence whatever is left of the
    /// old identity, erase its entire local state (OMAP, CIT,
    /// backreference index, chunk + replica stores), then re-admit it
    /// `Up` with zero holdings — recovery and the auto-enqueued
    /// rebalance backfill it from authoritative copies. Rejoining
    /// *with* the stale state is never offered: its refcounts and
    /// references describe a map edition that no longer exists, and
    /// merging them would double-count shared chunks or resurrect
    /// deleted objects (DESIGN.md §13). [`Error::NotRemoved`] when the
    /// server is not `Out` (a live identity restarts via
    /// [`Cluster::restart_server`] instead), [`Error::UnknownServer`]
    /// for ids the map has never seen. Like a restart, the rejoined
    /// server re-queues recovery backfill for every server still `Out`.
    pub fn rejoin_server(&self, id: ServerId) -> Result<()> {
        let body = || {
            match self.monitor.map().server(id) {
                None => return Err(Error::UnknownServer(id.0)),
                Some(s) if s.state != ServerState::Out => {
                    return Err(Error::NotRemoved(id.0));
                }
                Some(_) => {}
            }
            let shared = {
                let osds = self.osds.lock().unwrap();
                let osd = osds.get(&id).ok_or(Error::ServerDown(id.0))?;
                // fence: idempotent when the out-transition already
                // killed it, and load-bearing when the server is a
                // fail-slow zombie that was marked out while running —
                // no lane may serve stale state once the wipe starts
                osd.kill();
                osd.shared.clone()
            };
            crate::membership::wipe_local_state(&shared)?;
            shared.injector.revive();
            if let Some(det) = &self.detector {
                // fresh proof of life, as on restart: the new
                // incarnation is not judged on the old one's silence
                det.register(id, self.clock.now_ms());
            }
            self.monitor.mark_up(id)?;
            Metrics::add(&self.metrics.membership_rejoins, 1);
            // the rejoined server missed every recovery trigger while
            // fenced: re-queue backfill for the servers still Out
            for s in &self.monitor.map().servers {
                if s.state == ServerState::Out {
                    shared.recovery.enqueue(s.id.0);
                }
            }
            crate::membership::auto_rebalance(&self.monitor, &self.dir, &self.metrics);
            Ok(())
        };
        self.obs.with_root("membership/rejoin", || self.clock.now_ms(), body)
    }

    /// Install (or clear with `None`) the failure detector's per-
    /// observer test hook: every heartbeat verdict passes through it
    /// with the observer's index, so tests model a flaky or lying
    /// observer and prove the quorum holds ([`ObserverHook`],
    /// [`crate::recovery::detector`]). [`Error::Invalid`] when the
    /// cluster was built without [`ClusterConfig::failure_detection`].
    pub fn set_observer_hook(&self, hook: Option<ObserverHook>) -> Result<()> {
        let det = self.detector.as_ref().ok_or_else(|| {
            Error::Invalid("observer hook needs failure_detection".into())
        })?;
        det.set_observer_hook(hook);
        Ok(())
    }

    /// Run `f` against one server's shared state. Integrity tests and the
    /// scrub example use this to inject bit-rot into the chunk store or
    /// drop replica copies — the faults the scrub subsystem exists to
    /// find and heal.
    pub fn with_osd<R>(&self, id: ServerId, f: impl FnOnce(&OsdShared) -> R) -> Result<R> {
        let osds = self.osds.lock().unwrap();
        let osd = osds.get(&id).ok_or(Error::ServerDown(id.0))?;
        Ok(f(&osd.shared))
    }

    // ---- maintenance ----

    fn control(&self, id: ServerId, req: Req) -> Result<Resp> {
        let addr = self.dir.lookup(id, Lane::Control)?;
        let size = req.wire_size();
        addr.call(req, size)
    }

    fn live_ids(&self) -> Vec<ServerId> {
        self.osds.lock().unwrap().keys().copied().collect()
    }

    /// Drain every server's async-consistency queue (quiesce for tests).
    pub fn flush_consistency(&self) -> Result<()> {
        for id in self.live_ids() {
            let _ = self.control(id, Req::FlushConsistency);
        }
        Ok(())
    }

    /// Drain every server's tier-2 fingerprint-migration queue
    /// ([`FpMode::Tiered`], DESIGN.md §16): each pending chunk is
    /// batch-hashed and moved into the content-addressed dedup domain
    /// before this returns. A no-op under [`FpMode::Inline`].
    pub fn fp_flush(&self) -> Result<()> {
        for id in self.live_ids() {
            let _ = self.control(id, Req::FpipeFlush);
        }
        Ok(())
    }

    /// Run a GC pass everywhere with the given age threshold.
    pub fn run_gc(&self, threshold_ms: u64) -> Result<()> {
        for id in self.live_ids() {
            let _ = self.control(id, Req::RunGc { threshold_ms });
        }
        Ok(())
    }

    /// Trigger a rebalance scan on every live server (after map
    /// changes) and block until the scans drain — the synchronous
    /// admin form of the auto-enqueue that membership events fire.
    /// Scans run on each server's rebalance worker; use
    /// [`Cluster::rebalance_status`] to watch them without blocking.
    pub fn rebalance(&self) -> Result<()> {
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::StartRebalance) {
                Ok(_) => {}
                // a dead server cannot hold misplaced data a scan
                // would find; it rebalances after restart/rejoin
                Err(Error::ServerDown(_)) => {}
                Err(e) => return Err(e),
            }
        }
        self.rebalance_wait().map(|_| ())
    }

    /// Audit + re-derive the backreference index on every live server
    /// (the one-shot migration/repair). Returns `(records, mismatches)`
    /// summed over the cluster: index records after the rebuild and
    /// index ↔ OMAP discrepancies the pre-rebuild audits found.
    pub fn rebuild_backrefs(&self) -> Result<(u64, u64)> {
        let mut total = (0u64, 0u64);
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::RebuildBackrefs) {
                Ok(Resp::BackrefReport {
                    records,
                    mismatches,
                }) => {
                    total.0 += records;
                    total.1 += mismatches;
                }
                Ok(Resp::Err(e)) => return Err(Error::TxAborted(e)),
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {} // rebuilt on restart anyway
                Err(e) => return Err(e),
            }
        }
        Ok(total)
    }

    /// Who references this chunk? Asks every live server's backreference
    /// index (each server indexes its own OMAP) and returns the merged
    /// `(object name, reference multiplicity)` list — the admin
    /// counterpart of the scrub fast path, O(referrers) per server
    /// instead of a cluster-wide OMAP dump.
    pub fn referrers(&self, fp: crate::Fingerprint) -> Result<Vec<(String, u64)>> {
        let mut out: Vec<(String, u64)> = Vec::new();
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            let Ok(addr) = self.dir.lookup(id, Lane::Backend) else {
                continue;
            };
            let req = Req::ListRefs { fp };
            let size = req.wire_size();
            match addr.call(req, size) {
                Ok(Resp::Referrers(list)) => out.extend(list),
                Ok(Resp::Err(e)) => return Err(Error::TxAborted(e)),
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {} // skipped like audit()
                Err(e) => return Err(e),
            }
        }
        out.sort();
        Ok(out)
    }

    /// Aggregate statistics.
    pub fn stats(&self) -> ClusterStats {
        // every increment lands on exactly one registry entry (one
        // server's, or the cluster-scope one), so each cluster total is
        // the straight sum of that counter across entries.
        let entries = self.obs.entries();
        let sum = |f: fn(&Metrics) -> &AtomicU64| -> u64 {
            entries.iter().map(|(_, e)| Metrics::get(f(e.metrics()))).sum()
        };
        let mut s = ClusterStats {
            logical_bytes: sum(|m| &m.bytes_logical),
            stored_bytes: sum(|m| &m.bytes_stored),
            replica_bytes: sum(|m| &m.bytes_replica),
            dedup_hits: sum(|m| &m.dedup_hits),
            unique_chunks: sum(|m| &m.unique_chunks),
            cit_lookups: sum(|m| &m.cit_lookups),
            repairs: sum(|m| &m.repairs),
            gc_reclaimed: sum(|m| &m.gc_reclaimed),
            tx_aborts: sum(|m| &m.tx_aborts),
            scrub_chunks_checked: sum(|m| &m.scrub_chunks_checked),
            scrub_bytes_verified: sum(|m| &m.scrub_bytes_verified),
            scrub_corruptions_found: sum(|m| &m.scrub_corruptions_found),
            scrub_repaired: sum(|m| &m.scrub_repaired),
            backref_updates: sum(|m| &m.backref_updates),
            backref_lookups: sum(|m| &m.backref_lookups),
            backref_rebuilds: sum(|m| &m.backref_rebuilds),
            backref_mismatches: sum(|m| &m.backref_mismatches),
            probe_batches: sum(|m| &m.probe_batches),
            probe_hits: sum(|m| &m.probe_hits),
            store_batches: sum(|m| &m.store_batches),
            batch_items: sum(|m| &m.batch_items),
            need_data_resends: sum(|m| &m.need_data_resends),
            wire_bytes: sum(|m| &m.wire_bytes),
            sched_fires: sum(|m| &m.sched_fires),
            sched_skipped_busy: sum(|m| &m.sched_skipped_busy),
            flow_granted_scrub: sum(|m| &m.flow_granted_scrub),
            flow_granted_rebalance: sum(|m| &m.flow_granted_rebalance),
            flow_granted_gc: sum(|m| &m.flow_granted_gc),
            flow_waits: sum(|m| &m.flow_waits),
            backpressure_busy: sum(|m| &m.backpressure_busy),
            backpressure_retries: sum(|m| &m.backpressure_retries),
            backpressure_window_shrinks: sum(|m| &m.backpressure_window_shrinks),
            backpressure_gave_up: sum(|m| &m.backpressure_gave_up),
            flow_granted_recovery: sum(|m| &m.flow_granted_recovery),
            detector_probes: sum(|m| &m.detector_probes),
            detector_marked_down: sum(|m| &m.detector_marked_down),
            detector_marked_up: sum(|m| &m.detector_marked_up),
            detector_marked_out: sum(|m| &m.detector_marked_out),
            recovery_runs: sum(|m| &m.recovery_runs),
            recovery_chunks_scanned: sum(|m| &m.recovery_chunks_scanned),
            recovery_chunks_restored: sum(|m| &m.recovery_chunks_restored),
            recovery_copies_pushed: sum(|m| &m.recovery_copies_pushed),
            recovery_bytes: sum(|m| &m.recovery_bytes),
            recovery_omap_recovered: sum(|m| &m.recovery_omap_recovered),
            recovery_refs_fixed: sum(|m| &m.recovery_refs_fixed),
            recovery_lost: sum(|m| &m.recovery_lost),
            read_amp_reads: sum(|m| &m.read_amp_reads),
            read_amp_homes: sum(|m| &m.read_amp_homes),
            read_batches: sum(|m| &m.read_batches),
            read_batch_items: sum(|m| &m.read_batch_items),
            read_chunk_fetches: sum(|m| &m.read_chunk_fetches),
            read_fallbacks: sum(|m| &m.read_fallbacks),
            read_degraded_busy: sum(|m| &m.read_degraded_busy),
            read_degraded_dead: sum(|m| &m.read_degraded_dead),
            read_cache_hits: sum(|m| &m.read_cache_hits),
            read_cache_misses: sum(|m| &m.read_cache_misses),
            read_cache_insertions: sum(|m| &m.read_cache_insertions),
            read_cache_evictions: sum(|m| &m.read_cache_evictions),
            read_cache_invalidations: sum(|m| &m.read_cache_invalidations),
            dup_chunks_planted: sum(|m| &m.dup_chunks_planted),
            dup_chunks_evicted: sum(|m| &m.dup_chunks_evicted),
            membership_rejoins: sum(|m| &m.membership_rejoins),
            membership_wipes: sum(|m| &m.membership_wipes),
            membership_auto_rebalances: sum(|m| &m.membership_auto_rebalances),
            replica_push_failures: sum(|m| &m.replica_push_failures),
            redundancy_promotions: sum(|m| &m.redundancy_promotions),
            redundancy_demotions: sum(|m| &m.redundancy_demotions),
            redundancy_target_copies: sum(|m| &m.redundancy_target_copies),
            dup_plants_reclaimed: sum(|m| &m.dup_plants_reclaimed),
            fp_weak_hits: sum(|m| &m.fp_weak_hits),
            fp_weak_misses: sum(|m| &m.fp_weak_misses),
            fp_strong_hashes: sum(|m| &m.fp_strong_hashes),
            fp_deferred: sum(|m| &m.fp_deferred),
            fp_batch_calls: sum(|m| &m.fp_batch_calls),
            fp_batch_items: sum(|m| &m.fp_batch_items),
            fp_verify_rejects: sum(|m| &m.fp_verify_rejects),
            fp_migrations: sum(|m| &m.fp_migrations),
            per_server: Vec::new(),
        };
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            if let Ok(Resp::Stats(st)) = self.control(id, Req::GetStats) {
                s.per_server.push(st);
            }
        }
        if !s.per_server.is_empty() {
            // ground truth from the backends beats the running counter
            // (migration/GC would otherwise need perfectly paired
            // increments and decrements to stay exact).
            s.stored_bytes = s.per_server.iter().map(|p| p.bytes_stored).sum();
            s.replica_bytes = s.per_server.iter().map(|p| p.replica_bytes).sum();
        }
        s
    }

    /// A typed point-in-time snapshot of every metric in the cluster:
    /// per-server counters, per-op-class latency histograms (with
    /// p50/p90/p99 readout), per-lane queue depths and flow-budget
    /// utilization per maintenance class. See [`MetricsSnapshot`] for
    /// aggregation, skew/hot-shard detection and the Prometheus-text /
    /// JSON renderers.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        let osds = self.osds.lock().unwrap();
        let mut snap = MetricsSnapshot {
            now_ms: self.clock.now_ms(),
            servers: Vec::new(),
        };
        for (id, entry) in self.obs.entries() {
            let m = entry.metrics();
            let mut server = ServerSnapshot {
                server: id,
                counters: m.counters(),
                histograms: m
                    .histograms()
                    .into_iter()
                    .map(|(name, h)| (name, h.snapshot()))
                    .collect(),
                queue_depths: entry.gauge_values(),
                flow: Vec::new(),
            };
            if let Some(osd) = osds.get(&ServerId(id)) {
                let flow = &osd.shared.flow;
                let weights = flow.config().weights;
                let total = flow.granted_total();
                for (i, class) in MaintClass::ALL.into_iter().enumerate() {
                    let granted = flow.granted(class);
                    server.flow.push(FlowClassUtil {
                        class: maint_class_name(class),
                        granted,
                        weight: weights[i],
                        share: if total == 0 {
                            0.0
                        } else {
                            granted as f64 / total as f64
                        },
                    });
                }
            }
            snap.servers.push(server);
        }
        snap
    }

    /// Reassembled span trees of every retained (tail- or head-sampled)
    /// trace, merged across all servers' span rings.
    pub fn trace_dump(&self) -> TraceDump {
        self.obs.trace_dump()
    }

    /// Snapshot history captured by the clock-driven sampler
    /// ([`crate::obs::ObsConfig::sample_every_ms`]), oldest first.
    pub fn sampled_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.obs.samples()
    }

    /// Cluster-wide invariant check: for every CIT entry, the refcount
    /// must equal the number of OMAP references across the cluster, valid
    /// entries must have data present, and every referenced fingerprint
    /// must have a CIT entry.
    pub fn audit(&self) -> Result<AuditReport> {
        let mut dumps: Vec<AuditDump> = Vec::new();
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::Audit) {
                Ok(Resp::Audit(d)) => dumps.push(d),
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {} // dead servers skipped
                Err(e) => return Err(e),
            }
        }
        // Disk-local dedup keeps an independent CIT per server: the same
        // fingerprint legitimately has one refcount per server, matched by
        // that server's own OMAP references. Cluster-wide and central
        // dedup have exactly one CIT entry per fingerprint, matched by the
        // cluster-wide reference count.
        let per_server = self.cfg.dedup == DedupMode::DiskLocal;
        let mut report = AuditReport::default();
        // each server's backreference index must be an exact inversion of
        // its own OMAP (purely local invariant in every dedup mode)
        for d in &dumps {
            for m in &d.backref_mismatches {
                report.violations.push(format!("osd.{}: {m}", d.server));
            }
        }
        let scopes: Vec<Vec<&AuditDump>> = if per_server {
            dumps.iter().map(|d| vec![d]).collect()
        } else {
            vec![dumps.iter().collect()]
        };
        for scope in scopes {
            let mut refs: HashMap<crate::dedup::fingerprint::Fingerprint, u64> = HashMap::new();
            for d in &scope {
                for (fp, n) in &d.omap_refs {
                    *refs.entry(*fp).or_insert(0) += n;
                }
            }
            let present: std::collections::HashSet<_> =
                scope.iter().flat_map(|d| d.data_fps.iter().copied()).collect();
            report.references += refs.values().sum::<u64>();
            let mut seen = std::collections::HashSet::new();
            for d in &scope {
                for (fp, rfc, valid) in &d.cit {
                    report.fingerprints += 1;
                    seen.insert(*fp);
                    let expected = refs.get(fp).copied().unwrap_or(0);
                    if *rfc != expected {
                        report.violations.push(format!(
                            "osd.{}: {fp:?} refcount {rfc} != {expected} omap references",
                            d.server
                        ));
                    }
                    if *valid && !present.contains(fp) {
                        report.violations.push(format!(
                            "osd.{}: {fp:?} valid flag but data missing",
                            d.server
                        ));
                    }
                }
            }
            for fp in refs.keys() {
                if !seen.contains(fp) {
                    report
                        .violations
                        .push(format!("{fp:?} referenced but no CIT entry in scope"));
                }
            }
        }
        Ok(report)
    }

    /// Census every referenced chunk's live copy count against its
    /// refcount-banded target (see [`RedundancyReport`]). Walks each
    /// live home's CIT and checks the chain's replica slots directly;
    /// locality plants are excluded from the copy count, and copies on
    /// dead servers do not count toward durability.
    pub fn redundancy_report(&self) -> Result<RedundancyReport> {
        use crate::dedup::engine::chunk_copy_key;
        let shares: HashMap<ServerId, Arc<OsdShared>> = {
            let osds = self.osds.lock().unwrap();
            osds.iter()
                .filter(|(_, o)| !o.shared.injector.is_dead())
                .map(|(id, o)| (*id, o.shared.clone()))
                .collect()
        };
        let live = self.monitor.map().up_count();
        let top_band = self.cfg.redundancy.top_band_min_refs();
        let mut report = RedundancyReport::default();
        let mut ids: Vec<ServerId> = shares.keys().copied().collect();
        ids.sort();
        for id in ids {
            let sh = &shares[&id];
            for fp in sh.shard.cit_fingerprints()? {
                let Some(entry) = sh.shard.cit_get(&fp)? else {
                    continue;
                };
                if entry.refcount == 0 {
                    continue; // unreferenced: GC's business, no target
                }
                let chain = sh.chunk_chain(fp.placement_key());
                if self.cfg.dedup == DedupMode::ClusterWide && chain.first() != Some(&id) {
                    continue; // misplaced: the rebalancer owns the move
                }
                let target = self
                    .cfg
                    .redundancy
                    .target_copies(entry.refcount, self.cfg.replication, live)
                    as u64;
                let mut copies = u64::from(sh.store.stat(&fp.to_bytes())?);
                for peer in chain.iter().skip(1) {
                    let Some(peer_sh) = shares.get(peer) else {
                        continue; // dead holder: its copy is not durable
                    };
                    if *peer == id || peer_sh.chunk_cache.planted_contains(&fp) {
                        continue; // locality plant ≠ redundancy copy
                    }
                    if peer_sh.replica_store.stat(&chunk_copy_key(&fp))? {
                        copies += 1;
                        report.copy_bytes += entry.len as u64;
                    }
                }
                report.chunks += 1;
                report.primary_bytes += entry.len as u64;
                match copies.cmp(&target) {
                    std::cmp::Ordering::Less => report.below_target += 1,
                    std::cmp::Ordering::Equal => report.at_target += 1,
                    std::cmp::Ordering::Greater => report.above_target += 1,
                }
                if top_band.is_some_and(|min| entry.refcount >= min) {
                    report.top_band_chunks += 1;
                    if copies < target {
                        report.top_band_below += 1;
                    }
                }
            }
        }
        Ok(report)
    }

    /// Start an online scrub pass on every live server (see
    /// [`crate::scrub`] for the subsystem): first the ensure phase gives
    /// every referenced fingerprint a CIT entry at its home, then each
    /// server's scrub worker walks its CIT in fingerprint-ordered
    /// windows, concurrently with foreground I/O. Dead servers are
    /// skipped (they converge on their next scrub after restart); every
    /// other error propagates.
    pub fn start_scrub(&self, opts: ScrubOptions) -> Result<()> {
        // refuse up front while any server is still scrubbing, so a
        // rejection cannot leave half the cluster started (best-effort:
        // the per-server workers still reject races individually with
        // the same typed error).
        let status = self.scrub_status()?;
        if let Some(busy) = status
            .per_server
            .iter()
            .find(|s| matches!(s.state, ScrubState::Queued | ScrubState::Running))
        {
            return Err(Error::ScrubBusy(busy.server));
        }
        let mut ids = self.live_ids();
        ids.sort();
        for id in &ids {
            match self.control(*id, Req::ScrubEnsure) {
                Ok(Resp::Err(e)) => return Err(Error::TxAborted(e)),
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {}
                Err(e) => return Err(e),
            }
        }
        for id in &ids {
            match self.control(*id, Req::StartScrub { opts: opts.clone() }) {
                Ok(Resp::Busy) => return Err(Error::ScrubBusy(id.0)),
                Ok(Resp::Err(e)) => return Err(Error::Invalid(e)),
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Snapshot every live server's scrub progress, aggregated into a
    /// [`ScrubReport`].
    pub fn scrub_status(&self) -> Result<ScrubReport> {
        let mut report = ScrubReport::default();
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::ScrubStatus) {
                Ok(Resp::Scrub(st)) => {
                    report.chunks_checked += st.chunks_checked;
                    report.bytes_verified += st.bytes_verified;
                    report.corruptions_found += st.corruptions_found;
                    report.repaired += st.repaired;
                    report.flags_confirmed += st.flags_confirmed;
                    report.refs_fixed += st.refs_fixed;
                    report.misplaced += st.misplaced;
                    report.lost += st.lost;
                    report.copies_unverified += st.copies_unverified;
                    report.per_server.push(st);
                }
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {} // dead servers skipped
                Err(e) => return Err(e),
            }
        }
        Ok(report)
    }

    /// Block until no live server's scrub is queued or running; returns
    /// the final aggregated report.
    pub fn scrub_wait(&self) -> Result<ScrubReport> {
        loop {
            let report = self.scrub_status()?;
            if !report.is_running() {
                return Ok(report);
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }

    /// Snapshot every live server's recovery-backfill progress,
    /// aggregated into a [`RecoveryReport`]. Dead servers are skipped
    /// (their jobs are volatile and re-queued on restart).
    pub fn recovery_status(&self) -> Result<RecoveryReport> {
        let mut report = RecoveryReport::default();
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::RecoveryStatus) {
                Ok(Resp::Recovery(st)) => {
                    report.chunks_scanned += st.chunks_scanned;
                    report.chunks_restored += st.chunks_restored;
                    report.copies_pushed += st.copies_pushed;
                    report.bytes_recovered += st.bytes_recovered;
                    report.omap_recovered += st.omap_recovered;
                    report.refs_fixed += st.refs_fixed;
                    report.lost_chunks += st.lost_chunks;
                    report.per_server.push(st);
                }
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {} // dead servers skipped
                Err(e) => return Err(e),
            }
        }
        Ok(report)
    }

    /// Block until no live server's recovery job is queued or running;
    /// returns the final aggregated report. Note for virtual-clock tests
    /// with a *finite* maintenance budget: recovery charges draw from
    /// the Recovery flow class, whose refill only moves with the clock —
    /// poll [`Cluster::recovery_status`] in a loop interleaved with
    /// [`Cluster::advance_clock`] instead of calling this.
    pub fn recovery_wait(&self) -> Result<RecoveryReport> {
        loop {
            let report = self.recovery_status()?;
            if !report.is_running() {
                return Ok(report);
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }

    /// Snapshot every live server's rebalance-worker progress,
    /// aggregated into a [`RebalanceProgress`]. Dead servers are
    /// skipped (their queued scans are volatile; restart/rejoin paths
    /// re-enqueue on the next map change).
    pub fn rebalance_status(&self) -> Result<RebalanceProgress> {
        let mut report = RebalanceProgress::default();
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::RebalanceStatus) {
                Ok(Resp::Rebalance(st)) => {
                    report.runs += st.runs;
                    report.chunks_moved += st.chunks_moved;
                    report.chunk_bytes_moved += st.chunk_bytes_moved;
                    report.omap_moved += st.omap_moved;
                    report.skipped_unreachable += st.skipped_unreachable;
                    report.per_server.push(st);
                }
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {} // dead servers skipped
                Err(e) => return Err(e),
            }
        }
        Ok(report)
    }

    /// Block until no live server's rebalance scan is queued or
    /// running; returns the final aggregated report. The same
    /// finite-budget caveat as [`Cluster::recovery_wait`] applies:
    /// scans charge the Rebalance flow class, so virtual-clock tests
    /// with a finite budget should poll [`Cluster::rebalance_status`]
    /// interleaved with [`Cluster::advance_clock`] instead.
    pub fn rebalance_wait(&self) -> Result<RebalanceProgress> {
        loop {
            let report = self.rebalance_status()?;
            if !report.is_running() {
                return Ok(report);
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }

    /// Arm (or disarm with `None`) the periodic-scrub schedule on every
    /// live server (see [`crate::sched`]). Each server fires its own
    /// passes on its own scrub worker with deterministic per-server
    /// jitter; a due time hitting a still-running pass is skipped, never
    /// stacked. Dead servers are skipped here (their schedule state is
    /// whatever it was before they died); servers added later start
    /// unscheduled.
    pub fn set_schedule(&self, schedule: Option<ScrubSchedule>) -> Result<()> {
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::SetSchedule { schedule }) {
                Ok(Resp::Err(e)) => return Err(Error::Invalid(e)),
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Snapshot every live server's maintenance-scheduler state (armed
    /// schedule, next due time, fire/skip counts).
    pub fn schedule_status(&self) -> Result<Vec<SchedStatus>> {
        let mut out = Vec::new();
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            match self.control(id, Req::SchedStatus) {
                Ok(Resp::Sched(st)) => out.push(st),
                Ok(_) => {}
                Err(Error::ServerDown(_)) => {} // dead servers skipped
                Err(e) => return Err(e),
            }
        }
        Ok(out)
    }

    /// Advance the virtual clock by `ticks` ms and evaluate every live
    /// server's maintenance schedule at the new time. Only valid when
    /// the cluster was built with [`ClockSource::Sim`]; returns the new
    /// clock reading. This is how deterministic tests drive cadence:
    /// time moves exactly when and as far as the test says, and each due
    /// time fires at most once (the per-server re-arm is atomic even
    /// against the background scheduler thread). The `SchedTick` is
    /// fired without waiting for the reply, so advancing the clock never
    /// blocks behind a control lane that is itself paced by the budget —
    /// the caller can always keep virtual time (and therefore refill)
    /// moving. Ordering stays deterministic: any later control-lane
    /// request (scrub/schedule status) queues behind the tick on the
    /// same lane, so it observes the post-tick state.
    pub fn advance_clock(&self, ticks: u64) -> Result<u64> {
        let Some(sim) = &self.sim else {
            return Err(Error::Invalid("advance_clock needs a SimClock".into()));
        };
        let now = sim.advance(ticks);
        let mut ids = self.live_ids();
        ids.sort();
        for id in ids {
            let Ok(addr) = self.dir.lookup(id, Lane::Control) else {
                continue; // dead servers don't tick
            };
            let req = Req::SchedTick;
            let size = req.wire_size();
            let _ = addr.send(req, size); // fire-and-forget (see above)
        }
        if let Some(det) = &self.detector {
            // the failure detector evaluates at the new virtual time:
            // heartbeats are bounded-wait and recovery triggers are
            // fire-and-forget, so this cannot stall the clock either
            detector::run_tick(det, &self.monitor, &self.dir, &self.osds, &self.metrics, now);
        }
        // clock-driven metrics sampler: one snapshot per crossed period
        // boundary (no-op unless `obs.sample_every_ms` is set)
        self.obs.maybe_sample(now, || self.metrics_snapshot());
        Ok(now)
    }

    /// Current cluster-clock reading in ms (wall or virtual).
    pub fn now_ms(&self) -> u64 {
        self.clock.now_ms()
    }

    /// Back-compat convenience: run one full light scrub and block until
    /// it completes everywhere. Returns the number of repairs applied
    /// (refcount fixes + data restores) — the old quiesced scrub's
    /// contract, now served by the online subsystem. A pass that aborted
    /// on a live server is an error (dead servers are skipped, matching
    /// [`Cluster::audit`]).
    pub fn scrub(&self) -> Result<usize> {
        self.start_scrub(ScrubOptions::light())?;
        let report = self.scrub_wait()?;
        if let Some(why) = report.first_failure() {
            return Err(Error::TxAborted(format!("scrub failed: {why}")));
        }
        Ok((report.refs_fixed + report.repaired) as usize)
    }

    /// Graceful teardown: stop the detector thread and every OSD thread.
    pub fn shutdown(mut self) {
        self.det_shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.det_thread.take() {
            let _ = t.join();
        }
        let mut osds = self.osds.lock().unwrap();
        let ids: Vec<ServerId> = osds.keys().copied().collect();
        for id in ids {
            if let Some(osd) = osds.remove(&id) {
                osd.stop();
            }
        }
    }
}

/// Snapshot label of a maintenance class.
fn maint_class_name(class: MaintClass) -> &'static str {
    match class {
        MaintClass::Scrub => "scrub",
        MaintClass::Rebalance => "rebalance",
        MaintClass::Gc => "gc",
        MaintClass::Recovery => "recovery",
    }
}

/// Data-path handle: routes object ops to the right server with degraded
/// fallback to replicas. Every op runs inside a client root span
/// ([`crate::obs::Registry::with_root`]) — the anchor the tail-sampler's
/// retention decision and `trace_dump`'s tree reassembly hang off.
#[derive(Clone)]
pub struct Client {
    dedup: DedupMode,
    map: Arc<RwLock<crate::cluster::ClusterMap>>,
    pgmap: Arc<PgMap>,
    dir: Dir,
    clock: Arc<dyn Clock>,
    obs: Arc<Registry>,
}

impl Client {
    fn chain_for(&self, name: &str) -> Vec<ServerId> {
        if self.dedup == DedupMode::Central {
            return vec![ServerId(0)];
        }
        let key = crate::hash::fnv1a64(name.as_bytes());
        let map = self.map.read().unwrap();
        self.pgmap.select(&map, key)
    }

    fn frontend_call(&self, name: &str, mk: impl Fn() -> Req) -> Result<Resp> {
        let chain = self.chain_for(name);
        let mut last = Error::NoQuorum;
        for id in chain {
            match self.dir.lookup(id, Lane::Frontend) {
                Ok(addr) => {
                    let req = mk();
                    let size = req.wire_size();
                    match addr.call(req, size) {
                        Ok(resp) => return Ok(resp),
                        Err(e) => last = e,
                    }
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// Write an object. Returns (logical bytes, unique bytes stored).
    pub fn put_object(&self, name: &str, data: &[u8]) -> Result<(u64, u64)> {
        let body = || {
            // writes do NOT fall back: the primary owns the transaction
            // (a down primary is the monitor's job to mark out).
            let chain = self.chain_for(name);
            let primary = *chain.first().ok_or(Error::NoQuorum)?;
            let addr = self.dir.lookup(primary, Lane::Frontend)?;
            let req = Req::PutObject {
                name: name.to_string(),
                data: data.to_vec(),
            };
            let size = req.wire_size();
            match addr.call(req, size)? {
                Resp::PutAck { logical, unique } => Ok((logical, unique)),
                Resp::Err(e) => Err(Error::TxAborted(e)),
                other => Err(Error::TxAborted(format!("unexpected reply {other:?}"))),
            }
        };
        self.obs.with_root("client/put", || self.clock.now_ms(), body)
    }

    /// Read an object (degraded fallback to replica holders).
    pub fn get_object(&self, name: &str) -> Result<Vec<u8>> {
        let body = || {
            match self.frontend_call(name, || Req::GetObject {
                name: name.to_string(),
            })? {
                Resp::Object(data) => Ok(data),
                Resp::NotFound => Err(Error::ObjectNotFound(name.to_string())),
                Resp::Err(e) => Err(Error::TxAborted(e)),
                other => Err(Error::TxAborted(format!("unexpected reply {other:?}"))),
            }
        };
        self.obs.with_root("client/get", || self.clock.now_ms(), body)
    }

    /// Delete an object.
    pub fn delete_object(&self, name: &str) -> Result<()> {
        let body = || {
            match self.frontend_call(name, || Req::DeleteObject {
                name: name.to_string(),
            })? {
                Resp::Ok => Ok(()),
                Resp::NotFound => Err(Error::ObjectNotFound(name.to_string())),
                Resp::Err(e) => Err(Error::TxAborted(e)),
                other => Err(Error::TxAborted(format!("unexpected reply {other:?}"))),
            }
        };
        self.obs.with_root("client/delete", || self.clock.now_ms(), body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        assert!(Cluster::new(ClusterConfig {
            servers: 0,
            ..Default::default()
        })
        .is_err());
        assert!(Cluster::new(ClusterConfig {
            replication: 0,
            servers: 1,
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn boot_write_read_shutdown() {
        let cluster = Cluster::new(ClusterConfig {
            servers: 3,
            replication: 2,
            chunking: Chunking::Fixed { size: 1024 },
            ..Default::default()
        })
        .unwrap();
        let client = cluster.client();
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let (logical, unique) = client.put_object("hello", &data).unwrap();
        assert_eq!(logical, 10_000);
        assert!(unique > 0);
        assert_eq!(client.get_object("hello").unwrap(), data);
        cluster.shutdown();
    }

    #[test]
    fn duplicate_objects_dedup() {
        let cluster = Cluster::new(ClusterConfig {
            servers: 4,
            replication: 1,
            chunking: Chunking::Fixed { size: 512 },
            ..Default::default()
        })
        .unwrap();
        let client = cluster.client();
        let data = vec![42u8; 8192];
        client.put_object("a", &data).unwrap();
        let (_, unique_second) = client.put_object("b", &data).unwrap();
        assert_eq!(unique_second, 0, "second copy should store nothing");
        let stats = cluster.stats();
        assert!(stats.savings() > 0.45, "savings {}", stats.savings());
        cluster.shutdown();
    }
}
