//! Tiered fingerprint pipeline (DESIGN.md §16).
//!
//! Cryptographic hashing is the dedup scaling ceiling: with
//! [`FpMode::Inline`] (the default, bit-for-bit the pre-pipeline
//! behavior) every `put` SHA-1s every chunk on the frontend thread.
//! This module adds [`FpMode::Tiered`], a two-tier scheme:
//!
//! * **Tier 1 (inline, cheap).** At chunk boundaries the write path
//!   computes a *weak* 64-bit hash ([`weak64`]: FNV-1a over the chunk
//!   folded with the gear rolling hash that the CDC chunker already
//!   uses) and consults a per-server direct-mapped candidacy filter.
//!   A filter **hit** means "probably a duplicate": the chunk joins a
//!   batch that gets the strong fingerprint from one
//!   [`crate::dedup::fingerprint::FingerprintProvider::digests`] call
//!   and then takes the normal content-addressed scatter path. A
//!   filter **miss** means "looks unique": the chunk skips inline
//!   SHA-1 entirely and is stored locally under a synthetic *pending*
//!   fingerprint ([`pending_fp`]) with a
//!   [`crate::dedup::cit::CommitFlag::Pending`] CIT state, placed by
//!   object locality (its placement key is derived from the object
//!   name, so the object's own primary is the chunk's home by
//!   construction — reads, scrub, recovery and rebalance all agree).
//! * **Tier 2 (background, batched).** A per-OSD worker drains the
//!   pending queue, reads the deferred payloads and resolves their
//!   strong fingerprints in real batches through the provider trait
//!   (finally giving the XLA backend of DESIGN.md §8 a batch to
//!   accelerate), then migrates each chunk into the content-addressed
//!   domain under the flag-based consistency protocol: store the
//!   strong-fingerprint chunk at its content home with the full
//!   reference count, rewrite every referencing OMAP entry, reclaim
//!   the pending identity. Three crash points
//!   ([`CrashPoint::BeforeFpMigrateStore`],
//!   [`CrashPoint::AfterFpMigrateStore`],
//!   [`CrashPoint::AfterFpMigrateOmap`]) cover the migration; a crash
//!   anywhere converges through the existing machinery — scrub's
//!   refcount reconcile heals a double-granted store, GC reclaims an
//!   orphaned pending identity, and a restart re-queues surviving
//!   pending chunks ([`crate::dedup::gc::recovery_scan`]).
//!
//! **Verify-before-merge invariant.** A weak hit never grants a
//! refcount: filter hits go through the strong fingerprint, and a
//! pending chunk only accretes references after a byte-compare against
//! the stored payload ([`store_pending_local`] via the classify
//! pre-check). Weak collisions are therefore impossible to merge —
//! they cost one inline strong hash ([`Metrics::fp_verify_rejects`])
//! and nothing else.

use crate::dedup::cit::{CitEntry, CommitFlag};
use crate::dedup::engine;
use crate::dedup::fingerprint::Fingerprint;
use crate::dedup::omap::OmapEntry;
use crate::error::{Error, Result};
use crate::failure::CrashPoint;
use crate::hash::fnv::fnv1a64;
use crate::hash::gear::Gear;
use crate::metrics::Metrics;
use crate::storage::osd::OsdShared;
use crate::storage::proto::{Req, Resp};
use std::borrow::Cow;
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Worker poll interval (mirrors the other OSD maintenance loops).
const POLL: Duration = Duration::from_millis(50);

/// Idle polls between self-healing sweeps of the CIT for pending
/// entries that fell out of the in-memory queue (crash, rebalance).
const SWEEP_IDLE_POLLS: usize = 20;

/// Fingerprint pipeline mode (see [`crate::ClusterConfig::fp_mode`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpMode {
    /// Strong fingerprint computed inline for every chunk on the write
    /// path — the default, bit-for-bit today's behavior.
    Inline,
    /// Two-tier pipeline: weak prefilter inline, strong hashing only
    /// for probable duplicates, everything else deferred to the
    /// batched background worker. Effective for
    /// [`crate::DedupMode::ClusterWide`]; the other modes ignore it.
    Tiered {
        /// Direct-mapped weak-filter slots per server (each slot is
        /// one `u64`); more slots → fewer aliasing evictions → fewer
        /// false weak hits.
        filter_slots: usize,
        /// Max pending chunks resolved per background
        /// [`crate::dedup::fingerprint::FingerprintProvider::digests`]
        /// call.
        batch: usize,
        /// Significant low bits of the weak hash (≤ 64). Narrowing
        /// this is a test hook for forcing weak collisions; production
        /// keeps the full 64 bits.
        weak_bits: u8,
    },
}

impl FpMode {
    /// The tiered mode with production defaults: 64 Ki filter slots,
    /// batches of 64, full 64-bit weak hashes.
    pub fn tiered() -> Self {
        FpMode::Tiered {
            filter_slots: 1 << 16,
            batch: 64,
            weak_bits: 64,
        }
    }

    /// True for [`FpMode::Tiered`].
    pub fn is_tiered(&self) -> bool {
        matches!(self, FpMode::Tiered { .. })
    }
}

/// Mask selecting the significant low bits of a weak hash.
pub fn weak_mask(bits: u8) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// The tier-1 weak hash: FNV-1a over the whole chunk, folded with the
/// final gear rolling-hash state. The gear state alone only covers the
/// trailing window, so the FNV term supplies full-content coverage;
/// the gear term reuses work the CDC chunker already does per byte.
pub fn weak64(data: &[u8]) -> u64 {
    let mut g = Gear::new();
    for &b in data {
        g.roll(b);
    }
    fnv1a64(data) ^ ((g.value() as u64) << 32)
}

/// Marker word stored in `w2` of a pending fingerprint. A real SHA-1
/// digest matches it with probability 2⁻³², and a false match only
/// makes that one chunk take the (correct, slower) pending read path —
/// an availability rounding error, never a merge.
const PENDING_MAGIC: u32 = 0xFEED_90D5;

/// Synthetic CIT identity for a deferred chunk: `w0‖w1` is the FNV-1a
/// of the *object name* — so [`Fingerprint::placement_key`] routes the
/// pending chunk to the same chain as the object's OMAP record, i.e.
/// the server performing the `put` is the chunk's home by construction
/// — `w2` is [the pending marker](is_pending), and `w3‖w4` embeds the
/// (masked) weak hash for later weak verification by deep scrub.
pub fn pending_fp(name: &str, weak: u64) -> Fingerprint {
    let h = fnv1a64(name.as_bytes());
    Fingerprint([
        (h >> 32) as u32,
        h as u32,
        PENDING_MAGIC,
        (weak >> 32) as u32,
        weak as u32,
    ])
}

/// Is this fingerprint a pending (tier-1 deferred) identity?
pub fn is_pending(fp: &Fingerprint) -> bool {
    fp.0[2] == PENDING_MAGIC
}

/// The weak hash embedded in a pending fingerprint (`w3‖w4`).
pub fn pending_weak(fp: &Fingerprint) -> u64 {
    ((fp.0[3] as u64) << 32) | fp.0[4] as u64
}

/// Content check that understands both fingerprint domains: pending
/// identities verify against their embedded weak hash, real ones
/// against a strong digest computed through the server's
/// [`crate::dedup::fingerprint::FingerprintProvider`] (so an
/// accelerated provider is used on every verification path, not just
/// the write path).
pub fn chunk_matches(sh: &OsdShared, fp: &Fingerprint, data: &[u8]) -> bool {
    if is_pending(fp) {
        let mask = match sh.cfg.fp_mode {
            FpMode::Tiered { weak_bits, .. } => weak_mask(weak_bits),
            FpMode::Inline => u64::MAX,
        };
        (weak64(data) & mask) == pending_weak(fp)
    } else {
        sh.provider.digests(&[data])[0] == *fp
    }
}

/// Per-server direct-mapped weak-hash candidacy filter. One atomic
/// `u64` per slot; zero means empty. Both error directions are safe:
/// a false positive costs one inline strong hash, a false negative
/// defers a duplicate to tier 2 (where the strong hash merges it).
pub struct WeakFilter {
    slots: Vec<AtomicU64>,
}

impl WeakFilter {
    /// A filter with `slots` entries (0 = disabled, every probe misses).
    pub fn new(slots: usize) -> Self {
        let mut v = Vec::with_capacity(slots);
        v.resize_with(slots, || AtomicU64::new(0));
        WeakFilter { slots: v }
    }

    /// Probe-and-insert: returns `true` when `weak` was already in its
    /// slot (a *candidate duplicate*); otherwise records it and
    /// returns `false`. `weak` 0 is encoded as 1 so the empty sentinel
    /// stays unambiguous (the 0↔1 alias is one more false positive).
    pub fn hit_or_insert(&self, weak: u64) -> bool {
        if self.slots.is_empty() {
            return false;
        }
        let enc = weak.max(1);
        let slot = (weak % self.slots.len() as u64) as usize;
        self.slots[slot].swap(enc, Ordering::Relaxed) == enc
    }
}

struct FpipeInner {
    /// Pending identities awaiting tier-2 resolution, FIFO.
    queue: VecDeque<Fingerprint>,
    /// Everything in `queue` *plus* batches currently being migrated —
    /// suppresses duplicate enqueues of in-flight identities.
    queued: HashSet<Fingerprint>,
    /// Identities handed out by `take_*` and not yet `finish`ed.
    inflight: usize,
}

/// Control block of the tier-2 worker: the pending queue, the
/// in-flight set and the tier-1 weak filter (kept together so one
/// `OsdShared` field carries the whole pipeline state).
pub struct FpipeCtl {
    inner: Mutex<FpipeInner>,
    cv: Condvar,
    filter: WeakFilter,
}

impl FpipeCtl {
    /// A control block sized for `mode` (an empty filter for
    /// [`FpMode::Inline`], where tier 1 never runs).
    pub fn for_mode(mode: FpMode) -> Self {
        let slots = match mode {
            FpMode::Tiered { filter_slots, .. } => filter_slots,
            FpMode::Inline => 0,
        };
        FpipeCtl {
            inner: Mutex::new(FpipeInner {
                queue: VecDeque::new(),
                queued: HashSet::new(),
                inflight: 0,
            }),
            cv: Condvar::new(),
            filter: WeakFilter::new(slots),
        }
    }

    /// The tier-1 weak filter.
    pub fn filter(&self) -> &WeakFilter {
        &self.filter
    }

    /// Queue a pending identity for tier-2 resolution. Dedups against
    /// both the queue and in-flight batches; returns whether it was
    /// actually added.
    pub fn enqueue(&self, fp: Fingerprint) -> bool {
        let mut g = self.inner.lock().unwrap();
        if !g.queued.insert(fp) {
            return false;
        }
        g.queue.push_back(fp);
        self.cv.notify_all();
        true
    }

    /// Worker side: wait up to `timeout` for work, then take up to
    /// `max` identities. Taken items stay in the dedup set until
    /// [`FpipeCtl::finish`].
    pub fn take_batch(&self, timeout: Duration, max: usize) -> Vec<Fingerprint> {
        let mut g = self.inner.lock().unwrap();
        if g.queue.is_empty() {
            let (g2, _) = self.cv.wait_timeout(g, timeout).unwrap();
            g = g2;
        }
        Self::pop(&mut g, max)
    }

    /// Non-blocking [`FpipeCtl::take_batch`] (the synchronous flush
    /// path).
    pub fn take_now(&self, max: usize) -> Vec<Fingerprint> {
        let mut g = self.inner.lock().unwrap();
        Self::pop(&mut g, max)
    }

    fn pop(g: &mut FpipeInner, max: usize) -> Vec<Fingerprint> {
        let n = max.max(1).min(g.queue.len());
        let out: Vec<Fingerprint> = g.queue.drain(..n).collect();
        g.inflight += out.len();
        out
    }

    /// Worker side: a batch from `take_*` has been fully processed
    /// (migrated or intentionally skipped) — drop it from the dedup
    /// set so later events can re-queue the survivors.
    pub fn finish(&self, batch: &[Fingerprint]) {
        let mut g = self.inner.lock().unwrap();
        for fp in batch {
            g.queued.remove(fp);
        }
        g.inflight = g.inflight.saturating_sub(batch.len());
    }

    /// Identities handed out and not yet finished.
    pub fn inflight(&self) -> usize {
        self.inner.lock().unwrap().inflight
    }

    /// Queued (not yet taken) identities.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all queued state (server kill; a restart re-queues from
    /// the CIT via [`crate::dedup::gc::recovery_scan`]).
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.queue.clear();
        g.queued.clear();
        g.inflight = 0;
        self.cv.notify_all();
    }
}

/// Tier-1 classification of one object's chunks.
pub(crate) struct Classified {
    /// Per-chunk identity: strong fingerprint (filter hit or collision
    /// fallback) or pending identity (deferred).
    pub digests: Vec<Fingerprint>,
    /// The pending identities in `digests` — stored locally by the
    /// caller and skipped by the content scatter.
    pub pending: HashSet<Fingerprint>,
}

/// Tier 1: weak-hash every chunk, strong-hash the probable duplicates
/// in one batched provider call, defer the rest under pending
/// identities. A pending identity that already exists in the local CIT
/// is only reused after a byte-compare against the stored payload —
/// on mismatch (a weak collision on the same object) the chunk falls
/// back to an inline strong hash and can never merge
/// ([`Metrics::fp_verify_rejects`]).
pub(crate) fn classify(sh: &OsdShared, name: &str, chunks: &[&[u8]]) -> Result<Classified> {
    let FpMode::Tiered { weak_bits, .. } = sh.cfg.fp_mode else {
        unreachable!("classify is only called in tiered mode");
    };
    let mask = weak_mask(weak_bits);
    let mut digests: Vec<Option<Fingerprint>> = vec![None; chunks.len()];
    let mut strong_idx: Vec<usize> = Vec::new();
    let mut pending: HashSet<Fingerprint> = HashSet::new();
    for (i, c) in chunks.iter().enumerate() {
        let w = weak64(c) & mask;
        if sh.fpipe.filter().hit_or_insert(w) {
            Metrics::add(&sh.metrics.fp_weak_hits, 1);
            strong_idx.push(i);
            continue;
        }
        Metrics::add(&sh.metrics.fp_weak_misses, 1);
        let pid = pending_fp(name, w);
        let clean = if pending.contains(&pid) {
            // same weak, same object, earlier chunk of this very put:
            // the filter made that impossible (the first miss inserted
            // the weak), but stay defensive — byte-compare below.
            false
        } else {
            sh.shard.cit_get(&pid)?.is_none()
        };
        if clean {
            digests[i] = Some(pid);
            pending.insert(pid);
            Metrics::add(&sh.metrics.fp_deferred, 1);
        } else {
            // the identity exists (an earlier deferral of this object
            // with the same masked weak): verify by content before
            // reusing it — the verify-before-merge invariant.
            match sh.store.get(&pid.to_bytes())? {
                Some(prev) if prev.as_slice() == *c => {
                    digests[i] = Some(pid);
                    pending.insert(pid);
                    Metrics::add(&sh.metrics.fp_deferred, 1);
                }
                _ => {
                    Metrics::add(&sh.metrics.fp_verify_rejects, 1);
                    strong_idx.push(i);
                }
            }
        }
    }
    if !strong_idx.is_empty() {
        let subset: Vec<&[u8]> = strong_idx.iter().map(|&i| chunks[i]).collect();
        let fps = sh.provider.digests(&subset);
        Metrics::add(&sh.metrics.fp_strong_hashes, fps.len() as u64);
        for (fp, &i) in fps.into_iter().zip(&strong_idx) {
            digests[i] = Some(fp);
        }
    }
    Ok(Classified {
        digests: digests.into_iter().flatten().collect(),
        pending,
    })
}

fn died() -> Error {
    Error::TxAborted("server crashed".into())
}

/// Store a tier-1 deferred chunk locally under its pending identity:
/// CIT upsert with [`CommitFlag::Pending`], payload under the pending
/// key, replica fan-out for durability. An existing identity accretes
/// the references (the classify pre-check already byte-verified the
/// payload). Returns `dedup_hit` like
/// [`crate::dedup::engine::store_chunk_local`].
pub(crate) fn store_pending_local(
    sh: &OsdShared,
    pid: &Fingerprint,
    data: &[u8],
    refs: u64,
) -> Result<bool> {
    Metrics::add(&sh.metrics.cit_lookups, 1);
    let now = sh.now_ms();
    let mut prior = false;
    sh.charge_meta_io(); // modeled DM-Shard write
    sh.shard.cit_update(pid, |cur| match cur {
        Some(mut e) => {
            prior = true;
            e.refcount += refs;
            Some(e)
        }
        None => Some(CitEntry {
            refcount: refs,
            flag: CommitFlag::Pending,
            len: data.len() as u32,
            flagged_at_ms: now,
        }),
    })?;
    if prior {
        Metrics::add(&sh.metrics.dedup_hits, refs);
        return Ok(true);
    }
    if sh.injector.maybe_crash(CrashPoint::AfterCitInsert) {
        return Err(died());
    }
    sh.store.put(&pid.to_bytes(), data)?;
    if sh.injector.maybe_crash(CrashPoint::AfterDataStore) {
        return Err(died());
    }
    Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
    Metrics::add(&sh.metrics.unique_chunks, 1);
    engine::replicate_chunk(sh, pid, data)?;
    Ok(false)
}

/// The tier-2 worker loop (the OSD's tenth thread). Drains the pending
/// queue in batches; when idle, periodically sweeps the CIT for
/// referenced pending entries that fell out of the in-memory queue
/// (crash before enqueue, rebalance hand-off) so the pipeline is
/// self-healing.
pub fn fpipe_loop(sh: Arc<OsdShared>, shutdown: Arc<AtomicBool>) {
    let FpMode::Tiered { batch, .. } = sh.cfg.fp_mode else {
        return;
    };
    let mut idle = 0usize;
    while !shutdown.load(Ordering::SeqCst) {
        if sh.injector.is_dead() {
            std::thread::sleep(POLL);
            continue;
        }
        let b = sh.fpipe.take_batch(POLL, batch);
        if b.is_empty() {
            idle += 1;
            if idle >= SWEEP_IDLE_POLLS {
                idle = 0;
                sweep(&sh);
            }
            continue;
        }
        idle = 0;
        let _ = migrate_batch(&sh, &b);
        sh.fpipe.finish(&b);
    }
}

/// Synchronous drain for the `FpipeFlush` control request: migrate
/// everything queued and wait out batches the background worker holds
/// in flight. Quiesces the pipeline for tests and benches.
pub(crate) fn flush(sh: &OsdShared) -> Result<()> {
    let FpMode::Tiered { batch, .. } = sh.cfg.fp_mode else {
        return Ok(());
    };
    loop {
        let b = sh.fpipe.take_now(batch);
        if b.is_empty() {
            if sh.fpipe.inflight() == 0 {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        let r = migrate_batch(sh, &b);
        sh.fpipe.finish(&b);
        r?;
    }
}

/// Self-healing sweep: re-queue every referenced pending CIT entry not
/// already queued or in flight ([`FpipeCtl::enqueue`] dedups).
fn sweep(sh: &OsdShared) {
    let Ok(fps) = sh.shard.cit_fingerprints() else {
        return;
    };
    for fp in fps {
        if !is_pending(&fp) {
            continue;
        }
        let Ok(Some(e)) = sh.shard.cit_get(&fp) else {
            continue;
        };
        if e.flag != CommitFlag::Pending {
            continue;
        }
        if sh.shard.backref_refs(&fp).unwrap_or(0) > 0 {
            sh.fpipe.enqueue(fp);
        }
    }
}

/// Resolve one batch of pending identities: read the deferred
/// payloads, strong-hash them in a single batched provider call
/// ([`Metrics::fp_batch_calls`] / [`Metrics::fp_batch_items`]), then
/// migrate each into the content-addressed domain. Returns how many
/// migrated; identities whose entry or payload vanished (GC, overwrite
/// rollback) are skipped and left to GC.
pub(crate) fn migrate_batch(sh: &OsdShared, pids: &[Fingerprint]) -> Result<usize> {
    let mut work: Vec<(Fingerprint, Vec<u8>)> = Vec::new();
    for pid in pids {
        let Some(e) = sh.shard.cit_get(pid)? else {
            continue;
        };
        if e.flag != CommitFlag::Pending {
            continue;
        }
        let Some(data) = sh.store.get(&pid.to_bytes())? else {
            // payload lost before resolution: scrub's presence check
            // repairs it from a replica copy and re-queues
            continue;
        };
        work.push((*pid, data));
    }
    if work.is_empty() {
        return Ok(0);
    }
    let payloads: Vec<&[u8]> = work.iter().map(|(_, d)| d.as_slice()).collect();
    let fps = sh.provider.digests(&payloads);
    Metrics::add(&sh.metrics.fp_batch_calls, 1);
    Metrics::add(&sh.metrics.fp_batch_items, fps.len() as u64);
    let mut migrated = 0usize;
    for ((pid, data), fp) in work.iter().zip(&fps) {
        match migrate_one(sh, pid, data, fp) {
            Ok(true) => migrated += 1,
            Ok(false) => {}
            Err(e) => {
                if sh.injector.is_dead() {
                    return Err(e);
                }
                // transient (dead peer mid-store): the identity stays
                // Pending; the idle sweep re-queues it later.
            }
        }
    }
    Ok(migrated)
}

/// Migrate one resolved chunk `pid → fp`:
///
/// 1. store the strong-fingerprint chunk at its content home carrying
///    the pending identity's full reference count (a dedup hit there
///    merges under strong-digest verification — never under the weak
///    hash);
/// 2. rewrite every referencing OMAP entry `pid → fp` under the object
///    lock (backref-indexed: O(referrers), all local by placement);
/// 3. reclaim the pending identity (CIT entry, payload, replica
///    copies) through the GC choke point.
///
/// Crash between 1 and 2: re-migration double-grants the refcount and
/// scrub's reconcile settles it. Crash between 2 and 3: the pending
/// identity has zero references and ages into GC reclaim. Either way
/// the audit converges clean.
fn migrate_one(sh: &OsdShared, pid: &Fingerprint, data: &[u8], fp: &Fingerprint) -> Result<bool> {
    let refs = sh.shard.backref_refs(pid)?;
    Metrics::add(&sh.metrics.backref_lookups, 1);
    if refs == 0 {
        // orphaned deferral (rollback or overwrite): GC's pending arm
        // reclaims it after aging
        return Ok(false);
    }
    if sh.injector.maybe_crash(CrashPoint::BeforeFpMigrateStore) {
        return Err(died());
    }
    let target = sh.chunk_chain(fp.placement_key())[0];
    if target == sh.id {
        engine::store_chunk_local(sh, fp, Cow::Borrowed(data), refs)?;
    } else {
        let req = Req::StoreChunk {
            fp: *fp,
            data: data.to_vec(),
            refs,
        };
        match engine::backend_call(sh, target, req)? {
            Resp::StoreAck { .. } => {}
            Resp::Err(e) => return Err(Error::TxAborted(e)),
            _ => return Err(Error::TxAborted("bad store reply".into())),
        }
    }
    if sh.injector.maybe_crash(CrashPoint::AfterFpMigrateStore) {
        return Err(died());
    }
    for br in sh.shard.backref_referrers(pid)? {
        let _guard = sh.obj_lock.lock().unwrap();
        let Some(old) = sh.shard.omap_get(&br.object)? else {
            continue;
        };
        let chunks: Vec<(Fingerprint, u32)> = old
            .chunks
            .iter()
            .map(|&(c, len)| if c == *pid { (*fp, len) } else { (c, len) })
            .collect();
        let fps: Vec<Fingerprint> = chunks.iter().map(|&(c, _)| c).collect();
        let entry = OmapEntry::new(old.name.clone(), engine::object_fingerprint(&fps), chunks);
        sh.charge_meta_io(); // modeled DM-Shard write
        let deltas = sh.shard.omap_put(&entry)?;
        if deltas.total() > 0 {
            sh.charge_meta_io();
            Metrics::add(&sh.metrics.backref_updates, deltas.total());
        }
        let chain = sh.object_chain(&old.name);
        let failures = engine::replicate(
            sh,
            &chain,
            &engine::omap_copy_key(&old.name),
            &entry.encode(),
            sh.cfg.replication,
        )?;
        if failures > 0 {
            Metrics::add(&sh.metrics.replica_push_failures, failures as u64);
        }
    }
    if sh.injector.maybe_crash(CrashPoint::AfterFpMigrateOmap) {
        return Err(died());
    }
    crate::dedup::gc::reclaim(sh, pid)?;
    Metrics::add(&sh.metrics.fp_migrations, 1);
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pending_identity_roundtrip() {
        let w = 0xDEAD_BEEF_CAFE_F00Du64;
        let pid = pending_fp("obj-7", w);
        assert!(is_pending(&pid));
        assert_eq!(pending_weak(&pid), w);
        // placement agrees with the object's chain key
        assert_eq!(pid.placement_key(), fnv1a64(b"obj-7"));
        // a real digest is not pending (up to the 2^-32 marker alias)
        let real = Fingerprint::of(b"some chunk");
        assert_eq!(is_pending(&real), real.0[2] == 0xFEED_90D5);
    }

    #[test]
    fn weak64_is_content_sensitive() {
        let a = vec![7u8; 4096];
        let mut b = a.clone();
        b[0] ^= 1; // a leading flip: outside the gear window, caught by fnv
        assert_ne!(weak64(&a), weak64(&b));
        assert_eq!(weak64(&a), weak64(&a.clone()));
    }

    #[test]
    fn weak_mask_bounds() {
        assert_eq!(weak_mask(64), u64::MAX);
        assert_eq!(weak_mask(8), 0xFF);
        assert_eq!(weak_mask(0), 0);
    }

    #[test]
    fn filter_hit_and_eviction() {
        let f = WeakFilter::new(2);
        assert!(!f.hit_or_insert(10)); // miss, inserted (slot 0)
        assert!(f.hit_or_insert(10)); // hit
        assert!(!f.hit_or_insert(12)); // same slot, different weak: evicts
        assert!(!f.hit_or_insert(10)); // evicted → miss again
        let off = WeakFilter::new(0);
        assert!(!off.hit_or_insert(10));
        assert!(!off.hit_or_insert(10)); // disabled filter never hits
    }

    #[test]
    fn ctl_dedups_and_tracks_inflight() {
        let ctl = FpipeCtl::for_mode(FpMode::tiered());
        let a = pending_fp("a", 1);
        let b = pending_fp("b", 2);
        assert!(ctl.enqueue(a));
        assert!(!ctl.enqueue(a)); // queued dedup
        assert!(ctl.enqueue(b));
        let batch = ctl.take_now(10);
        assert_eq!(batch.len(), 2);
        assert_eq!(ctl.inflight(), 2);
        assert!(!ctl.enqueue(a)); // in-flight dedup
        ctl.finish(&batch);
        assert_eq!(ctl.inflight(), 0);
        assert!(ctl.enqueue(a)); // finished → re-queue allowed
        ctl.clear();
        assert!(ctl.is_empty());
        assert_eq!(ctl.inflight(), 0);
    }
}
