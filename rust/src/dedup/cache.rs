//! Per-server hot-chunk cache + selective-duplication tracker (DESIGN.md §14).
//!
//! The cluster-wide content placement that buys the paper's space
//! savings also fragments reads: a dedup'd object's chunks live on
//! whichever servers their fingerprints hash to, so one `get` fans out
//! across the cluster. This module is the read path's answer:
//!
//! * [`ChunkCache`] — a size-bounded, refcount- and recency-aware
//!   (segmented-LRU) payload cache consulted before any store or fabric
//!   hop. Values are content-addressed (keyed by fingerprint), so a hit
//!   can never serve *wrong* bytes; invalidation hooks in GC reclaim,
//!   scrub quarantine, recovery re-homing, rebalance migration and the
//!   rejoin wipe keep a cached chunk from outliving its CIT entry.
//! * The selective-duplication tracker ([`ChunkCache::note_remote_fetch`]
//!   / [`ChunkCache::plant_register`]) — counts remote fetches per chunk
//!   so the engine can plant extra locality copies of hot fragmenting
//!   chunks (arXiv:2411.01407's partial-repetition idea) under a byte
//!   budget, governed by [`DupPolicy`].
//!
//! Everything lives behind one mutex: the cache is touched once per
//! chunk read, and the simulated fabric dominates latency by orders of
//! magnitude.

use crate::dedup::fingerprint::Fingerprint;
use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

/// Sizing and admission policy for the per-server [`ChunkCache`].
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Total payload bytes the cache may hold; `0` disables the cache
    /// entirely (every lookup misses, every insert is dropped).
    pub capacity_bytes: u64,
    /// Local backref refcount at or above which a chunk is admitted
    /// straight into the protected segment: heavily shared chunks are
    /// exactly the ones many objects' reads will come back for.
    pub hot_band: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity_bytes: 64 << 20,
            hot_band: 2,
        }
    }
}

/// Policy for fragmentation-aware selective duplication: when a chunk
/// keeps getting fetched over the fabric *and* reads are fanning out
/// wide, plant a local replica-slot copy of it so future reads stay
/// home. Copies are ordinary replica-store entries (`c:<fp>`), so
/// audit/GC/recovery reasoning is unchanged.
#[derive(Clone, Copy, Debug)]
pub struct DupPolicy {
    /// Remote fetches of one chunk observed by one server before that
    /// server plants a locality copy.
    pub fetch_threshold: u32,
    /// Minimum mean read amplification (distinct homes touched per
    /// object read, ×100 — `150` means 1.5 homes/read) before any
    /// planting happens: duplication only pays when reads fragment.
    pub min_mean_amp_x100: u64,
    /// Byte budget for planted copies per server; planting past the
    /// budget evicts the oldest planted copies first.
    pub max_bytes: u64,
}

impl Default for DupPolicy {
    fn default() -> Self {
        DupPolicy {
            fetch_threshold: 3,
            min_mean_amp_x100: 150,
            max_bytes: 16 << 20,
        }
    }
}

/// One resident cache entry.
struct Slot {
    data: Vec<u8>,
    seq: u64,
    protected: bool,
}

/// Mutex-guarded cache state (see module docs for why one lock is fine).
struct Inner {
    seq: u64,
    map: HashMap<Fingerprint, Slot>,
    /// Recency index of the probation segment (seq → fp).
    probation: BTreeMap<u64, Fingerprint>,
    /// Recency index of the protected segment (seq → fp).
    protected: BTreeMap<u64, Fingerprint>,
    bytes: u64,
    protected_bytes: u64,
    /// Remote-fetch counts feeding the selective-duplication policy.
    fetches: HashMap<Fingerprint, u32>,
    /// Locality copies this server has planted: fp → (plant seq, len).
    planted: HashMap<Fingerprint, (u64, u64)>,
    planted_order: BTreeMap<u64, Fingerprint>,
    planted_bytes: u64,
}

/// Per-server hot-chunk cache: segmented LRU (probation + protected)
/// over chunk payloads, keyed by fingerprint. See module docs.
pub struct ChunkCache {
    cfg: CacheConfig,
    inner: Mutex<Inner>,
}

impl ChunkCache {
    /// Fraction of capacity reserved for the protected segment (¾).
    fn protected_target(&self) -> u64 {
        self.cfg.capacity_bytes / 4 * 3
    }

    /// New empty cache with the given sizing policy.
    pub fn new(cfg: CacheConfig) -> Self {
        ChunkCache {
            cfg,
            inner: Mutex::new(Inner {
                seq: 0,
                map: HashMap::new(),
                probation: BTreeMap::new(),
                protected: BTreeMap::new(),
                bytes: 0,
                protected_bytes: 0,
                fetches: HashMap::new(),
                planted: HashMap::new(),
                planted_order: BTreeMap::new(),
                planted_bytes: 0,
            }),
        }
    }

    /// Look up a chunk payload. A probation hit is promoted to the
    /// protected segment (the second touch is the SLRU hotness signal);
    /// a protected hit refreshes recency.
    pub fn get(&self, fp: &Fingerprint) -> Option<Vec<u8>> {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let slot = inner.map.get_mut(fp)?;
        let from = if slot.protected {
            &mut inner.protected
        } else {
            &mut inner.probation
        };
        from.remove(&slot.seq);
        inner.seq += 1;
        slot.seq = inner.seq;
        if !slot.protected {
            slot.protected = true;
            inner.protected_bytes += slot.data.len() as u64;
        }
        inner.protected.insert(slot.seq, *fp);
        let data = slot.data.clone();
        self.rebalance(inner);
        Some(data)
    }

    /// Insert a chunk payload. `hot` (refcount ≥ [`CacheConfig::hot_band`]
    /// at admission time) lands it straight in the protected segment.
    /// Returns how many resident entries were evicted to make room.
    pub fn insert(&self, fp: Fingerprint, data: &[u8], hot: bool) -> u64 {
        let len = data.len() as u64;
        // Refuse oversized entries: one giant chunk must not flush the
        // whole working set.
        if self.cfg.capacity_bytes == 0 || len > self.cfg.capacity_bytes / 4 {
            return 0;
        }
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        if let Some(slot) = inner.map.get(&fp) {
            // Already resident (content-addressed, so same bytes):
            // refresh recency only.
            let (seq, protected) = (slot.seq, slot.protected);
            let from = if protected {
                &mut inner.protected
            } else {
                &mut inner.probation
            };
            from.remove(&seq);
            inner.seq += 1;
            let new_seq = inner.seq;
            let slot = inner.map.get_mut(&fp).unwrap();
            slot.seq = new_seq;
            if protected {
                inner.protected.insert(new_seq, fp);
            } else {
                inner.probation.insert(new_seq, fp);
            }
            return 0;
        }
        inner.seq += 1;
        let seq = inner.seq;
        inner.map.insert(
            fp,
            Slot {
                data: data.to_vec(),
                seq,
                protected: hot,
            },
        );
        inner.bytes += len;
        if hot {
            inner.protected_bytes += len;
            inner.protected.insert(seq, fp);
        } else {
            inner.probation.insert(seq, fp);
        }
        self.rebalance(inner);
        let mut evicted = 0;
        while inner.bytes > self.cfg.capacity_bytes {
            let victim = inner
                .probation
                .iter()
                .next()
                .or_else(|| inner.protected.iter().next())
                .map(|(_, fp)| *fp);
            let Some(victim) = victim else { break };
            Self::remove_slot(inner, &victim);
            evicted += 1;
        }
        evicted
    }

    /// Demote oldest protected entries to probation until the protected
    /// segment fits its ¾-of-capacity target.
    fn rebalance(&self, inner: &mut Inner) {
        while inner.protected_bytes > self.protected_target() {
            let Some((&seq, &fp)) = inner.protected.iter().next() else {
                break;
            };
            inner.protected.remove(&seq);
            let slot = inner.map.get_mut(&fp).unwrap();
            slot.protected = false;
            inner.protected_bytes -= slot.data.len() as u64;
            inner.probation.insert(seq, fp);
        }
    }

    /// Unlink one resident entry (all indices + byte accounting).
    fn remove_slot(inner: &mut Inner, fp: &Fingerprint) -> bool {
        let Some(slot) = inner.map.remove(fp) else {
            return false;
        };
        let len = slot.data.len() as u64;
        inner.bytes -= len;
        if slot.protected {
            inner.protected_bytes -= len;
            inner.protected.remove(&slot.seq);
        } else {
            inner.probation.remove(&slot.seq);
        }
        true
    }

    /// Drop a chunk from the cache (and reset its remote-fetch count so
    /// a reclaimed chunk must re-earn duplication). Returns whether a
    /// resident entry was actually dropped — the invalidation hooks use
    /// this to count only real invalidations.
    pub fn invalidate(&self, fp: &Fingerprint) -> bool {
        let mut g = self.inner.lock().unwrap();
        g.fetches.remove(fp);
        Self::remove_slot(&mut g, fp)
    }

    /// Empty the cache and all selective-duplication bookkeeping. Wired
    /// into `Osd::kill` and the rejoin wipe, like the span ring.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.map.clear();
        g.probation.clear();
        g.protected.clear();
        g.bytes = 0;
        g.protected_bytes = 0;
        g.fetches.clear();
        g.planted.clear();
        g.planted_order.clear();
        g.planted_bytes = 0;
    }

    /// Whether a chunk is resident (tests and invalidation proofs).
    pub fn contains(&self, fp: &Fingerprint) -> bool {
        self.inner.lock().unwrap().map.contains_key(fp)
    }

    /// Total resident payload bytes.
    pub fn bytes(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    /// Resident entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // ----------------------------------------------------------------
    // selective-duplication tracker
    // ----------------------------------------------------------------

    /// Record that this server fetched `fp` over the fabric; returns the
    /// running count the [`DupPolicy::fetch_threshold`] gate compares.
    pub fn note_remote_fetch(&self, fp: &Fingerprint) -> u32 {
        let mut g = self.inner.lock().unwrap();
        let n = g.fetches.entry(*fp).or_insert(0);
        *n = n.saturating_add(1);
        *n
    }

    /// Register a planted locality copy of `len` bytes and return the
    /// oldest previously planted fingerprints that must be evicted to
    /// keep the total under `max_bytes` (the engine deletes their
    /// replica-store entries). The fresh plant itself is never evicted.
    pub fn plant_register(&self, fp: &Fingerprint, len: u64, max_bytes: u64) -> Vec<Fingerprint> {
        let mut g = self.inner.lock().unwrap();
        if g.planted.contains_key(fp) {
            return Vec::new();
        }
        g.seq += 1;
        let seq = g.seq;
        g.planted.insert(*fp, (seq, len));
        g.planted_order.insert(seq, *fp);
        g.planted_bytes += len;
        let mut victims = Vec::new();
        while g.planted_bytes > max_bytes && g.planted.len() > 1 {
            let Some((&vseq, &vfp)) = g.planted_order.iter().next() else {
                break;
            };
            if vfp == *fp {
                break;
            }
            g.planted_order.remove(&vseq);
            let (_, vlen) = g.planted.remove(&vfp).unwrap();
            g.planted_bytes -= vlen;
            victims.push(vfp);
        }
        victims
    }

    /// Whether this server planted a locality copy of `fp` (the read
    /// path digest-verifies such copies before serving them).
    pub fn planted_contains(&self, fp: &Fingerprint) -> bool {
        self.inner.lock().unwrap().planted.contains_key(fp)
    }

    /// Deregister a planted locality copy, returning its recorded length
    /// (`None` when `fp` was never planted here). The caller deletes the
    /// replica-store entry — this is the bookkeeping half of the
    /// `invalidate_chunk` choke point that keeps a plant from outliving
    /// its chunk as an orphan.
    pub fn plant_deregister(&self, fp: &Fingerprint) -> Option<u64> {
        let mut g = self.inner.lock().unwrap();
        let (seq, len) = g.planted.remove(fp)?;
        g.planted_order.remove(&seq);
        g.planted_bytes -= len;
        Some(len)
    }

    /// Total bytes of planted locality copies.
    pub fn planted_bytes(&self) -> u64 {
        self.inner.lock().unwrap().planted_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(n: u8) -> Fingerprint {
        Fingerprint::of(&[n; 7])
    }

    fn cache(capacity: u64) -> ChunkCache {
        ChunkCache::new(CacheConfig {
            capacity_bytes: capacity,
            hot_band: 2,
        })
    }

    #[test]
    fn hit_miss_and_promotion() {
        let c = cache(4096);
        assert!(c.get(&fp(1)).is_none());
        c.insert(fp(1), &[1u8; 100], false);
        assert_eq!(c.get(&fp(1)).unwrap(), vec![1u8; 100]);
        // promoted on first hit: still resident, still correct
        assert_eq!(c.get(&fp(1)).unwrap(), vec![1u8; 100]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn eviction_prefers_probation_over_protected() {
        let c = cache(1000);
        c.insert(fp(1), &[1u8; 200], true); // protected (hot band)
        c.insert(fp(2), &[2u8; 200], false); // probation
        c.insert(fp(3), &[3u8; 200], false); // probation
        // 700 more bytes forces eviction; probation-first means the
        // cold fp(2) goes before the hot fp(1).
        c.insert(fp(4), &[4u8; 200], false);
        c.insert(fp(5), &[5u8; 200], false);
        assert!(c.contains(&fp(1)), "protected entry survived");
        assert!(!c.contains(&fp(2)), "oldest probation entry evicted");
        assert!(c.bytes() <= 1000);
    }

    #[test]
    fn oversized_and_zero_capacity_rejected() {
        let c = cache(1000);
        assert_eq!(c.insert(fp(1), &[0u8; 600], false), 0);
        assert!(!c.contains(&fp(1)), "oversized entry not admitted");
        let z = cache(0);
        z.insert(fp(2), &[0u8; 4], false);
        assert!(!z.contains(&fp(2)), "zero capacity disables cache");
    }

    #[test]
    fn invalidate_and_clear() {
        let c = cache(4096);
        c.insert(fp(1), &[1u8; 10], false);
        c.insert(fp(2), &[2u8; 10], true);
        assert!(c.invalidate(&fp(1)));
        assert!(!c.invalidate(&fp(1)), "second invalidate is a no-op");
        assert!(c.contains(&fp(2)));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn fetch_counter_and_plant_budget() {
        let c = cache(4096);
        assert_eq!(c.note_remote_fetch(&fp(1)), 1);
        assert_eq!(c.note_remote_fetch(&fp(1)), 2);
        // invalidation resets hotness
        c.invalidate(&fp(1));
        assert_eq!(c.note_remote_fetch(&fp(1)), 1);

        assert!(c.plant_register(&fp(1), 300, 500).is_empty());
        assert!(c.planted_contains(&fp(1)));
        // re-registering is a no-op
        assert!(c.plant_register(&fp(1), 300, 500).is_empty());
        assert_eq!(c.planted_bytes(), 300);
        // budget overflow evicts the oldest plant, never the fresh one
        let victims = c.plant_register(&fp(2), 300, 500);
        assert_eq!(victims, vec![fp(1)]);
        assert!(c.planted_contains(&fp(2)));
        assert_eq!(c.planted_bytes(), 300);
    }

    #[test]
    fn plant_deregister_releases_budget() {
        let c = cache(4096);
        assert_eq!(c.plant_deregister(&fp(1)), None, "never planted");
        c.plant_register(&fp(1), 300, 1000);
        c.plant_register(&fp(2), 200, 1000);
        assert_eq!(c.plant_deregister(&fp(1)), Some(300));
        assert!(!c.planted_contains(&fp(1)));
        assert_eq!(c.planted_bytes(), 200);
        assert_eq!(c.plant_deregister(&fp(1)), None, "second call is a no-op");
        // the freed budget admits a new plant without evicting fp(2)
        assert!(c.plant_register(&fp(3), 300, 500).is_empty());
        assert!(c.planted_contains(&fp(2)));
    }
}
