//! Chunk Information Table (CIT) records — the performance-sensitive half
//! of the DM-Shard (paper §2.2): fingerprint → (reference count, commit
//! flag). "All the lookup and reference update operations are possible via
//! this data structure."

use crate::error::Result;
use crate::util::codec::{Reader, Writer};

/// Commit-flag states (paper §2.4): 0 = invalid (chunk may be missing /
/// transaction not yet confirmed), 1 = valid (content confirmed present),
/// 2 = pending (tier-1 deferred identity awaiting strong-fingerprint
/// resolution by the [`crate::dedup::fpipe`] worker, DESIGN.md §16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitFlag {
    /// Transaction not yet confirmed; the chunk data may be missing.
    Invalid,
    /// Content confirmed present on stable storage.
    Valid,
    /// Deferred weak-hash identity: payload present locally, strong
    /// fingerprint not yet computed. Never eligible for remote refcount
    /// grants (`cit_valid_many` and [`crate::dedup::engine::grant_ref_local`]
    /// both require `Valid`) — the verify-before-merge invariant.
    Pending,
}

impl CommitFlag {
    fn to_u8(self) -> u8 {
        match self {
            CommitFlag::Invalid => 0,
            CommitFlag::Valid => 1,
            CommitFlag::Pending => 2,
        }
    }

    fn from_u8(v: u8) -> Self {
        match v {
            1 => CommitFlag::Valid,
            2 => CommitFlag::Pending,
            _ => CommitFlag::Invalid,
        }
    }
}

/// One CIT entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CitEntry {
    /// Number of OMAP references pointing at this chunk.
    pub refcount: u64,
    /// Tagged-consistency commit flag.
    pub flag: CommitFlag,
    /// Stored chunk length in bytes (for space accounting / GC).
    pub len: u32,
    /// Monotonic timestamp (ms since cluster start) of the last flag
    /// transition — drives the GC collection threshold.
    pub flagged_at_ms: u64,
}

impl CitEntry {
    /// Encode to the KV value format.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u64(self.refcount);
        w.put_u8(self.flag.to_u8());
        w.put_u32(self.len);
        w.put_u64(self.flagged_at_ms);
        w.into_bytes()
    }

    /// Decode from the KV value format.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        Ok(CitEntry {
            refcount: r.get_u64()?,
            flag: CommitFlag::from_u8(r.get_u8()?),
            len: r.get_u32()?,
            flagged_at_ms: r.get_u64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let e = CitEntry {
            refcount: 42,
            flag: CommitFlag::Valid,
            len: 4096,
            flagged_at_ms: 123456,
        };
        assert_eq!(CitEntry::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn default_flag_is_invalid() {
        assert_eq!(CommitFlag::from_u8(0), CommitFlag::Invalid);
        assert_eq!(CommitFlag::from_u8(7), CommitFlag::Invalid);
        assert_eq!(CommitFlag::from_u8(1), CommitFlag::Valid);
    }

    #[test]
    fn pending_flag_roundtrip() {
        let e = CitEntry {
            refcount: 3,
            flag: CommitFlag::Pending,
            len: 9,
            flagged_at_ms: 5,
        };
        assert_eq!(CitEntry::decode(&e.encode()).unwrap(), e);
        assert_eq!(CommitFlag::from_u8(2), CommitFlag::Pending);
    }

    #[test]
    fn truncated_rejected() {
        let e = CitEntry {
            refcount: 1,
            flag: CommitFlag::Invalid,
            len: 0,
            flagged_at_ms: 0,
        };
        let mut b = e.encode();
        b.truncate(5);
        assert!(CitEntry::decode(&b).is_err());
    }
}
