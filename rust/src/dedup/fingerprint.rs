//! Chunk content fingerprints and fingerprint computation providers.

use crate::hash::sha1::sha1_words;
use crate::util::hex;

/// SHA-1 content fingerprint, stored as the 5 big-endian state words (the
/// layout shared with the Pallas kernel and the XLA runtime).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub [u32; 5]);

impl Fingerprint {
    /// Fingerprint of a chunk's content.
    pub fn of(data: &[u8]) -> Self {
        Fingerprint(sha1_words(data))
    }

    /// The placement key: the first digest word extended to 64 bits with
    /// the second (content-based placement, paper §2.3).
    pub fn placement_key(&self) -> u64 {
        ((self.0[0] as u64) << 32) | self.0[1] as u64
    }

    /// 20-byte big-endian digest.
    pub fn to_bytes(&self) -> [u8; 20] {
        let mut out = [0u8; 20];
        for (i, w) in self.0.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    /// Parse from 20 bytes.
    pub fn from_bytes(b: &[u8]) -> Option<Self> {
        if b.len() != 20 {
            return None;
        }
        let mut w = [0u32; 5];
        for i in 0..5 {
            w[i] = u32::from_be_bytes([b[i * 4], b[i * 4 + 1], b[i * 4 + 2], b[i * 4 + 3]]);
        }
        Some(Fingerprint(w))
    }

    /// Canonical 40-char hex form.
    pub fn to_hex(&self) -> String {
        hex::encode(&self.to_bytes())
    }
}

impl std::fmt::Debug for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fp:{}", &self.to_hex()[..12])
    }
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_hex())
    }
}

/// A fingerprint computation engine.
///
/// Implementations: [`RustSha1Provider`] (scalar, per-frontend-thread) and
/// `runtime::BatchFingerprinter` (the AOT Pallas kernel through PJRT).
pub trait FingerprintProvider: Send + Sync {
    /// Digest a batch of chunks (arbitrary sizes).
    fn digests(&self, chunks: &[&[u8]]) -> Vec<Fingerprint>;

    /// Provider name for configs/reports.
    fn name(&self) -> &'static str;
}

/// Scalar from-scratch SHA-1 (the default provider; runs on the calling
/// OSD frontend thread, so it parallelizes across servers).
pub struct RustSha1Provider;

impl FingerprintProvider for RustSha1Provider {
    fn digests(&self, chunks: &[&[u8]]) -> Vec<Fingerprint> {
        chunks.iter().map(|c| Fingerprint::of(c)).collect()
    }

    fn name(&self) -> &'static str {
        "rust-sha1"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp_of_known_vector() {
        let fp = Fingerprint::of(b"abc");
        assert_eq!(fp.to_hex(), "a9993e364706816aba3e25717850c26c9cd0d89d");
    }

    #[test]
    fn bytes_roundtrip() {
        let fp = Fingerprint::of(b"roundtrip");
        let b = fp.to_bytes();
        assert_eq!(Fingerprint::from_bytes(&b).unwrap(), fp);
        assert!(Fingerprint::from_bytes(&b[..19]).is_none());
    }

    #[test]
    fn placement_key_uses_leading_words() {
        let fp = Fingerprint([0x11223344, 0x55667788, 0, 0, 0]);
        assert_eq!(fp.placement_key(), 0x1122334455667788);
    }

    #[test]
    fn provider_batches() {
        let chunks: Vec<&[u8]> = vec![b"a", b"b", b"a"];
        let d = RustSha1Provider.digests(&chunks);
        assert_eq!(d.len(), 3);
        assert_eq!(d[0], d[2]);
        assert_ne!(d[0], d[1]);
    }

    #[test]
    fn debug_is_short() {
        let s = format!("{:?}", Fingerprint::of(b"x"));
        assert!(s.starts_with("fp:") && s.len() == 15);
    }
}
