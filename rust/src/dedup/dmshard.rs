//! The Deduplication Metadata Shard (paper §2.2).
//!
//! Every storage server hosts one DM-Shard with two *separate* persistent
//! structures — the Object Map and the Chunk Information Table — each its
//! own [`KvStore`] instance with an independent lock ("reduced congestion
//! on a single data structure when multiple I/Os access the data
//! structure"). The shard also carries the *transaction lock* used only by
//! the synchronous consistency comparators of Fig. 5(b); the paper's
//! asynchronous tagged mode never takes it.

use crate::dedup::cit::{CitEntry, CommitFlag};
use crate::dedup::fingerprint::Fingerprint;
use crate::dedup::omap::OmapEntry;
use crate::error::Result;
use crate::kvstore::KvStore;
use std::sync::Mutex;

/// One server's deduplication metadata shard.
pub struct DmShard {
    omap: Box<dyn KvStore>,
    cit: Box<dyn KvStore>,
    /// Transaction lock for the synchronous consistency comparators.
    pub tx_lock: Mutex<()>,
    /// Serializes CIT read-modify-writes: a fingerprint can be updated
    /// concurrently from the backend lane (remote StoreChunk) and the
    /// frontend lane (local chunks bypass the fabric), so `cit_update`
    /// must be atomic.
    rmw: Mutex<()>,
}

impl DmShard {
    /// Build over two KV stores (OMAP, CIT).
    pub fn new(omap: Box<dyn KvStore>, cit: Box<dyn KvStore>) -> Self {
        DmShard {
            omap,
            cit,
            tx_lock: Mutex::new(()),
            rmw: Mutex::new(()),
        }
    }

    // ---- OMAP ----

    /// Insert/replace an object's layout entry.
    pub fn omap_put(&self, entry: &OmapEntry) -> Result<()> {
        self.omap.put(entry.name.as_bytes(), &entry.encode())
    }

    /// Fetch an object's layout entry.
    pub fn omap_get(&self, name: &str) -> Result<Option<OmapEntry>> {
        match self.omap.get(name.as_bytes())? {
            Some(v) => Ok(Some(OmapEntry::decode(&v)?)),
            None => Ok(None),
        }
    }

    /// Delete an object's layout entry; true if it existed.
    pub fn omap_delete(&self, name: &str) -> Result<bool> {
        self.omap.delete(name.as_bytes())
    }

    /// All object names in this shard.
    pub fn omap_names(&self) -> Result<Vec<String>> {
        Ok(self
            .omap
            .keys()?
            .into_iter()
            .filter_map(|k| String::from_utf8(k).ok())
            .collect())
    }

    /// Number of objects in this shard.
    pub fn omap_len(&self) -> usize {
        self.omap.len()
    }

    // ---- CIT ----

    /// Fetch a CIT entry.
    pub fn cit_get(&self, fp: &Fingerprint) -> Result<Option<CitEntry>> {
        match self.cit.get(&fp.to_bytes())? {
            Some(v) => Ok(Some(CitEntry::decode(&v)?)),
            None => Ok(None),
        }
    }

    /// Insert/replace a CIT entry.
    pub fn cit_put(&self, fp: &Fingerprint, entry: &CitEntry) -> Result<()> {
        self.cit.put(&fp.to_bytes(), &entry.encode())
    }

    /// Delete a CIT entry; true if it existed.
    pub fn cit_delete(&self, fp: &Fingerprint) -> Result<bool> {
        self.cit.delete(&fp.to_bytes())
    }

    /// Read-modify-write a CIT entry under the CIT store's own lock
    /// granularity (single key). Returns the updated entry, or `None` if
    /// absent and `f` declined to create it.
    pub fn cit_update(
        &self,
        fp: &Fingerprint,
        f: impl FnOnce(Option<CitEntry>) -> Option<CitEntry>,
    ) -> Result<Option<CitEntry>> {
        // The store is internally synchronized per-op; cross-op atomicity
        // (get → modify → put) needs the shard RMW lock because frontend
        // and backend lanes both mutate the CIT.
        let _guard = self.rmw.lock().unwrap();
        let cur = self.cit_get(fp)?;
        match f(cur) {
            Some(next) => {
                self.cit_put(fp, &next)?;
                Ok(Some(next))
            }
            None => Ok(None),
        }
    }

    /// Flip the commit flag of an existing entry.
    pub fn cit_set_flag(&self, fp: &Fingerprint, flag: CommitFlag, now_ms: u64) -> Result<bool> {
        Ok(self
            .cit_update(fp, |cur| {
                cur.map(|mut e| {
                    e.flag = flag;
                    e.flagged_at_ms = now_ms;
                    e
                })
            })?
            .is_some())
    }

    /// All fingerprints in the CIT.
    pub fn cit_fingerprints(&self) -> Result<Vec<Fingerprint>> {
        Ok(self
            .cit
            .keys()?
            .into_iter()
            .filter_map(|k| Fingerprint::from_bytes(&k))
            .collect())
    }

    /// Number of CIT entries.
    pub fn cit_len(&self) -> usize {
        self.cit.len()
    }

    /// Flush both stores.
    pub fn sync(&self) -> Result<()> {
        self.omap.sync()?;
        self.cit.sync()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::MemKv;

    fn shard() -> DmShard {
        DmShard::new(Box::new(MemKv::new()), Box::new(MemKv::new()))
    }

    #[test]
    fn omap_crud() {
        let s = shard();
        let e = OmapEntry::new(
            "obj".into(),
            Fingerprint::of(b"obj"),
            vec![(Fingerprint::of(b"c"), 10)],
        );
        s.omap_put(&e).unwrap();
        assert_eq!(s.omap_get("obj").unwrap().unwrap(), e);
        assert_eq!(s.omap_len(), 1);
        assert_eq!(s.omap_names().unwrap(), vec!["obj".to_string()]);
        assert!(s.omap_delete("obj").unwrap());
        assert!(s.omap_get("obj").unwrap().is_none());
    }

    #[test]
    fn cit_crud_and_update() {
        let s = shard();
        let fp = Fingerprint::of(b"chunk");
        assert!(s.cit_get(&fp).unwrap().is_none());
        s.cit_put(
            &fp,
            &CitEntry {
                refcount: 1,
                flag: CommitFlag::Invalid,
                len: 100,
                flagged_at_ms: 5,
            },
        )
        .unwrap();
        let e = s
            .cit_update(&fp, |cur| {
                let mut e = cur.unwrap();
                e.refcount += 2;
                Some(e)
            })
            .unwrap()
            .unwrap();
        assert_eq!(e.refcount, 3);
        assert!(s.cit_set_flag(&fp, CommitFlag::Valid, 9).unwrap());
        let e = s.cit_get(&fp).unwrap().unwrap();
        assert_eq!(e.flag, CommitFlag::Valid);
        assert_eq!(e.flagged_at_ms, 9);
        assert_eq!(s.cit_fingerprints().unwrap(), vec![fp]);
        assert!(s.cit_delete(&fp).unwrap());
        assert_eq!(s.cit_len(), 0);
    }

    #[test]
    fn set_flag_on_missing_is_false() {
        let s = shard();
        assert!(!s.cit_set_flag(&Fingerprint::of(b"x"), CommitFlag::Valid, 0).unwrap());
    }

    #[test]
    fn update_can_decline_creation() {
        let s = shard();
        let fp = Fingerprint::of(b"nope");
        let r = s.cit_update(&fp, |cur| cur).unwrap();
        assert!(r.is_none());
        assert!(s.cit_get(&fp).unwrap().is_none());
    }
}
