//! The Deduplication Metadata Shard (paper §2.2).
//!
//! Every storage server hosts one DM-Shard with three *separate*
//! persistent structures — the Object Map, the Chunk Information Table
//! and the backreference index — each its own [`KvStore`] instance with
//! an independent lock ("reduced congestion on a single data structure
//! when multiple I/Os access the data structure"). The shard also carries
//! the *transaction lock* used only by the synchronous consistency
//! comparators of Fig. 5(b); the paper's asynchronous tagged mode never
//! takes it.
//!
//! The **backreference index** (DESIGN.md §6) is the inverted OMAP:
//! `chunk fingerprint → referring (object, ordinals)` records keyed so
//! that one prefix range read enumerates a fingerprint's referrers. It is
//! *derived, non-authoritative* metadata — the OMAP is always the source
//! of truth — maintained inside [`DmShard::omap_put`] /
//! [`DmShard::omap_delete`] under the OMAP read-modify-write lock, fully
//! re-derivable by [`DmShard::rebuild_backrefs`] (run after crash
//! recovery and as the one-shot migration for pre-index stores) and
//! cross-checked by [`DmShard::backref_audit`].

use crate::dedup::cit::{CitEntry, CommitFlag};
use crate::dedup::fingerprint::Fingerprint;
use crate::dedup::omap::{backrefs_of, BackrefEntry, OmapEntry};
use crate::error::Result;
use crate::kvstore::KvStore;
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

/// Index mutation counts returned by an OMAP write (for metrics and the
/// modeled DM-Shard I/O cost).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackrefDelta {
    /// Backreference records written (inserted or overwritten).
    pub added: u64,
    /// Backreference records deleted (stale referrers of an overwrite).
    pub removed: u64,
}

impl BackrefDelta {
    /// Total index records touched.
    pub fn total(&self) -> u64 {
        self.added + self.removed
    }
}

/// One server's deduplication metadata shard.
pub struct DmShard {
    omap: Box<dyn KvStore>,
    cit: Box<dyn KvStore>,
    backref: Box<dyn KvStore>,
    /// Transaction lock for the synchronous consistency comparators.
    pub tx_lock: Mutex<()>,
    /// Serializes CIT read-modify-writes: a fingerprint can be updated
    /// concurrently from the backend lane (remote StoreChunk) and the
    /// frontend lane (local chunks bypass the fabric), so `cit_update`
    /// must be atomic.
    rmw: Mutex<()>,
    /// Serializes OMAP read-modify-writes so the backreference index is
    /// diffed and applied atomically with respect to concurrent OMAP
    /// mutations of the same object (frontend overwrite racing a
    /// rebalance migration, rebuild racing a write).
    omap_rmw: Mutex<()>,
}

impl DmShard {
    /// Build over three KV stores (OMAP, CIT, backreference index).
    pub fn new(
        omap: Box<dyn KvStore>,
        cit: Box<dyn KvStore>,
        backref: Box<dyn KvStore>,
    ) -> Self {
        DmShard {
            omap,
            cit,
            backref,
            tx_lock: Mutex::new(()),
            rmw: Mutex::new(()),
            omap_rmw: Mutex::new(()),
        }
    }

    // ---- OMAP ----

    /// Insert/replace an object's layout entry, keeping the backreference
    /// index in step: stale referrer records of an overwritten layout are
    /// deleted, the new layout's records are written. Returns the index
    /// mutation counts.
    pub fn omap_put(&self, entry: &OmapEntry) -> Result<BackrefDelta> {
        let _guard = self.omap_rmw.lock().unwrap();
        self.omap_put_locked(entry)
    }

    /// Insert an object's layout only if the OMAP holds no entry for the
    /// name; `None` when one exists (nothing written). Recovery adoption
    /// uses this so re-homing a record from a surviving replica copy can
    /// never clobber a racing fresh write — the check and the write
    /// happen under one acquisition of the OMAP read-modify-write lock.
    pub fn omap_put_if_absent(&self, entry: &OmapEntry) -> Result<Option<BackrefDelta>> {
        let _guard = self.omap_rmw.lock().unwrap();
        if self.omap.get(entry.name.as_bytes())?.is_some() {
            return Ok(None);
        }
        self.omap_put_locked(entry).map(Some)
    }

    fn omap_put_locked(&self, entry: &OmapEntry) -> Result<BackrefDelta> {
        let old = self.omap_get(&entry.name)?;
        self.omap.put(entry.name.as_bytes(), &entry.encode())?;
        let mut delta = BackrefDelta::default();
        let new_backrefs = backrefs_of(entry);
        if let Some(old) = old {
            let keep: HashSet<Fingerprint> = new_backrefs.iter().map(|b| b.fp).collect();
            for stale in backrefs_of(&old) {
                if !keep.contains(&stale.fp) && self.backref.delete(&stale.key())? {
                    delta.removed += 1;
                }
            }
        }
        for b in new_backrefs {
            self.backref.put(&b.key(), &b.encode())?;
            delta.added += 1;
        }
        Ok(delta)
    }

    /// Fetch an object's layout entry.
    pub fn omap_get(&self, name: &str) -> Result<Option<OmapEntry>> {
        match self.omap.get(name.as_bytes())? {
            Some(v) => Ok(Some(OmapEntry::decode(&v)?)),
            None => Ok(None),
        }
    }

    /// Delete an object's layout entry and its backreference records.
    /// Returns the index mutation counts, or `None` when the object did
    /// not exist (symmetric with [`DmShard::omap_put`]).
    pub fn omap_delete(&self, name: &str) -> Result<Option<BackrefDelta>> {
        let _guard = self.omap_rmw.lock().unwrap();
        let Some(entry) = self.omap_get(name)? else {
            return Ok(None);
        };
        let mut delta = BackrefDelta::default();
        for b in backrefs_of(&entry) {
            if self.backref.delete(&b.key())? {
                delta.removed += 1;
            }
        }
        self.omap.delete(name.as_bytes())?;
        Ok(Some(delta))
    }

    /// All object names in this shard.
    pub fn omap_names(&self) -> Result<Vec<String>> {
        Ok(self
            .omap
            .keys()?
            .into_iter()
            .filter_map(|k| String::from_utf8(k).ok())
            .collect())
    }

    /// Number of objects in this shard.
    pub fn omap_len(&self) -> usize {
        self.omap.len()
    }

    // ---- backreference index ----

    /// This shard's local reference count for one fingerprint, answered
    /// from the index in O(log n + referrers) — the `CountRefs` fast
    /// path. Never touches the OMAP. All index readers take the OMAP
    /// read-modify-write lock so they can never observe a half-applied
    /// overwrite diff or a mid-flight [`DmShard::rebuild_backrefs`]
    /// (which clears the index before repopulating it).
    pub fn backref_refs(&self, fp: &Fingerprint) -> Result<u64> {
        let _guard = self.omap_rmw.lock().unwrap();
        self.backref_refs_locked(fp)
    }

    fn backref_refs_locked(&self, fp: &Fingerprint) -> Result<u64> {
        let mut total = 0u64;
        for (_key, value) in self.backref.scan_prefix(&BackrefEntry::prefix(fp))? {
            total += BackrefEntry::decode_refs(&value)?;
        }
        Ok(total)
    }

    /// Batched [`DmShard::backref_refs`] (one scrub window's worth),
    /// answered under one lock acquisition.
    pub fn backref_refs_many(&self, fps: &[Fingerprint]) -> Result<Vec<u64>> {
        let _guard = self.omap_rmw.lock().unwrap();
        fps.iter().map(|fp| self.backref_refs_locked(fp)).collect()
    }

    /// All referrers of one fingerprint, fully decoded (diagnostics /
    /// `ListRefs`).
    pub fn backref_referrers(&self, fp: &Fingerprint) -> Result<Vec<BackrefEntry>> {
        let _guard = self.omap_rmw.lock().unwrap();
        self.backref
            .scan_prefix(&BackrefEntry::prefix(fp))?
            .into_iter()
            .map(|(k, v)| BackrefEntry::decode(&k, &v))
            .collect()
    }

    /// Every distinct fingerprint referenced by this shard's OMAP, with
    /// its chunk length — one ordered index walk, no OMAP entry ever
    /// decoded (the scrub ensure-phase input).
    pub fn backref_referenced(&self) -> Result<Vec<(Fingerprint, u32)>> {
        let _guard = self.omap_rmw.lock().unwrap();
        let mut out: Vec<(Fingerprint, u32)> = Vec::new();
        for (key, value) in self.backref.scan_prefix(&[])? {
            let (fp, _) = BackrefEntry::decode_key(&key)?;
            if out.last().map(|(last, _)| *last) == Some(fp) {
                continue; // same fingerprint, next referrer — keys are ordered
            }
            let (len, _) = BackrefEntry::decode_value(&value)?;
            out.push((fp, len));
        }
        Ok(out)
    }

    /// Number of backreference records in the index.
    pub fn backref_len(&self) -> usize {
        self.backref.len()
    }

    /// Re-derive the whole index from the OMAP (the source of truth).
    /// Run as the one-shot migration for stores that predate the index
    /// and after crash recovery (a crash can separate an OMAP write from
    /// its index update). Applied as a diff — records already correct are
    /// left untouched — so the clean-recovery common case appends nothing
    /// to a log-structured backing store (a delete-all-then-rewrite would
    /// grow `backref.log` by ~2× the index per restart, forever). Returns
    /// the number of records in the rebuilt index.
    pub fn rebuild_backrefs(&self) -> Result<usize> {
        let _guard = self.omap_rmw.lock().unwrap();
        self.rebuild_backrefs_locked()
    }

    fn rebuild_backrefs_locked(&self) -> Result<usize> {
        let mut expected = self.derive_backrefs_locked()?;
        let records = expected.len();
        for (key, value) in self.backref.scan_prefix(&[])? {
            let correct = expected.get(&key).map_or(false, |want| *want == value);
            if correct {
                expected.remove(&key); // already right: no churn
            } else {
                self.backref.delete(&key)?; // stale or drifted
            }
        }
        for (key, value) in expected {
            self.backref.put(&key, &value)?;
        }
        Ok(records)
    }

    /// The index the OMAP implies: every layout entry exploded to its
    /// backref `(key, value)` records. Callers hold `omap_rmw`.
    fn derive_backrefs_locked(&self) -> Result<HashMap<Vec<u8>, Vec<u8>>> {
        let mut expected: HashMap<Vec<u8>, Vec<u8>> = HashMap::new();
        for name in self.omap_names()? {
            if let Some(entry) = self.omap_get(&name)? {
                for b in backrefs_of(&entry) {
                    expected.insert(b.key(), b.encode());
                }
            }
        }
        Ok(expected)
    }

    /// Cross-check the index against the OMAP. Returns one human-readable
    /// line per discrepancy (stale record, missing record, value drift);
    /// empty means index ≡ OMAP. Quiescent-state checker: concurrent OMAP
    /// writes are excluded by the OMAP read-modify-write lock, but a
    /// mutation queued behind the audit will of course change the answer.
    pub fn backref_audit(&self) -> Result<Vec<String>> {
        let _guard = self.omap_rmw.lock().unwrap();
        self.backref_audit_locked()
    }

    fn backref_audit_locked(&self) -> Result<Vec<String>> {
        let mut expected = self.derive_backrefs_locked()?;
        let mut problems = Vec::new();
        for (key, value) in self.backref.scan_prefix(&[])? {
            match expected.remove(&key) {
                None => problems.push(format!(
                    "stale backref record {:?} (no OMAP reference)",
                    BackrefEntry::decode_key(&key)
                )),
                Some(want) if want != value => problems.push(format!(
                    "backref drift for {:?}: index disagrees with OMAP layout",
                    BackrefEntry::decode_key(&key)
                )),
                Some(_) => {}
            }
        }
        for key in expected.keys() {
            problems.push(format!(
                "missing backref record {:?} (OMAP reference not indexed)",
                BackrefEntry::decode_key(key)
            ));
        }
        Ok(problems)
    }

    /// The [`crate::storage::proto::Req::RebuildBackrefs`] body: audit,
    /// then re-derive, under ONE lock acquisition — a foreground OMAP
    /// write slipping between a separate audit and rebuild would make the
    /// reported mismatch count describe drift the rebuild never saw.
    /// Returns `(records in the rebuilt index, pre-rebuild discrepancies)`.
    pub fn audit_and_rebuild_backrefs(&self) -> Result<(usize, Vec<String>)> {
        let _guard = self.omap_rmw.lock().unwrap();
        let problems = self.backref_audit_locked()?;
        let records = self.rebuild_backrefs_locked()?;
        Ok((records, problems))
    }

    /// Reference implementation of local reference counting: a full OMAP
    /// table walk, decoding every layout entry. O(objects × chunks) per
    /// call — kept as the audit/bench baseline the index is measured
    /// against; production paths use [`DmShard::backref_refs_many`].
    pub fn count_refs_scan(&self, fps: &[Fingerprint]) -> Result<Vec<u64>> {
        let wanted: HashSet<Fingerprint> = fps.iter().copied().collect();
        let mut counts: HashMap<Fingerprint, u64> = HashMap::with_capacity(wanted.len());
        for name in self.omap_names()? {
            let Some(entry) = self.omap_get(&name)? else {
                continue;
            };
            for (fp, _) in &entry.chunks {
                if wanted.contains(fp) {
                    *counts.entry(*fp).or_insert(0) += 1;
                }
            }
        }
        // answer by position so a fingerprint queried twice (windows are
        // arbitrary slices) gets its count at every position
        Ok(fps
            .iter()
            .map(|fp| counts.get(fp).copied().unwrap_or(0))
            .collect())
    }

    // ---- CIT ----

    /// Fetch a CIT entry.
    pub fn cit_get(&self, fp: &Fingerprint) -> Result<Option<CitEntry>> {
        match self.cit.get(&fp.to_bytes())? {
            Some(v) => Ok(Some(CitEntry::decode(&v)?)),
            None => Ok(None),
        }
    }

    /// Insert/replace a CIT entry.
    pub fn cit_put(&self, fp: &Fingerprint, entry: &CitEntry) -> Result<()> {
        self.cit.put(&fp.to_bytes(), &entry.encode())
    }

    /// Delete a CIT entry; true if it existed.
    pub fn cit_delete(&self, fp: &Fingerprint) -> Result<bool> {
        self.cit.delete(&fp.to_bytes())
    }

    /// Read-modify-write a CIT entry under the CIT store's own lock
    /// granularity (single key). Returns the updated entry, or `None` if
    /// absent and `f` declined to create it.
    pub fn cit_update(
        &self,
        fp: &Fingerprint,
        f: impl FnOnce(Option<CitEntry>) -> Option<CitEntry>,
    ) -> Result<Option<CitEntry>> {
        // The store is internally synchronized per-op; cross-op atomicity
        // (get → modify → put) needs the shard RMW lock because frontend
        // and backend lanes both mutate the CIT.
        let _guard = self.rmw.lock().unwrap();
        let cur = self.cit_get(fp)?;
        match f(cur) {
            Some(next) => {
                self.cit_put(fp, &next)?;
                Ok(Some(next))
            }
            None => Ok(None),
        }
    }

    /// Flip the commit flag of an existing entry.
    pub fn cit_set_flag(&self, fp: &Fingerprint, flag: CommitFlag, now_ms: u64) -> Result<bool> {
        Ok(self
            .cit_update(fp, |cur| {
                cur.map(|mut e| {
                    e.flag = flag;
                    e.flagged_at_ms = now_ms;
                    e
                })
            })?
            .is_some())
    }

    /// Batched commit-flag probe (Phase A of the batched write path):
    /// for each fingerprint, answered in request order, does a CIT entry
    /// exist here with a Valid flag? A single read-only pass — no RMW
    /// lock, no entry is ever written — so a stale answer is possible by
    /// design and is exactly what the Phase-B NeedData NACK covers.
    pub fn cit_valid_many(&self, fps: &[Fingerprint]) -> Result<Vec<bool>> {
        fps.iter()
            .map(|fp| {
                let e = self.cit_get(fp)?;
                Ok(e.is_some_and(|e| e.flag == CommitFlag::Valid))
            })
            .collect()
    }

    /// All fingerprints in the CIT.
    pub fn cit_fingerprints(&self) -> Result<Vec<Fingerprint>> {
        Ok(self
            .cit
            .keys()?
            .into_iter()
            .filter_map(|k| Fingerprint::from_bytes(&k))
            .collect())
    }

    /// Number of CIT entries.
    pub fn cit_len(&self) -> usize {
        self.cit.len()
    }

    /// Flush all three stores.
    pub fn sync(&self) -> Result<()> {
        self.omap.sync()?;
        self.cit.sync()?;
        self.backref.sync()
    }

    /// Erase all three stores (wipe-and-rejoin). Taken under both
    /// read-modify-write locks so no concurrent OMAP/CIT mutation can
    /// interleave with the wipe and resurrect a partial record; callers
    /// must have fenced the server's lanes first, this is belt and
    /// braces.
    pub fn wipe(&self) -> Result<()> {
        let _omap_guard = self.omap_rmw.lock().unwrap();
        let _cit_guard = self.rmw.lock().unwrap();
        self.omap.clear()?;
        self.cit.clear()?;
        self.backref.clear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::MemKv;

    fn shard() -> DmShard {
        DmShard::new(
            Box::new(MemKv::new()),
            Box::new(MemKv::new()),
            Box::new(MemKv::new()),
        )
    }

    #[test]
    fn omap_crud() {
        let s = shard();
        let e = OmapEntry::new(
            "obj".into(),
            Fingerprint::of(b"obj"),
            vec![(Fingerprint::of(b"c"), 10)],
        );
        s.omap_put(&e).unwrap();
        assert_eq!(s.omap_get("obj").unwrap().unwrap(), e);
        assert_eq!(s.omap_len(), 1);
        assert_eq!(s.omap_names().unwrap(), vec!["obj".to_string()]);
        let d = s.omap_delete("obj").unwrap().expect("existed");
        assert_eq!(d, BackrefDelta { added: 0, removed: 1 });
        assert!(s.omap_get("obj").unwrap().is_none());
        assert!(s.omap_delete("obj").unwrap().is_none(), "second delete");
    }

    #[test]
    fn wipe_empties_all_three_stores() {
        let s = shard();
        let e = OmapEntry::new(
            "obj".into(),
            Fingerprint::of(b"obj"),
            vec![(Fingerprint::of(b"c"), 10)],
        );
        s.omap_put(&e).unwrap();
        s.cit_put(
            &Fingerprint::of(b"c"),
            &CitEntry {
                refcount: 1,
                flag: CommitFlag::Valid,
                len: 10,
                flagged_at_ms: 0,
            },
        )
        .unwrap();
        assert!(s.omap_len() > 0 && s.cit_len() > 0 && s.backref_len() > 0);
        s.wipe().unwrap();
        assert_eq!(s.omap_len(), 0);
        assert_eq!(s.cit_len(), 0);
        assert_eq!(s.backref_len(), 0);
        assert!(s.omap_get("obj").unwrap().is_none());
    }

    #[test]
    fn omap_put_if_absent_never_clobbers() {
        let s = shard();
        let fresh = OmapEntry::new(
            "obj".into(),
            Fingerprint::of(b"v2"),
            vec![(Fingerprint::of(b"new"), 8)],
        );
        let stale = OmapEntry::new(
            "obj".into(),
            Fingerprint::of(b"v1"),
            vec![(Fingerprint::of(b"old"), 8)],
        );
        // adoption into an empty slot writes (and indexes) the record
        let delta = s.omap_put_if_absent(&stale).unwrap().expect("adopted");
        assert_eq!(delta.added, 1);
        // a later adoption attempt must not clobber an existing record
        s.omap_put(&fresh).unwrap();
        assert!(s.omap_put_if_absent(&stale).unwrap().is_none());
        assert_eq!(s.omap_get("obj").unwrap().unwrap(), fresh);
    }

    #[test]
    fn backref_index_tracks_omap_mutations() {
        let s = shard();
        let c1 = Fingerprint::of(b"c1");
        let c2 = Fingerprint::of(b"c2");
        let c3 = Fingerprint::of(b"c3");
        // two objects share c1; "a" references c1 twice
        let a = OmapEntry::new(
            "a".into(),
            Fingerprint::of(b"a"),
            vec![(c1, 10), (c2, 20), (c1, 10)],
        );
        let b = OmapEntry::new("b".into(), Fingerprint::of(b"b"), vec![(c1, 10)]);
        let d = s.omap_put(&a).unwrap();
        assert_eq!(d, BackrefDelta { added: 2, removed: 0 });
        s.omap_put(&b).unwrap();
        assert_eq!(s.backref_refs(&c1).unwrap(), 3);
        assert_eq!(s.backref_refs(&c2).unwrap(), 1);
        assert_eq!(s.backref_refs(&c3).unwrap(), 0);
        assert_eq!(
            s.backref_refs_many(&[c1, c2, c3]).unwrap(),
            s.count_refs_scan(&[c1, c2, c3]).unwrap()
        );
        let referrers = s.backref_referrers(&c1).unwrap();
        assert_eq!(referrers.len(), 2);
        let referenced = s.backref_referenced().unwrap();
        assert_eq!(referenced.len(), 2, "distinct fps: c1, c2");
        assert!(s.backref_audit().unwrap().is_empty());

        // overwrite "a" dropping c2, adding c3 → stale c2 record removed
        let a2 = OmapEntry::new("a".into(), Fingerprint::of(b"a2"), vec![(c1, 10), (c3, 30)]);
        let d = s.omap_put(&a2).unwrap();
        assert_eq!(d, BackrefDelta { added: 2, removed: 1 });
        assert_eq!(s.backref_refs(&c2).unwrap(), 0);
        assert_eq!(s.backref_refs(&c1).unwrap(), 2);
        assert_eq!(s.backref_refs(&c3).unwrap(), 1);
        assert!(s.backref_audit().unwrap().is_empty());

        // delete "b" → its c1 record goes too
        assert!(s.omap_delete("b").unwrap().is_some());
        assert_eq!(s.backref_refs(&c1).unwrap(), 1);
        assert!(s.backref_audit().unwrap().is_empty());
    }

    #[test]
    fn backref_rebuild_and_audit_catch_divergence() {
        let s = shard();
        let c1 = Fingerprint::of(b"c1");
        s.omap_put(&OmapEntry::new(
            "a".into(),
            Fingerprint::of(b"a"),
            vec![(c1, 10)],
        ))
        .unwrap();
        // simulate a torn update: nuke the index behind the shard's back
        for key in s.backref.keys().unwrap() {
            s.backref.delete(&key).unwrap();
        }
        assert_eq!(s.backref_refs(&c1).unwrap(), 0);
        let problems = s.backref_audit().unwrap();
        assert_eq!(problems.len(), 1);
        assert!(problems[0].contains("missing backref"), "{problems:?}");
        // rebuild re-derives from the OMAP
        assert_eq!(s.rebuild_backrefs().unwrap(), 1);
        assert!(s.backref_audit().unwrap().is_empty());
        assert_eq!(s.backref_refs(&c1).unwrap(), 1);
        // a stale record (referrer with no OMAP entry) is also caught
        let ghost = BackrefEntry {
            fp: c1,
            object: "ghost".into(),
            len: 10,
            ordinals: vec![0],
        };
        s.backref.put(&ghost.key(), &ghost.encode()).unwrap();
        let problems = s.backref_audit().unwrap();
        assert_eq!(problems.len(), 1);
        assert!(problems[0].contains("stale backref"), "{problems:?}");
    }

    #[test]
    fn cit_crud_and_update() {
        let s = shard();
        let fp = Fingerprint::of(b"chunk");
        assert!(s.cit_get(&fp).unwrap().is_none());
        s.cit_put(
            &fp,
            &CitEntry {
                refcount: 1,
                flag: CommitFlag::Invalid,
                len: 100,
                flagged_at_ms: 5,
            },
        )
        .unwrap();
        let e = s
            .cit_update(&fp, |cur| {
                let mut e = cur.unwrap();
                e.refcount += 2;
                Some(e)
            })
            .unwrap()
            .unwrap();
        assert_eq!(e.refcount, 3);
        assert!(s.cit_set_flag(&fp, CommitFlag::Valid, 9).unwrap());
        let e = s.cit_get(&fp).unwrap().unwrap();
        assert_eq!(e.flag, CommitFlag::Valid);
        assert_eq!(e.flagged_at_ms, 9);
        assert_eq!(s.cit_fingerprints().unwrap(), vec![fp]);
        assert!(s.cit_delete(&fp).unwrap());
        assert_eq!(s.cit_len(), 0);
    }

    #[test]
    fn cit_valid_many_reports_flag_state() {
        let s = shard();
        let a = Fingerprint::of(b"a");
        let b = Fingerprint::of(b"b");
        let c = Fingerprint::of(b"c");
        let entry = |flag| CitEntry {
            refcount: 1,
            flag,
            len: 8,
            flagged_at_ms: 0,
        };
        s.cit_put(&a, &entry(CommitFlag::Valid)).unwrap();
        s.cit_put(&b, &entry(CommitFlag::Invalid)).unwrap();
        let probed = s.cit_valid_many(&[a, b, c, a]).unwrap();
        assert_eq!(probed, vec![true, false, false, true]);
    }

    #[test]
    fn set_flag_on_missing_is_false() {
        let s = shard();
        assert!(!s.cit_set_flag(&Fingerprint::of(b"x"), CommitFlag::Valid, 0).unwrap());
    }

    #[test]
    fn update_can_decline_creation() {
        let s = shard();
        let fp = Fingerprint::of(b"nope");
        let r = s.cit_update(&fp, |cur| cur).unwrap();
        assert!(r.is_none());
        assert!(s.cit_get(&fp).unwrap().is_none());
    }
}
