//! Refcount-banded redundancy policy (FASTEN, arXiv:2312.08309).
//!
//! Dedup concentrates risk: losing the last copy of a million-referrer
//! chunk destroys every object that references it, while a refcount-1
//! chunk at flat `replication` is over-protected. The policy here maps
//! refcount *bands* to extra copy counts — e.g. refs ≥ 8 → +1 copy,
//! refs ≥ 64 → +2 — so redundancy tracks blast radius instead of being
//! uniform. Every path that plants or repairs copies (write-time
//! fan-out, scrub, recovery re-replication, rebalance migrate-out, the
//! online promote/demote hooks) asks [`RedundancyPolicy::target_copies`]
//! for the same answer, which is what makes the copy count converge
//! (DESIGN.md §15).

/// One band of the policy: chunks whose refcount is at least
/// [`RedundancyBand::min_refs`] get [`RedundancyBand::extra_copies`]
/// copies on top of the base replication factor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RedundancyBand {
    /// Inclusive refcount threshold that activates this band.
    pub min_refs: u64,
    /// Copies added on top of the configured base replication.
    pub extra_copies: usize,
}

/// Refcount band → copy count mapping, consulted by every plant/repair
/// path. The default (no bands) reproduces flat `replication`-copy
/// behavior exactly.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct RedundancyPolicy {
    bands: Vec<RedundancyBand>,
}

impl RedundancyPolicy {
    /// Flat policy: every chunk gets the base replication count
    /// regardless of refcount (the pre-banding behavior).
    pub fn flat() -> Self {
        Self::default()
    }

    /// Build a policy from `(min_refs, extra_copies)` pairs. Bands are
    /// sorted by threshold; a higher band never grants fewer copies than
    /// a lower one (extras are made monotone on construction, so a
    /// refcount crossing a threshold can only raise the target).
    pub fn new(bands: impl IntoIterator<Item = (u64, usize)>) -> Self {
        let mut bands: Vec<RedundancyBand> = bands
            .into_iter()
            .map(|(min_refs, extra_copies)| RedundancyBand {
                min_refs,
                extra_copies,
            })
            .collect();
        bands.sort_by_key(|b| b.min_refs);
        let mut floor = 0usize;
        for b in &mut bands {
            b.extra_copies = b.extra_copies.max(floor);
            floor = b.extra_copies;
        }
        RedundancyPolicy { bands }
    }

    /// The reference banded policy from the redundancy issue: refs ≥ 8
    /// → one extra copy, refs ≥ 64 → two.
    pub fn banded() -> Self {
        Self::new([(8, 1), (64, 2)])
    }

    /// True when no bands are configured (flat replication).
    pub fn is_flat(&self) -> bool {
        self.bands.is_empty()
    }

    /// The configured bands (threshold-ascending).
    pub fn bands(&self) -> &[RedundancyBand] {
        &self.bands
    }

    /// Extra copies granted to a chunk with `refcount` references: the
    /// highest band whose threshold it meets (0 below every band).
    pub fn extra_copies(&self, refcount: u64) -> usize {
        self.bands
            .iter()
            .rev()
            .find(|b| refcount >= b.min_refs)
            .map(|b| b.extra_copies)
            .unwrap_or(0)
    }

    /// Target copy count (primary included) for a chunk with `refcount`
    /// references under base replication `base`, capped by the number of
    /// live servers (`live`) — a 3-server cluster cannot hold 4 distinct
    /// copies — and floored at 1.
    pub fn target_copies(&self, refcount: u64, base: usize, live: usize) -> usize {
        (base + self.extra_copies(refcount)).clamp(1, live.max(1))
    }

    /// The most copies any band can demand (uncapped): the chain width
    /// placement must provision so the top band has slots to fill.
    pub fn max_copies(&self, base: usize) -> usize {
        base + self.bands.last().map(|b| b.extra_copies).unwrap_or(0)
    }

    /// The threshold of the highest band (`None` when flat) — benches
    /// and reports use it to isolate the hottest chunks.
    pub fn top_band_min_refs(&self) -> Option<u64> {
        self.bands.last().map(|b| b.min_refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_policy_matches_base_replication() {
        let p = RedundancyPolicy::flat();
        assert!(p.is_flat());
        for refs in [0, 1, 7, 8, 1_000_000] {
            assert_eq!(p.target_copies(refs, 2, 5), 2);
        }
        assert_eq!(p.max_copies(2), 2);
        assert_eq!(p.top_band_min_refs(), None);
    }

    #[test]
    fn banded_targets_step_at_thresholds() {
        let p = RedundancyPolicy::banded();
        assert_eq!(p.target_copies(7, 2, 10), 2);
        assert_eq!(p.target_copies(8, 2, 10), 3);
        assert_eq!(p.target_copies(63, 2, 10), 3);
        assert_eq!(p.target_copies(64, 2, 10), 4);
        assert_eq!(p.max_copies(2), 4);
        assert_eq!(p.top_band_min_refs(), Some(64));
    }

    #[test]
    fn target_capped_by_live_servers_and_floored_at_one() {
        let p = RedundancyPolicy::banded();
        assert_eq!(p.target_copies(1_000, 2, 3), 3, "capped by live count");
        assert_eq!(p.target_copies(1_000, 2, 0), 1, "empty cluster floors at 1");
        assert_eq!(RedundancyPolicy::flat().target_copies(0, 0, 5), 1);
    }

    #[test]
    fn bands_sorted_and_made_monotone() {
        // deliberately unsorted and non-monotone input
        let p = RedundancyPolicy::new([(64, 1), (8, 2)]);
        assert_eq!(p.bands()[0].min_refs, 8);
        assert_eq!(p.bands()[1].min_refs, 64);
        // the 64-band is raised to the 8-band's extras: crossing a
        // threshold upward can never lower the target
        assert_eq!(p.extra_copies(8), 2);
        assert_eq!(p.extra_copies(64), 2);
    }
}
