//! The deduplication I/O engine — the paper's Figure 3 transactions.
//!
//! [`put_object`] / [`get_object`] / [`delete_object`] run on an OSD
//! frontend lane (the object's name-hash primary — "OSS 1" in Figure 2);
//! [`store_chunk_local`] runs on the backend lane of the chunk's
//! content-hash home ("OSS 4"). Four dedup modes share these entry points:
//!
//! * [`DedupMode::ClusterWide`] — the paper: chunks and their CIT entries
//!   routed by fingerprint; intra-batch duplicates collapsed before any
//!   network I/O (the L2 graph's first-duplicate index does this when the
//!   XLA provider is active; the scalar path does it with a hash map).
//! * [`DedupMode::Central`] — comparator: one server (osd.0) owns all
//!   dedup metadata and performs all chunking/fingerprinting; chunk data
//!   is spread raw across the cluster.
//! * [`DedupMode::DiskLocal`] — comparator for Table 2: dedup only within
//!   the object's primary server.
//! * [`DedupMode::None`] — baseline: whole objects stored raw.
//!
//! The cluster-wide write path ships unique chunks through the batched
//! two-phase protocol by default ([`WriteBatching::TwoPhase`], DESIGN.md
//! §7): one `ProbeChunks` plus one `StoreChunkBatch` per distinct chunk
//! home — payloads only for probe misses — instead of one full-payload
//! `StoreChunk` per unique chunk ([`WriteBatching::Off`]).

use crate::cluster::ServerId;
use crate::dedup::cit::{CitEntry, CommitFlag};
use crate::dedup::consistency::ConsistencyMode;
use crate::dedup::fingerprint::Fingerprint;
use crate::dedup::omap::OmapEntry;
use crate::error::{Error, Result};
use crate::failure::CrashPoint;
use crate::metrics::Metrics;
use crate::net::{Lane, Pending};
use crate::sched::flow::MaintClass;
use crate::storage::osd::OsdShared;
use crate::storage::proto::{ChunkAck, ChunkPut, Req, Resp};
use std::borrow::Cow;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Which deduplication architecture the cluster runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DedupMode {
    /// No deduplication (baseline Ceph in the paper's figures).
    None,
    /// The paper's cluster-wide dedup (DM-Shard + content placement).
    ClusterWide,
    /// Central dedup-metadata server (osd.0).
    Central,
    /// Per-server local dedup (Table 2's disk-based comparator).
    DiskLocal,
}

impl DedupMode {
    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            DedupMode::None => "no-dedup",
            DedupMode::ClusterWide => "cluster-wide",
            DedupMode::Central => "central",
            DedupMode::DiskLocal => "disk-local",
        }
    }
}

/// Which protocol the cluster-wide write path uses to ship unique
/// chunks to their content homes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteBatching {
    /// Legacy protocol: one `StoreChunk` message (always carrying the
    /// full payload) per unique chunk — O(unique chunks) fabric
    /// messages per put.
    Off,
    /// Per-home two-phase batches: one `ProbeChunks` plus one
    /// `StoreChunkBatch` per distinct chunk home, payloads shipped only
    /// for probe misses, stale hints NACKed with `NeedData` and resent
    /// — ≤ 2 messages per distinct home per put.
    TwoPhase,
}

/// Which protocol the read path uses to gather a dedup'd object's
/// chunks from their content homes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadBatching {
    /// Legacy protocol: one `FetchChunk` message per chunk occurrence —
    /// O(chunks) fabric messages per read.
    Off,
    /// Per-home gather: the chunk list is grouped by placement chain
    /// and fetched with one `FetchChunkBatch` per distinct live home
    /// (after the hot-chunk cache and local stores are consulted), with
    /// per-item fallback to the legacy path for misses and dead homes —
    /// ≤ 1 backend message per distinct live chunk home per read.
    PerHome,
}

/// Backoff before the single retry the read path grants a `Busy` chunk
/// home (the AIMD-style courtesy the recovery prober extends, collapsed
/// to one attempt because a reader can always fall back to replicas).
const READ_RETRY_BACKOFF_US: u64 = 50;

/// Sentinel for "this server just crashed mid-transaction": the lane loop
/// checks the injector and drops the reply, so the message text never
/// reaches a client.
fn died() -> Error {
    Error::TxAborted("server crashed".into())
}

/// One granted chunk reference: (fingerprint, multiplicity, dedup hit).
type StoredRef = (Fingerprint, u64, bool);

/// Look up a backend lane and fire one request, charging the engine's
/// backend wire-byte accounting ([`Metrics::wire_bytes`]).
pub(crate) fn backend_send(sh: &OsdShared, target: ServerId, req: Req) -> Result<Pending<Resp>> {
    let addr = sh.dir.lookup(target, Lane::Backend)?;
    let size = req.wire_size();
    Metrics::add(&sh.metrics.wire_bytes, size as u64);
    addr.send(req, size)
}

/// [`backend_send`] + wait: a synchronous backend RPC.
pub(crate) fn backend_call(sh: &OsdShared, target: ServerId, req: Req) -> Result<Resp> {
    backend_send(sh, target, req)?.wait()
}

// --------------------------------------------------------------------
// write path
// --------------------------------------------------------------------

/// Whole-object write (frontend). Returns (logical bytes, unique bytes
/// newly stored).
pub fn put_object(sh: &OsdShared, name: &str, data: &[u8]) -> Result<(u64, u64)> {
    Metrics::add(&sh.metrics.bytes_logical, data.len() as u64);
    match sh.cfg.dedup {
        DedupMode::None => put_nodedup(sh, name, data),
        DedupMode::ClusterWide => put_dedup(sh, name, data, /*local_only=*/ false),
        DedupMode::DiskLocal => put_dedup(sh, name, data, /*local_only=*/ true),
        DedupMode::Central => put_central(sh, name, data),
    }
}

/// Baseline: store the whole object raw on this server + replicas.
fn put_nodedup(sh: &OsdShared, name: &str, data: &[u8]) -> Result<(u64, u64)> {
    let key = raw_object_key(name);
    sh.store.put(&key, data)?;
    Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
    let failures = replicate(sh, &sh.object_chain(name), &key, data, sh.cfg.replication)?;
    if failures > 0 {
        Metrics::add(&sh.metrics.replica_push_failures, failures as u64);
    }
    Ok((data.len() as u64, data.len() as u64))
}

/// Cluster-wide (and, with `local_only`, disk-local) dedup write.
fn put_dedup(sh: &OsdShared, name: &str, data: &[u8], local_only: bool) -> Result<(u64, u64)> {
    // SyncObject mode holds the object transaction lock for the whole
    // write and pays one extra synchronous flag I/O at the end.
    let _obj_guard = if sh.cfg.consistency == ConsistencyMode::SyncObject {
        Some(sh.obj_lock.lock().unwrap())
    } else {
        None
    };

    // 1. split + fingerprint. Under the tiered pipeline (DESIGN.md §16)
    //    unique-looking chunks skip the inline strong hash entirely and
    //    carry a pending identity; the inline path strong-hashes every
    //    chunk exactly as before.
    let chunks = sh.cfg.chunker.split(data);
    let tiered = !local_only && sh.cfg.fp_mode.is_tiered();
    let (digests, pending) = if tiered {
        let c = crate::dedup::fpipe::classify(sh, name, &chunks)?;
        (c.digests, c.pending)
    } else {
        Metrics::add(&sh.metrics.fp_strong_hashes, chunks.len() as u64);
        (sh.provider.digests(&chunks), HashSet::new())
    };

    // 2. collapse intra-batch duplicates (multiplicity per unique fp);
    //    first occurrence keeps the payload.
    let mut order: Vec<Fingerprint> = Vec::new();
    let mut uniq: HashMap<Fingerprint, (usize, u64)> = HashMap::new();
    for (i, fp) in digests.iter().enumerate() {
        match uniq.get_mut(fp) {
            Some((_, refs)) => *refs += 1,
            None => {
                uniq.insert(*fp, (i, 1));
                order.push(*fp);
            }
        }
    }

    // 3. route every unique chunk to its content home (scatter), gather
    //    acks. Local chunks bypass the fabric — same-machine shortcut.
    //    Pending identities never enter the content-addressed scatter:
    //    their placement key is the object's name hash, so they land on
    //    this server by object locality (tier 1 of §16).
    let mut stored: Vec<StoredRef> = Vec::new();
    let mut failed: Option<Error> = None;
    let mut scatter_order: Vec<Fingerprint> = Vec::new();
    for fp in &order {
        if pending.contains(fp) {
            let (idx, refs) = uniq[fp];
            match crate::dedup::fpipe::store_pending_local(sh, fp, chunks[idx], refs) {
                Ok(hit) => stored.push((*fp, refs, hit)),
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            }
        } else {
            scatter_order.push(*fp);
        }
    }
    let batched = !local_only && sh.cfg.write_batching == WriteBatching::TwoPhase;
    if failed.is_none() {
        let (mut granted, err) = if batched {
            scatter_batched(sh, &scatter_order, &uniq, &chunks)
        } else {
            scatter_single(sh, &scatter_order, &uniq, &chunks, local_only)
        };
        stored.append(&mut granted);
        failed = err;
    }
    if let Some(e) = failed {
        // abort: roll back references we already took.
        rollback(sh, &stored, local_only);
        Metrics::add(&sh.metrics.tx_aborts, 1);
        return Err(Error::TxAborted(format!("chunk store failed: {e}")));
    }

    if sh.injector.maybe_crash(CrashPoint::BeforeOmapWrite) {
        return Err(died());
    }

    // 4. OMAP entry (object layout) — object fp is the Merkle digest of
    //    the chunk fingerprints (reconstruction needs chunk fps, §2.2).
    // An overwrite replaces the layout: the old version's chunk
    // references must be released (after the new entry is durable).
    let old_entry = sh.shard.omap_get(name)?;
    let object_fp = object_fingerprint(&digests);
    let entry = OmapEntry::new(
        name.to_string(),
        object_fp,
        digests
            .iter()
            .zip(&chunks)
            .map(|(fp, c)| (*fp, c.len() as u32))
            .collect(),
    );
    sh.charge_meta_io(); // modeled DM-Shard write
    let backrefs = sh.shard.omap_put(&entry)?;
    if backrefs.total() > 0 {
        // the backreference-index column rides the same DM-Shard
        // transaction: one more modeled synchronous write
        sh.charge_meta_io();
        Metrics::add(&sh.metrics.backref_updates, backrefs.total());
    }

    // SyncObject: the single synchronous object-flag I/O.
    if sh.cfg.consistency == ConsistencyMode::SyncObject {
        sh.charge_meta_io(); // modeled DM-Shard write
        sh.store.put(&object_flag_key(name), &[1u8])?;
    }

    if sh.injector.maybe_crash(CrashPoint::AfterOmapWrite) {
        return Err(died());
    }

    // 5. replicate the OMAP record for read availability.
    let chain = sh.object_chain(name);
    let failures = replicate(
        sh,
        &chain,
        &omap_copy_key(name),
        &entry.encode(),
        sh.cfg.replication,
    )?;
    if failures > 0 {
        Metrics::add(&sh.metrics.replica_push_failures, failures as u64);
    }

    // 6. release the overwritten version's chunk references.
    if let Some(old) = old_entry {
        release_refs(sh, &old, local_only);
    }

    // 7. hand pending identities to the tier-2 migrator only now that
    //    the OMAP entry is durable, so its backref walk sees every
    //    referencing object.
    for fp in &pending {
        sh.fpipe.enqueue(*fp);
    }

    let unique: u64 = stored
        .iter()
        .filter(|(_, _, hit)| !hit)
        .map(|(fp, _, _)| chunks[uniq[fp].0].len() as u64)
        .sum();
    Ok((data.len() as u64, unique))
}

/// Legacy scatter ([`WriteBatching::Off`], and the disk-local mode):
/// one `StoreChunk` with the full payload per unique chunk, acks
/// gathered after all sends. Returns the references granted so far and
/// the first error (the caller rolls the grants back on error).
fn scatter_single(
    sh: &OsdShared,
    order: &[Fingerprint],
    uniq: &HashMap<Fingerprint, (usize, u64)>,
    chunks: &[&[u8]],
    local_only: bool,
) -> (Vec<StoredRef>, Option<Error>) {
    let mut pendings = Vec::new();
    let mut stored: Vec<StoredRef> = Vec::new();
    let mut failed: Option<Error> = None;
    for fp in order {
        let (idx, refs) = uniq[fp];
        let target = if local_only {
            sh.id
        } else {
            sh.chunk_chain(fp.placement_key())[0]
        };
        if target == sh.id {
            match store_chunk_local(sh, fp, Cow::Borrowed(chunks[idx]), refs) {
                Ok(hit) => stored.push((*fp, refs, hit)),
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            }
        } else {
            let req = Req::StoreChunk {
                fp: *fp,
                data: chunks[idx].to_vec(),
                refs,
            };
            match backend_send(sh, target, req) {
                Ok(p) => pendings.push((*fp, refs, p)),
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            }
        }
    }
    for (fp, refs, p) in pendings {
        match p.wait() {
            Ok(Resp::StoreAck { dedup_hit }) => stored.push((fp, refs, dedup_hit)),
            Ok(Resp::Err(e)) => failed = Some(Error::TxAborted(e)),
            Ok(_) => failed = Some(Error::TxAborted("bad store reply".into())),
            Err(e) => failed = Some(e),
        }
    }
    (stored, failed)
}

/// Two-phase batched scatter ([`WriteBatching::TwoPhase`]): group the
/// object's unique fingerprints by chunk home, probe each home once
/// (`ProbeChunks`, a read-only CIT pass), then send one
/// `StoreChunkBatch` per home carrying refcount grants for every item
/// but payloads only for probe misses. A `NeedData` NACK — the hint
/// went stale between the phases, e.g. GC reclaimed the chunk — gets
/// that item re-shipped with its payload, where the atomic
/// `cit_update` upsert in [`store_chunk_local`] restores it exactly
/// like any first store. Local chunks bypass the fabric like the
/// legacy path; probe failures degrade to all-miss (full payloads) and
/// the store phase surfaces any real error.
fn scatter_batched(
    sh: &OsdShared,
    order: &[Fingerprint],
    uniq: &HashMap<Fingerprint, (usize, u64)>,
    chunks: &[&[u8]],
) -> (Vec<StoredRef>, Option<Error>) {
    let mut stored: Vec<StoredRef> = Vec::new();
    let mut failed: Option<Error> = None;
    let mut groups: BTreeMap<ServerId, Vec<Fingerprint>> = BTreeMap::new();
    for fp in order {
        let target = sh.chunk_chain(fp.placement_key())[0];
        if target == sh.id {
            let (idx, refs) = uniq[fp];
            match store_chunk_local(sh, fp, Cow::Borrowed(chunks[idx]), refs) {
                Ok(hit) => stored.push((*fp, refs, hit)),
                Err(e) => return (stored, Some(e)),
            }
        } else {
            groups.entry(target).or_default().push(*fp);
        }
    }

    // Phase A: one read-only probe per home. A home that cannot answer
    // is treated as all-miss; the store phase surfaces its real error.
    let mut probes = Vec::new();
    for (target, fps) in &groups {
        Metrics::add(&sh.metrics.probe_batches, 1);
        if let Ok(p) = backend_send(sh, *target, Req::ProbeChunks { fps: fps.clone() }) {
            probes.push((*target, p));
        }
    }
    let mut valid: HashSet<Fingerprint> = HashSet::new();
    for (target, p) in probes {
        if let Ok(Resp::ProbeAck { valid: flags }) = p.wait() {
            let fps = &groups[&target];
            if flags.len() == fps.len() {
                for (fp, hit) in fps.iter().zip(flags) {
                    if hit {
                        Metrics::add(&sh.metrics.probe_hits, 1);
                        valid.insert(*fp);
                    }
                }
            }
        }
    }

    // Test hook: force deterministic state changes (GC, flag flips) in
    // the gap between the two phases.
    if let Some(hook) = sh.probe_gap_hook.lock().unwrap().take() {
        hook();
    }

    // Phase B: one batch per home; payloads only for probe misses.
    let mut pendings = Vec::new();
    for (target, fps) in &groups {
        let items = build_batch_items(fps, uniq, chunks, |fp| !valid.contains(fp));
        Metrics::add(&sh.metrics.store_batches, 1);
        Metrics::add(&sh.metrics.batch_items, items.len() as u64);
        match backend_send(sh, *target, Req::StoreChunkBatch { items }) {
            Ok(p) => pendings.push((*target, p)),
            Err(e) => failed = Some(e),
        }
    }
    let mut resends: Vec<(ServerId, Vec<Fingerprint>)> = Vec::new();
    for (target, p) in pendings {
        let fps = &groups[&target];
        let need = gather_batch_acks(p.wait(), fps, uniq, &mut stored, &mut failed);
        if !need.is_empty() {
            resends.push((target, need));
        }
    }

    // NACK path: re-ship stale-hint items with their payloads. A resent
    // item can never be NACKed again (the payload is in hand).
    for (target, fps) in resends {
        Metrics::add(&sh.metrics.need_data_resends, fps.len() as u64);
        let items = build_batch_items(&fps, uniq, chunks, |_| true);
        Metrics::add(&sh.metrics.store_batches, 1);
        Metrics::add(&sh.metrics.batch_items, items.len() as u64);
        let reply = backend_call(sh, target, Req::StoreChunkBatch { items });
        let nacked = gather_batch_acks(reply, &fps, uniq, &mut stored, &mut failed);
        if let Some(fp) = nacked.first() {
            failed = Some(Error::TxAborted(format!(
                "chunk {fp} NACKed with payload in hand"
            )));
        }
    }
    (stored, failed)
}

/// Build one home's `StoreChunkBatch` items: every item carries its
/// refcount grant; `ship` decides which also carry their payload
/// (Phase B ships probe misses, the NACK resend ships everything).
fn build_batch_items(
    fps: &[Fingerprint],
    uniq: &HashMap<Fingerprint, (usize, u64)>,
    chunks: &[&[u8]],
    ship: impl Fn(&Fingerprint) -> bool,
) -> Vec<ChunkPut> {
    fps.iter()
        .map(|fp| {
            let (idx, refs) = uniq[fp];
            ChunkPut {
                fp: *fp,
                refs,
                data: ship(fp).then(|| chunks[idx].to_vec()),
            }
        })
        .collect()
}

/// Fold one `StoreChunkBatch` reply into `stored`: granted items are
/// recorded, the first error lands in `failed`, and the fingerprints
/// NACKed with `NeedData` are returned for the caller to re-ship with
/// payloads.
fn gather_batch_acks(
    reply: Result<Resp>,
    fps: &[Fingerprint],
    uniq: &HashMap<Fingerprint, (usize, u64)>,
    stored: &mut Vec<StoredRef>,
    failed: &mut Option<Error>,
) -> Vec<Fingerprint> {
    let mut need: Vec<Fingerprint> = Vec::new();
    match reply {
        Ok(Resp::StoreBatchAck { acks }) if acks.len() == fps.len() => {
            for (fp, ack) in fps.iter().zip(acks) {
                match ack {
                    ChunkAck::Stored { dedup_hit } => {
                        stored.push((*fp, uniq[fp].1, dedup_hit));
                    }
                    ChunkAck::NeedData => need.push(*fp),
                }
            }
        }
        Ok(Resp::Err(e)) => *failed = Some(Error::TxAborted(e)),
        Ok(_) => *failed = Some(Error::TxAborted("bad batch reply".into())),
        Err(e) => *failed = Some(e),
    }
    need
}

/// Central-dedup write (runs on osd.0's frontend): all metadata local,
/// chunk data spread raw by fingerprint. Remote raw stores are
/// pipelined (send-then-gather, like the cluster-wide scatter) instead
/// of one blocking RPC per chunk; a new chunk's CIT entry is inserted
/// only after its ack, so a failed store never leaves a Valid entry
/// without data behind it.
fn put_central(sh: &OsdShared, name: &str, data: &[u8]) -> Result<(u64, u64)> {
    let chunks = sh.cfg.chunker.split(data);
    Metrics::add(&sh.metrics.fp_strong_hashes, chunks.len() as u64);
    let digests = sh.provider.digests(&chunks);

    // collapse intra-object multiplicity so a deferred CIT insert still
    // accounts later occurrences of the same new chunk
    let mut order: Vec<Fingerprint> = Vec::new();
    let mut uniq: HashMap<Fingerprint, (usize, u64)> = HashMap::new();
    for (i, fp) in digests.iter().enumerate() {
        match uniq.get_mut(fp) {
            Some((_, refs)) => *refs += 1,
            None => {
                uniq.insert(*fp, (i, 1));
                order.push(*fp);
            }
        }
    }

    let mut unique_bytes = 0u64;
    let mut pendings = Vec::new();
    let mut failed: Option<Error> = None;
    for fp in &order {
        let (i, refs) = uniq[fp];
        Metrics::add(&sh.metrics.cit_lookups, refs);
        match sh.shard.cit_get(fp)? {
            Some(mut e) => {
                e.refcount += refs;
                sh.charge_meta_io(); // modeled DM-Shard write
                sh.shard.cit_put(fp, &e)?;
                Metrics::add(&sh.metrics.dedup_hits, refs);
            }
            None => {
                // place the data raw on the content-derived server
                let target = sh.chunk_chain(fp.placement_key())[0];
                let key = fp.to_bytes().to_vec();
                if target == sh.id {
                    sh.store.put(&key, chunks[i])?;
                    Metrics::add(&sh.metrics.bytes_stored, chunks[i].len() as u64);
                    insert_central_entry(sh, fp, chunks[i].len() as u32, refs)?;
                    unique_bytes += chunks[i].len() as u64;
                } else {
                    let req = Req::StoreRaw {
                        key,
                        data: chunks[i].to_vec(),
                    };
                    match backend_send(sh, target, req) {
                        Ok(p) => pendings.push((*fp, i, refs, p)),
                        Err(e) => {
                            // stop sending, but still gather what is in
                            // flight below — their data may land
                            failed = Some(e);
                            break;
                        }
                    }
                }
            }
        }
    }
    for (fp, i, refs, p) in pendings {
        match p.wait() {
            Ok(Resp::Ok) => {
                // the data landed remotely: always record its CIT entry,
                // even on a doomed put — raw bytes stored on a
                // non-metadata server would otherwise be orphaned forever
                // (GC only walks the metadata owner's CIT, DESIGN.md §5)
                match insert_central_entry(sh, &fp, chunks[i].len() as u32, refs) {
                    Ok(()) => unique_bytes += chunks[i].len() as u64,
                    Err(e) => failed = Some(e),
                }
            }
            Ok(Resp::Err(e)) => failed = Some(Error::TxAborted(e)),
            Ok(_) => failed = Some(Error::TxAborted("bad raw store reply".into())),
            Err(e) => failed = Some(e),
        }
    }
    if let Some(e) = failed {
        return Err(Error::TxAborted(format!("raw store failed: {e}")));
    }
    let entry_chunks: Vec<(Fingerprint, u32)> = digests
        .iter()
        .zip(&chunks)
        .map(|(fp, c)| (*fp, c.len() as u32))
        .collect();
    let old_entry = sh.shard.omap_get(name)?;
    let entry = OmapEntry::new(name.to_string(), object_fingerprint(&digests), entry_chunks);
    sh.charge_meta_io(); // modeled DM-Shard write
    let backrefs = sh.shard.omap_put(&entry)?;
    if backrefs.total() > 0 {
        sh.charge_meta_io(); // modeled backref-index write
        Metrics::add(&sh.metrics.backref_updates, backrefs.total());
    }
    if let Some(old) = old_entry {
        // central keeps all CIT entries locally
        let mut counts: HashMap<Fingerprint, u64> = HashMap::new();
        for (fp, _) in &old.chunks {
            *counts.entry(*fp).or_insert(0) += 1;
        }
        for (fp, refs) in counts {
            dec_ref_local(sh, &fp, refs)?;
        }
    }
    Ok((data.len() as u64, unique_bytes))
}

/// Insert the central-mode CIT entry for a newly stored raw chunk
/// (central keeps every entry Valid inline — the metadata owner is the
/// transaction coordinator, so there is no tagged-commit window).
fn insert_central_entry(sh: &OsdShared, fp: &Fingerprint, len: u32, refs: u64) -> Result<()> {
    sh.charge_meta_io(); // modeled DM-Shard write
    sh.shard.cit_put(
        fp,
        &CitEntry {
            refcount: refs,
            flag: CommitFlag::Valid,
            len,
            flagged_at_ms: sh.now_ms(),
        },
    )?;
    Metrics::add(&sh.metrics.unique_chunks, 1);
    Ok(())
}

/// The chunk-home transaction ("OSS 4"): CIT lookup → refcount grant /
/// unique store, under the configured consistency mode. Returns
/// `dedup_hit`.
pub fn store_chunk_local(
    sh: &OsdShared,
    fp: &Fingerprint,
    data: Cow<'_, [u8]>,
    refs: u64,
) -> Result<bool> {
    Metrics::add(&sh.metrics.cit_lookups, 1);
    let now = sh.now_ms();
    let mode = sh.cfg.consistency;

    // SyncChunk holds the shard transaction lock across the whole chunk
    // transaction (the comparator's cost); other modes take no lock.
    let _tx_guard = if mode == ConsistencyMode::SyncChunk {
        Some(sh.shard.tx_lock.lock().unwrap())
    } else {
        None
    };

    // Atomic CIT upsert (the same fingerprint can arrive concurrently on
    // the frontend and backend lanes): existing entries get the refcount
    // grant; absent ones are inserted with the mode's initial flag.
    let initial_flag = match mode {
        // inline-valid modes (object-granularity flags live on the
        // frontend; None is the no-consistency baseline)
        ConsistencyMode::None | ConsistencyMode::SyncObject => CommitFlag::Valid,
        _ => CommitFlag::Invalid,
    };
    let mut prior: Option<CommitFlag> = None;
    let mut prior_refs = 0u64;
    sh.charge_meta_io(); // modeled DM-Shard write
    sh.shard.cit_update(fp, |cur| match cur {
        Some(mut e) => {
            prior = Some(e.flag);
            prior_refs = e.refcount;
            e.refcount += refs;
            Some(e)
        }
        None => Some(CitEntry {
            refcount: refs,
            flag: initial_flag,
            len: data.len() as u32,
            flagged_at_ms: now,
        }),
    })?;

    if let Some(prior_flag) = prior {
        // duplicate write.
        if prior_flag == CommitFlag::Invalid {
            // the paper's consistency check: stat the chunk; repair a
            // missing one from the payload in hand, then validate.
            if !sh.store.stat(&fp.to_bytes())? {
                Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
                replicate_chunk(sh, fp, &data)?;
                sh.store.put_owned(&fp.to_bytes(), data.into_owned())?;
            }
            sh.charge_meta_io(); // modeled DM-Shard write
            sh.shard.cit_set_flag(fp, CommitFlag::Valid, now)?;
            Metrics::add(&sh.metrics.repairs, 1);
        }
        Metrics::add(&sh.metrics.dedup_hits, refs);
        maybe_retarget(sh, fp, prior_refs, prior_refs + refs);
        return Ok(true);
    }

    // unique chunk: store the data; flag handling per consistency mode.
    if sh.injector.maybe_crash(CrashPoint::AfterCitInsert) {
        return Err(died());
    }
    sh.store.put(&fp.to_bytes(), &data)?;
    if sh.injector.maybe_crash(CrashPoint::AfterDataStore) {
        return Err(died());
    }
    match mode {
        ConsistencyMode::None | ConsistencyMode::SyncObject => {}
        ConsistencyMode::AsyncTagged => {
            // register with the consistency manager; the flag flips off
            // the critical path. No lock, no extra synchronous I/O.
            sh.pending.push(*fp);
        }
        ConsistencyMode::SyncChunk => {
            // the second synchronous flag I/O, under the tx lock.
            sh.charge_meta_io(); // modeled DM-Shard write
            sh.shard.cit_set_flag(fp, CommitFlag::Valid, now)?;
        }
    }
    Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
    Metrics::add(&sh.metrics.unique_chunks, 1);

    if sh.injector.maybe_crash(CrashPoint::BeforeReplicate) {
        return Err(died());
    }
    replicate_chunk(sh, fp, &data)?;
    Ok(false)
}

/// Payload-less refcount grant: a Phase-B batch item whose Phase-A
/// probe said the chunk was already Valid at this home. Bumps the
/// refcount iff a Valid CIT entry still exists; returns `false` — the
/// `NeedData` NACK — when the hint went stale (entry reclaimed or
/// invalidated between the phases). Nothing is changed on a NACK; the
/// caller re-ships the payload through [`store_chunk_local`], whose
/// atomic upsert + Invalid-flag repair remains the single source of
/// truth for stores that carry data.
pub fn grant_ref_local(sh: &OsdShared, fp: &Fingerprint, refs: u64) -> Result<bool> {
    Metrics::add(&sh.metrics.cit_lookups, 1);
    let _tx_guard = if sh.cfg.consistency == ConsistencyMode::SyncChunk {
        Some(sh.shard.tx_lock.lock().unwrap())
    } else {
        None
    };
    let mut granted = false;
    let mut prior_refs = 0u64;
    sh.shard.cit_update(fp, |cur| match cur {
        Some(mut e) if e.flag == CommitFlag::Valid => {
            granted = true;
            prior_refs = e.refcount;
            e.refcount += refs;
            Some(e)
        }
        // decline the write: no entry, or invalid without a payload to
        // repair from — the caller must re-send the data
        _ => None,
    })?;
    if granted {
        sh.charge_meta_io(); // modeled DM-Shard write
        Metrics::add(&sh.metrics.dedup_hits, refs);
        maybe_retarget(sh, fp, prior_refs, prior_refs + refs);
    }
    Ok(granted)
}

/// Refcount decrement (delete path / write rollback). Refcount-zero
/// entries are left for the GC pass to reclaim.
pub fn dec_ref_local(sh: &OsdShared, fp: &Fingerprint, refs: u64) -> Result<()> {
    let mut crossed: Option<(u64, u64)> = None;
    sh.shard.cit_update(fp, |cur| {
        cur.map(|mut e| {
            let old = e.refcount;
            e.refcount = e.refcount.saturating_sub(refs);
            crossed = Some((old, e.refcount));
            e
        })
    })?;
    if let Some((old, new)) = crossed {
        maybe_retarget(sh, fp, old, new);
    }
    Ok(())
}

/// Rebalance receiver: adopt a chunk + CIT entry that now belongs here.
pub fn absorb_migrated_chunk(
    sh: &OsdShared,
    fp: &Fingerprint,
    data: &[u8],
    refcount: u64,
    valid: bool,
) -> Result<()> {
    let now = sh.now_ms();
    // coherence: this server's view of the chunk is about to change
    invalidate_chunk(sh, fp);
    sh.shard.cit_update(fp, |cur| match cur {
        Some(mut e) => {
            e.refcount += refcount;
            Some(e)
        }
        None => Some(CitEntry {
            refcount,
            flag: if valid {
                CommitFlag::Valid
            } else {
                CommitFlag::Invalid
            },
            len: data.len() as u32,
            flagged_at_ms: now,
        }),
    })?;
    if !sh.store.stat(&fp.to_bytes())? {
        sh.store.put(&fp.to_bytes(), data)?;
        Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
    }
    replicate_chunk(sh, fp, data)?;
    Ok(())
}

// --------------------------------------------------------------------
// read path
// --------------------------------------------------------------------

/// Whole-object read (frontend). `Ok(None)` when unknown.
pub fn get_object(sh: &OsdShared, name: &str) -> Result<Option<Vec<u8>>> {
    match sh.cfg.dedup {
        DedupMode::None => {
            if let Some(d) = sh.store.get(&raw_object_key(name))? {
                // raw-mode reads count toward mean read amplification
                // too: one read answered by one home (this server).
                Metrics::add(&sh.metrics.read_amp_reads, 1);
                Metrics::add(&sh.metrics.read_amp_homes, 1);
                return Ok(Some(d));
            }
            // degraded read from a replica copy of the raw object
            let d = sh.replica_store.get(&raw_object_key(name))?;
            if d.is_some() {
                Metrics::add(&sh.metrics.read_amp_reads, 1);
                Metrics::add(&sh.metrics.read_amp_homes, 1);
            }
            Ok(d)
        }
        _ => {
            // OMAP lookup: local shard, else a replica copy we hold.
            let entry = match sh.shard.omap_get(name)? {
                Some(e) => Some(e),
                None => sh
                    .replica_store
                    .get(&omap_copy_key(name))?
                    .map(|v| OmapEntry::decode(&v))
                    .transpose()?,
            };
            let Some(entry) = entry else {
                return Ok(None);
            };
            let mut out = Vec::with_capacity(entry.size as usize);
            // read amplification: distinct servers whose data answered
            // this one object read (dedup scatters chunks by content, so
            // one read fans out across the cluster — this is the cost
            // side of the savings the paper measures).
            let mut homes: HashSet<ServerId> = HashSet::new();
            // DiskLocal never leaves this server, so there is nothing
            // to batch; the other modes gather per home by default.
            let batched = if sh.cfg.read_batching == ReadBatching::PerHome
                && sh.cfg.dedup != DedupMode::DiskLocal
            {
                Some(fetch_chunks_batched(sh, &entry.chunks, &mut homes)?)
            } else {
                None
            };
            for (fp, len) in &entry.chunks {
                let data = match &batched {
                    Some(m) => m
                        .get(fp)
                        .cloned()
                        .ok_or_else(|| Error::ChunkMissing(fp.to_hex()))?,
                    None => fetch_chunk(sh, fp, &mut homes)?,
                };
                if data.len() != *len as usize {
                    return Err(Error::Corrupt(format!(
                        "chunk {fp} length {} != {}",
                        data.len(),
                        len
                    )));
                }
                if sh.cfg.verify_read && !crate::dedup::fpipe::chunk_matches(sh, fp, &data) {
                    return Err(Error::Corrupt(format!("chunk {fp} digest mismatch")));
                }
                out.extend_from_slice(&data);
            }
            Metrics::add(&sh.metrics.read_amp_reads, 1);
            Metrics::add(&sh.metrics.read_amp_homes, homes.len() as u64);
            Ok(Some(out))
        }
    }
}

/// Fetch one chunk: local, then its content home, then replica copies
/// (degraded read path — "robust fault tolerance"). The server that
/// answered is added to `homes` (read-amplification accounting).
fn fetch_chunk(
    sh: &OsdShared,
    fp: &Fingerprint,
    homes: &mut HashSet<ServerId>,
) -> Result<Vec<u8>> {
    // hot-chunk cache first: content-addressed, so a hit can never be
    // wrong bytes (DESIGN.md §14).
    if let Some(d) = sh.chunk_cache.get(fp) {
        Metrics::add(&sh.metrics.read_cache_hits, 1);
        homes.insert(sh.id);
        return Ok(d);
    }
    Metrics::add(&sh.metrics.read_cache_misses, 1);
    let key = fp.to_bytes().to_vec();
    // central mode keeps data placement identical (raw by fp), so this
    // path is shared by all dedup modes.
    let chain = sh.chunk_chain(fp.placement_key());
    if chain.first() == Some(&sh.id) || sh.cfg.dedup == DedupMode::DiskLocal {
        if let Some(d) = sh.store.get(&key)? {
            homes.insert(sh.id);
            cache_insert(sh, fp, &d);
            return Ok(d);
        }
    }
    if sh.cfg.dedup == DedupMode::DiskLocal {
        return Err(Error::ChunkMissing(fp.to_hex()));
    }
    // primary over the fabric
    if chain.first() != Some(&sh.id) {
        if let Some(primary) = chain.first() {
            if let Ok(addr) = sh.dir.lookup(*primary, Lane::Backend) {
                let mut retried = false;
                loop {
                    let req = Req::FetchChunk { fp: *fp };
                    let size = req.wire_size();
                    Metrics::add(&sh.metrics.read_chunk_fetches, 1);
                    match addr.call(req, size) {
                        Ok(Resp::Data(d)) => {
                            homes.insert(*primary);
                            cache_insert(sh, fp, &d);
                            maybe_plant_dup(sh, fp, &d);
                            return Ok(d);
                        }
                        // a Busy home is alive: grant it one short
                        // backoff before burdening the replicas
                        Ok(Resp::Busy) if !retried => {
                            retried = true;
                            Metrics::add(&sh.metrics.backpressure_retries, 1);
                            std::thread::sleep(Duration::from_micros(READ_RETRY_BACKOFF_US));
                        }
                        Ok(Resp::Busy) => {
                            Metrics::add(&sh.metrics.read_degraded_busy, 1);
                            break; // fall through to replicas
                        }
                        Ok(_) | Err(_) => {
                            Metrics::add(&sh.metrics.read_degraded_dead, 1);
                            break; // fall through to replicas
                        }
                    }
                }
            }
        }
    }
    // replica copies
    for peer in chain.iter().skip(1) {
        let fetch = if *peer == sh.id {
            sh.replica_store.get(&chunk_copy_key(fp))?
        } else if let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) {
            let req = Req::FetchCopy {
                key: chunk_copy_key(fp),
            };
            let size = req.wire_size();
            match addr.call(req, size) {
                Ok(Resp::Data(d)) => Some(d),
                _ => None,
            }
        } else {
            None
        };
        if let Some(d) = fetch {
            homes.insert(*peer);
            return Ok(d);
        }
    }
    Err(Error::ChunkMissing(fp.to_hex()))
}

/// Batched read gather ([`ReadBatching::PerHome`], DESIGN.md §14): map
/// every *unique* fingerprint of one object to its payload, touching
/// each distinct live chunk home with at most one `FetchChunkBatch`.
///
/// Resolution order per chunk: hot-chunk cache → local primary store →
/// a digest-verified replica slot this server holds (chain membership
/// or a planted locality copy) → the per-home batch. Batch misses, Busy
/// homes (after one retry) and dead homes degrade per item through the
/// legacy [`fetch_chunk`] path, so fault tolerance is exactly the
/// unbatched path's.
fn fetch_chunks_batched(
    sh: &OsdShared,
    chunks: &[(Fingerprint, u32)],
    homes: &mut HashSet<ServerId>,
) -> Result<HashMap<Fingerprint, Vec<u8>>> {
    let mut out: HashMap<Fingerprint, Vec<u8>> = HashMap::new();
    let mut fallback: Vec<Fingerprint> = Vec::new();
    let mut by_home: BTreeMap<ServerId, Vec<Fingerprint>> = BTreeMap::new();
    let mut seen: HashSet<Fingerprint> = HashSet::new();
    for (fp, _len) in chunks {
        if !seen.insert(*fp) {
            continue; // intra-object duplicate: fetch once
        }
        if let Some(d) = sh.chunk_cache.get(fp) {
            Metrics::add(&sh.metrics.read_cache_hits, 1);
            homes.insert(sh.id);
            out.insert(*fp, d);
            continue;
        }
        Metrics::add(&sh.metrics.read_cache_misses, 1);
        let chain = sh.chunk_chain(fp.placement_key());
        if chain.first() == Some(&sh.id) {
            if let Some(d) = sh.store.get(&fp.to_bytes())? {
                homes.insert(sh.id);
                cache_insert(sh, fp, &d);
                out.insert(*fp, d);
            } else {
                // we are the home but the data is gone: degraded path
                fallback.push(*fp);
            }
            continue;
        }
        // a replica slot we hold (chain member or planted locality
        // copy) saves the fabric hop — but only digest-verified, so a
        // rotten copy falls through to the home exactly as the legacy
        // path would prefer the primary's bytes.
        if chain.contains(&sh.id) || sh.chunk_cache.planted_contains(fp) {
            if let Some(d) = sh.replica_store.get(&chunk_copy_key(fp))? {
                if crate::dedup::fpipe::chunk_matches(sh, fp, &d) {
                    homes.insert(sh.id);
                    cache_insert(sh, fp, &d);
                    out.insert(*fp, d);
                    continue;
                }
            }
        }
        match chain.first() {
            Some(home) => by_home.entry(*home).or_default().push(*fp),
            None => fallback.push(*fp),
        }
    }
    // one batch per distinct home: send all, then gather (the same
    // scatter shape as the write path's probe phase).
    let mut pendings: Vec<(ServerId, Vec<Fingerprint>, Option<Pending<Resp>>)> = Vec::new();
    for (home, fps) in by_home {
        let req = Req::FetchChunkBatch { fps: fps.clone() };
        Metrics::add(&sh.metrics.read_batches, 1);
        Metrics::add(&sh.metrics.read_batch_items, fps.len() as u64);
        let pending = backend_send(sh, home, req).ok();
        pendings.push((home, fps, pending));
    }
    for (home, fps, pending) in pendings {
        let mut resp = match pending {
            Some(p) => p.wait(),
            None => Err(Error::ServerDown(home.0)),
        };
        if matches!(resp, Ok(Resp::Busy)) {
            // honor Busy with one retried batch before degrading
            Metrics::add(&sh.metrics.backpressure_retries, 1);
            std::thread::sleep(Duration::from_micros(READ_RETRY_BACKOFF_US));
            let req = Req::FetchChunkBatch { fps: fps.clone() };
            Metrics::add(&sh.metrics.read_batches, 1);
            Metrics::add(&sh.metrics.read_batch_items, fps.len() as u64);
            resp = backend_call(sh, home, req);
        }
        match resp {
            Ok(Resp::ChunkBatch { items }) if items.len() == fps.len() => {
                for (fp, item) in fps.iter().zip(items) {
                    match item {
                        Some(d) => {
                            homes.insert(home);
                            cache_insert(sh, fp, &d);
                            maybe_plant_dup(sh, fp, &d);
                            out.insert(*fp, d);
                        }
                        None => fallback.push(*fp),
                    }
                }
            }
            Ok(Resp::Busy) => {
                Metrics::add(&sh.metrics.read_degraded_busy, fps.len() as u64);
                fallback.extend(fps);
            }
            Ok(_) | Err(_) => {
                Metrics::add(&sh.metrics.read_degraded_dead, fps.len() as u64);
                fallback.extend(fps);
            }
        }
    }
    // per-item degraded fallback through the legacy path (replica
    // copies, etc.) — a single lost chunk home never fails the read as
    // long as any copy survives.
    for fp in fallback {
        if out.contains_key(&fp) {
            continue;
        }
        Metrics::add(&sh.metrics.read_fallbacks, 1);
        let d = fetch_chunk(sh, &fp, homes)?;
        out.insert(fp, d);
    }
    Ok(out)
}

/// Admit freshly fetched chunk bytes to the hot-chunk cache, classed
/// hot (protected segment) when the local backref index says the chunk
/// is shared at or above the configured hot band.
fn cache_insert(sh: &OsdShared, fp: &Fingerprint, data: &[u8]) {
    if sh.cfg.cache.capacity_bytes == 0 {
        return;
    }
    let hot = sh
        .shard
        .backref_refs(fp)
        .map(|n| n >= sh.cfg.cache.hot_band)
        .unwrap_or(false);
    let evicted = sh.chunk_cache.insert(*fp, data, hot);
    Metrics::add(&sh.metrics.read_cache_insertions, 1);
    Metrics::add(&sh.metrics.read_cache_evictions, evicted);
}

/// Fragmentation-aware selective duplication (DESIGN.md §14): when a
/// remotely homed chunk keeps getting fetched over the fabric while
/// reads are fanning out wide, plant a locality copy in this server's
/// replica store — an ordinary replica-slot copy (`c:<fp>`), so
/// audit/GC/recovery reasoning is unchanged — charged to the rebalance
/// class of the maintenance flow budget (non-blocking: a dry budget
/// skips the plant rather than stalling the read).
fn maybe_plant_dup(sh: &OsdShared, fp: &Fingerprint, data: &[u8]) {
    let Some(policy) = sh.cfg.selective_dup else {
        return;
    };
    if sh.cfg.dedup != DedupMode::ClusterWide {
        return;
    }
    let n = sh.chunk_cache.note_remote_fetch(fp);
    if n < policy.fetch_threshold || sh.chunk_cache.planted_contains(fp) {
        return;
    }
    // only worth a copy when reads actually fragment
    let reads = sh.metrics.read_amp_reads.load(Ordering::Relaxed);
    let amp_homes = sh.metrics.read_amp_homes.load(Ordering::Relaxed);
    if reads == 0 || amp_homes * 100 < policy.min_mean_amp_x100 * reads {
        return;
    }
    // chain members already hold a copy in their replica slot
    if sh.chunk_chain(fp.placement_key()).contains(&sh.id) {
        return;
    }
    let Some(granted) = sh.flow.try_take(MaintClass::Rebalance, data.len() as u64) else {
        return;
    };
    Metrics::add(&sh.metrics.flow_granted_rebalance, granted);
    if sh.replica_store.put(&chunk_copy_key(fp), data).is_err() {
        return;
    }
    Metrics::add(&sh.metrics.dup_chunks_planted, 1);
    Metrics::add(&sh.metrics.bytes_replica, data.len() as u64);
    for victim in sh.chunk_cache.plant_register(fp, data.len() as u64, policy.max_bytes) {
        let _ = sh.replica_store.delete(&chunk_copy_key(&victim));
        Metrics::add(&sh.metrics.dup_chunks_evicted, 1);
    }
}

// --------------------------------------------------------------------
// delete path
// --------------------------------------------------------------------

/// Whole-object delete (frontend); decrements chunk references. Returns
/// false when the object was unknown.
pub fn delete_object(sh: &OsdShared, name: &str) -> Result<bool> {
    match sh.cfg.dedup {
        DedupMode::None => {
            let existed = sh.store.delete(&raw_object_key(name))?;
            for peer in sh.object_chain(name).iter().skip(1) {
                if let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) {
                    let _ = addr.call(
                        Req::DeleteCopy {
                            key: raw_object_key(name),
                        },
                        64,
                    );
                }
            }
            Ok(existed)
        }
        _ => {
            let Some(entry) = sh.shard.omap_get(name)? else {
                return Ok(false);
            };
            let local_only =
                sh.cfg.dedup == DedupMode::DiskLocal || sh.cfg.dedup == DedupMode::Central;
            // drop the layout and its backreference records first, then
            // decrement chunk refcounts: a crash in between leaves
            // refcounts too HIGH (repaired down by the scrub light pass),
            // never a zero refcount with live-looking backrefs — which
            // would fight GC's index cross-match.
            if let Some(delta) = sh.shard.omap_delete(name)? {
                Metrics::add(&sh.metrics.backref_updates, delta.removed);
            }
            release_refs(sh, &entry, local_only);
            for peer in sh.object_chain(name).iter().skip(1) {
                if let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) {
                    let _ = addr.call(
                        Req::DeleteCopy {
                            key: omap_copy_key(name),
                        },
                        64,
                    );
                }
            }
            Ok(true)
        }
    }
}

// --------------------------------------------------------------------
// helpers
// --------------------------------------------------------------------

/// Cache-coherence hook (DESIGN.md §14): drop one chunk from this
/// server's hot-chunk cache after an event that retired or rewrote its
/// local data — GC reclaim, scrub quarantine/repair, recovery
/// re-homing, rebalance migration, or an incoming `DeleteCopy`. Keeps
/// the invariant that a cached chunk never outlives its CIT entry on
/// the same server, and — the same one-choke-point argument — that a
/// planted locality copy never outlives the chunk it duplicates: a
/// registered plant is deregistered and its replica-slot entry deleted
/// here, so a reclaim can't leave an orphan behind.
pub fn invalidate_chunk(sh: &OsdShared, fp: &Fingerprint) {
    if sh.chunk_cache.invalidate(fp) {
        Metrics::add(&sh.metrics.read_cache_invalidations, 1);
    }
    if sh.chunk_cache.plant_deregister(fp).is_some() {
        let _ = sh.replica_store.delete(&chunk_copy_key(fp));
        Metrics::add(&sh.metrics.dup_plants_reclaimed, 1);
    }
}

/// Inverse of [`chunk_copy_key`]: the fingerprint inside a replica-slot
/// chunk-copy key (`None` for OMAP / raw-object / flag keys).
pub fn chunk_copy_fp(key: &[u8]) -> Option<Fingerprint> {
    key.strip_prefix(b"c:").and_then(Fingerprint::from_bytes)
}

/// Key for a whole raw object (no-dedup mode).
pub fn raw_object_key(name: &str) -> Vec<u8> {
    let mut k = b"obj:".to_vec();
    k.extend_from_slice(name.as_bytes());
    k
}

/// Key for a replica copy of a chunk.
pub fn chunk_copy_key(fp: &Fingerprint) -> Vec<u8> {
    let mut k = b"c:".to_vec();
    k.extend_from_slice(&fp.to_bytes());
    k
}

/// Key for a replica copy of an OMAP record.
pub fn omap_copy_key(name: &str) -> Vec<u8> {
    let mut k = b"o:".to_vec();
    k.extend_from_slice(name.as_bytes());
    k
}

/// Key for the SyncObject commit-flag record.
pub fn object_flag_key(name: &str) -> Vec<u8> {
    let mut k = b"of:".to_vec();
    k.extend_from_slice(name.as_bytes());
    k
}

/// Whole-object fingerprint: Merkle digest over the chunk fingerprints.
pub fn object_fingerprint(digests: &[Fingerprint]) -> Fingerprint {
    let mut buf = Vec::with_capacity(digests.len() * 20);
    for d in digests {
        buf.extend_from_slice(&d.to_bytes());
    }
    Fingerprint::of(&buf)
}

/// Replicate a chunk's data to its banded share of the placement chain:
/// the copy target comes from the redundancy policy applied to the
/// chunk's *current* refcount, so the write-time fan-out, scrub,
/// recovery and rebalance all agree on the same count (DESIGN.md §15).
/// With [`crate::storage::osd::OsdConfig::verify_write`] on, each
/// replica is then asked to confirm its copy by content.
pub(crate) fn replicate_chunk(sh: &OsdShared, fp: &Fingerprint, data: &[u8]) -> Result<()> {
    let refcount = sh
        .shard
        .cit_get(fp)
        .ok()
        .flatten()
        .map(|e| e.refcount)
        .unwrap_or(1);
    let target = sh.redundancy_target(refcount);
    Metrics::add(&sh.metrics.redundancy_target_copies, target as u64);
    let chain = sh.chunk_chain(fp.placement_key());
    let failures = replicate(sh, &chain, &chunk_copy_key(fp), data, target)?;
    if failures > 0 {
        // a dead/Busy replica slot left this chunk under target: record
        // the debt so the next scrub window heals it first
        Metrics::add(&sh.metrics.replica_push_failures, failures as u64);
        sh.note_repair_debt(*fp);
    }
    if sh.cfg.verify_write {
        verify_replicas(sh, &chain, fp, target);
    }
    Ok(())
}

/// Write-time replica confirmation: ask each of the `copies - 1`
/// replica slots to hash its copy of `fp` and compare (`VerifyCopy` —
/// only the verdict crosses the wire). Non-fatal by design: a missing
/// or mismatched copy is counted in `write_verify_mismatches` and left
/// for scrub/recovery to heal, never failing a write that already met
/// its durability bar. A `Busy` shed or a dead peer is counted in
/// `replica_push_failures` and recorded as repair debt, so the next
/// scrub window re-probes it first.
fn verify_replicas(sh: &OsdShared, chain: &[ServerId], fp: &Fingerprint, copies: usize) {
    if copies <= 1 {
        return;
    }
    for peer in chain.iter().skip(1).take(copies - 1) {
        if *peer == sh.id {
            continue;
        }
        let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) else {
            Metrics::add(&sh.metrics.replica_push_failures, 1);
            sh.note_repair_debt(*fp);
            continue;
        };
        let req = Req::VerifyCopy {
            key: chunk_copy_key(fp),
            fp: *fp,
        };
        let size = req.wire_size();
        Metrics::add(&sh.metrics.write_verifies, 1);
        match addr.call(req, size) {
            Ok(Resp::CopyState {
                present: true,
                matches: true,
            }) => {}
            Ok(Resp::Busy) | Err(_) => {
                // shed or dead peer: counted, and queued for the next
                // scrub window instead of waiting for the full walk
                Metrics::add(&sh.metrics.replica_push_failures, 1);
                sh.note_repair_debt(*fp);
            }
            Ok(_) => {
                Metrics::add(&sh.metrics.write_verify_mismatches, 1);
                sh.note_repair_debt(*fp);
            }
        }
    }
}

/// Replicate `key → data` to the first `copies - 1` chain members after
/// the primary, skipping ourselves. Replication failures are non-fatal
/// (degraded durability, like Ceph acking with min_size) but no longer
/// silent: the returned count says how many pushes failed (dead peer,
/// send error, or a non-`Ok` reply) so callers can account the gap.
pub(crate) fn replicate(
    sh: &OsdShared,
    chain: &[crate::cluster::ServerId],
    key: &[u8],
    data: &[u8],
    copies: usize,
) -> Result<usize> {
    if copies <= 1 {
        return Ok(0);
    }
    let mut failures = 0usize;
    let mut pendings = Vec::new();
    for peer in chain.iter().skip(1).take(copies - 1) {
        if *peer == sh.id {
            continue;
        }
        let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) else {
            failures += 1;
            continue;
        };
        let req = Req::PutCopy {
            key: key.to_vec(),
            data: data.to_vec(),
        };
        let size = req.wire_size();
        match addr.send(req, size) {
            Ok(p) => pendings.push(p),
            Err(_) => failures += 1,
        }
    }
    for p in pendings {
        match p.wait() {
            Ok(Resp::Ok) => {}
            _ => failures += 1,
        }
    }
    Ok(failures)
}

/// Online promote/demote (DESIGN.md §15): when a refcount change moved
/// a chunk across a redundancy band threshold, add or drop copies on
/// the chunk's home so its redundancy tracks its blast radius.
/// Flow-budgeted (rebalance class, non-blocking) and best-effort: a dry
/// budget, dead peer or `Busy` shed leaves convergence to the scrub. A
/// demotion computes its slots from the *new* refcount's target, so it
/// can never drop a copy the current band still requires.
fn maybe_retarget(sh: &OsdShared, fp: &Fingerprint, old_refs: u64, new_refs: u64) {
    if sh.cfg.redundancy.is_flat() || sh.cfg.dedup == DedupMode::Central {
        return;
    }
    let old_t = sh.redundancy_target(old_refs);
    let new_t = sh.redundancy_target(new_refs);
    if new_t > old_t {
        promote_copies(sh, fp, old_t, new_t);
    } else if new_t < old_t {
        demote_copies(sh, fp, new_t, old_t);
    }
}

/// Copy-add half of the online retarget: push the primary's payload to
/// the chain slots the higher band newly demands.
fn promote_copies(sh: &OsdShared, fp: &Fingerprint, old_t: usize, new_t: usize) {
    let Ok(Some(data)) = sh.store.get(&fp.to_bytes()) else {
        return; // no local primary (mid-migration): scrub converges it
    };
    let cost = data.len() as u64 * (new_t - old_t) as u64;
    let Some(granted) = sh.flow.try_take(MaintClass::Rebalance, cost) else {
        return; // dry budget: the next scrub pass converges stragglers
    };
    Metrics::add(&sh.metrics.flow_granted_rebalance, granted);
    let chain = sh.chunk_chain(fp.placement_key());
    for peer in chain.iter().skip(old_t).take(new_t - old_t) {
        if *peer == sh.id {
            continue;
        }
        let reply = sh.dir.lookup(*peer, Lane::Replica).ok().and_then(|addr| {
            let req = Req::PutCopy {
                key: chunk_copy_key(fp),
                data: data.clone(),
            };
            let size = req.wire_size();
            addr.call(req, size).ok()
        });
        match reply {
            Some(Resp::Ok) => Metrics::add(&sh.metrics.redundancy_promotions, 1),
            _ => {
                Metrics::add(&sh.metrics.replica_push_failures, 1);
                sh.note_repair_debt(*fp);
            }
        }
    }
}

/// Copy-drop half of the online retarget: ask the chain slots beyond
/// the new target to drop their redundancy copies. The holder consults
/// its plant registry ([`Req::DemoteCopy`]) — a locality plant under
/// the same key was never a redundancy copy and survives the demotion.
fn demote_copies(sh: &OsdShared, fp: &Fingerprint, new_t: usize, old_t: usize) {
    let Some(granted) = sh
        .flow
        .try_take(MaintClass::Rebalance, 64 * (old_t - new_t) as u64)
    else {
        return; // dry budget: scrub drops the excess later
    };
    Metrics::add(&sh.metrics.flow_granted_rebalance, granted);
    let chain = sh.chunk_chain(fp.placement_key());
    for peer in chain.iter().skip(new_t).take(old_t - new_t) {
        if *peer == sh.id {
            continue;
        }
        if let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) {
            let req = Req::DemoteCopy { fp: *fp };
            let size = req.wire_size();
            if let Ok(Resp::Ok) = addr.call(req, size) {
                Metrics::add(&sh.metrics.redundancy_demotions, 1);
            }
        }
    }
}

/// Release every chunk reference held by an OMAP entry (delete path and
/// overwrite replacement): collapse multiplicity, then decrement at each
/// chunk home — one `DecRefBatch` per remote home.
fn release_refs(sh: &OsdShared, entry: &OmapEntry, local_only: bool) {
    let mut counts: HashMap<Fingerprint, u64> = HashMap::new();
    for (fp, _) in &entry.chunks {
        *counts.entry(*fp).or_insert(0) += 1;
    }
    dec_refs_grouped(sh, counts.into_iter(), local_only);
}

/// Write-abort rollback: undo reference increments already granted —
/// one `DecRefBatch` per remote home.
fn rollback(sh: &OsdShared, stored: &[StoredRef], local_only: bool) {
    let refs = stored.iter().map(|(fp, refs, _)| (*fp, *refs));
    dec_refs_grouped(sh, refs, local_only);
}

/// Group refcount decrements by chunk home: local ones applied
/// directly, one `DecRefBatch` call per remote home. Dead homes are
/// skipped (scrub reconciles later).
fn dec_refs_grouped(
    sh: &OsdShared,
    refs: impl Iterator<Item = (Fingerprint, u64)>,
    local_only: bool,
) {
    let mut groups: BTreeMap<ServerId, Vec<(Fingerprint, u64)>> = BTreeMap::new();
    for (fp, n) in refs {
        let target = if local_only {
            sh.id
        } else {
            sh.chunk_chain(fp.placement_key())[0]
        };
        if target == sh.id {
            let _ = dec_ref_local(sh, &fp, n);
        } else {
            groups.entry(target).or_default().push((fp, n));
        }
    }
    for (target, items) in groups {
        let _ = backend_call(sh, target, Req::DecRefBatch { items });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_fingerprint_depends_on_order() {
        let a = Fingerprint::of(b"a");
        let b = Fingerprint::of(b"b");
        assert_ne!(object_fingerprint(&[a, b]), object_fingerprint(&[b, a]));
        assert_eq!(object_fingerprint(&[a, b]), object_fingerprint(&[a, b]));
    }

    #[test]
    fn key_namespaces_disjoint() {
        let fp = Fingerprint::of(b"x");
        let keys = [
            raw_object_key("n"),
            chunk_copy_key(&fp),
            omap_copy_key("n"),
            object_flag_key("n"),
        ];
        for (i, a) in keys.iter().enumerate() {
            for (j, b) in keys.iter().enumerate() {
                if i != j {
                    assert_ne!(a, b);
                }
            }
        }
    }

    #[test]
    fn mode_names() {
        assert_eq!(DedupMode::ClusterWide.name(), "cluster-wide");
        assert_eq!(DedupMode::None.name(), "no-dedup");
    }
}
