//! Asynchronous tagged consistency (paper §2.4) and its synchronous
//! comparators.
//!
//! Every CIT entry starts with an **invalid** commit flag. In the paper's
//! design ([`ConsistencyMode::AsyncTagged`]) completed chunk writes are
//! registered with a per-server consistency manager; a background thread
//! verifies the chunk is on stable storage and flips the flag to valid —
//! no transaction lock is ever taken, so the write path pays (almost)
//! nothing. The comparators of Fig. 5(b) are:
//!
//! * [`ConsistencyMode::SyncChunk`] — per-chunk flag switch as a second
//!   synchronous metadata I/O under the shard transaction lock;
//! * [`ConsistencyMode::SyncObject`] — one object-granularity flag I/O,
//!   with the object transaction lock held for the whole object write;
//! * [`ConsistencyMode::None`] — flags written valid inline (the
//!   "baseline cluster-wide deduplication" bar of Fig. 5(b)); a crash can
//!   leave a valid flag pointing at missing data, which is exactly the
//!   inconsistency the tagged design exists to prevent.

use crate::dedup::fingerprint::Fingerprint;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Consistency policy for commit flags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsistencyMode {
    /// No flag protocol (Fig. 5(b) baseline; not crash-consistent).
    None,
    /// The paper's asynchronous tagged consistency.
    AsyncTagged,
    /// Synchronous per-chunk flag switch (+ transaction lock).
    SyncChunk,
    /// Synchronous per-object flag switch (+ object transaction lock).
    SyncObject,
}

impl ConsistencyMode {
    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            ConsistencyMode::None => "none",
            ConsistencyMode::AsyncTagged => "async-tagged",
            ConsistencyMode::SyncChunk => "sync-chunk",
            ConsistencyMode::SyncObject => "sync-object",
        }
    }
}

/// The queue between write I/Os and the consistency-manager thread
/// ("all the incoming write I/Os register to consistency manager").
#[derive(Default)]
pub struct PendingFlags {
    q: Mutex<VecDeque<Fingerprint>>,
    cv: Condvar,
}

impl PendingFlags {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a completed chunk write.
    pub fn push(&self, fp: Fingerprint) {
        self.q.lock().unwrap().push_back(fp);
        self.cv.notify_one();
    }

    /// Pop one registration, waiting up to `timeout`.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<Fingerprint> {
        let mut q = self.q.lock().unwrap();
        if let Some(fp) = q.pop_front() {
            return Some(fp);
        }
        let (mut q, _) = self.cv.wait_timeout(q, timeout).unwrap();
        q.pop_front()
    }

    /// Drain everything queued right now (flush / tests).
    pub fn drain(&self) -> Vec<Fingerprint> {
        self.q.lock().unwrap().drain(..).collect()
    }

    /// Discard all registrations (crash: in-memory state is lost).
    pub fn clear(&self) {
        self.q.lock().unwrap().clear();
    }

    /// Queue depth.
    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop() {
        let p = PendingFlags::new();
        let fp = Fingerprint::of(b"x");
        p.push(fp);
        assert_eq!(p.len(), 1);
        assert_eq!(p.pop_timeout(Duration::from_millis(1)), Some(fp));
        assert!(p.is_empty());
    }

    #[test]
    fn pop_times_out_empty() {
        let p = PendingFlags::new();
        assert_eq!(p.pop_timeout(Duration::from_millis(5)), None);
    }

    #[test]
    fn wakes_blocked_popper() {
        let p = Arc::new(PendingFlags::new());
        let p2 = p.clone();
        let t = std::thread::spawn(move || p2.pop_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        p.push(Fingerprint::of(b"wake"));
        assert_eq!(t.join().unwrap(), Some(Fingerprint::of(b"wake")));
    }

    #[test]
    fn clear_models_crash() {
        let p = PendingFlags::new();
        p.push(Fingerprint::of(b"a"));
        p.push(Fingerprint::of(b"b"));
        p.clear();
        assert!(p.is_empty());
    }

    #[test]
    fn mode_names() {
        assert_eq!(ConsistencyMode::AsyncTagged.name(), "async-tagged");
        assert_eq!(ConsistencyMode::None.name(), "none");
    }
}
