//! Object Map (OMAP) records — the layout/reconstruction half of the
//! DM-Shard (paper §2.2): object name → object fingerprint + ordered
//! chunk fingerprint list (with per-chunk lengths so short tail chunks
//! reassemble exactly).

use crate::dedup::fingerprint::Fingerprint;
use crate::error::{Error, Result};
use crate::util::codec::{Reader, Writer};

/// One OMAP entry: everything needed to reconstruct an object.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OmapEntry {
    /// Object name (the DHT key the client hashed to find this server).
    pub name: String,
    /// Whole-object fingerprint ("if we do not maintain the hash of
    /// object, we cannot reconstruct the original object", §2.2).
    pub object_fp: Fingerprint,
    /// Ordered chunk list: (fingerprint, length).
    pub chunks: Vec<(Fingerprint, u32)>,
    /// Total logical size (= sum of chunk lengths; denormalized).
    pub size: u64,
}

impl OmapEntry {
    /// Build an entry, computing `size` from the chunk list.
    pub fn new(name: String, object_fp: Fingerprint, chunks: Vec<(Fingerprint, u32)>) -> Self {
        let size = chunks.iter().map(|(_, l)| *l as u64).sum();
        OmapEntry {
            name,
            object_fp,
            chunks,
            size,
        }
    }

    /// Encode to the KV value format.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_str(&self.name);
        w.put_bytes(&self.object_fp.to_bytes());
        w.put_u64(self.size);
        w.put_u32(self.chunks.len() as u32);
        for (fp, len) in &self.chunks {
            w.put_bytes(&fp.to_bytes());
            w.put_u32(*len);
        }
        w.into_bytes()
    }

    /// Decode from the KV value format.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let name = r.get_str()?;
        let object_fp = Fingerprint::from_bytes(&r.get_bytes()?)
            .ok_or_else(|| Error::Corrupt("bad object fp".into()))?;
        let size = r.get_u64()?;
        let n = r.get_u32()? as usize;
        let mut chunks = Vec::with_capacity(n);
        for _ in 0..n {
            let fp = Fingerprint::from_bytes(&r.get_bytes()?)
                .ok_or_else(|| Error::Corrupt("bad chunk fp".into()))?;
            let len = r.get_u32()?;
            chunks.push((fp, len));
        }
        Ok(OmapEntry {
            name,
            object_fp,
            chunks,
            size,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> OmapEntry {
        OmapEntry::new(
            "vm-image-7".into(),
            Fingerprint::of(b"whole object"),
            vec![
                (Fingerprint::of(b"c0"), 4096),
                (Fingerprint::of(b"c1"), 4096),
                (Fingerprint::of(b"tail"), 100),
            ],
        )
    }

    #[test]
    fn roundtrip() {
        let e = sample();
        assert_eq!(OmapEntry::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn size_is_sum_of_chunks() {
        assert_eq!(sample().size, 4096 + 4096 + 100);
    }

    #[test]
    fn empty_object() {
        let e = OmapEntry::new("empty".into(), Fingerprint::of(b""), vec![]);
        let d = OmapEntry::decode(&e.encode()).unwrap();
        assert_eq!(d.size, 0);
        assert!(d.chunks.is_empty());
    }

    #[test]
    fn corrupt_fp_detected() {
        let e = sample();
        let mut b = e.encode();
        // shrink the embedded object-fp length prefix to 19 → decode fails
        let name_len = 4 + e.name.len();
        b[name_len] = 19;
        assert!(OmapEntry::decode(&b).is_err());
    }
}
