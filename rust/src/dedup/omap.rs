//! Object Map (OMAP) records — the layout/reconstruction half of the
//! DM-Shard (paper §2.2): object name → object fingerprint + ordered
//! chunk fingerprint list (with per-chunk lengths so short tail chunks
//! reassemble exactly) — plus the [`BackrefEntry`] codec of the
//! **backreference index**, the inverted mapping `chunk fingerprint →
//! referring objects` that lets `CountRefs`, GC cross-matching and scrub
//! reconciliation answer from an indexed range read instead of a full
//! OMAP scan (DESIGN.md §6).

use crate::dedup::fingerprint::Fingerprint;
use crate::error::{Error, Result};
use crate::util::codec::{Reader, Writer};

/// One OMAP entry: everything needed to reconstruct an object.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OmapEntry {
    /// Object name (the DHT key the client hashed to find this server).
    pub name: String,
    /// Whole-object fingerprint ("if we do not maintain the hash of
    /// object, we cannot reconstruct the original object", §2.2).
    pub object_fp: Fingerprint,
    /// Ordered chunk list: (fingerprint, length).
    pub chunks: Vec<(Fingerprint, u32)>,
    /// Total logical size (= sum of chunk lengths; denormalized).
    pub size: u64,
}

impl OmapEntry {
    /// Build an entry, computing `size` from the chunk list.
    pub fn new(name: String, object_fp: Fingerprint, chunks: Vec<(Fingerprint, u32)>) -> Self {
        let size = chunks.iter().map(|(_, l)| *l as u64).sum();
        OmapEntry {
            name,
            object_fp,
            chunks,
            size,
        }
    }

    /// Encode to the KV value format.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_str(&self.name);
        w.put_bytes(&self.object_fp.to_bytes());
        w.put_u64(self.size);
        w.put_u32(self.chunks.len() as u32);
        for (fp, len) in &self.chunks {
            w.put_bytes(&fp.to_bytes());
            w.put_u32(*len);
        }
        w.into_bytes()
    }

    /// Decode from the KV value format.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let name = r.get_str()?;
        let object_fp = Fingerprint::from_bytes(&r.get_bytes()?)
            .ok_or_else(|| Error::Corrupt("bad object fp".into()))?;
        let size = r.get_u64()?;
        let n = r.get_u32()? as usize;
        let mut chunks = Vec::with_capacity(n);
        for _ in 0..n {
            let fp = Fingerprint::from_bytes(&r.get_bytes()?)
                .ok_or_else(|| Error::Corrupt("bad chunk fp".into()))?;
            let len = r.get_u32()?;
            chunks.push((fp, len));
        }
        Ok(OmapEntry {
            name,
            object_fp,
            chunks,
            size,
        })
    }
}

/// One backreference-index entry: the set of positions (`ordinals`) at
/// which one object references one chunk fingerprint.
///
/// **Keyspace layout.** The index key is the 20-byte fingerprint digest
/// followed by the raw object-name bytes, so all referrers of a
/// fingerprint are contiguous under the fixed-width prefix
/// [`BackrefEntry::prefix`] and a single [`crate::kvstore::KvStore::scan_prefix`]
/// range read enumerates them in O(log n + referrers). The fingerprint is
/// fixed-width, so key parsing is unambiguous without a separator.
///
/// The value carries the chunk length (denormalized from the OMAP entry —
/// the scrub ensure-phase needs it to seed a CIT entry without touching
/// the OMAP) and the ordinal list; the entry's reference multiplicity is
/// `ordinals.len()` (one object can reference the same chunk at several
/// positions).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BackrefEntry {
    /// The referenced chunk fingerprint.
    pub fp: Fingerprint,
    /// Name of the referring object (an OMAP key on the same server).
    pub object: String,
    /// Chunk length in bytes (denormalized from the OMAP chunk list).
    pub len: u32,
    /// Positions in the object's chunk list that reference `fp`
    /// (ascending; never empty for a stored entry).
    pub ordinals: Vec<u32>,
}

impl BackrefEntry {
    /// Index key of this entry (`fp bytes ‖ object-name bytes`).
    pub fn key(&self) -> Vec<u8> {
        Self::key_for(&self.fp, &self.object)
    }

    /// Index key for a (fingerprint, object) pair.
    pub fn key_for(fp: &Fingerprint, object: &str) -> Vec<u8> {
        let mut k = Vec::with_capacity(20 + object.len());
        k.extend_from_slice(&fp.to_bytes());
        k.extend_from_slice(object.as_bytes());
        k
    }

    /// Fixed-width range-scan prefix covering every referrer of `fp`.
    pub fn prefix(fp: &Fingerprint) -> [u8; 20] {
        fp.to_bytes()
    }

    /// Parse an index key back into its (fingerprint, object) pair.
    pub fn decode_key(key: &[u8]) -> Result<(Fingerprint, String)> {
        if key.len() < 20 {
            return Err(Error::Corrupt("backref key too short".into()));
        }
        let fp = Fingerprint::from_bytes(&key[..20])
            .ok_or_else(|| Error::Corrupt("bad backref fp".into()))?;
        let object = String::from_utf8(key[20..].to_vec())
            .map_err(|_| Error::Corrupt("backref object name not utf-8".into()))?;
        Ok((fp, object))
    }

    /// Reference multiplicity carried by this entry.
    pub fn refs(&self) -> u64 {
        self.ordinals.len() as u64
    }

    /// Encode the value half (`len`, ordinal count, ordinals).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u32(self.len);
        w.put_u32(self.ordinals.len() as u32);
        for o in &self.ordinals {
            w.put_u32(*o);
        }
        w.into_bytes()
    }

    /// Decode a full entry from an index `(key, value)` pair.
    pub fn decode(key: &[u8], value: &[u8]) -> Result<Self> {
        let (fp, object) = Self::decode_key(key)?;
        let (len, ordinals) = Self::decode_value(value)?;
        Ok(BackrefEntry {
            fp,
            object,
            len,
            ordinals,
        })
    }

    /// Decode only the value half: `(chunk len, ordinals)`. Cheap path for
    /// `CountRefs`, which does not need the object name parsed.
    pub fn decode_value(value: &[u8]) -> Result<(u32, Vec<u32>)> {
        let mut r = Reader::new(value);
        let len = r.get_u32()?;
        let n = r.get_u32()? as usize;
        let mut ordinals = Vec::with_capacity(n);
        for _ in 0..n {
            ordinals.push(r.get_u32()?);
        }
        Ok((len, ordinals))
    }

    /// Decode only the reference multiplicity (the ordinal count) without
    /// materializing the ordinal list — the `CountRefs` hot path.
    pub fn decode_refs(value: &[u8]) -> Result<u64> {
        let mut r = Reader::new(value);
        let _len = r.get_u32()?;
        Ok(r.get_u32()? as u64)
    }
}

/// Explode an OMAP entry into its backreference-index entries: one
/// [`BackrefEntry`] per distinct chunk fingerprint, ordinals ascending.
pub fn backrefs_of(entry: &OmapEntry) -> Vec<BackrefEntry> {
    let mut by_fp: std::collections::HashMap<Fingerprint, BackrefEntry> =
        std::collections::HashMap::new();
    for (ordinal, (fp, len)) in entry.chunks.iter().enumerate() {
        by_fp
            .entry(*fp)
            .or_insert_with(|| BackrefEntry {
                fp: *fp,
                object: entry.name.clone(),
                len: *len,
                ordinals: Vec::new(),
            })
            .ordinals
            .push(ordinal as u32);
    }
    let mut out: Vec<BackrefEntry> = by_fp.into_values().collect();
    out.sort_by_key(|b| b.fp);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> OmapEntry {
        OmapEntry::new(
            "vm-image-7".into(),
            Fingerprint::of(b"whole object"),
            vec![
                (Fingerprint::of(b"c0"), 4096),
                (Fingerprint::of(b"c1"), 4096),
                (Fingerprint::of(b"tail"), 100),
            ],
        )
    }

    #[test]
    fn roundtrip() {
        let e = sample();
        assert_eq!(OmapEntry::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn size_is_sum_of_chunks() {
        assert_eq!(sample().size, 4096 + 4096 + 100);
    }

    #[test]
    fn empty_object() {
        let e = OmapEntry::new("empty".into(), Fingerprint::of(b""), vec![]);
        let d = OmapEntry::decode(&e.encode()).unwrap();
        assert_eq!(d.size, 0);
        assert!(d.chunks.is_empty());
    }

    #[test]
    fn corrupt_fp_detected() {
        let e = sample();
        let mut b = e.encode();
        // shrink the embedded object-fp length prefix to 19 → decode fails
        let name_len = 4 + e.name.len();
        b[name_len] = 19;
        assert!(OmapEntry::decode(&b).is_err());
    }

    #[test]
    fn backref_codec_roundtrip() {
        let e = BackrefEntry {
            fp: Fingerprint::of(b"chunk"),
            object: "vm-image-7".into(),
            len: 4096,
            ordinals: vec![0, 3, 17],
        };
        let d = BackrefEntry::decode(&e.key(), &e.encode()).unwrap();
        assert_eq!(d, e);
        assert_eq!(d.refs(), 3);
        assert_eq!(BackrefEntry::decode_refs(&e.encode()).unwrap(), 3);
        assert_eq!(
            BackrefEntry::decode_value(&e.encode()).unwrap(),
            (4096, vec![0, 3, 17])
        );
        // the key is prefix ‖ name, parseable without a separator
        assert!(e.key().starts_with(&BackrefEntry::prefix(&e.fp)));
        assert_eq!(
            BackrefEntry::decode_key(&e.key()).unwrap(),
            (e.fp, "vm-image-7".to_string())
        );
    }

    #[test]
    fn backref_codec_rejects_corrupt() {
        assert!(BackrefEntry::decode_key(b"short").is_err());
        let e = BackrefEntry {
            fp: Fingerprint::of(b"c"),
            object: "o".into(),
            len: 8,
            ordinals: vec![1],
        };
        let mut v = e.encode();
        v.truncate(6); // truncated ordinal list
        assert!(BackrefEntry::decode_value(&v).is_err());
    }

    #[test]
    fn backrefs_of_collapses_multiplicity() {
        let dup = Fingerprint::of(b"dup");
        let uniq = Fingerprint::of(b"uniq");
        let e = OmapEntry::new(
            "obj".into(),
            Fingerprint::of(b"obj"),
            vec![(dup, 100), (uniq, 200), (dup, 100)],
        );
        let brs = backrefs_of(&e);
        assert_eq!(brs.len(), 2, "one entry per distinct fingerprint");
        let d = brs.iter().find(|b| b.fp == dup).unwrap();
        assert_eq!(d.ordinals, vec![0, 2]);
        assert_eq!(d.refs(), 2);
        assert_eq!(d.len, 100);
        let u = brs.iter().find(|b| b.fp == uniq).unwrap();
        assert_eq!(u.ordinals, vec![1]);
        assert!(brs.iter().all(|b| b.object == "obj"));
    }

    #[test]
    fn backref_keys_disjoint_per_object() {
        let fp = Fingerprint::of(b"c");
        assert_ne!(
            BackrefEntry::key_for(&fp, "a"),
            BackrefEntry::key_for(&fp, "b")
        );
        assert_ne!(
            BackrefEntry::key_for(&Fingerprint::of(b"c1"), "a"),
            BackrefEntry::key_for(&Fingerprint::of(b"c2"), "a")
        );
    }
}
