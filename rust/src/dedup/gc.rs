//! Garbage collection and flag confirmation (paper §2.4).
//!
//! Three related passes over a server's CIT:
//!
//! * [`confirm_flag`] — the consistency-manager step: verify a registered
//!   chunk is on stable storage, then flip its flag to valid.
//! * [`run`] — the periodic GC: fingerprints whose flag has been invalid
//!   for longer than the threshold are *cross-matched* (re-checked); if
//!   still invalid they are reclaimed — CIT entry, chunk data and replica
//!   copies. Referenced-but-invalid entries are repaired instead of
//!   reclaimed (re-fingerprint the present data → flip, or restore from
//!   a digest-verified surviving copy — "recover reference errors and
//!   lost data chunks"). Valid entries whose
//!   refcount dropped to zero (deleted objects) age out the same way.
//!   Before any reclaim, the candidate is cross-matched against the local
//!   **backreference index** (an O(referrers) range read, DESIGN.md §6):
//!   a refcount that leaked to zero while OMAP references survive is
//!   repaired, never reclaimed.
//! * [`recovery_scan`] — after a restart: the in-memory registration
//!   queue died with the server, so every invalid CIT entry is re-examined
//!   (present → re-register for confirmation; missing → left for GC).

use crate::dedup::cit::CommitFlag;
use crate::dedup::engine::{chunk_copy_key, DedupMode};
use crate::dedup::fingerprint::Fingerprint;
use crate::error::Result;
use crate::metrics::Metrics;
use crate::net::Lane;
use crate::sched::flow::MaintClass;
use crate::storage::osd::OsdShared;
use crate::storage::proto::Req;

/// Outcome of a GC pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    /// CIT entries + chunks reclaimed.
    pub reclaimed: usize,
    /// Invalid entries repaired (data present or restored from replica).
    pub repaired: usize,
    /// Entries skipped (not yet past the threshold).
    pub young: usize,
    /// Referenced entries whose data could not be found anywhere.
    pub lost: usize,
}

/// Consistency-manager confirmation: chunk present → flag valid. Only an
/// `Invalid` flag is flipped — a `Pending` entry (tier 1 of the
/// fingerprint pipeline, DESIGN.md §16) is awaiting its strong digest and
/// must never be confirmed into the dedup domain on presence alone.
pub fn confirm_flag(sh: &OsdShared, fp: &Fingerprint) -> Result<()> {
    let present = sh.store.stat(&fp.to_bytes())?;
    if present {
        let cur = sh.shard.cit_get(fp)?;
        if cur.map(|e| e.flag) == Some(CommitFlag::Invalid) {
            sh.charge_meta_io(); // modeled DM-Shard write
            sh.shard.cit_set_flag(fp, CommitFlag::Valid, sh.now_ms())?;
        }
    }
    Ok(())
}

/// One GC pass; `threshold_ms` is the paper's "pre-defined threshold"
/// between collection and cross-match.
pub fn run(sh: &OsdShared, threshold_ms: u64) -> Result<GcReport> {
    let now = sh.now_ms();
    let mut report = GcReport::default();
    for fp in sh.shard.cit_fingerprints()? {
        let Some(e) = sh.shard.cit_get(&fp)? else {
            continue;
        };
        let aged = now.saturating_sub(e.flagged_at_ms) >= threshold_ms;
        match (e.flag, e.refcount) {
            (CommitFlag::Valid, 0) if aged => {
                // deleted-object remnant — unless the backref index says
                // live references leaked the count to zero.
                if let Some(live) = indexed_live_refs(sh, &fp)? {
                    sh.charge_meta_io(); // modeled DM-Shard write
                    sh.shard.cit_update(&fp, |cur| {
                        cur.map(|mut e| {
                            e.refcount = e.refcount.max(live);
                            e
                        })
                    })?;
                    Metrics::add(&sh.metrics.repairs, 1);
                    report.repaired += 1;
                } else {
                    reclaim(sh, &fp)?;
                    report.reclaimed += 1;
                }
            }
            (CommitFlag::Valid, _) => {}
            (CommitFlag::Invalid, _) if !aged => report.young += 1,
            (CommitFlag::Invalid, 0) => {
                // cross-match: nothing re-validated it → garbage of a
                // failed transaction — again index-checked first.
                if let Some(live) = indexed_live_refs(sh, &fp)? {
                    sh.charge_meta_io(); // modeled DM-Shard write
                    sh.shard.cit_update(&fp, |cur| {
                        cur.map(|mut e| {
                            e.refcount = e.refcount.max(live);
                            e
                        })
                    })?;
                    if repair(sh, &fp)? {
                        report.repaired += 1;
                    } else {
                        report.lost += 1;
                    }
                } else {
                    reclaim(sh, &fp)?;
                    report.reclaimed += 1;
                }
            }
            (CommitFlag::Invalid, _) => {
                // referenced but invalid: repair rather than reclaim.
                if repair(sh, &fp)? {
                    report.repaired += 1;
                } else {
                    report.lost += 1;
                }
            }
            (CommitFlag::Pending, _) if !aged => report.young += 1,
            (CommitFlag::Pending, 0) => {
                // a migrated (or rolled-back) pending identity: the
                // strong-fingerprint chunk took over its references.
                // Index-checked like every reclaim — leaked live refs
                // put it back on the migration queue instead.
                if let Some(live) = indexed_live_refs(sh, &fp)? {
                    sh.charge_meta_io(); // modeled DM-Shard write
                    sh.shard.cit_update(&fp, |cur| {
                        cur.map(|mut e| {
                            e.refcount = e.refcount.max(live);
                            e
                        })
                    })?;
                    sh.fpipe.enqueue(fp);
                    Metrics::add(&sh.metrics.repairs, 1);
                    report.repaired += 1;
                } else {
                    reclaim(sh, &fp)?;
                    report.reclaimed += 1;
                }
            }
            (CommitFlag::Pending, _) => {
                // referenced by count — cross-match the index. Live
                // references make it strictly the migrator's business:
                // GC must never "repair" a pending identity with a
                // strong hash (that is exactly the inline work tier 1
                // deferred), so it only re-queues. No surviving OMAP
                // reference means the count is stale (a crash between
                // the migrator's OMAP rewrite and its reclaim) and the
                // identity is garbage.
                if indexed_live_refs(sh, &fp)?.is_some() {
                    sh.fpipe.enqueue(fp);
                } else {
                    reclaim(sh, &fp)?;
                    report.reclaimed += 1;
                }
            }
        }
    }
    Metrics::add(&sh.metrics.gc_reclaimed, report.reclaimed as u64);
    Ok(report)
}

/// Post-restart scan: re-register every invalid entry whose data is
/// actually present (the registration queue is volatile and died with the
/// server); leaves truly-missing chunks for GC / repair.
pub fn recovery_scan(sh: &OsdShared) -> Result<usize> {
    let mut re_registered = 0usize;
    for fp in sh.shard.cit_fingerprints()? {
        let Some(e) = sh.shard.cit_get(&fp)? else {
            continue;
        };
        if e.flag == CommitFlag::Invalid && sh.store.stat(&fp.to_bytes())? {
            sh.pending.push(fp);
            re_registered += 1;
        } else if e.flag == CommitFlag::Pending && sh.store.stat(&fp.to_bytes())? {
            // the tier-2 migration queue is volatile too: a restart
            // re-queues every present pending chunk (DESIGN.md §16)
            sh.fpipe.enqueue(fp);
            re_registered += 1;
        }
    }
    Ok(re_registered)
}

/// GC cross-match against the local backreference index: `Some(n)` when
/// this server's own OMAP still holds `n > 0` references to `fp` — a
/// reclaim would lose live data, so the caller repairs instead. In
/// cluster-wide mode the local index only sees local objects, so `n` is a
/// *lower bound* on the cluster-wide count (sufficient to veto a reclaim;
/// the scrub light pass settles the exact count). `None` means the local
/// index holds no references — in the local-metadata modes (disk-local,
/// central) that verdict is authoritative; in cluster-wide mode remote
/// references are still possible, but those keep the refcount above zero
/// via the normal DecRef protocol, so a zero count plus an empty local
/// index is the same evidence the paper's cross-match acts on.
fn indexed_live_refs(sh: &OsdShared, fp: &Fingerprint) -> Result<Option<u64>> {
    if sh.cfg.dedup == DedupMode::None {
        return Ok(None);
    }
    let n = sh.shard.backref_refs(fp)?;
    Metrics::add(&sh.metrics.backref_lookups, 1);
    Ok(if n > 0 { Some(n) } else { None })
}

pub(crate) fn reclaim(sh: &OsdShared, fp: &Fingerprint) -> Result<()> {
    // coherence: the CIT entry dies, so the cached payload must too
    crate::dedup::engine::invalidate_chunk(sh, fp);
    sh.shard.cit_delete(fp)?;
    if let Ok(Some(data)) = sh.store.get(&fp.to_bytes()) {
        // reclaim I/O draws from the shared maintenance budget
        sh.charge_maint(MaintClass::Gc, (data.len() as u64).max(64));
        sh.store.delete(&fp.to_bytes())?;
        let stored = &sh.metrics.bytes_stored;
        // saturating decrement of the space accounting
        let mut cur = Metrics::get(stored);
        loop {
            let next = cur.saturating_sub(data.len() as u64);
            match stored.compare_exchange_weak(
                cur,
                next,
                std::sync::atomic::Ordering::Relaxed,
                std::sync::atomic::Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(v) => cur = v,
            }
        }
    }
    // Drop replica copies. Broadcast to every Up server, not just the
    // chain: selective duplication may have planted locality copies on
    // off-chain readers, and a reclaim that skipped them would strand
    // orphans under the same `c:` key (the holder's `DeleteCopy` path
    // routes through `invalidate_chunk`, which also deregisters the
    // plant). A Down server misses the broadcast; its stale copy is
    // bounded by the plant budget and swept by its next scrub pass.
    let peers: Vec<_> = {
        let map = sh.map.read().unwrap();
        map.up_servers().map(|s| s.id).collect()
    };
    for peer in peers {
        if peer == sh.id {
            continue;
        }
        if let Ok(addr) = sh.dir.lookup(peer, Lane::Replica) {
            let _ = addr.call(
                Req::DeleteCopy {
                    key: chunk_copy_key(fp),
                },
                64,
            );
        }
    }
    Ok(())
}

/// Repair a referenced-but-invalid entry **by content**: present data
/// is re-fingerprinted before the flag flips — a presence-only stat
/// would resurrect a chunk deep scrub quarantined as rotten (flag
/// Invalid, data present but corrupt). Missing or corrupt data is
/// restored from a digest-verified surviving copy
/// ([`crate::recovery::fetch_any_copy`]: own replica slot, then the
/// chain's healthy copies, then the off-chain sweep), then flipped.
/// Returns false when no healthy copy exists anywhere.
fn repair(sh: &OsdShared, fp: &Fingerprint) -> Result<bool> {
    // a pending identity (DESIGN.md §16) is repaired back to Pending,
    // never Valid: its strong digest is unresolved and only the tier-2
    // migrator may admit it to the dedup domain
    let healthy_flag = if crate::dedup::fpipe::is_pending(fp) {
        sh.fpipe.enqueue(*fp);
        CommitFlag::Pending
    } else {
        CommitFlag::Valid
    };
    if let Some(data) = sh.store.get(&fp.to_bytes())? {
        if crate::dedup::fpipe::chunk_matches(sh, fp, &data) {
            sh.charge_meta_io(); // modeled DM-Shard write
            sh.shard.cit_set_flag(fp, healthy_flag, sh.now_ms())?;
            Metrics::add(&sh.metrics.repairs, 1);
            return Ok(true);
        }
        // present but rotten: fall through to the verified restore —
        // never flip a quarantined chunk back to Valid on presence alone
    }
    let Some(data) = crate::recovery::fetch_any_copy(sh, fp)? else {
        return Ok(false);
    };
    // coherence: the local bytes are about to be rewritten
    crate::dedup::engine::invalidate_chunk(sh, fp);
    sh.charge_maint(MaintClass::Gc, (data.len() as u64).max(64));
    let had_data = sh.store.stat(&fp.to_bytes())?;
    sh.store.put(&fp.to_bytes(), &data)?;
    if !had_data {
        Metrics::add(&sh.metrics.bytes_stored, data.len() as u64);
    }
    sh.charge_meta_io(); // modeled DM-Shard write
    sh.shard.cit_set_flag(fp, healthy_flag, sh.now_ms())?;
    Metrics::add(&sh.metrics.repairs, 1);
    Ok(true)
}
