//! Object → chunk splitting.
//!
//! The paper uses fixed-size chunks ("splitting the object into small
//! fixed-size data chunks", §2.1); [`Chunking::Cdc`] adds gear-hash
//! content-defined chunking as the natural extension (it shares the gear
//! table with the Pallas CDC kernel, so both find identical boundaries).

use crate::hash::gear::Gear;

/// Chunking policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Chunking {
    /// Fixed-size chunks of `size` bytes (last chunk may be short).
    Fixed { size: usize },
    /// Gear-CDC: cut where `gear & mask == 0`, clamped to [min, max].
    Cdc { min: usize, mask: u32, max: usize },
}

impl Chunking {
    /// A sane CDC config for a target mean chunk size (power of two).
    pub fn cdc_with_mean(mean: usize) -> Self {
        assert!(mean.is_power_of_two() && mean >= 256);
        Chunking::Cdc {
            min: mean / 4,
            mask: (mean - 1) as u32,
            max: mean * 4,
        }
    }
}

/// Splits byte slices into chunk ranges according to a [`Chunking`].
#[derive(Clone, Copy, Debug)]
pub struct Chunker {
    policy: Chunking,
}

impl Chunker {
    /// New chunker with the given policy.
    pub fn new(policy: Chunking) -> Self {
        match policy {
            Chunking::Fixed { size } => assert!(size > 0, "chunk size must be > 0"),
            Chunking::Cdc { min, max, .. } => {
                assert!(min > 0 && max >= min, "bad CDC bounds")
            }
        }
        Chunker { policy }
    }

    /// The policy in effect.
    pub fn policy(&self) -> Chunking {
        self.policy
    }

    /// Split `data` into contiguous chunk ranges covering it exactly.
    pub fn split<'a>(&self, data: &'a [u8]) -> Vec<&'a [u8]> {
        if data.is_empty() {
            return Vec::new();
        }
        match self.policy {
            Chunking::Fixed { size } => data.chunks(size).collect(),
            Chunking::Cdc { min, mask, max } => {
                let mut out = Vec::new();
                let mut start = 0usize;
                let mut g = Gear::new();
                let mut len = 0usize;
                for (i, &b) in data.iter().enumerate() {
                    let h = g.roll(b);
                    len += 1;
                    let cut = len >= max || (len >= min && (h & mask) == 0);
                    if cut {
                        out.push(&data[start..=i]);
                        start = i + 1;
                        g = Gear::new();
                        len = 0;
                    }
                }
                if start < data.len() {
                    out.push(&data[start..]);
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::XorShift128Plus;

    fn payload(seed: u64, n: usize) -> Vec<u8> {
        let mut rng = XorShift128Plus::new(seed);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    #[test]
    fn fixed_exact_multiple() {
        let c = Chunker::new(Chunking::Fixed { size: 4 });
        let chunks = c.split(b"abcdefgh");
        assert_eq!(chunks, vec![b"abcd".as_slice(), b"efgh".as_slice()]);
    }

    #[test]
    fn fixed_short_tail() {
        let c = Chunker::new(Chunking::Fixed { size: 4 });
        let chunks = c.split(b"abcdefg");
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[1], b"efg");
    }

    #[test]
    fn empty_input_no_chunks() {
        for policy in [
            Chunking::Fixed { size: 8 },
            Chunking::cdc_with_mean(1024),
        ] {
            assert!(Chunker::new(policy).split(b"").is_empty());
        }
    }

    #[test]
    fn cdc_respects_bounds_and_reconstructs() {
        let data = payload(1, 200_000);
        let c = Chunker::new(Chunking::cdc_with_mean(4096));
        let chunks = c.split(&data);
        let mut rebuilt = Vec::new();
        for (i, ch) in chunks.iter().enumerate() {
            if i + 1 < chunks.len() {
                assert!(ch.len() >= 1024 && ch.len() <= 16384, "chunk {i}: {}", ch.len());
            }
            rebuilt.extend_from_slice(ch);
        }
        assert_eq!(rebuilt, data);
        // mean in the right ballpark
        let mean = data.len() / chunks.len();
        assert!(mean > 1500 && mean < 10000, "mean {mean}");
    }

    #[test]
    fn cdc_boundary_shift_is_local() {
        // CDC's raison d'être: inserting bytes near the front only changes
        // nearby chunk boundaries; later chunks re-align.
        let a = payload(2, 100_000);
        let mut b = a.clone();
        b.splice(100..100, [1u8, 2, 3].iter().copied());
        let c = Chunker::new(Chunking::cdc_with_mean(2048));
        let ca: Vec<Vec<u8>> = c.split(&a).into_iter().map(<[u8]>::to_vec).collect();
        let cb: Vec<Vec<u8>> = c.split(&b).into_iter().map(<[u8]>::to_vec).collect();
        // count identical chunks via set intersection on content
        let set: std::collections::HashSet<&Vec<u8>> = ca.iter().collect();
        let shared = cb.iter().filter(|c| set.contains(c)).count();
        assert!(
            shared * 10 >= cb.len() * 8,
            "only {shared}/{} chunks survived a 3-byte insert",
            cb.len()
        );
    }

    #[test]
    fn property_reconstruction_any_policy() {
        prop::check(
            prop::Config { cases: 40, ..Default::default() },
            |rng, size| {
                let data = prop::bytes(rng, 1 + size as usize * 200);
                let policy = if rng.next_u64() % 2 == 0 {
                    Chunking::Fixed {
                        size: 1 + rng.below(1000) as usize,
                    }
                } else {
                    Chunking::Cdc {
                        min: 1 + rng.below(64) as usize,
                        mask: (1 << (3 + rng.below(6))) - 1,
                        max: 65 + rng.below(4000) as usize,
                    }
                };
                (data, policy)
            },
            |(data, policy)| {
                let chunks = Chunker::new(*policy).split(data);
                let rebuilt: Vec<u8> = chunks.concat();
                if rebuilt != *data {
                    return Err("reconstruction mismatch".into());
                }
                if let Chunking::Cdc { max, .. } = policy {
                    if chunks.iter().any(|c| c.len() > *max) {
                        return Err("max violated".into());
                    }
                }
                if data.is_empty() != chunks.is_empty() {
                    return Err("empty handling".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "chunk size")]
    fn zero_fixed_size_rejected() {
        Chunker::new(Chunking::Fixed { size: 0 });
    }
}
