//! The paper's contribution: cluster-wide deduplication.
//!
//! * [`chunker`] — fixed-size and gear-CDC object splitting (§2.1).
//! * [`fingerprint`] — SHA-1 content fingerprints and the provider
//!   abstraction over the scalar CPU path and the XLA-batched kernel.
//! * [`omap`] / [`cit`] / [`dmshard`] — the DM-Shard (§2.2): Object Map
//!   and Chunk Information Table as *separate* synchronized KV stores.
//! * [`engine`] — the write/read/delete transactions of Figure 3,
//!   executed by OSD frontends (and by the central server in the
//!   central-dedup baseline).
//! * [`consistency`] — asynchronous tagged consistency plus the sync
//!   chunk-/object-granularity comparators of Figure 5(b) (§2.4).
//! * [`gc`] — the garbage-collection pass over invalid commit flags.
//! * [`cache`] — the per-server hot-chunk cache and the
//!   fragmentation-aware selective-duplication tracker (§14).
//! * [`redundancy`] — the refcount-banded copy-count policy every
//!   plant/repair path consults (§15).
//! * [`fpipe`] — the tiered fingerprint pipeline: weak-hash prefilter
//!   inline, deferred batched strong hashing in the background, and
//!   verify-before-merge collision safety (§16).

pub mod cache;
pub mod chunker;
pub mod cit;
pub mod consistency;
pub mod dmshard;
pub mod engine;
pub mod fingerprint;
pub mod fpipe;
pub mod gc;
pub mod omap;
pub mod redundancy;

pub use chunker::{Chunker, Chunking};
pub use consistency::ConsistencyMode;
pub use fingerprint::{Fingerprint, FingerprintProvider, RustSha1Provider};
pub use fpipe::FpMode;
pub use redundancy::{RedundancyBand, RedundancyPolicy};
