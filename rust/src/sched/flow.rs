//! The shared maintenance budget: a multi-consumer, weighted token
//! bucket.
//!
//! Scrub windows, rebalance migration batches and GC reclaims all share
//! the same disks and fabric lanes with foreground I/O. The original
//! scrub-private [`crate::scrub::rate::TokenBucket`] capped *scrub*
//! bandwidth, but rebalance and GC drew from nowhere — three background
//! subsystems colliding blindly on the same replica lanes. The
//! [`FlowController`] generalizes the bucket into one **per-server
//! budget** split across weighted classes ([`MaintClass`]): every
//! maintenance byte (or byte-equivalent probe) is charged to its class,
//! each class refills at `budget × weight / Σweights` tokens per tick of
//! the injected [`Clock`], and an idle class's tokens roll over **capped
//! at its burst capacity** — so a returning class can catch up a little
//! but can never starve the others or the foreground.

use crate::util::clock::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Upper bound on one wall sleep between refill re-checks of a blocked
/// [`FlowController::take`]. The actual sleep is proportional to the
/// token deficit; this cap keeps reaction to a virtual-clock advance
/// bounded. A wall-time implementation detail, not a timing dependency:
/// token accounting is entirely clock-driven.
const MAX_WAIT_POLL: Duration = Duration::from_millis(50);

/// Background-maintenance consumer classes sharing one budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaintClass {
    /// Scrub window walks (probes + deep re-reads).
    Scrub,
    /// Rebalance migration batches (chunk/OMAP/raw moves).
    Rebalance,
    /// GC reclaims and repair restores.
    Gc,
    /// Recovery backfill after a server loss: re-replicated chunk and
    /// OMAP-record bytes plus their probes ([`crate::recovery`]).
    Recovery,
}

impl MaintClass {
    /// All classes, in weight-array order.
    pub const ALL: [MaintClass; 4] = [
        MaintClass::Scrub,
        MaintClass::Rebalance,
        MaintClass::Gc,
        MaintClass::Recovery,
    ];

    fn idx(self) -> usize {
        match self {
            MaintClass::Scrub => 0,
            MaintClass::Rebalance => 1,
            MaintClass::Gc => 2,
            MaintClass::Recovery => 3,
        }
    }
}

/// Configuration of one server's maintenance budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlowConfig {
    /// Tokens (bytes or byte-equivalents) refilled per clock tick (ms),
    /// shared across all classes. 0 = unlimited (every take is free).
    pub budget_per_tick: u64,
    /// Relative share per class, in [`MaintClass::ALL`] order
    /// (Scrub, Rebalance, Gc, Recovery). A zero weight gives that class
    /// the minimum trickle (it still refills at ≥ 1 token per burst
    /// window).
    pub weights: [u32; 4],
    /// Burst capacity in ticks: each class accumulates at most
    /// `burst_ticks` ticks' worth of its own refill while idle.
    pub burst_ticks: u64,
}

impl Default for FlowConfig {
    fn default() -> Self {
        FlowConfig {
            budget_per_tick: 0,
            weights: [1, 1, 1, 1],
            burst_ticks: 1000,
        }
    }
}

/// Outcome of one [`FlowController::take`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TakeOutcome {
    /// Tokens actually deducted (the requested cost, clamped to the
    /// class's burst capacity so one oversized item cannot stall the
    /// consumer forever — same clamp as the scrub token bucket).
    pub granted: u64,
    /// True when the caller had to wait for a refill.
    pub waited: bool,
}

struct FlowInner {
    /// Current tokens per class (fractional refill accumulates).
    tokens: [f64; 4],
    /// Clock reading of the last refill.
    last_ms: u64,
}

/// A per-server, multi-class maintenance token bucket driven by the
/// injected clock. All methods are `&self`; consumers on different
/// threads (scrub worker, control lane) share one instance.
pub struct FlowController {
    cfg: FlowConfig,
    clock: Arc<dyn Clock>,
    inner: Mutex<FlowInner>,
    granted: [AtomicU64; 4],
    waits: AtomicU64,
}

impl FlowController {
    /// A controller whose class buckets start full (one burst available
    /// at boot, like the scrub bucket).
    pub fn new(cfg: FlowConfig, clock: Arc<dyn Clock>) -> Self {
        let now = clock.now_ms();
        let tokens = std::array::from_fn(|i| Self::cap_for(&cfg, i));
        FlowController {
            cfg,
            clock,
            inner: Mutex::new(FlowInner {
                tokens,
                last_ms: now,
            }),
            granted: std::array::from_fn(|_| AtomicU64::new(0)),
            waits: AtomicU64::new(0),
        }
    }

    /// Is this controller a no-op (unlimited budget)?
    pub fn unlimited(&self) -> bool {
        self.cfg.budget_per_tick == 0
    }

    /// The configuration in effect.
    pub fn config(&self) -> &FlowConfig {
        &self.cfg
    }

    /// Refill rate for class index `i` in tokens per tick, floored at
    /// the minimum trickle (one token per burst window) so a zero-weight
    /// class is throttled hard but can never starve a blocked consumer
    /// forever.
    fn rate_for(cfg: &FlowConfig, i: usize) -> f64 {
        let sum: u64 = cfg.weights.iter().map(|w| *w as u64).sum();
        let share = if sum == 0 {
            0.0
        } else {
            cfg.budget_per_tick as f64 * cfg.weights[i] as f64 / sum as f64
        };
        share.max(1.0 / cfg.burst_ticks.max(1) as f64)
    }

    /// Burst capacity for class index `i` (at least one token so a
    /// zero-weight class still trickles instead of deadlocking).
    fn cap_for(cfg: &FlowConfig, i: usize) -> f64 {
        (Self::rate_for(cfg, i) * cfg.burst_ticks as f64).max(1.0)
    }

    fn refill(&self, g: &mut FlowInner) {
        let now = self.clock.now_ms();
        let elapsed = now.saturating_sub(g.last_ms) as f64;
        if elapsed <= 0.0 {
            return;
        }
        for (i, tokens) in g.tokens.iter_mut().enumerate() {
            let cap = Self::cap_for(&self.cfg, i);
            *tokens = (*tokens + elapsed * Self::rate_for(&self.cfg, i)).min(cap);
        }
        g.last_ms = now;
    }

    /// Non-blocking draw: `Some(granted)` when the class had tokens for
    /// the (capacity-clamped) cost, `None` when it must wait for refill.
    pub fn try_take(&self, class: MaintClass, cost: u64) -> Option<u64> {
        let i = class.idx();
        if self.unlimited() {
            self.granted[i].fetch_add(cost, Ordering::Relaxed);
            return Some(cost);
        }
        let mut g = self.inner.lock().unwrap();
        self.refill(&mut g);
        let clamped = (cost as f64).min(Self::cap_for(&self.cfg, i));
        if g.tokens[i] + 1e-9 < clamped {
            return None;
        }
        g.tokens[i] -= clamped;
        let granted = clamped.round() as u64;
        self.granted[i].fetch_add(granted, Ordering::Relaxed);
        Some(granted)
    }

    /// Blocking draw: waits until the class can cover the clamped cost.
    /// The wait is deficit-proportional (re-checking at least every
    /// [`MAX_WAIT_POLL`] so virtual-clock advances are noticed promptly).
    /// Note for virtual-clock tests: the refill only moves with
    /// [`Clock::now_ms`], so a finite budget requires the test to keep
    /// advancing the clock while maintenance runs — a frozen `SimClock`
    /// plus an exhausted class blocks the caller until the next advance.
    pub fn take(&self, class: MaintClass, cost: u64) -> TakeOutcome {
        if let Some(granted) = self.try_take(class, cost) {
            return TakeOutcome {
                granted,
                waited: false,
            };
        }
        self.waits.fetch_add(1, Ordering::Relaxed);
        loop {
            std::thread::sleep(self.wait_hint(class, cost));
            if let Some(granted) = self.try_take(class, cost) {
                return TakeOutcome {
                    granted,
                    waited: true,
                };
            }
        }
    }

    /// How long a blocked taker should sleep before re-checking: the
    /// time the deficit takes to refill at the class rate (ticks ≈ ms),
    /// clamped to `[1ms, MAX_WAIT_POLL]`.
    fn wait_hint(&self, class: MaintClass, cost: u64) -> Duration {
        let i = class.idx();
        let g = self.inner.lock().unwrap();
        let clamped = (cost as f64).min(Self::cap_for(&self.cfg, i));
        let deficit = (clamped - g.tokens[i]).max(0.0);
        let ms = (deficit / Self::rate_for(&self.cfg, i)).ceil() as u64;
        Duration::from_millis(ms.max(1)).min(MAX_WAIT_POLL)
    }

    /// Tokens granted to one class so far.
    pub fn granted(&self, class: MaintClass) -> u64 {
        self.granted[class.idx()].load(Ordering::Relaxed)
    }

    /// Tokens granted across all classes.
    pub fn granted_total(&self) -> u64 {
        MaintClass::ALL.iter().map(|c| self.granted(*c)).sum()
    }

    /// Times a [`take`](Self::take) had to wait for refill.
    pub fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    #[cfg(test)]
    fn drain(&self) {
        let mut g = self.inner.lock().unwrap();
        self.refill(&mut g);
        g.tokens = [0.0; 4];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SimClock;

    fn controller(cfg: FlowConfig) -> (FlowController, Arc<SimClock>) {
        let sim = Arc::new(SimClock::new());
        let clock: Arc<dyn Clock> = sim.clone();
        (FlowController::new(cfg, clock), sim)
    }

    /// Greedily draw 1-token units for `class` until the bucket is dry.
    fn drain_class(f: &FlowController, class: MaintClass) -> u64 {
        let mut n = 0;
        while f.try_take(class, 1).is_some() {
            n += 1;
        }
        n
    }

    #[test]
    fn unlimited_is_free() {
        let (f, _sim) = controller(FlowConfig::default());
        assert!(f.unlimited());
        for _ in 0..1000 {
            assert_eq!(f.try_take(MaintClass::Rebalance, 1 << 20), Some(1 << 20));
        }
        assert_eq!(f.granted(MaintClass::Rebalance), 1000 << 20);
        assert_eq!(f.waits(), 0);
    }

    #[test]
    fn weighted_fairness_between_scrub_and_rebalance() {
        // 100 tokens/tick split 3:1 between Scrub and Rebalance (Gc has
        // weight 0 and only gets the minimum trickle). Both classes draw
        // greedily every tick for 200 ticks: granted totals must land on
        // the 3:1 split of the whole budget.
        let (f, sim) = controller(FlowConfig {
            budget_per_tick: 100,
            weights: [3, 1, 0, 0],
            burst_ticks: 10,
        });
        f.drain();
        for _ in 0..200 {
            sim.advance(1);
            drain_class(&f, MaintClass::Scrub);
            drain_class(&f, MaintClass::Rebalance);
        }
        let scrub = f.granted(MaintClass::Scrub);
        let rebal = f.granted(MaintClass::Rebalance);
        // 200 ticks × 75/tick and × 25/tick, ±1 rounding per tick
        assert!(
            (14_800..=15_000).contains(&scrub),
            "scrub granted {scrub}, want ~15000"
        );
        assert!(
            (4_800..=5_000).contains(&rebal),
            "rebalance granted {rebal}, want ~5000"
        );
        // combined draw never exceeds the budget over the elapsed ticks
        assert!(scrub + rebal <= 200 * 100);
    }

    #[test]
    fn idle_class_rolls_over_capped_and_never_starves_the_active_one() {
        // Rebalance idles for 1000 ticks while Scrub drains every tick.
        // The idle class accumulates at most its burst capacity
        // (50 tokens/tick × 20 ticks = 1000); Scrub's own flow is
        // untouched by the idler.
        let (f, sim) = controller(FlowConfig {
            budget_per_tick: 100,
            weights: [1, 1, 0, 0],
            burst_ticks: 20,
        });
        f.drain();
        let mut scrub_granted = 0;
        for _ in 0..1000 {
            sim.advance(1);
            scrub_granted += drain_class(&f, MaintClass::Scrub);
        }
        // Scrub saw its full 50/tick share for all 1000 ticks.
        assert!(
            (49_800..=50_000).contains(&scrub_granted),
            "scrub granted {scrub_granted}, want ~50000"
        );
        // The idler's rollover is capped at one burst, not 1000 ticks'
        // worth of hoarded tokens.
        let burst = drain_class(&f, MaintClass::Rebalance);
        assert!(
            (900..=1_000).contains(&burst),
            "rebalance burst {burst}, want ≤ 1000 (burst cap)"
        );
        assert_eq!(f.try_take(MaintClass::Rebalance, 1), None);
    }

    #[test]
    fn oversized_cost_is_clamped_to_burst() {
        let (f, sim) = controller(FlowConfig {
            budget_per_tick: 10,
            weights: [1, 0, 0, 0],
            burst_ticks: 10,
        });
        sim.advance(1_000_000);
        // capacity is 100; an oversized draw grants the clamp, not the ask
        let out = f.take(MaintClass::Scrub, u64::MAX);
        assert!(!out.waited);
        assert_eq!(out.granted, 100);
    }

    #[test]
    fn blocking_take_waits_for_virtual_refill() {
        let (f, sim) = controller(FlowConfig {
            budget_per_tick: 10,
            weights: [1, 1, 1, 1],
            burst_ticks: 3,
        });
        f.drain();
        let sim2 = sim.clone();
        let driver = std::thread::spawn(move || {
            // keep virtual time moving until the taker gets through
            for _ in 0..1000 {
                sim2.advance(1);
                std::thread::sleep(Duration::from_micros(200));
            }
        });
        let out = f.take(MaintClass::Gc, 5);
        assert!(out.waited);
        assert_eq!(out.granted, 5);
        assert_eq!(f.waits(), 1);
        driver.join().unwrap();
    }
}
