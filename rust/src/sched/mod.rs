//! Maintenance scheduling & cluster-wide flow control.
//!
//! The scrub subsystem (PR 1) made integrity passes *online*; this
//! module makes them *continuous* and *polite*:
//!
//! * **Periodic cadence** — every server carries a [`SchedCtl`] holding
//!   an optional [`ScrubSchedule`] (cron-style: one pass every
//!   `every_ticks` ms of cluster time, plus a deterministic per-fire
//!   jitter so the fleet doesn't scrub in lock-step). A due schedule
//!   queues a pass on the server's own scrub worker; a pass still
//!   running is **skipped, never stacked** (the worker's typed
//!   [`crate::error::Error::ScrubBusy`] rejection is counted, and the
//!   schedule simply re-arms one period out — cron semantics, no
//!   backfill after downtime).
//! * **Virtual time** — all scheduling reads the injected
//!   [`crate::util::clock::Clock`]. Under
//!   [`crate::util::clock::WallClock`] a per-server scheduler thread
//!   polls the schedule; under [`crate::util::clock::SimClock`] a test
//!   drives cadence deterministically with
//!   [`crate::api::Cluster::advance_clock`], which advances the virtual
//!   clock and ticks every live server. Both paths funnel through
//!   [`tick`], whose check-and-re-arm is atomic — concurrent tickers
//!   can never double-fire one due time.
//! * **Shared budget** — scrub, rebalance, GC and recovery backfill
//!   ([`crate::recovery`]) draw their I/O from one per-server
//!   [`flow::FlowController`] (see that module) instead of colliding
//!   blindly on the same disks and lanes.
//! * **Backpressure** — the replica lane sheds `VerifyCopy` storms with
//!   `Busy` NACKs that senders honor with AIMD window shrink and
//!   backoff ([`backpressure`]).

pub mod backpressure;
pub mod flow;

use crate::error::Error;
use crate::metrics::Metrics;
use crate::scrub::{ScrubKind, ScrubOptions};
use crate::storage::osd::OsdShared;
use crate::util::rng::SplitMix64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Wall poll interval of the per-server scheduler thread while a
/// schedule is armed. Irrelevant to virtual-clock tests (they tick
/// explicitly); under a wall clock it bounds how late past-due
/// schedules fire.
const POLL: Duration = Duration::from_millis(10);
/// Wall poll interval while no schedule is armed: only the shutdown
/// flag and a cheap armed check run, so the unarmed thread stays as
/// quiet as the other lane threads (which poll at 50 ms too).
const IDLE_POLL: Duration = Duration::from_millis(50);

/// A cron-style per-server scrub cadence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScrubSchedule {
    /// Clock ticks (ms of cluster time) between pass starts.
    pub every_ticks: u64,
    /// Depth of the scheduled passes.
    pub kind: ScrubKind,
    /// Max extra ticks added to each arming — a deterministic
    /// pseudo-random offset in `[0, jitter]` derived from (server,
    /// fire count), so servers with the same schedule spread out
    /// instead of scrubbing in lock-step. A due pass always fires
    /// within `every_ticks + jitter` of the previous arming.
    pub jitter: u64,
}

impl ScrubSchedule {
    /// A light scrub every `every_ticks` with no jitter.
    pub fn light_every(every_ticks: u64) -> Self {
        ScrubSchedule {
            every_ticks,
            kind: ScrubKind::Light,
            jitter: 0,
        }
    }

    /// A deep scrub every `every_ticks` with no jitter.
    pub fn deep_every(every_ticks: u64) -> Self {
        ScrubSchedule {
            kind: ScrubKind::Deep,
            ..Self::light_every(every_ticks)
        }
    }

    /// Set the jitter bound.
    pub fn with_jitter(mut self, jitter: u64) -> Self {
        self.jitter = jitter;
        self
    }
}

/// One server's scheduler snapshot (see [`crate::api::Cluster::schedule_status`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStatus {
    /// Server id.
    pub server: u32,
    /// The armed schedule, if any.
    pub schedule: Option<ScrubSchedule>,
    /// Clock reading the next pass is due at (0 when disarmed).
    pub next_due_ms: u64,
    /// Scheduled passes accepted by the scrub worker.
    pub fires: u64,
    /// Due times skipped because a pass was still queued or running.
    pub skipped_busy: u64,
    /// Clock reading of the last accepted fire (0 = never).
    pub last_fired_ms: u64,
    /// Clock reading the snapshot was taken at.
    pub now_ms: u64,
}

#[derive(Default)]
struct SchedInner {
    schedule: Option<ScrubSchedule>,
    next_due_ms: u64,
    fires: u64,
    skipped_busy: u64,
    last_fired_ms: u64,
}

/// Per-server scheduler control block: the armed schedule plus fire
/// accounting. Survives kill/restart like configuration does (a dead
/// server's schedule stays armed; [`tick`] refuses to fire while the
/// injector reports dead, and the first tick after restart catches up
/// with one pass).
#[derive(Default)]
pub struct SchedCtl {
    inner: Mutex<SchedInner>,
}

/// Deterministic jitter draw for one (server, arming) pair.
fn jitter_for(server: u32, arming: u64, max: u64) -> u64 {
    if max == 0 {
        return 0;
    }
    let seed = 0x5EED_5C4B_u64 ^ ((server as u64) << 32) ^ arming;
    SplitMix64::new(seed).below(max + 1)
}

impl SchedCtl {
    /// Idle control block (no schedule armed).
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm (or disarm with `None`) the schedule. The first due time is
    /// one full period plus jitter from `now` — schedules never fire
    /// immediately on arming.
    pub fn set(&self, server: u32, now: u64, schedule: Option<ScrubSchedule>) {
        let mut g = self.inner.lock().unwrap();
        g.schedule = schedule;
        g.next_due_ms = match schedule {
            Some(s) => {
                let j = jitter_for(server, g.fires + g.skipped_busy, s.jitter);
                now + s.every_ticks.max(1) + j
            }
            None => 0,
        };
    }

    /// Atomic check-and-re-arm: when the schedule is due at `now`,
    /// re-arm one period (plus jitter) out and return the pass kind to
    /// fire. Exactly one caller wins per due time; there is no backfill
    /// (a clock jumped N periods ahead still fires once).
    fn due(&self, server: u32, now: u64) -> Option<ScrubKind> {
        let mut g = self.inner.lock().unwrap();
        let s = g.schedule?;
        if now < g.next_due_ms {
            return None;
        }
        let arming = g.fires + g.skipped_busy + 1;
        g.next_due_ms = now + s.every_ticks.max(1) + jitter_for(server, arming, s.jitter);
        Some(s.kind)
    }

    fn record_fire(&self, now: u64) {
        let mut g = self.inner.lock().unwrap();
        g.fires += 1;
        g.last_fired_ms = now;
    }

    fn record_skip(&self) {
        self.inner.lock().unwrap().skipped_busy += 1;
    }

    /// Is a schedule currently armed?
    pub fn armed(&self) -> bool {
        self.inner.lock().unwrap().schedule.is_some()
    }

    /// Snapshot for the admin API.
    pub fn status(&self, server: u32, now: u64) -> SchedStatus {
        let g = self.inner.lock().unwrap();
        SchedStatus {
            server,
            schedule: g.schedule,
            next_due_ms: g.next_due_ms,
            fires: g.fires,
            skipped_busy: g.skipped_busy,
            last_fired_ms: g.last_fired_ms,
            now_ms: now,
        }
    }
}

/// One scheduler evaluation for one server: fire the armed schedule if
/// due. Called from the per-server scheduler thread (wall clock) and
/// from the control lane's `SchedTick` handler
/// ([`crate::api::Cluster::advance_clock`]); the [`SchedCtl`] guarantees
/// a due time fires at most once no matter how many tickers race.
pub fn tick(sh: &OsdShared) {
    if sh.injector.is_dead() {
        return;
    }
    let now = sh.now_ms();
    let Some(kind) = sh.sched.due(sh.id.0, now) else {
        return;
    };
    // Scheduled passes run at unlimited per-pass rate: the shared
    // FlowController is the budget that matters here.
    let opts = match kind {
        ScrubKind::Light => ScrubOptions::light(),
        ScrubKind::Deep => ScrubOptions::deep(),
    };
    match sh.scrub.start(opts) {
        Ok(()) => {
            sh.sched.record_fire(now);
            Metrics::add(&sh.metrics.sched_fires, 1);
        }
        Err(Error::ScrubBusy(_)) => {
            // skip-if-running: never stack passes; try again next period
            sh.sched.record_skip();
            Metrics::add(&sh.metrics.sched_skipped_busy, 1);
        }
        Err(_) => {}
    }
}

/// The per-server scheduler thread body (spawned by
/// [`crate::storage::osd::Osd::spawn`]). While no schedule is armed it
/// only polls the shutdown flag at the lane threads' cadence.
pub fn sched_loop(sh: Arc<OsdShared>, sd: Arc<AtomicBool>) {
    while !sd.load(Ordering::SeqCst) {
        if sh.sched.armed() {
            std::thread::sleep(POLL);
            tick(&sh);
        } else {
            std::thread::sleep(IDLE_POLL);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_builders() {
        let s = ScrubSchedule::deep_every(500).with_jitter(50);
        assert_eq!(s.every_ticks, 500);
        assert_eq!(s.kind, ScrubKind::Deep);
        assert_eq!(s.jitter, 50);
        assert_eq!(ScrubSchedule::light_every(10).kind, ScrubKind::Light);
    }

    #[test]
    fn due_fires_once_per_period_within_jitter() {
        let ctl = SchedCtl::new();
        ctl.set(7, 0, Some(ScrubSchedule::light_every(100).with_jitter(20)));
        let st = ctl.status(7, 0);
        assert!(st.next_due_ms >= 100 && st.next_due_ms <= 120);
        // not due before the arming point
        assert!(ctl.due(7, st.next_due_ms - 1).is_none());
        // due exactly once at/after it, no matter how many tickers ask
        assert_eq!(ctl.due(7, st.next_due_ms), Some(ScrubKind::Light));
        assert!(ctl.due(7, st.next_due_ms).is_none());
        // re-armed within one period + jitter of the fire
        let st2 = ctl.status(7, st.next_due_ms);
        assert!(st2.next_due_ms > st.next_due_ms);
        assert!(st2.next_due_ms <= st.next_due_ms + 120);
    }

    #[test]
    fn clock_jump_fires_once_no_backfill() {
        let ctl = SchedCtl::new();
        ctl.set(1, 0, Some(ScrubSchedule::light_every(10)));
        // jump 10 periods ahead: one fire, re-armed from now
        assert!(ctl.due(1, 1000).is_some());
        assert!(ctl.due(1, 1000).is_none());
        let st = ctl.status(1, 1000);
        assert_eq!(st.next_due_ms, 1010);
    }

    #[test]
    fn disarm_stops_firing() {
        let ctl = SchedCtl::new();
        ctl.set(0, 0, Some(ScrubSchedule::light_every(5)));
        assert!(ctl.due(0, 100).is_some());
        ctl.set(0, 100, None);
        assert!(ctl.due(0, 10_000).is_none());
        assert_eq!(ctl.status(0, 0).next_due_ms, 0);
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        for arming in 0..50 {
            let a = jitter_for(3, arming, 20);
            let b = jitter_for(3, arming, 20);
            assert_eq!(a, b);
            assert!(a <= 20);
        }
        assert_eq!(jitter_for(3, 1, 0), 0);
    }
}
