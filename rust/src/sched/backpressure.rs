//! Replica-side backpressure on the `VerifyCopy` lane.
//!
//! A deep scrub pipelines [`crate::storage::proto::Req::VerifyCopy`]
//! probes across its window, and every scrubbing server does so
//! concurrently — a hot server's replica lane can be flooded with
//! verification work that starves real replica traffic (`PutCopy`,
//! `FetchCopy` for degraded reads). Two halves fix this:
//!
//! * **Receiver ([`Gate`])** — before serving a `VerifyCopy`, the
//!   replica lane reads its own queue depth (the envelope in hand plus
//!   everything queued behind it, via [`crate::net::Inbox::backlog`] —
//!   *all* replica traffic, so probes yield to foreground `PutCopy`/
//!   `FetchCopy` work too). Past a configured cap it sheds the probe
//!   with a cheap [`crate::storage::proto::Resp::Busy`] NACK *before*
//!   any hashing happens.
//! * **Sender ([`VerifyWindow`])** — the scrubber keeps an AIMD send
//!   window: additive increase on each verdict, halve on each `Busy`,
//!   plus exponential backoff between retries of NACKed probes. Every
//!   NACKed probe is retried until a verdict arrives, so backpressure
//!   delays verification but never skips it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Receiver-side admission gate for the replica lane's `VerifyCopy`
/// traffic. One per server; all counters are cheap relaxed atomics.
pub struct Gate {
    /// Max lane depth (the request being served plus the backlog queued
    /// behind it — any replica traffic) at which a `VerifyCopy` is still
    /// admitted; 0 = unlimited.
    cap: usize,
    /// `Busy` NACKs sent.
    busy: AtomicU64,
    /// Highest in-flight count ever *observed* at admission (including
    /// rejected requests) — proves a storm actually formed.
    observed_peak: AtomicU64,
    /// Highest in-flight count ever *admitted* — the test-hook counter:
    /// never exceeds `cap` when a cap is set.
    admitted_peak: AtomicU64,
    /// Test hook: stall each admitted `VerifyCopy` this many µs before
    /// serving it, so tests can make the lane slow enough for a
    /// deterministic storm. Always 0 in production.
    hold_us: AtomicU64,
}

impl Gate {
    /// A gate with the given in-flight cap (0 = unlimited).
    pub fn new(cap: usize) -> Self {
        Gate {
            cap,
            busy: AtomicU64::new(0),
            observed_peak: AtomicU64::new(0),
            admitted_peak: AtomicU64::new(0),
            hold_us: AtomicU64::new(0),
        }
    }

    /// The configured in-flight cap (0 = unlimited).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Admission check for one `VerifyCopy` whose lane has `backlog`
    /// requests still queued behind it. Returns false when the request
    /// must be NACKed with `Busy`. An admitted request is stalled by the
    /// test hold, modeling a slow verification service.
    pub fn admit(&self, backlog: usize) -> bool {
        let inflight = backlog as u64 + 1;
        self.observed_peak.fetch_max(inflight, Ordering::Relaxed);
        if self.cap != 0 && inflight > self.cap as u64 {
            self.busy.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        self.admitted_peak.fetch_max(inflight, Ordering::Relaxed);
        let hold = self.hold_us.load(Ordering::Relaxed);
        if hold > 0 {
            std::thread::sleep(Duration::from_micros(hold));
        }
        true
    }

    /// `Busy` NACKs sent so far.
    pub fn busy_nacks(&self) -> u64 {
        self.busy.load(Ordering::Relaxed)
    }

    /// Highest in-flight count observed at admission (incl. rejects).
    pub fn observed_peak(&self) -> u64 {
        self.observed_peak.load(Ordering::Relaxed)
    }

    /// Highest in-flight count admitted (≤ cap whenever a cap is set).
    pub fn admitted_peak(&self) -> u64 {
        self.admitted_peak.load(Ordering::Relaxed)
    }

    /// Arm the slow-service test hook (µs of stall per admitted probe).
    pub fn set_hold_for_tests(&self, hold: Duration) {
        self.hold_us.store(hold.as_micros() as u64, Ordering::Relaxed);
    }
}

/// Sender-side AIMD window for pipelined `VerifyCopy` probes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifyWindow {
    size: usize,
    max: usize,
}

impl VerifyWindow {
    /// A window starting at `init` probes, growing to at most `max`.
    pub fn new(init: usize, max: usize) -> Self {
        let max = max.max(1);
        VerifyWindow {
            size: init.clamp(1, max),
            max,
        }
    }

    /// Probes the sender may keep in flight right now.
    pub fn size(&self) -> usize {
        self.size
    }

    /// A verdict arrived: additive increase (+1 up to the max).
    pub fn on_ok(&mut self) {
        self.size = (self.size + 1).min(self.max);
    }

    /// A `Busy` NACK arrived: multiplicative decrease (halve, floor 1).
    /// Returns true when the window actually shrank.
    pub fn on_busy(&mut self) -> bool {
        let old = self.size;
        self.size = (self.size / 2).max(1);
        self.size != old
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_unlimited_admits_everything() {
        let g = Gate::new(0);
        for backlog in 0..100 {
            assert!(g.admit(backlog));
        }
        assert_eq!(g.busy_nacks(), 0);
        assert_eq!(g.observed_peak(), 100);
        assert_eq!(g.admitted_peak(), 100);
    }

    #[test]
    fn gate_caps_inflight_and_counts_nacks() {
        let g = Gate::new(2);
        assert!(g.admit(0)); // in flight 1
        assert!(g.admit(1)); // in flight 2 == cap
        assert!(!g.admit(2)); // in flight 3 > cap → Busy
        assert!(!g.admit(5));
        assert_eq!(g.busy_nacks(), 2);
        assert_eq!(g.observed_peak(), 6, "rejects are observed");
        assert_eq!(g.admitted_peak(), 2, "admissions never exceed the cap");
    }

    #[test]
    fn window_aimd() {
        let mut w = VerifyWindow::new(8, 32);
        assert_eq!(w.size(), 8);
        assert!(w.on_busy());
        assert_eq!(w.size(), 4);
        assert!(w.on_busy());
        assert!(w.on_busy());
        assert_eq!(w.size(), 1);
        assert!(!w.on_busy(), "floor of 1 cannot shrink further");
        for _ in 0..100 {
            w.on_ok();
        }
        assert_eq!(w.size(), 32, "growth capped at max");
        let w2 = VerifyWindow::new(0, 0);
        assert_eq!(w2.size(), 1, "degenerate bounds clamp to 1");
    }
}
