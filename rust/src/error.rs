//! Crate-wide error type.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the storage cluster and its substrates.
#[derive(Debug)]
pub enum Error {
    /// Object name not present in any OMAP.
    ObjectNotFound(String),
    /// A chunk referenced by an OMAP entry could not be fetched anywhere.
    ChunkMissing(String),
    /// The target server is down / not responding (killed or crashed).
    ServerDown(u32),
    /// The server id names no entry in the cluster map (admin ops on
    /// unknown ids are rejected, never silently ignored).
    UnknownServer(u32),
    /// The server was marked `Out` (removed from the cluster, its data
    /// re-replicated elsewhere); it cannot be restarted back into the
    /// map — its state is stale by construction. The only way back in
    /// is [`crate::api::Cluster::rejoin_server`], which wipes first.
    ServerRemoved(u32),
    /// The server is not marked `Out` — wipe-and-rejoin only applies to
    /// removed servers (an Up/Down server is still a live identity).
    NotRemoved(u32),
    /// The cluster has no live server able to serve the request.
    NoQuorum,
    /// A write transaction was aborted (partial failure, rolled back).
    TxAborted(String),
    /// A scrub pass is already queued or running on this server; the new
    /// pass was neither started nor stacked (re-arm and retry later).
    ScrubBusy(u32),
    /// Corrupt on-disk record (CRC mismatch, truncated record, bad magic).
    Corrupt(String),
    /// Underlying I/O error.
    Io(std::io::Error),
    /// XLA runtime error (artifact load / compile / execute).
    Xla(String),
    /// Invalid configuration or argument.
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ObjectNotFound(name) => write!(f, "object not found: {name}"),
            Error::ChunkMissing(fp) => write!(f, "chunk missing: {fp}"),
            Error::ServerDown(id) => write!(f, "server osd.{id} is down"),
            Error::UnknownServer(id) => write!(f, "unknown server osd.{id}"),
            Error::ServerRemoved(id) => {
                write!(f, "server osd.{id} was marked out and removed from the cluster")
            }
            Error::NotRemoved(id) => {
                write!(f, "server osd.{id} is not removed (rejoin requires an out server)")
            }
            Error::NoQuorum => write!(f, "no live server available"),
            Error::TxAborted(why) => write!(f, "transaction aborted: {why}"),
            Error::ScrubBusy(id) => write!(f, "scrub already running on osd.{id}"),
            Error::Corrupt(what) => write!(f, "corrupt record: {what}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(e) => write!(f, "xla runtime error: {e}"),
            Error::Invalid(what) => write!(f, "invalid: {what}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(Error::ServerDown(3).to_string(), "server osd.3 is down");
        assert_eq!(Error::UnknownServer(9).to_string(), "unknown server osd.9");
        assert!(Error::ServerRemoved(2).to_string().contains("osd.2"));
        assert!(Error::NotRemoved(4).to_string().contains("osd.4"));
        assert!(Error::ObjectNotFound("x".into()).to_string().contains("x"));
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
