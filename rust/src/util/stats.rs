//! Sample statistics for the bench harness and metrics.

/// Summary of a set of f64 samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub stdev: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median (nearest rank).
    pub p50: f64,
    /// 95th percentile (nearest rank).
    pub p95: f64,
    /// Largest sample.
    pub max: f64,
}

/// Compute a [`Summary`]; panics on empty input.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "no samples");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        stdev: var.sqrt(),
        min: sorted[0],
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
        max: sorted[n - 1],
    }
}

/// Nearest-rank percentile over a pre-sorted slice; `q` in [0,1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_edges() {
        let v = [10.0, 20.0, 30.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 1.0), 30.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        summarize(&[]);
    }
}
