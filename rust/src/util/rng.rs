//! Deterministic pseudo-random number generation (no `rand` offline).
//!
//! [`SplitMix64`] is the same generator the Python side uses to derive the
//! gear table (`python/compile/kernels/ref.py::gear_table`), which lets the
//! Rust chunker regenerate bit-identical constants. [`XorShift128Plus`] is
//! the bulk generator for workload payloads (fast, good-enough quality).

/// SplitMix64: tiny, high-quality, used for seeding and table derivation.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit output (upper-entropy bits).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() & 0xFFFF_FFFF) as u32
    }

    /// Uniform in `[0, n)`; `n` must be nonzero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for our workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform float in `[0, 1)`.
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// xorshift128+ — fast bulk generator for synthetic payload bytes.
#[derive(Clone, Debug)]
pub struct XorShift128Plus {
    s0: u64,
    s1: u64,
}

impl XorShift128Plus {
    /// Seeded via SplitMix64 (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() | 1;
        let s1 = sm.next_u64();
        XorShift128Plus { s0, s1 }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Fill `buf` with pseudo-random bytes.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let last = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&last[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_python_gear_derivation() {
        // First value of the sequence used by ref.gear_table(): the python
        // side starts from x = golden, then advances once before output.
        let mut sm = SplitMix64::new(0x9E3779B97F4A7C15);
        let first = (sm.next_u64() & 0xFFFF_FFFF) as u32;
        assert_eq!(first, 0xA1B965F4); // pinned in python tests too
    }

    #[test]
    fn splitmix_deterministic() {
        let (mut a, mut b) = (SplitMix64::new(7), SplitMix64::new(7));
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut sm = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(sm.below(17) < 17);
        }
    }

    #[test]
    fn unit_f64_in_range() {
        let mut sm = SplitMix64::new(2);
        for _ in 0..1000 {
            let v = sm.unit_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn xorshift_fill_bytes_covers_tail() {
        let mut x = XorShift128Plus::new(3);
        let mut buf = [0u8; 13];
        x.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn xorshift_streams_differ_by_seed() {
        let mut a = XorShift128Plus::new(1);
        let mut b = XorShift128Plus::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
