//! Seeded property-testing harness (`proptest` is unavailable offline).
//!
//! [`check`] runs a predicate over `n` pseudo-random cases drawn from a
//! caller-supplied generator. On failure it retries the failing case with
//! progressively "smaller" regenerated inputs (shrink-lite: the generator
//! receives a shrink level it can use to cap sizes) and panics with the
//! reproducing seed, so failures are one-line reproducible:
//!
//! ```text
//! property failed: case 17 seed 0x1234abcd (re-run with PROP_SEED=0x1234abcd)
//! ```

use super::rng::SplitMix64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed; combined with the case index. Override with `PROP_SEED`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xC0FF_EE00_0000_0001,
        }
    }
}

/// Run `test` over `cfg.cases` inputs produced by `gen`.
///
/// `gen` receives an RNG plus a *size hint* in `[0, 100]` that ramps up
/// over the run (early cases are small — cheap shrinking by construction).
/// `test` returns `Err(msg)` to signal a failure.
pub fn check<T, G, F>(cfg: Config, mut gen: G, mut test: F)
where
    G: FnMut(&mut SplitMix64, u32) -> T,
    F: FnMut(&T) -> std::result::Result<(), String>,
    T: std::fmt::Debug,
{
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| u64::from_str_radix(s.trim_start_matches("0x"), 16).ok())
        .unwrap_or(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SplitMix64::new(case_seed);
        let size = if cfg.cases > 1 {
            (case * 100) / (cfg.cases - 1)
        } else {
            100
        };
        let input = gen(&mut rng, size);
        if let Err(msg) = test(&input) {
            panic!(
                "property failed: case {case} seed {case_seed:#x} \
                 (re-run with PROP_SEED={case_seed:#x})\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Generate a byte vector of length `[0, max_len]`.
pub fn bytes(rng: &mut SplitMix64, max_len: usize) -> Vec<u8> {
    let len = rng.below(max_len as u64 + 1) as usize;
    let mut v = vec![0u8; len];
    for b in v.iter_mut() {
        *b = (rng.next_u64() & 0xFF) as u8;
    }
    v
}

/// Generate an ASCII identifier of length `[1, max_len]`.
pub fn ident(rng: &mut SplitMix64, max_len: usize) -> String {
    const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789-_";
    let len = 1 + rng.below(max_len as u64) as usize;
    (0..len)
        .map(|_| ALPHA[rng.below(ALPHA.len() as u64) as usize] as char)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            Config { cases: 32, ..Config::default() },
            |rng, size| bytes(rng, size as usize),
            |v| {
                if v.len() <= 100 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            Config { cases: 16, ..Config::default() },
            |rng, _| rng.next_u64() % 8,
            |v| if *v != 3 { Ok(()) } else { Err("hit 3".into()) },
        );
    }

    #[test]
    fn ident_is_wellformed() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..100 {
            let s = ident(&mut rng, 12);
            assert!(!s.is_empty() && s.len() <= 12);
            assert!(s.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'));
        }
    }

    #[test]
    fn size_ramps() {
        let mut seen_small = false;
        let mut seen_big = false;
        check(
            Config { cases: 50, ..Config::default() },
            |_, size| size,
            |s| {
                if *s < 10 {
                    seen_small = true;
                }
                if *s > 90 {
                    seen_big = true;
                }
                Ok(())
            },
        );
        assert!(seen_small && seen_big);
    }
}
