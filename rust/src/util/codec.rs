//! Minimal binary record codec (no `serde` offline).
//!
//! Records are length-prefixed little-endian fields written into a `Vec`
//! and read back with a cursor. Used by the KV store record format, OMAP /
//! CIT entries and fabric messages.

use crate::error::{Error, Result};

/// Append-only record writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// New empty writer.
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    /// Finish and take the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a little-endian u32.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian u64.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed byte string.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }
}

/// Cursor-based record reader.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Read from the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Corrupt(format!(
                "record truncated: need {n} at {} of {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian u32.
    pub fn get_u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    /// Read a little-endian u64.
    pub fn get_u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    /// Read a length-prefixed byte string.
    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b).map_err(|_| Error::Corrupt("invalid utf-8".into()))
    }

    /// True when the whole buffer has been consumed.
    pub fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }
}

/// CRC-32 (IEEE, reflected) — used to checksum KV log records.
pub fn crc32(data: &[u8]) -> u32 {
    // Table-less bitwise implementation; the KV log calls this per record,
    // and records are small enough that this is not the bottleneck (a
    // table variant lives in `kvstore::logkv` if profiling says otherwise).
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX);
        w.put_bytes(b"chunk");
        w.put_str("object-name");
        let bytes = w.into_bytes();

        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_bytes().unwrap(), b"chunk");
        assert_eq!(r.get_str().unwrap(), "object-name");
        assert!(r.is_empty());
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.put_bytes(b"hello");
        let mut bytes = w.into_bytes();
        bytes.truncate(6);
        let mut r = Reader::new(&bytes);
        assert!(r.get_bytes().is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // "123456789" -> 0xCBF43926 (standard check value for CRC-32/IEEE)
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
