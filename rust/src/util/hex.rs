//! Hex encoding/decoding for fingerprints and on-disk object names.

/// Encode bytes as lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    const TABLE: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(TABLE[(b >> 4) as usize] as char);
        out.push(TABLE[(b & 0xF) as usize] as char);
    }
    out
}

/// Decode lowercase/uppercase hex; returns `None` on bad input.
pub fn decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(s.len() / 2);
    let b = s.as_bytes();
    for i in (0..b.len()).step_by(2) {
        let hi = (b[i] as char).to_digit(16)?;
        let lo = (b[i + 1] as char).to_digit(16)?;
        out.push(((hi << 4) | lo) as u8);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data = [0u8, 1, 0x7f, 0x80, 0xff];
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn known_vector() {
        assert_eq!(encode(b"\xde\xad\xbe\xef"), "deadbeef");
        assert_eq!(decode("DEADBEEF").unwrap(), b"\xde\xad\xbe\xef");
    }

    #[test]
    fn rejects_bad() {
        assert!(decode("abc").is_none());
        assert!(decode("zz").is_none());
    }
}
