//! Injectable time: one [`Clock`] trait, two implementations.
//!
//! Everything time-dependent in the cluster — CIT timestamps, GC age
//! thresholds, scrub pass bookkeeping, the maintenance scheduler's
//! cadence and the [`crate::sched::flow::FlowController`] refill — reads
//! time through an `Arc<dyn Clock>` threaded into
//! [`crate::storage::osd::OsdShared`]. Production clusters run on
//! [`WallClock`] (monotonic, cluster-start-relative, exactly the old
//! behavior); tests run on [`SimClock`], a **virtual clock** that only
//! moves when the test calls [`SimClock::advance`] — so cadence,
//! throttling and backpressure become deterministic properties asserted
//! from counters, never from wall-time sleeps.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A source of cluster time in milliseconds ("ticks"). Shared by all
/// servers of a cluster so CIT timestamps and GC thresholds are
/// comparable cluster-wide.
pub trait Clock: Send + Sync {
    /// Milliseconds since cluster start.
    fn now_ms(&self) -> u64;

    /// Pause the calling thread for roughly `d` of *this clock's* time.
    /// Wall clocks really sleep; the virtual clock cannot wait for time
    /// it does not drive, so it yields instead — callers use this for
    /// heuristic delays (settling, backoff), never for correctness.
    fn sleep(&self, d: Duration);
}

/// Monotonic wall-clock time, relative to construction (cluster start).
pub struct WallClock(Instant);

impl WallClock {
    /// A clock starting at 0 now.
    pub fn new() -> Self {
        WallClock(Instant::now())
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ms(&self) -> u64 {
        self.0.elapsed().as_millis() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// A deterministic virtual clock: `now_ms` is a counter that moves only
/// when [`advance`](SimClock::advance) is called (typically via
/// [`crate::api::Cluster::advance_clock`], which also ticks every
/// server's maintenance scheduler).
#[derive(Default)]
pub struct SimClock {
    now: AtomicU64,
}

impl SimClock {
    /// A virtual clock at tick 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Move virtual time forward by `ticks` ms; returns the new now.
    pub fn advance(&self, ticks: u64) -> u64 {
        self.now.fetch_add(ticks, Ordering::SeqCst) + ticks
    }
}

impl Clock for SimClock {
    fn now_ms(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep(&self, _d: Duration) {
        // Virtual time is driven externally; a sleeper cannot make it
        // pass. Yield so whoever drives the clock gets the CPU.
        std::thread::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_advances() {
        let c = WallClock::new();
        let t0 = c.now_ms();
        c.sleep(Duration::from_millis(5));
        assert!(c.now_ms() >= t0 + 4);
    }

    #[test]
    fn sim_clock_only_moves_on_advance() {
        let c = SimClock::new();
        assert_eq!(c.now_ms(), 0);
        c.sleep(Duration::from_secs(3600)); // returns immediately
        assert_eq!(c.now_ms(), 0);
        assert_eq!(c.advance(250), 250);
        assert_eq!(c.now_ms(), 250);
        assert_eq!(c.advance(750), 1000);
    }
}
