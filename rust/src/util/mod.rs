//! Small self-contained substrates: injectable clocks, RNG, hex, record
//! codec, statistics and a property-testing harness.
//!
//! The offline crate universe has no `rand`, `serde` or `proptest`, so the
//! pieces the rest of the crate needs are implemented here from scratch.

pub mod clock;
pub mod codec;
pub mod hex;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::SplitMix64;
