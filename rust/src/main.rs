//! `snss-dedup` CLI — demo driver and workload runner for the cluster-wide
//! deduplication system.
//!
//! ```text
//! snss-dedup demo                          # tiny end-to-end demo
//! snss-dedup workload [opts]               # FIO-like run, prints bandwidth
//! snss-dedup artifacts [--dir artifacts]   # inspect AOT artifacts
//! snss-dedup help
//! ```
//!
//! Workload options (all `--key value`):
//! `--mode cluster-wide|central|disk-local|no-dedup`, `--servers N`,
//! `--threads N`, `--objects N`, `--object-mb N`, `--chunk-kb N`,
//! `--dedup-pct P`, `--consistency async-tagged|sync-chunk|sync-object|none`,
//! `--replication N`, `--fingerprint rust|xla`, `--seed S`.

use snss_dedup::api::{
    Cluster, ClusterConfig, Consistency, DedupMode, FingerprintBackend,
};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let code = match cmd {
        "demo" => demo(),
        "workload" => workload(rest),
        "artifacts" => artifacts(rest),
        _ => {
            print!("{}", HELP);
            0
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
snss-dedup — cluster-wide deduplication for shared-nothing storage

USAGE:
  snss-dedup demo                tiny end-to-end demo
  snss-dedup workload [opts]     FIO-like run, prints bandwidth + savings
  snss-dedup artifacts [--dir D] inspect AOT artifacts
  snss-dedup help

WORKLOAD OPTIONS (defaults in parens):
  --mode M          cluster-wide | central | disk-local | no-dedup (cluster-wide)
  --servers N       storage servers (8)
  --threads N       client threads (8)
  --objects N       objects to write (32)
  --object-mb N     object size in MiB (4)
  --chunk-kb N      chunk size in KiB (512)
  --dedup-pct P     duplicate-block percentage (0)
  --consistency C   async-tagged | sync-chunk | sync-object | none (async-tagged)
  --replication N   replica count (1)
  --fingerprint F   rust | xla (rust)
  --seed S          workload seed (0x5EED)
";

fn opt(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
}

fn opt_u64(args: &[String], key: &str, default: u64) -> u64 {
    opt(args, key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn parse_mode(s: &str) -> DedupMode {
    match s {
        "central" => DedupMode::Central,
        "disk-local" => DedupMode::DiskLocal,
        "no-dedup" => DedupMode::None,
        _ => DedupMode::ClusterWide,
    }
}

fn parse_consistency(s: &str) -> Consistency {
    match s {
        "sync-chunk" => Consistency::SyncChunk,
        "sync-object" => Consistency::SyncObject,
        "none" => Consistency::None,
        _ => Consistency::AsyncTagged,
    }
}

fn demo() -> i32 {
    println!("== snss-dedup demo: 4 servers, cluster-wide dedup ==");
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .expect("boot cluster");
    let client = cluster.client();
    let payload = vec![7u8; 1 << 20];
    client.put_object("alpha", &payload).expect("put alpha");
    client.put_object("beta", &payload).expect("put beta (duplicate)");
    let back = client.get_object("beta").expect("get beta");
    assert_eq!(back, payload);
    cluster.flush_consistency().ok();
    let stats = cluster.stats();
    println!(
        "logical={} MiB stored={} KiB savings={:.1}% dedup_hits={}",
        stats.logical_bytes >> 20,
        stats.stored_bytes >> 10,
        stats.savings() * 100.0,
        stats.dedup_hits
    );
    let audit = cluster.audit().expect("audit");
    println!("audit: {} fingerprints, ok={}", audit.fingerprints, audit.is_ok());
    cluster.shutdown();
    println!("demo OK");
    0
}

fn workload(args: &[String]) -> i32 {
    let servers = opt_u64(args, "--servers", 8) as usize;
    let threads = opt_u64(args, "--threads", 8) as usize;
    let objects = opt_u64(args, "--objects", 32);
    let object_mb = opt_u64(args, "--object-mb", 4) as usize;
    let chunk_kb = opt_u64(args, "--chunk-kb", 512) as usize;
    let dedup_pct = opt_u64(args, "--dedup-pct", 0).min(100) as u8;
    let seed = opt_u64(args, "--seed", 0x5EED);
    let replication = opt_u64(args, "--replication", 1) as usize;
    let mode = parse_mode(&opt(args, "--mode").unwrap_or_default());
    let consistency = parse_consistency(&opt(args, "--consistency").unwrap_or_default());
    let fingerprint = match opt(args, "--fingerprint").as_deref() {
        Some("xla") => FingerprintBackend::Xla {
            artifacts_dir: "artifacts".into(),
        },
        _ => FingerprintBackend::RustSha1,
    };

    let cluster = Cluster::new(ClusterConfig {
        servers,
        replication,
        dedup: mode,
        consistency,
        chunking: Chunking::Fixed {
            size: chunk_kb * 1024,
        },
        fingerprint,
        ..Default::default()
    })
    .expect("boot cluster");

    let gen = Arc::new(Generator::new(WorkloadSpec {
        object_size: object_mb << 20,
        unit: chunk_kb * 1024,
        dedup_pct,
        seed,
        ..Default::default()
    }));

    println!(
        "== workload: mode={} servers={servers} threads={threads} objects={objects} \
         object={object_mb}MiB chunk={chunk_kb}KiB dedup={dedup_pct}% consistency={} ==",
        mode.name(),
        consistency.name()
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..threads {
        let client = cluster.client();
        let gen = gen.clone();
        handles.push(std::thread::spawn(move || {
            let mut written = 0u64;
            let mut idx = t as u64;
            while idx < objects {
                let (name, data) = gen.named_object(idx);
                match client.put_object(&name, &data) {
                    Ok((logical, _)) => written += logical,
                    Err(e) => eprintln!("put {name}: {e}"),
                }
                idx += threads as u64;
            }
            written
        }));
    }
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed();
    cluster.flush_consistency().ok();
    let stats = cluster.stats();
    let mbps = total as f64 / (1 << 20) as f64 / dt.as_secs_f64();
    println!(
        "wrote {} MiB in {:.2}s -> {:.1} MiB/s | stored {} MiB | savings {:.1}% | hits {}",
        total >> 20,
        dt.as_secs_f64(),
        mbps,
        stats.stored_bytes >> 20,
        stats.savings() * 100.0,
        stats.dedup_hits
    );
    let audit = cluster.audit().expect("audit");
    if !audit.is_ok() {
        eprintln!("AUDIT VIOLATIONS: {:?}", audit.violations);
        return 1;
    }
    cluster.shutdown();
    0
}

fn artifacts(args: &[String]) -> i32 {
    let dir = opt(args, "--dir").unwrap_or_else(|| "artifacts".into());
    match snss_dedup::runtime::parse_manifest(std::path::Path::new(&dir)) {
        Ok(specs) => {
            println!("{} artifacts in {dir}:", specs.len());
            for s in specs {
                println!(
                    "  {:<16} kind={:<12} batch={:<3} chunk={:<7} tile={} file={}",
                    s.name,
                    s.kind,
                    s.batch,
                    s.chunk_bytes,
                    s.tile,
                    s.file.display()
                );
            }
            0
        }
        Err(e) => {
            eprintln!("cannot read manifest in {dir}: {e} (run `make artifacts`)");
            1
        }
    }
}
