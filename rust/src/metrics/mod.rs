//! Cluster metrics: atomic counters plus a fixed-bucket latency histogram.
//! All counters are cheap relaxed atomics — safe to bump from any lane.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Cluster-wide counters (one shared instance per cluster).
#[derive(Default)]
pub struct Metrics {
    /// Logical bytes accepted from clients (pre-dedup).
    pub bytes_logical: AtomicU64,
    /// Unique chunk bytes stored (primary copies).
    pub bytes_stored: AtomicU64,
    /// Replica chunk bytes stored.
    pub bytes_replica: AtomicU64,
    /// CIT lookups served.
    pub cit_lookups: AtomicU64,
    /// Duplicate hits (refcount increments granted).
    pub dedup_hits: AtomicU64,
    /// Unique chunks written.
    pub unique_chunks: AtomicU64,
    /// Fabric messages sent.
    pub messages: AtomicU64,
    /// Repair events (invalid-flag consistency checks that restored state).
    pub repairs: AtomicU64,
    /// Chunks reclaimed by GC.
    pub gc_reclaimed: AtomicU64,
    /// Write transactions aborted.
    pub tx_aborts: AtomicU64,
    /// CIT entries examined by scrub passes (light + deep).
    pub scrub_chunks_checked: AtomicU64,
    /// Chunk bytes re-read and re-fingerprinted by deep scrub.
    pub scrub_bytes_verified: AtomicU64,
    /// Primary-chunk digest mismatches (bit-rot) found by deep scrub.
    pub scrub_corruptions_found: AtomicU64,
    /// Scrub repairs applied (restored primaries, rewritten bit-rot,
    /// re-pushed replica copies).
    pub scrub_repaired: AtomicU64,
    /// Backreference-index records written or deleted by OMAP mutations.
    pub backref_updates: AtomicU64,
    /// Fingerprints whose reference count was answered from the
    /// backreference index (the `CountRefs` fast path).
    pub backref_lookups: AtomicU64,
    /// Full index re-derivations from the OMAP (crash recovery + the
    /// one-shot pre-index store migration).
    pub backref_rebuilds: AtomicU64,
    /// Index ↔ OMAP discrepancies found by audits (0 in steady state).
    pub backref_mismatches: AtomicU64,
    /// `ProbeChunks` messages sent (Phase A of the batched write path).
    pub probe_batches: AtomicU64,
    /// Fingerprints a Phase-A probe reported already Valid at their home
    /// (their payloads were elided from Phase B).
    pub probe_hits: AtomicU64,
    /// `StoreChunkBatch` messages sent (Phase B plus NeedData resends).
    pub store_batches: AtomicU64,
    /// Chunk items carried by all `StoreChunkBatch` messages sent.
    pub batch_items: AtomicU64,
    /// Fingerprints re-shipped with payload after a `NeedData` NACK (the
    /// probe hint went stale between the two phases).
    pub need_data_resends: AtomicU64,
    /// Bytes the dedup engine put on the backend lane (chunk scatter,
    /// probes, batches, refcount releases, central-mode raw stores) —
    /// request wire sizes, excluding replica-lane traffic.
    pub wire_bytes: AtomicU64,
    /// Scheduled scrub passes accepted by scrub workers (maintenance
    /// scheduler fires).
    pub sched_fires: AtomicU64,
    /// Scheduled due times skipped because a pass was still queued or
    /// running on that server (skip-if-running, never stacked).
    pub sched_skipped_busy: AtomicU64,
    /// Maintenance tokens granted to scrub by the shared FlowController.
    pub flow_granted_scrub: AtomicU64,
    /// Maintenance tokens granted to rebalance by the FlowController.
    pub flow_granted_rebalance: AtomicU64,
    /// Maintenance tokens granted to GC by the FlowController.
    pub flow_granted_gc: AtomicU64,
    /// Maintenance tokens granted to recovery backfill by the
    /// FlowController.
    pub flow_granted_recovery: AtomicU64,
    /// Times a maintenance consumer had to wait for budget refill.
    pub flow_waits: AtomicU64,
    /// `Busy` NACKs sent by replica lanes shedding `VerifyCopy` storms.
    pub backpressure_busy: AtomicU64,
    /// `VerifyCopy` probes re-sent by scrubbers after a `Busy` NACK.
    pub backpressure_retries: AtomicU64,
    /// Sender AIMD window halvings triggered by `Busy` NACKs.
    pub backpressure_window_shrinks: AtomicU64,
    /// `VerifyCopy` probes abandoned after the retry budget (left for
    /// the next scheduled pass; 0 in steady state).
    pub backpressure_gave_up: AtomicU64,
    /// Heartbeat probes sent by the failure detector.
    pub detector_probes: AtomicU64,
    /// Servers the detector marked Down (silent past the grace window).
    pub detector_marked_down: AtomicU64,
    /// Down servers the detector marked Up again (heartbeats resumed).
    pub detector_marked_up: AtomicU64,
    /// Servers the detector marked Out (silent past the out window) —
    /// each out-transition also triggers recovery backfill everywhere.
    pub detector_marked_out: AtomicU64,
    /// Recovery jobs started by workers (one per surviving server per
    /// out-transition, plus re-runs after a crashed recovery).
    pub recovery_runs: AtomicU64,
    /// CIT entries examined by recovery backfill passes.
    pub recovery_chunks_scanned: AtomicU64,
    /// Primary chunks restored from a surviving copy by recovery.
    pub recovery_chunks_restored: AtomicU64,
    /// Replica copies (chunk + OMAP record) re-pushed by recovery to
    /// restore the configured replication factor.
    pub recovery_copies_pushed: AtomicU64,
    /// Bytes re-replicated by recovery (restored primaries + pushed
    /// copies + re-homed OMAP records).
    pub recovery_bytes: AtomicU64,
    /// OMAP records re-homed onto their new primary from a surviving
    /// replica copy after their old primary left the cluster.
    pub recovery_omap_recovered: AtomicU64,
    /// CIT refcounts re-synchronized by recovery's reconcile step.
    pub recovery_refs_fixed: AtomicU64,
    /// Referenced chunks recovery could not restore from any surviving
    /// copy (quarantined behind an invalid flag; 0 unless more copies
    /// were lost than the replication factor covers).
    pub recovery_lost: AtomicU64,
    /// Write-path latency histogram.
    pub put_latency: Histogram,
}

impl Metrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// add helper
    #[inline]
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// read helper
    #[inline]
    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Space savings so far: 1 - stored/logical (0 when nothing written).
    pub fn savings(&self) -> f64 {
        let logical = Self::get(&self.bytes_logical);
        let stored = Self::get(&self.bytes_stored);
        if logical == 0 {
            0.0
        } else {
            1.0 - stored as f64 / logical as f64
        }
    }
}

/// Log-scaled latency histogram: bucket i covers [2^i, 2^(i+1)) µs.
pub struct Histogram {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate quantile (bucket upper bound) for `q` in [0,1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = (n as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn savings_math() {
        let m = Metrics::new();
        assert_eq!(m.savings(), 0.0);
        Metrics::add(&m.bytes_logical, 100);
        Metrics::add(&m.bytes_stored, 15);
        assert!((m.savings() - 0.85).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets() {
        let h = Histogram::default();
        h.record(Duration::from_micros(1));
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(100));
        h.record(Duration::from_millis(10));
        assert_eq!(h.count(), 4);
        assert!(h.mean_us() > 1.0);
        // p50 should land in the 100µs bucket's range
        let p50 = h.quantile_us(0.5);
        assert!((64..=256).contains(&p50), "p50={p50}");
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
