//! Per-server metrics: atomic counters plus fixed-bucket latency
//! histograms. All counters are cheap relaxed atomics — safe to bump
//! from any lane.
//!
//! Since the observability overhaul each server owns its **own**
//! `Metrics` instance, registered in the cluster's
//! [`crate::obs::Registry`]; the cluster-wide view
//! ([`crate::api::Cluster::stats`],
//! [`crate::api::Cluster::metrics_snapshot`]) is an aggregation over
//! the registry, which is what makes per-server skew and hot-shard
//! detection observable at all. [`Metrics::counters`] and
//! [`Metrics::histograms`] are the single authoritative enumeration
//! the exposition layer renders from.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// One server's counters (one instance per server, plus one
/// cluster-scope instance for client/detector activity).
#[derive(Default)]
pub struct Metrics {
    /// Logical bytes accepted from clients (pre-dedup).
    pub bytes_logical: AtomicU64,
    /// Unique chunk bytes stored (primary copies).
    pub bytes_stored: AtomicU64,
    /// Replica chunk bytes stored.
    pub bytes_replica: AtomicU64,
    /// CIT lookups served.
    pub cit_lookups: AtomicU64,
    /// Duplicate hits (refcount increments granted).
    pub dedup_hits: AtomicU64,
    /// Unique chunks written.
    pub unique_chunks: AtomicU64,
    /// Fabric messages sent.
    pub messages: AtomicU64,
    /// Repair events (invalid-flag consistency checks that restored state).
    pub repairs: AtomicU64,
    /// Chunks reclaimed by GC.
    pub gc_reclaimed: AtomicU64,
    /// Write transactions aborted.
    pub tx_aborts: AtomicU64,
    /// CIT entries examined by scrub passes (light + deep).
    pub scrub_chunks_checked: AtomicU64,
    /// Chunk bytes re-read and re-fingerprinted by deep scrub.
    pub scrub_bytes_verified: AtomicU64,
    /// Primary-chunk digest mismatches (bit-rot) found by deep scrub.
    pub scrub_corruptions_found: AtomicU64,
    /// Scrub repairs applied (restored primaries, rewritten bit-rot,
    /// re-pushed replica copies).
    pub scrub_repaired: AtomicU64,
    /// Backreference-index records written or deleted by OMAP mutations.
    pub backref_updates: AtomicU64,
    /// Fingerprints whose reference count was answered from the
    /// backreference index (the `CountRefs` fast path).
    pub backref_lookups: AtomicU64,
    /// Full index re-derivations from the OMAP (crash recovery + the
    /// one-shot pre-index store migration).
    pub backref_rebuilds: AtomicU64,
    /// Index ↔ OMAP discrepancies found by audits (0 in steady state).
    pub backref_mismatches: AtomicU64,
    /// `ProbeChunks` messages sent (Phase A of the batched write path).
    pub probe_batches: AtomicU64,
    /// Fingerprints a Phase-A probe reported already Valid at their home
    /// (their payloads were elided from Phase B).
    pub probe_hits: AtomicU64,
    /// `StoreChunkBatch` messages sent (Phase B plus NeedData resends).
    pub store_batches: AtomicU64,
    /// Chunk items carried by all `StoreChunkBatch` messages sent.
    pub batch_items: AtomicU64,
    /// Fingerprints re-shipped with payload after a `NeedData` NACK (the
    /// probe hint went stale between the two phases).
    pub need_data_resends: AtomicU64,
    /// Bytes the dedup engine put on the backend lane (chunk scatter,
    /// probes, batches, refcount releases, central-mode raw stores) —
    /// request wire sizes, excluding replica-lane traffic.
    pub wire_bytes: AtomicU64,
    /// Scheduled scrub passes accepted by scrub workers (maintenance
    /// scheduler fires).
    pub sched_fires: AtomicU64,
    /// Scheduled due times skipped because a pass was still queued or
    /// running on that server (skip-if-running, never stacked).
    pub sched_skipped_busy: AtomicU64,
    /// Maintenance tokens granted to scrub by the shared FlowController.
    pub flow_granted_scrub: AtomicU64,
    /// Maintenance tokens granted to rebalance by the FlowController.
    pub flow_granted_rebalance: AtomicU64,
    /// Maintenance tokens granted to GC by the FlowController.
    pub flow_granted_gc: AtomicU64,
    /// Maintenance tokens granted to recovery backfill by the
    /// FlowController.
    pub flow_granted_recovery: AtomicU64,
    /// Times a maintenance consumer had to wait for budget refill.
    pub flow_waits: AtomicU64,
    /// `Busy` NACKs sent by replica lanes shedding `VerifyCopy` storms.
    pub backpressure_busy: AtomicU64,
    /// `VerifyCopy` probes re-sent by scrubbers after a `Busy` NACK.
    pub backpressure_retries: AtomicU64,
    /// Sender AIMD window halvings triggered by `Busy` NACKs.
    pub backpressure_window_shrinks: AtomicU64,
    /// `VerifyCopy` probes abandoned after the retry budget (left for
    /// the next scheduled pass; 0 in steady state).
    pub backpressure_gave_up: AtomicU64,
    /// Heartbeat probes sent by the failure detector.
    pub detector_probes: AtomicU64,
    /// Servers the detector marked Down (silent past the grace window).
    pub detector_marked_down: AtomicU64,
    /// Down servers the detector marked Up again (heartbeats resumed).
    pub detector_marked_up: AtomicU64,
    /// Servers the detector marked Out (silent past the out window) —
    /// each out-transition also triggers recovery backfill everywhere.
    pub detector_marked_out: AtomicU64,
    /// Recovery jobs started by workers (one per surviving server per
    /// out-transition, plus re-runs after a crashed recovery).
    pub recovery_runs: AtomicU64,
    /// CIT entries examined by recovery backfill passes.
    pub recovery_chunks_scanned: AtomicU64,
    /// Primary chunks restored from a surviving copy by recovery.
    pub recovery_chunks_restored: AtomicU64,
    /// Replica copies (chunk + OMAP record) re-pushed by recovery to
    /// restore the configured replication factor.
    pub recovery_copies_pushed: AtomicU64,
    /// Bytes re-replicated by recovery (restored primaries + pushed
    /// copies + re-homed OMAP records).
    pub recovery_bytes: AtomicU64,
    /// OMAP records re-homed onto their new primary from a surviving
    /// replica copy after their old primary left the cluster.
    pub recovery_omap_recovered: AtomicU64,
    /// CIT refcounts re-synchronized by recovery's reconcile step.
    pub recovery_refs_fixed: AtomicU64,
    /// Referenced chunks recovery could not restore from any surviving
    /// copy (quarantined behind an invalid flag; 0 unless more copies
    /// were lost than the replication factor covers).
    pub recovery_lost: AtomicU64,
    /// Object reads that touched at least one chunk home (the
    /// read-amplification denominator).
    pub read_amp_reads: AtomicU64,
    /// Distinct chunk homes (servers) that served data across all object
    /// reads — `read_amp_homes / read_amp_reads` is the mean
    /// read-amplification (the fragmentation signal: how many servers a
    /// single object read fans out to).
    pub read_amp_homes: AtomicU64,
    /// `FetchChunkBatch` messages sent by the batched read path (one
    /// per distinct live chunk home per read, plus Busy retries).
    pub read_batches: AtomicU64,
    /// Chunk fetches carried inside `FetchChunkBatch` messages.
    pub read_batch_items: AtomicU64,
    /// Single-chunk `FetchChunk` messages sent (legacy read path and
    /// per-item degraded fallback; 0 on a healthy batched cluster).
    pub read_chunk_fetches: AtomicU64,
    /// Chunks the batched read path degraded to the per-item legacy
    /// path (batch miss, Busy after retry, or dead home).
    pub read_fallbacks: AtomicU64,
    /// Chunk fetches that fell back after a home answered `Busy` twice
    /// (once plus the granted retry).
    pub read_degraded_busy: AtomicU64,
    /// Chunk fetches that fell back because the home was dead,
    /// unreachable, or missing the chunk.
    pub read_degraded_dead: AtomicU64,
    /// Hot-chunk cache hits (payload served without a store or fabric
    /// hop).
    pub read_cache_hits: AtomicU64,
    /// Hot-chunk cache misses.
    pub read_cache_misses: AtomicU64,
    /// Payloads admitted to the hot-chunk cache.
    pub read_cache_insertions: AtomicU64,
    /// Cache entries evicted by capacity pressure.
    pub read_cache_evictions: AtomicU64,
    /// Cache entries dropped by coherence invalidation hooks (GC
    /// reclaim, scrub quarantine, recovery re-home, rebalance
    /// migration).
    pub read_cache_invalidations: AtomicU64,
    /// Locality copies planted by fragmentation-aware selective
    /// duplication.
    pub dup_chunks_planted: AtomicU64,
    /// Planted locality copies evicted to respect the duplication byte
    /// budget.
    pub dup_chunks_evicted: AtomicU64,
    /// Post-write `VerifyCopy` probes issued by the optional
    /// write-verification leg (`verify_write`).
    pub write_verifies: AtomicU64,
    /// Write-verification probes whose replica was missing or
    /// digest-mismatched (0 in steady state).
    pub write_verify_mismatches: AtomicU64,
    /// Out servers re-admitted by wipe-and-rejoin
    /// ([`crate::api::Cluster::rejoin_server`]).
    pub membership_rejoins: AtomicU64,
    /// Local-state wipes performed on the rejoin path (KV + CIT + OMAP
    /// + chunk/replica stores erased before re-admission).
    pub membership_wipes: AtomicU64,
    /// Rebalance scans auto-enqueued by membership changes (add, out,
    /// rejoin) — one per map-change event, fanned to every Up server.
    pub membership_auto_rebalances: AtomicU64,
    /// Replica-copy pushes that failed (dead peer, `Busy` shed, or an
    /// error reply) at any fan-out site — write-time replication, scrub
    /// copy repair, recovery re-replication, rebalance OMAP refresh.
    /// Each failure leaves the key under its target copy count until a
    /// scrub/recovery pass converges it (0 on a healthy cluster).
    pub replica_push_failures: AtomicU64,
    /// Copy-add promotions executed because an IncRef carried a chunk's
    /// refcount across a redundancy band threshold.
    pub redundancy_promotions: AtomicU64,
    /// Copy-drop demotions executed because a DecRef carried a chunk's
    /// refcount below a redundancy band threshold (plant-registry-aware:
    /// a locality plant is never dropped as a redundancy copy).
    pub redundancy_demotions: AtomicU64,
    /// Sum of banded target copy counts computed at write-time
    /// replication fan-out — divide by `unique_chunks` for the mean
    /// write-time target under the active [`RedundancyPolicy`].
    ///
    /// [`RedundancyPolicy`]: crate::dedup::redundancy::RedundancyPolicy
    pub redundancy_target_copies: AtomicU64,
    /// Orphaned locality plants reclaimed through the
    /// `invalidate_chunk` choke point (a planted replica-slot copy
    /// deleted + deregistered when its chunk was retired).
    pub dup_plants_reclaimed: AtomicU64,
    /// Tier-1 weak-filter hits: chunks classified as probable
    /// duplicates and strong-hashed inline (DESIGN.md §16).
    pub fp_weak_hits: AtomicU64,
    /// Tier-1 weak-filter misses: chunks that looked unique at the
    /// weak tier.
    pub fp_weak_misses: AtomicU64,
    /// Strong fingerprints computed *inline on the write path* (all
    /// chunks under `FpMode::Inline`; only probable duplicates and
    /// collision fallbacks under `FpMode::Tiered`).
    pub fp_strong_hashes: AtomicU64,
    /// Chunks deferred under a pending identity for background
    /// resolution.
    pub fp_deferred: AtomicU64,
    /// Batched `FingerprintProvider::digests` calls made by the tier-2
    /// worker.
    pub fp_batch_calls: AtomicU64,
    /// Chunks hashed across all tier-2 batched calls
    /// (`fp_batch_items / fp_batch_calls` = mean batch size).
    pub fp_batch_items: AtomicU64,
    /// Weak collisions caught by byte-compare before any merge (the
    /// chunk fell back to an inline strong hash; nothing was merged).
    pub fp_verify_rejects: AtomicU64,
    /// Pending identities fully migrated into the content-addressed
    /// domain (strong chunk stored, OMAP rewritten, identity
    /// reclaimed).
    pub fp_migrations: AtomicU64,
    /// Write-path (put) latency histogram.
    pub put_latency: Histogram,
    /// Read-path (get) latency histogram.
    pub get_latency: Histogram,
    /// Delete-path latency histogram.
    pub delete_latency: Histogram,
    /// Per-window scrub latency (one sample per scrub window).
    pub scrub_window_latency: Histogram,
    /// Per-stage recovery-backfill latency (one sample per stage of
    /// each recovery job: OMAP re-homing + ensure, then chunk backfill).
    pub recovery_stage_latency: Histogram,
    /// Per-chunk rebalance migration latency (one sample per
    /// `MigrateChunk` round-trip).
    pub rebalance_migration_latency: Histogram,
}

impl Metrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// add helper
    #[inline]
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// read helper
    #[inline]
    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// The authoritative name → value enumeration of every counter.
    /// The exposition renderers and the aggregation path both consume
    /// this, so a counter added here automatically shows up everywhere.
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        macro_rules! counters {
            ($($field:ident),* $(,)?) => {
                vec![$((stringify!($field), Self::get(&self.$field))),*]
            };
        }
        counters![
            bytes_logical,
            bytes_stored,
            bytes_replica,
            cit_lookups,
            dedup_hits,
            unique_chunks,
            messages,
            repairs,
            gc_reclaimed,
            tx_aborts,
            scrub_chunks_checked,
            scrub_bytes_verified,
            scrub_corruptions_found,
            scrub_repaired,
            backref_updates,
            backref_lookups,
            backref_rebuilds,
            backref_mismatches,
            probe_batches,
            probe_hits,
            store_batches,
            batch_items,
            need_data_resends,
            wire_bytes,
            sched_fires,
            sched_skipped_busy,
            flow_granted_scrub,
            flow_granted_rebalance,
            flow_granted_gc,
            flow_granted_recovery,
            flow_waits,
            backpressure_busy,
            backpressure_retries,
            backpressure_window_shrinks,
            backpressure_gave_up,
            detector_probes,
            detector_marked_down,
            detector_marked_up,
            detector_marked_out,
            recovery_runs,
            recovery_chunks_scanned,
            recovery_chunks_restored,
            recovery_copies_pushed,
            recovery_bytes,
            recovery_omap_recovered,
            recovery_refs_fixed,
            recovery_lost,
            read_amp_reads,
            read_amp_homes,
            read_batches,
            read_batch_items,
            read_chunk_fetches,
            read_fallbacks,
            read_degraded_busy,
            read_degraded_dead,
            read_cache_hits,
            read_cache_misses,
            read_cache_insertions,
            read_cache_evictions,
            read_cache_invalidations,
            dup_chunks_planted,
            dup_chunks_evicted,
            write_verifies,
            write_verify_mismatches,
            membership_rejoins,
            membership_wipes,
            membership_auto_rebalances,
            replica_push_failures,
            redundancy_promotions,
            redundancy_demotions,
            redundancy_target_copies,
            dup_plants_reclaimed,
            fp_weak_hits,
            fp_weak_misses,
            fp_strong_hashes,
            fp_deferred,
            fp_batch_calls,
            fp_batch_items,
            fp_verify_rejects,
            fp_migrations,
        ]
    }

    /// The authoritative name → histogram enumeration (same contract as
    /// [`Metrics::counters`]).
    pub fn histograms(&self) -> Vec<(&'static str, &Histogram)> {
        vec![
            ("put_latency", &self.put_latency),
            ("get_latency", &self.get_latency),
            ("delete_latency", &self.delete_latency),
            ("scrub_window_latency", &self.scrub_window_latency),
            ("recovery_stage_latency", &self.recovery_stage_latency),
            (
                "rebalance_migration_latency",
                &self.rebalance_migration_latency,
            ),
        ]
    }

    /// Space savings so far: 1 - stored/logical (0 when nothing written).
    pub fn savings(&self) -> f64 {
        let logical = Self::get(&self.bytes_logical);
        let stored = Self::get(&self.bytes_stored);
        if logical == 0 {
            0.0
        } else {
            1.0 - stored as f64 / logical as f64
        }
    }
}

/// Log-scaled latency histogram: bucket i covers [2^i, 2^(i+1)) µs.
pub struct Histogram {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate quantile (bucket upper bound) for `q` in [0,1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        self.snapshot().quantile_us(q)
    }

    /// A point-in-time copy of the histogram (relaxed loads; counts may
    /// be mid-update skewed by concurrent writers, which is fine for
    /// monitoring).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

/// A point-in-time, mergeable copy of a [`Histogram`] with quantile
/// readout — what [`crate::api::Cluster::metrics_snapshot`] carries per
/// server, and what the benches derive their p50/p99 figures from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    /// Number of samples.
    pub count: u64,
    /// Sum of all samples in microseconds.
    pub sum_us: u64,
    /// Log-scaled sample counts: bucket i covers [2^i, 2^(i+1)) µs.
    pub buckets: [u64; 32],
}

impl HistogramSnapshot {
    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Approximate quantile (bucket upper bound) for `q` in [0,1] — the
    /// same log-bucket readout the live histogram serves. Empty → 0.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 32
    }

    /// Median (p50) readout in microseconds.
    pub fn p50_us(&self) -> u64 {
        self.quantile_us(0.50)
    }

    /// p90 readout in microseconds.
    pub fn p90_us(&self) -> u64 {
        self.quantile_us(0.90)
    }

    /// p99 readout in microseconds.
    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }

    /// Fold another snapshot into this one (bucket-wise sum) — how the
    /// cluster-level histogram view is built from per-server snapshots.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        self.count += other.count;
        self.sum_us += other.sum_us;
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn savings_math() {
        let m = Metrics::new();
        assert_eq!(m.savings(), 0.0);
        Metrics::add(&m.bytes_logical, 100);
        Metrics::add(&m.bytes_stored, 15);
        assert!((m.savings() - 0.85).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets() {
        let h = Histogram::default();
        h.record(Duration::from_micros(1));
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(100));
        h.record(Duration::from_millis(10));
        assert_eq!(h.count(), 4);
        assert!(h.mean_us() > 1.0);
        // p50 should land in the 100µs bucket's range
        let p50 = h.quantile_us(0.5);
        assert!((64..=256).contains(&p50), "p50={p50}");
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_quantiles_are_monotone() {
        let h = Histogram::default();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert!(s.p50_us() <= s.p90_us());
        assert!(s.p90_us() <= s.p99_us());
        assert!(s.p99_us() > 0);
        assert_eq!(s.quantile_us(0.5), h.quantile_us(0.5));
    }

    #[test]
    fn snapshot_merge_sums_buckets() {
        let (a, b) = (Histogram::default(), Histogram::default());
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(10));
        b.record(Duration::from_millis(100));
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.sum_us, 100_020);
        // the merged p99 must reflect b's slow sample
        assert!(m.p99_us() >= 100_000, "p99={}", m.p99_us());
    }

    #[test]
    fn counter_enumeration_names_are_unique_and_live() {
        let m = Metrics::new();
        Metrics::add(&m.read_amp_homes, 3);
        let counters = m.counters();
        let names: std::collections::HashSet<&str> =
            counters.iter().map(|(n, _)| *n).collect();
        assert_eq!(names.len(), counters.len(), "duplicate counter name");
        let homes = counters
            .iter()
            .find(|(n, _)| *n == "read_amp_homes")
            .unwrap()
            .1;
        assert_eq!(homes, 3);
        assert_eq!(m.histograms().len(), 6);
    }
}
