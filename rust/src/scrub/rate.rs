//! Token-bucket rate limiting for background maintenance I/O.
//!
//! The scrub worker shares disks and fabric lanes with foreground
//! traffic, so every byte it reads (and every entry it probes) is charged
//! against a refilling token budget. The bucket holds at most one
//! second's worth of tokens — a scrub that falls behind does not get to
//! burst-catch-up and starve clients.

use std::time::{Duration, Instant};

/// A token bucket charged in bytes (or byte-equivalents for metadata
/// probes). `rate == 0` disables limiting entirely.
pub struct TokenBucket {
    /// Refill rate in tokens/second; 0 = unlimited.
    rate: u64,
    /// Maximum accumulated tokens (one second of refill).
    capacity: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// A bucket refilling at `rate` tokens/second, starting full.
    pub fn new(rate: u64) -> Self {
        let capacity = rate.max(1) as f64;
        TokenBucket {
            rate,
            capacity,
            tokens: capacity,
            last: Instant::now(),
        }
    }

    /// Is this bucket a no-op (unlimited)?
    pub fn unlimited(&self) -> bool {
        self.rate == 0
    }

    /// Take `cost` tokens, sleeping until the refill covers the deficit.
    /// Costs above one second's budget are clamped to the bucket capacity
    /// (a single oversized chunk must not stall the scrub forever).
    pub fn take(&mut self, cost: u64) {
        if self.rate == 0 {
            return;
        }
        let cost = (cost as f64).min(self.capacity);
        loop {
            let now = Instant::now();
            let elapsed = now.duration_since(self.last).as_secs_f64();
            self.tokens = (self.tokens + elapsed * self.rate as f64).min(self.capacity);
            self.last = now;
            if self.tokens >= cost {
                self.tokens -= cost;
                return;
            }
            let deficit = cost - self.tokens;
            let wait = Duration::from_secs_f64(deficit / self.rate as f64);
            std::thread::sleep(wait.min(Duration::from_millis(50)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_never_sleeps() {
        let mut b = TokenBucket::new(0);
        assert!(b.unlimited());
        let t0 = Instant::now();
        for _ in 0..1000 {
            b.take(1 << 20);
        }
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn limited_rate_paces_consumption() {
        // 1 MiB/s bucket starts full (1 MiB burst); draining 1.5 MiB must
        // take at least ~0.4s of refill.
        let mut b = TokenBucket::new(1 << 20);
        let t0 = Instant::now();
        for _ in 0..6 {
            b.take(256 << 10);
        }
        assert!(
            t0.elapsed() >= Duration::from_millis(300),
            "elapsed {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn oversized_cost_is_clamped() {
        let mut b = TokenBucket::new(1024);
        let t0 = Instant::now();
        b.take(u64::MAX); // would deadlock without the clamp
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
