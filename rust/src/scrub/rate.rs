//! Token-bucket rate limiting for background maintenance I/O.
//!
//! The scrub worker shares disks and fabric lanes with foreground
//! traffic, so every byte it reads (and every entry it probes) is charged
//! against a refilling token budget. The bucket holds at most one
//! second's worth of tokens — a scrub that falls behind does not get to
//! burst-catch-up and starve clients.
//!
//! Since the maintenance-scheduler work this bucket refills from the
//! injected [`Clock`] rather than wall time, so a
//! [`crate::util::clock::SimClock`]-driven test controls exactly how
//! much budget a pass sees. The cluster-shared generalization (weighted
//! classes, one budget for scrub **and** rebalance **and** GC) lives in
//! [`crate::sched::flow::FlowController`]; this per-pass bucket remains
//! as the `ScrubOptions::rate_bytes_per_sec` knob.

use crate::util::clock::{Clock, WallClock};
use std::sync::Arc;
use std::time::Duration;

/// Upper bound on one wall sleep between refill re-checks while a take
/// waits (keeps reaction to virtual-clock advances bounded). The actual
/// sleep is deficit-proportional; an implementation detail, not a timing
/// dependency — the token accounting itself is entirely clock-driven.
const MAX_WAIT_POLL: Duration = Duration::from_millis(50);

/// A token bucket charged in bytes (or byte-equivalents for metadata
/// probes). `rate == 0` disables limiting entirely.
pub struct TokenBucket {
    /// Refill rate in tokens/second of clock time; 0 = unlimited.
    rate: u64,
    /// Maximum accumulated tokens (one second of refill).
    capacity: f64,
    tokens: f64,
    last_ms: u64,
    clock: Arc<dyn Clock>,
}

impl TokenBucket {
    /// A wall-clock bucket refilling at `rate` tokens/second, starting
    /// full.
    pub fn new(rate: u64) -> Self {
        Self::with_clock(rate, Arc::new(WallClock::new()))
    }

    /// A bucket refilling at `rate` tokens per second of `clock` time,
    /// starting full.
    pub fn with_clock(rate: u64, clock: Arc<dyn Clock>) -> Self {
        let capacity = rate.max(1) as f64;
        TokenBucket {
            rate,
            capacity,
            tokens: capacity,
            last_ms: clock.now_ms(),
            clock,
        }
    }

    /// Is this bucket a no-op (unlimited)?
    pub fn unlimited(&self) -> bool {
        self.rate == 0
    }

    fn refill(&mut self) {
        let now = self.clock.now_ms();
        let elapsed_ms = now.saturating_sub(self.last_ms);
        if elapsed_ms > 0 {
            let refill = elapsed_ms as f64 * self.rate as f64 / 1000.0;
            self.tokens = (self.tokens + refill).min(self.capacity);
            self.last_ms = now;
        }
    }

    /// Take `cost` tokens, waiting until the refill covers the deficit.
    /// Costs above one second's budget are clamped to the bucket capacity
    /// (a single oversized chunk must not stall the scrub forever).
    pub fn take(&mut self, cost: u64) {
        if self.rate == 0 {
            return;
        }
        let cost = (cost as f64).min(self.capacity);
        loop {
            self.refill();
            if self.tokens >= cost {
                self.tokens -= cost;
                return;
            }
            // deficit-proportional wall sleep (ticks ≈ ms), capped so a
            // virtual-clock advance is noticed promptly
            let deficit = cost - self.tokens;
            let ms = (deficit * 1000.0 / self.rate as f64).ceil() as u64;
            std::thread::sleep(Duration::from_millis(ms.max(1)).min(MAX_WAIT_POLL));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SimClock;
    use std::time::Instant;

    #[test]
    fn unlimited_never_sleeps() {
        let mut b = TokenBucket::new(0);
        assert!(b.unlimited());
        let t0 = Instant::now();
        for _ in 0..1000 {
            b.take(1 << 20);
        }
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn limited_rate_paces_consumption() {
        // 1 MiB/s bucket starts full (1 MiB burst); draining 1.5 MiB must
        // take at least ~0.4s of refill.
        let mut b = TokenBucket::new(1 << 20);
        let t0 = Instant::now();
        for _ in 0..6 {
            b.take(256 << 10);
        }
        assert!(
            t0.elapsed() >= Duration::from_millis(300),
            "elapsed {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn oversized_cost_is_clamped() {
        let mut b = TokenBucket::new(1024);
        let t0 = Instant::now();
        b.take(u64::MAX); // would deadlock without the clamp
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn virtual_clock_drives_refill() {
        let sim = Arc::new(SimClock::new());
        let mut b = TokenBucket::with_clock(1000, sim.clone());
        b.take(1000); // drain the initial burst, no waiting needed
        let sim2 = sim.clone();
        let driver = std::thread::spawn(move || {
            // 500 virtual ms in steps: refills 500 tokens over ~50ms wall
            for _ in 0..50 {
                std::thread::sleep(Duration::from_millis(1));
                sim2.advance(10);
            }
        });
        b.take(500); // blocks until virtual refill covers it
        driver.join().unwrap();
        assert!(sim.now_ms() >= 500);
    }
}
