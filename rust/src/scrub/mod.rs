//! Online scrub & repair — the distributed, rate-limited integrity
//! subsystem.
//!
//! The paper's robustness story (flag-based asynchronous consistency plus
//! the GC cross-match, §2.4) recovers reference errors and lost chunks
//! *reactively*. This module adds the proactive half: every server
//! continuously re-verifies its own slice of the dedup state while
//! foreground I/O keeps flowing — no cluster-wide quiesce, no full
//! CIT/OMAP dumps shipped to a central checker.
//!
//! Each OSD runs one **scrub worker thread** that walks the local CIT in
//! fingerprint-ordered **windows** (see [`ScrubOptions::window`]):
//!
//! * **Light scrub** — for every window it resolves the cluster-wide OMAP
//!   reference count of each fingerprint via batched [`Req::CountRefs`]
//!   fabric messages, each answered from the holder's backreference
//!   index in O(referrers) (instead of the old full-OMAP table walk,
//!   see DESIGN.md §6), fixes refcount
//!   drift with a compare-and-swap update, confirms commit flags against
//!   chunk presence, and restores missing primaries from replica copies.
//! * **Deep scrub** — additionally re-reads every chunk, re-fingerprints
//!   the whole window through the batched SHA-1 provider (the same
//!   [`crate::runtime`] path the write path uses), compares primary
//!   content against replica copies ([`Req::VerifyCopy`]), and repairs
//!   bit-rot and lost copies from a healthy replica.
//!
//! **Rate limiting** — every probe and every byte re-read is charged to a
//! per-pass [`rate::TokenBucket`] (the `ScrubOptions` knob) *and* to the
//! server's shared maintenance budget
//! ([`crate::sched::flow::FlowController`]), so scrub bandwidth is capped
//! and never collides blindly with rebalance or GC over the same disks
//! and lanes.
//!
//! **Backpressure** — deep-scrub replica comparisons are pipelined under
//! an AIMD window; a replica lane over its `VerifyCopy` in-flight cap
//! sheds the probe with a `Busy` NACK, which shrinks the sender's window
//! and schedules a backed-off retry ([`crate::sched::backpressure`]).
//!
//! **Scheduling** — one-shot passes start via
//! [`crate::api::Cluster::start_scrub`]; the periodic cadence (cron-style
//! per-OSD schedule with skip-if-running semantics) lives in
//! [`crate::sched`].
//!
//! **Epoch awareness** — each window records the map epoch before
//! scanning and discards its findings if a rebalance bumped the epoch
//! mid-window; entries whose content home moved away are counted
//! *misplaced* and left for the rebalancer, never "repaired".
//!
//! **Online safety** — a foreground write takes chunk references *before*
//! its OMAP entry lands, so a naive online cross-match would see phantom
//! leaks. Refcount fixes are therefore double-read (suspects are
//! re-counted after a short delay) and applied with a CAS that aborts if
//! the CIT entry moved underneath the scrubber. Residual drift from
//! still-in-flight transactions is caught by the next pass.
//!
//! Orchestration lives in [`crate::api::Cluster::start_scrub`] /
//! [`scrub_status`](crate::api::Cluster::scrub_status) /
//! [`scrub_wait`](crate::api::Cluster::scrub_wait): a cluster scrub first
//! runs the **ensure phase** ([`ensure_referenced`]) on every server so
//! every referenced fingerprint has a CIT entry at its home (the audit's
//! "referenced but no CIT entry" case), then starts the per-server
//! window walks, which converge the cluster back to a clean
//! [`crate::api::AuditReport`].

pub mod rate;

use crate::cluster::ServerId;
use crate::dedup::cit::{CitEntry, CommitFlag};
use crate::dedup::engine::{self, chunk_copy_key, DedupMode};
use crate::dedup::fingerprint::Fingerprint;
use crate::error::{Error, Result};
use crate::failure::CrashPoint;
use crate::metrics::Metrics;
use crate::net::{Lane, Pending};
use crate::sched::backpressure::VerifyWindow;
use crate::sched::flow::MaintClass;
use crate::storage::osd::OsdShared;
use crate::storage::proto::{Req, Resp};
use self::rate::TokenBucket;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Byte-equivalent cost charged per light-scrub entry probe.
const LIGHT_ENTRY_COST: u64 = 64;
/// Delay before re-observing a suspected refcount mismatch (lets
/// in-flight write transactions land their OMAP entries).
const CONFIRM_DELAY: Duration = Duration::from_millis(20);
/// Worker poll interval for new jobs / shutdown.
const POLL: Duration = Duration::from_millis(50);
/// Initial AIMD window of pipelined `VerifyCopy` probes per deep-scrub
/// batch (see [`crate::sched::backpressure`]).
const VERIFY_WINDOW_INIT: usize = 8;
/// Max AIMD window of pipelined `VerifyCopy` probes.
const VERIFY_WINDOW_MAX: usize = 32;
/// Retry budget per `Busy`-NACKed probe before it is left for the next
/// pass (generous: with the window shrunk to 1 the storm always drains).
const VERIFY_MAX_ATTEMPTS: u32 = 100;
/// Base wall backoff after a `Busy` NACK (doubles per attempt, capped at
/// `BASE << 6` ≈ 12.8 ms — pacing only, never an assertion surface).
const VERIFY_BACKOFF_BASE_US: u64 = 200;

/// Scrub depth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScrubKind {
    /// Refcounts, commit flags, chunk presence (unconfirmed flags are
    /// content-verified before flipping, so a quarantined corrupt chunk
    /// is never re-validated by presence alone).
    Light,
    /// Light checks plus data re-read, re-fingerprint and replica
    /// comparison/repair.
    Deep,
}

/// Parameters of one scrub pass.
#[derive(Clone, Debug)]
pub struct ScrubOptions {
    /// Depth of the pass.
    pub kind: ScrubKind,
    /// CIT entries examined per window (epoch checks and refcount
    /// resolution happen at window granularity).
    pub window: usize,
    /// Token-bucket budget in bytes/second (light probes are charged a
    /// small byte-equivalent); 0 = unlimited.
    pub rate_bytes_per_sec: u64,
}

impl ScrubOptions {
    /// Unlimited-rate light scrub.
    pub fn light() -> Self {
        ScrubOptions {
            kind: ScrubKind::Light,
            window: 256,
            rate_bytes_per_sec: 0,
        }
    }

    /// Unlimited-rate deep scrub.
    pub fn deep() -> Self {
        ScrubOptions {
            kind: ScrubKind::Deep,
            ..Self::light()
        }
    }

    /// Cap scrub bandwidth (bytes/second; 0 = unlimited).
    pub fn with_rate(mut self, bytes_per_sec: u64) -> Self {
        self.rate_bytes_per_sec = bytes_per_sec;
        self
    }

    /// Entries per window (minimum 1).
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }
}

impl Default for ScrubOptions {
    fn default() -> Self {
        Self::light()
    }
}

/// Lifecycle of a server's scrub job.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum ScrubState {
    /// No scrub has run since boot (or the last status reset).
    #[default]
    Idle,
    /// Accepted, waiting for the worker thread to pick it up.
    Queued,
    /// The window walk is in progress.
    Running,
    /// Completed the full CIT walk.
    Done,
    /// Aborted (server died mid-pass, or an I/O error).
    Failed(String),
}

/// One server's scrub progress snapshot.
#[derive(Clone, Debug, Default)]
pub struct ScrubStatus {
    /// Server id.
    pub server: u32,
    /// Job lifecycle state.
    pub state: ScrubState,
    /// True when the current/last pass is a deep scrub.
    pub deep: bool,
    /// Windows completed.
    pub windows: u64,
    /// CIT entries examined.
    pub chunks_checked: u64,
    /// Bytes re-read and re-fingerprinted (deep only).
    pub bytes_verified: u64,
    /// Digest mismatches found on primary chunk data (deep only).
    pub corruptions_found: u64,
    /// Data repairs applied (restored primaries, rewritten bit-rot,
    /// re-pushed replica copies).
    pub repaired: u64,
    /// Commit flags confirmed valid against present data.
    pub flags_confirmed: u64,
    /// CIT refcounts re-synchronized to the cluster-wide OMAP count.
    pub refs_fixed: u64,
    /// Entries skipped because the map moved their home elsewhere
    /// (the rebalancer owns those).
    pub misplaced: u64,
    /// Referenced chunks with no healthy copy anywhere (quarantined
    /// behind an invalid flag).
    pub lost: u64,
    /// Replica-copy probes abandoned after the backpressure retry
    /// budget (left for the next pass; 0 in steady state).
    pub copies_unverified: u64,
    /// Windows whose refcount resolution was skipped (peer down).
    pub windows_skipped: u64,
    /// Windows discarded because the map epoch changed mid-window.
    pub epoch_restarts: u64,
    /// Pass start (ms since cluster start).
    pub started_ms: u64,
    /// Pass end (ms since cluster start; 0 while running).
    pub finished_ms: u64,
}

/// Per-server scrub control block: job hand-off to the worker thread plus
/// the externally visible status. Volatile (a crash aborts the pass).
#[derive(Default)]
pub struct ScrubCtl {
    inner: Mutex<CtlInner>,
    cv: Condvar,
}

#[derive(Default)]
struct CtlInner {
    queued: Option<ScrubOptions>,
    status: ScrubStatus,
}

impl ScrubCtl {
    /// Idle control block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Idle control block that already knows its server id, so a
    /// [`Error::ScrubBusy`] rejection names the busy server even before
    /// the first pass ran.
    pub fn for_server(server: u32) -> Self {
        let ctl = Self::default();
        ctl.inner.lock().unwrap().status.server = server;
        ctl
    }

    /// Queue a scrub pass. Explicit skip-if-running semantics: while a
    /// pass is queued or running the call is rejected with the typed
    /// [`Error::ScrubBusy`] — the in-flight pass's status is never
    /// clobbered and passes never stack. Callers (the maintenance
    /// scheduler, admin retries) decide whether to skip or re-arm.
    pub fn start(&self, opts: ScrubOptions) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.queued.is_some() || matches!(g.status.state, ScrubState::Queued | ScrubState::Running)
        {
            return Err(Error::ScrubBusy(g.status.server));
        }
        g.status = ScrubStatus {
            server: g.status.server,
            state: ScrubState::Queued,
            deep: opts.kind == ScrubKind::Deep,
            ..Default::default()
        };
        g.queued = Some(opts);
        self.cv.notify_one();
        Ok(())
    }

    /// Current status snapshot.
    pub fn status(&self) -> ScrubStatus {
        self.inner.lock().unwrap().status.clone()
    }

    fn take_job(&self, timeout: Duration) -> Option<ScrubOptions> {
        let mut g = self.inner.lock().unwrap();
        if g.queued.is_none() {
            g = self.cv.wait_timeout(g, timeout).unwrap().0;
        }
        g.queued.take()
    }

    fn update(&self, f: impl FnOnce(&mut ScrubStatus)) {
        f(&mut self.inner.lock().unwrap().status);
    }

    /// Crash semantics (called from `Osd::kill`): any in-flight job is
    /// volatile and dies with the process — the queued hand-off is
    /// dropped and its progress zeroed. A pass already running is
    /// aborted by the worker's own per-item liveness checks.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.queued = None;
        if matches!(g.status.state, ScrubState::Queued | ScrubState::Running) {
            g.status = ScrubStatus {
                server: g.status.server,
                state: ScrubState::Failed("server crashed".into()),
                deep: g.status.deep,
                ..Default::default()
            };
        }
    }
}

/// The per-server scrub worker thread body (spawned by
/// [`crate::storage::osd::Osd::spawn`]). Waits for queued jobs and runs
/// one full CIT walk per job.
pub fn scrub_loop(sh: Arc<OsdShared>, sd: Arc<AtomicBool>) {
    while !sd.load(Ordering::SeqCst) {
        let Some(opts) = sh.scrub.take_job(POLL) else {
            continue;
        };
        let started = sh.now_ms();
        sh.scrub.update(|st| {
            st.server = sh.id.0;
            st.state = ScrubState::Running;
            st.started_ms = started;
        });
        let outcome = run_pass(&sh, &opts);
        let finished = sh.now_ms();
        sh.scrub.update(|st| {
            st.finished_ms = finished;
            st.state = match &outcome {
                Ok(()) => ScrubState::Done,
                Err(e) => ScrubState::Failed(e.to_string()),
            };
        });
    }
}

/// One full pass: drain the write path's repair debt first (fingerprints
/// whose replica fan-out hit a dead or `Busy` peer — scrubbed at deep
/// strength regardless of the pass kind, since only the replica
/// comparison can close a copy gap), then walk the CIT snapshot in
/// fingerprint order, one window at a time.
fn run_pass(sh: &OsdShared, opts: &ScrubOptions) -> Result<()> {
    let deep = opts.kind == ScrubKind::Deep;
    let mut bucket = TokenBucket::with_clock(opts.rate_bytes_per_sec, sh.clock.clone());
    let mut debt = sh.take_repair_debt();
    if !debt.is_empty() {
        debt.sort();
        debt.dedup();
        for window in debt.chunks(opts.window.max(1)) {
            ensure_alive(sh)?;
            let t0 = Instant::now();
            scrub_window(sh, /*deep=*/ true, &mut bucket, window)?;
            sh.metrics.scrub_window_latency.record(t0.elapsed());
            sh.scrub.update(|st| st.windows += 1);
        }
    }
    let mut fps = sh.shard.cit_fingerprints()?;
    fps.sort();
    for window in fps.chunks(opts.window.max(1)) {
        ensure_alive(sh)?;
        let t0 = Instant::now();
        scrub_window(sh, deep, &mut bucket, window)?;
        sh.metrics.scrub_window_latency.record(t0.elapsed());
        sh.scrub.update(|st| st.windows += 1);
    }
    Ok(())
}

/// A killed/crashed server must stop scrubbing at once — a dead machine
/// issues no further disk writes or fabric calls. Checked per item, not
/// just per window, so the crash model matches the lanes'.
fn ensure_alive(sh: &OsdShared) -> Result<()> {
    if sh.injector.is_dead() {
        Err(Error::ServerDown(sh.id.0))
    } else {
        Ok(())
    }
}

fn scrub_window(
    sh: &OsdShared,
    deep: bool,
    bucket: &mut TokenBucket,
    window: &[Fingerprint],
) -> Result<()> {
    let epoch0 = sh.map.read().unwrap().epoch;

    // ---- select this window's targets (skip misplaced entries) ----
    let mut targets: Vec<Fingerprint> = Vec::with_capacity(window.len());
    for fp in window {
        ensure_alive(sh)?;
        let Some(entry) = sh.shard.cit_get(fp)? else {
            continue; // reclaimed since the snapshot
        };
        if sh.cfg.dedup == DedupMode::ClusterWide
            && sh.chunk_chain(fp.placement_key()).first() != Some(&sh.id)
        {
            // the map moved this fingerprint's home; rebalance owns the
            // move — flagging it here would be a false "misplaced" find.
            sh.scrub.update(|st| st.misplaced += 1);
            continue;
        }
        let cost = if deep {
            (entry.len as u64).max(LIGHT_ENTRY_COST)
        } else {
            LIGHT_ENTRY_COST
        };
        // per-pass cap (ScrubOptions knob) and the cluster's shared
        // maintenance budget both see every byte
        bucket.take(cost);
        sh.charge_maint(MaintClass::Scrub, cost);
        targets.push(*fp);
        sh.scrub.update(|st| st.chunks_checked += 1);
        Metrics::add(&sh.metrics.scrub_chunks_checked, 1);
    }
    if targets.is_empty() {
        return Ok(());
    }

    match reconcile_refcounts(sh, epoch0, &targets)? {
        ReconcileVerdict::Done { fixed } => sh.scrub.update(|st| st.refs_fixed += fixed),
        ReconcileVerdict::PeerDown => sh.scrub.update(|st| st.windows_skipped += 1),
        ReconcileVerdict::EpochMoved => sh.scrub.update(|st| st.epoch_restarts += 1),
    }
    check_presence_and_data(sh, deep, &targets)?;
    Ok(())
}

/// Outcome of one [`reconcile_refcounts`] window.
pub(crate) enum ReconcileVerdict {
    /// The window's counts were resolved; `fixed` refcounts were
    /// CAS-repaired.
    Done {
        /// Refcounts re-synchronized to the cluster-wide count.
        fixed: u64,
    },
    /// A reference holder was unreachable — the window was skipped (a
    /// count with a blind spot must never zero live references).
    PeerDown,
    /// The map epoch moved mid-window — findings discarded (reference
    /// homes may have moved).
    EpochMoved,
}

/// Light-scrub core, shared with the recovery backfill
/// ([`crate::recovery`]): resolve every target's cluster-wide OMAP
/// reference count over the fabric and CAS-fix drifted CIT refcounts.
/// Servers marked `Out` are excluded from the count — their references
/// left scope with them (surviving records are re-homed by recovery),
/// matching what the audit can see.
pub(crate) fn reconcile_refcounts(
    sh: &OsdShared,
    epoch0: u64,
    targets: &[Fingerprint],
) -> Result<ReconcileVerdict> {
    let Some(expected) = cluster_ref_counts(sh, targets)? else {
        return Ok(ReconcileVerdict::PeerDown);
    };

    // first read: collect suspects (fp, wanted, observed refcount)
    let mut suspects: Vec<(Fingerprint, u64, u64)> = Vec::new();
    for (i, fp) in targets.iter().enumerate() {
        let Some(cur) = sh.shard.cit_get(fp)? else {
            continue;
        };
        if cur.refcount != expected[i] {
            suspects.push((*fp, expected[i], cur.refcount));
        }
    }
    if suspects.is_empty() {
        return Ok(ReconcileVerdict::Done { fixed: 0 });
    }

    // double-read: an in-flight write takes chunk references before its
    // OMAP entry lands, so a single observation cannot distinguish a
    // leak from a transaction in progress. (Virtual clocks yield instead
    // of sleeping — residual drift settles on a later pass either way.)
    sh.clock.sleep(CONFIRM_DELAY);
    let suspect_fps: Vec<Fingerprint> = suspects.iter().map(|s| s.0).collect();
    let Some(confirm) = cluster_ref_counts(sh, &suspect_fps)? else {
        return Ok(ReconcileVerdict::PeerDown);
    };
    if sh.map.read().unwrap().epoch != epoch0 {
        // rebalance mid-window: reference homes may have moved; discard.
        return Ok(ReconcileVerdict::EpochMoved);
    }
    let mut total_fixed = 0u64;
    for (k, (fp, want, seen)) in suspects.iter().enumerate() {
        ensure_alive(sh)?;
        if confirm[k] != *want {
            continue; // still moving; the next pass settles it
        }
        let mut fixed = false;
        sh.shard.cit_update(fp, |cur| {
            cur.map(|mut e| {
                if e.refcount == *seen {
                    e.refcount = *want;
                    fixed = true;
                }
                e
            })
        })?;
        if fixed {
            total_fixed += 1;
        }
    }
    Ok(ReconcileVerdict::Done { fixed: total_fixed })
}

/// Presence/flag agreement for every referenced target, plus (deep) data
/// re-read, batched re-fingerprint and replica comparison/repair.
fn check_presence_and_data(sh: &OsdShared, deep: bool, targets: &[Fingerprint]) -> Result<()> {
    let mut reads: Vec<(Fingerprint, Vec<u8>)> = Vec::new();
    // stored-but-unconfirmed entries are confirmed by *content*: gather
    // the window's candidates and re-fingerprint them through one
    // batched provider call instead of one scalar hash per chunk
    let mut confirms: Vec<(Fingerprint, Vec<u8>)> = Vec::new();
    for fp in targets {
        ensure_alive(sh)?;
        let Some(entry) = sh.shard.cit_get(fp)? else {
            continue;
        };
        if entry.refcount == 0 {
            continue; // unreferenced: aging + reclaim is GC's business
        }
        if sh.cfg.dedup == DedupMode::Central
            && sh.chunk_chain(fp.placement_key()).first() != Some(&sh.id)
        {
            // central comparator: the data lives raw on another server.
            // The light pass leaves it alone; the deep pass verifies it
            // in place over the fabric (`VerifyRaw` — the holder hashes
            // locally, only the verdict crosses the wire) and repairs
            // through the recovery fetch path. This closes the old §5
            // known limit: central-mode raw data on non-metadata servers
            // is deep-scrubbed like everything else.
            if deep {
                deep_verify_remote_raw(sh, fp, &entry)?;
            }
            continue;
        }
        let present = sh.store.stat(&fp.to_bytes())?;
        match (entry.flag, present) {
            (CommitFlag::Valid, true) => {}
            (CommitFlag::Pending, true) => {
                // awaiting its strong digest (DESIGN.md §16): the flag
                // is the tier-2 migrator's to flip, but scrub makes
                // sure the chunk stays on the migration queue; the deep
                // pass below still verifies its bytes against the weak
                // identity.
                sh.fpipe.enqueue(*fp);
            }
            (CommitFlag::Invalid, true) => {
                // stored but never confirmed (e.g. a crash wiped the
                // registration queue) — or rot deep scrub quarantined
                // earlier. Confirm by *content*, not mere presence, so
                // the quarantine of a corrupt chunk is never undone;
                // hashed after the loop in one batched call.
                let data = sh.store.get(&fp.to_bytes())?.unwrap_or_default();
                confirms.push((*fp, data));
                continue; // the batch pass queues its own deep read
            }
            (_, false) => {
                // lost primary: restore from a digest-verified replica.
                if !repair_primary_from_copy(sh, fp)? {
                    sh.scrub.update(|st| st.lost += 1);
                    if entry.flag == CommitFlag::Valid {
                        // quarantine: audit must not see a valid flag
                        // pointing at missing data; GC keeps cross-
                        // matching it in case a replica reappears.
                        sh.charge_meta_io();
                        sh.shard.cit_set_flag(fp, CommitFlag::Invalid, sh.now_ms())?;
                        // coherence: a quarantined chunk must not keep
                        // serving from the cache
                        engine::invalidate_chunk(sh, fp);
                    }
                    continue;
                }
            }
        }
        if deep {
            if let Some(data) = sh.store.get(&fp.to_bytes())? {
                reads.push((*fp, data));
            }
        }
    }

    if !confirms.is_empty() {
        confirm_flags_batched(sh, deep, &mut reads, confirms)?;
    }
    if !reads.is_empty() {
        deep_verify(sh, reads)?;
    }
    Ok(())
}

/// Content-confirm one window's stored-but-invalid entries with a
/// single batched [`crate::dedup::fingerprint::FingerprintProvider`]
/// call: matches flip Valid (and join the deep reads), mismatches go
/// through the corruption repair path exactly as the per-chunk confirm
/// did.
fn confirm_flags_batched(
    sh: &OsdShared,
    deep: bool,
    reads: &mut Vec<(Fingerprint, Vec<u8>)>,
    confirms: Vec<(Fingerprint, Vec<u8>)>,
) -> Result<()> {
    let digests = {
        let refs: Vec<&[u8]> = confirms.iter().map(|(_, d)| d.as_slice()).collect();
        sh.provider.digests(&refs)
    };
    for ((fp, data), got) in confirms.into_iter().zip(digests) {
        ensure_alive(sh)?;
        if got == fp {
            sh.charge_meta_io();
            sh.shard.cit_set_flag(&fp, CommitFlag::Valid, sh.now_ms())?;
            sh.scrub.update(|st| st.flags_confirmed += 1);
            if deep {
                reads.push((fp, data));
            }
        } else {
            sh.scrub.update(|st| st.corruptions_found += 1);
            Metrics::add(&sh.metrics.scrub_corruptions_found, 1);
            if repair_primary_from_copy(sh, &fp)? {
                if deep {
                    if let Some(good) = sh.store.get(&fp.to_bytes())? {
                        reads.push((fp, good));
                    }
                }
            } else {
                sh.scrub.update(|st| st.lost += 1);
                // stays quarantined behind the flag
            }
        }
    }
    Ok(())
}

/// Replace a corrupt or missing primary chunk from a digest-verified
/// replica copy and flip its flag valid. Returns false when no healthy
/// copy exists anywhere — the chain is tried first, then the recovery
/// fetch path sweeps every other live server (after an out-transition
/// the surviving copies may sit on servers the new chain no longer
/// names).
fn repair_primary_from_copy(sh: &OsdShared, fp: &Fingerprint) -> Result<bool> {
    if sh.injector.maybe_crash(CrashPoint::BeforeScrubRepair) {
        return Err(Error::ServerDown(sh.id.0));
    }
    let Some(good) = crate::recovery::fetch_any_copy(sh, fp)? else {
        return Ok(false);
    };
    // coherence: the local bytes are about to be rewritten
    engine::invalidate_chunk(sh, fp);
    sh.store.put(&fp.to_bytes(), &good)?;
    if sh.injector.maybe_crash(CrashPoint::AfterScrubRepair) {
        return Err(Error::ServerDown(sh.id.0));
    }
    sh.charge_meta_io();
    let flag = if crate::dedup::fpipe::is_pending(fp) {
        // a pending identity stays pending: its strong digest is still
        // unresolved, so a repair must not admit it to the dedup domain
        // — put it back on the migration queue instead
        sh.fpipe.enqueue(*fp);
        CommitFlag::Pending
    } else {
        CommitFlag::Valid
    };
    sh.shard.cit_set_flag(fp, flag, sh.now_ms())?;
    sh.scrub.update(|st| st.repaired += 1);
    Metrics::add(&sh.metrics.scrub_repaired, 1);
    Metrics::add(&sh.metrics.repairs, 1);
    Ok(true)
}

/// Central-mode deep scrub of a raw chunk stored on a non-metadata
/// server: ask the data home to hash its copy ([`Req::VerifyRaw`]);
/// on rot or loss, re-ship surviving bytes found through the recovery
/// fetch path, else quarantine the CIT entry behind an invalid flag so
/// reads fail loudly instead of serving holes.
fn deep_verify_remote_raw(sh: &OsdShared, fp: &Fingerprint, entry: &CitEntry) -> Result<()> {
    let chain = sh.chunk_chain(fp.placement_key());
    let Some(home) = chain.first().copied() else {
        return Ok(());
    };
    let Ok(addr) = sh.dir.lookup(home, Lane::Backend) else {
        return Ok(()); // dead home: nothing to verify until it returns
    };
    // VerifyRaw does strictly-local hashing at the holder (like
    // VerifyCopy on the replica lane); the scrub worker stays a pure
    // client of the lane graph
    let req = Req::VerifyRaw {
        key: fp.to_bytes().to_vec(),
        fp: *fp,
    };
    let size = req.wire_size();
    let (present, matches) = match addr.call(req, size) {
        Ok(Resp::CopyState { present, matches }) => (present, matches),
        Ok(_) | Err(_) => return Ok(()), // dead home: next pass verifies
    };
    sh.scrub.update(|st| st.bytes_verified += entry.len as u64);
    Metrics::add(&sh.metrics.scrub_bytes_verified, entry.len as u64);
    if present && matches {
        return Ok(());
    }
    if present {
        sh.scrub.update(|st| st.corruptions_found += 1);
        Metrics::add(&sh.metrics.scrub_corruptions_found, 1);
    }
    if sh.injector.maybe_crash(CrashPoint::BeforeScrubRepair) {
        return Err(Error::ServerDown(sh.id.0));
    }
    match crate::recovery::fetch_any_copy(sh, fp)? {
        Some(good) => {
            let req = Req::StoreRaw {
                key: fp.to_bytes().to_vec(),
                data: good,
            };
            let size = req.wire_size();
            if matches!(addr.call(req, size), Ok(Resp::Ok)) {
                sh.scrub.update(|st| st.repaired += 1);
                Metrics::add(&sh.metrics.scrub_repaired, 1);
                Metrics::add(&sh.metrics.repairs, 1);
            }
        }
        None => {
            // central fans no copies out, so rot on a raw holder is
            // usually unrecoverable: quarantine rather than re-validate
            sh.scrub.update(|st| st.lost += 1);
            sh.charge_meta_io();
            sh.shard.cit_set_flag(fp, CommitFlag::Invalid, sh.now_ms())?;
            engine::invalidate_chunk(sh, fp);
        }
    }
    Ok(())
}

/// Deep-scrub verification of one window's chunk reads: one batched
/// digest call, then per-chunk corruption repair, then one pipelined,
/// backpressure-aware replica comparison over the whole window
/// ([`verify_copies_windowed`]).
fn deep_verify(sh: &OsdShared, mut reads: Vec<(Fingerprint, Vec<u8>)>) -> Result<()> {
    // pending identities (DESIGN.md §16) are verified against their
    // weak identity — the strong digest is exactly what tier 2 has not
    // computed yet — everything else through one batched digest call
    let digests = {
        let refs: Vec<&[u8]> = reads
            .iter()
            .filter(|(fp, _)| !crate::dedup::fpipe::is_pending(fp))
            .map(|(_, d)| d.as_slice())
            .collect::<Vec<_>>();
        sh.provider.digests(&refs)
    };
    let mut strong = digests.into_iter();
    // `intact[i]` ⇔ reads[i] holds known-good primary bytes afterwards
    let mut intact = vec![false; reads.len()];
    for i in 0..reads.len() {
        ensure_alive(sh)?;
        let fp = reads[i].0;
        let len = reads[i].1.len() as u64;
        sh.scrub.update(|st| st.bytes_verified += len);
        Metrics::add(&sh.metrics.scrub_bytes_verified, len);
        let ok = if crate::dedup::fpipe::is_pending(&fp) {
            crate::dedup::fpipe::chunk_matches(sh, &fp, &reads[i].1)
        } else {
            strong.next().map(|got| got == fp).unwrap_or(false)
        };
        if ok {
            intact[i] = true;
            continue;
        }
        // bit-rot on the primary copy.
        sh.scrub.update(|st| st.corruptions_found += 1);
        Metrics::add(&sh.metrics.scrub_corruptions_found, 1);
        if repair_primary_from_copy(sh, &fp)? {
            if let Some(good) = sh.store.get(&fp.to_bytes())? {
                reads[i].1 = good;
                intact[i] = true;
            }
        } else {
            // no healthy copy anywhere: quarantine behind an invalid
            // flag rather than serving rot as valid (the content-based
            // flag confirm above keeps the quarantine from being
            // undone by later passes).
            sh.scrub.update(|st| st.lost += 1);
            sh.charge_meta_io();
            sh.shard.cit_set_flag(&fp, CommitFlag::Invalid, sh.now_ms())?;
            engine::invalidate_chunk(sh, &fp);
        }
    }

    // Replica comparison for every chunk whose primary bytes are good
    // (central-mode raw placement never fans out copies; the write path
    // never fans out a copy to the primary itself). The per-chunk copy
    // target is *banded* — the redundancy policy applied to the chunk's
    // current refcount — so scrub heals to the same count the write path
    // planted and the online promote/demote hooks steer toward
    // (DESIGN.md §15). Chain slots beyond the target hold stale copies
    // left by a missed demotion (e.g. the holder was down): the scrub
    // demotes them, so copy counts converge from above as well as below.
    let mut tasks: Vec<CopyTask> = Vec::new();
    let mut demotions: Vec<(Fingerprint, ServerId)> = Vec::new();
    if sh.cfg.dedup != DedupMode::Central {
        for (i, ok) in intact.iter().enumerate() {
            if !*ok {
                continue;
            }
            let fp = reads[i].0;
            let refcount = sh.shard.cit_get(&fp)?.map(|e| e.refcount).unwrap_or(0);
            let target = sh.redundancy_target(refcount);
            let chain = sh.chunk_chain(fp.placement_key());
            for peer in chain.iter().skip(1).take(target.saturating_sub(1)) {
                if *peer != sh.id {
                    tasks.push(CopyTask {
                        peer: *peer,
                        read_idx: i,
                        attempts: 0,
                    });
                }
            }
            if !sh.cfg.redundancy.is_flat() {
                for peer in chain.iter().skip(target.max(1)) {
                    if *peer != sh.id {
                        demotions.push((fp, *peer));
                    }
                }
            }
        }
    }
    verify_copies_windowed(sh, &reads, tasks)?;
    demote_excess_copies(sh, &demotions);
    Ok(())
}

/// Drop stale redundancy copies on chain slots beyond a chunk's banded
/// target (a demotion the online hook could not deliver — dead holder,
/// dry flow budget). The holder consults its plant registry
/// ([`Req::DemoteCopy`]): a locality plant under the same key was never
/// counted toward the target and survives. Best-effort — an unreachable
/// holder is retried by its or our next pass.
fn demote_excess_copies(sh: &OsdShared, demotions: &[(Fingerprint, ServerId)]) {
    for (fp, peer) in demotions {
        let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) else {
            continue;
        };
        let req = Req::DemoteCopy { fp: *fp };
        let size = req.wire_size();
        if let Ok(Resp::Ok) = addr.call(req, size) {
            Metrics::add(&sh.metrics.redundancy_demotions, 1);
        }
    }
}

/// One pending replica comparison of a deep-scrub window: chunk
/// `read_idx` of the window's reads, checked on `peer`.
struct CopyTask {
    peer: ServerId,
    read_idx: usize,
    attempts: u32,
}

/// Pipelined replica comparison under an AIMD send window: up to
/// [`VerifyWindow::size`] `VerifyCopy` probes are in flight at once;
/// [`Resp::Busy`] NACKs from gated replica lanes halve the window and
/// requeue the probe (with exponential wall backoff) until a verdict
/// arrives — backpressure delays verification, it never skips it.
/// Missing or corrupt copies are re-pushed from the known-good primary
/// bytes.
fn verify_copies_windowed(
    sh: &OsdShared,
    reads: &[(Fingerprint, Vec<u8>)],
    tasks: Vec<CopyTask>,
) -> Result<()> {
    if tasks.is_empty() {
        return Ok(());
    }
    let mut win = VerifyWindow::new(VERIFY_WINDOW_INIT, VERIFY_WINDOW_MAX);
    let mut queue: VecDeque<CopyTask> = tasks.into();
    while !queue.is_empty() {
        ensure_alive(sh)?;
        // scatter up to one window of probes
        let mut inflight: Vec<(CopyTask, Pending<Resp>)> = Vec::new();
        while inflight.len() < win.size() {
            let Some(task) = queue.pop_front() else {
                break;
            };
            let fp = reads[task.read_idx].0;
            let Ok(addr) = sh.dir.lookup(task.peer, Lane::Replica) else {
                continue; // dead peer: nothing to fix right now
            };
            let req = Req::VerifyCopy {
                key: chunk_copy_key(&fp),
                fp,
            };
            let size = req.wire_size();
            if let Ok(pending) = addr.send(req, size) {
                inflight.push((task, pending));
            }
        }
        if inflight.is_empty() {
            break; // every remaining peer is unreachable
        }
        // gather verdicts; Busy NACKs shrink the window and requeue
        let mut backoff_shift = 0u32;
        for (mut task, pending) in inflight {
            match pending.wait() {
                Ok(Resp::CopyState { present, matches }) => {
                    win.on_ok();
                    if !(present && matches) {
                        push_copy_repair(sh, &reads[task.read_idx], task.peer)?;
                    }
                }
                Ok(Resp::Busy) => {
                    if win.on_busy() {
                        Metrics::add(&sh.metrics.backpressure_window_shrinks, 1);
                    }
                    task.attempts += 1;
                    if task.attempts >= VERIFY_MAX_ATTEMPTS {
                        // not silent: the pass reports the unverified
                        // copy so "clean" is never claimed for it
                        sh.scrub.update(|st| st.copies_unverified += 1);
                        Metrics::add(&sh.metrics.backpressure_gave_up, 1);
                    } else {
                        Metrics::add(&sh.metrics.backpressure_retries, 1);
                        backoff_shift = backoff_shift.max(task.attempts.min(6));
                        queue.push_back(task);
                    }
                }
                Ok(_) | Err(_) => {} // dead peer: nothing to fix right now
            }
        }
        if backoff_shift > 0 {
            std::thread::sleep(Duration::from_micros(
                VERIFY_BACKOFF_BASE_US << backoff_shift,
            ));
        }
    }
    Ok(())
}

/// Re-push one known-good primary's bytes to a peer whose replica copy
/// was missing or corrupt.
fn push_copy_repair(sh: &OsdShared, read: &(Fingerprint, Vec<u8>), peer: ServerId) -> Result<()> {
    let (fp, data) = read;
    if sh.injector.maybe_crash(CrashPoint::BeforeScrubRepair) {
        return Err(Error::ServerDown(sh.id.0));
    }
    let Ok(addr) = sh.dir.lookup(peer, Lane::Replica) else {
        Metrics::add(&sh.metrics.replica_push_failures, 1);
        sh.note_repair_debt(*fp);
        return Ok(());
    };
    let req = Req::PutCopy {
        key: chunk_copy_key(fp),
        data: data.clone(),
    };
    let size = req.wire_size();
    if matches!(addr.call(req, size), Ok(Resp::Ok)) {
        sh.scrub.update(|st| st.repaired += 1);
        Metrics::add(&sh.metrics.scrub_repaired, 1);
        Metrics::add(&sh.metrics.repairs, 1);
    } else {
        // dead peer or shed push: counted, and queued so the next pass
        // re-tries this fingerprint ahead of the full walk
        Metrics::add(&sh.metrics.replica_push_failures, 1);
        sh.note_repair_debt(*fp);
    }
    Ok(())
}

/// Fetch a replica copy whose content actually matches `fp` (a corrupt
/// replica must never be used to "repair" the primary). Walks the
/// current placement chain; [`crate::recovery::fetch_any_copy`] layers
/// the off-chain sweep on top.
pub(crate) fn fetch_healthy_copy(sh: &OsdShared, fp: &Fingerprint) -> Result<Option<Vec<u8>>> {
    for peer in sh.chunk_chain(fp.placement_key()).iter().skip(1) {
        let data = if *peer == sh.id {
            sh.replica_store.get(&chunk_copy_key(fp))?
        } else if let Ok(addr) = sh.dir.lookup(*peer, Lane::Replica) {
            match addr.call(
                Req::FetchCopy {
                    key: chunk_copy_key(fp),
                },
                64,
            ) {
                Ok(Resp::Data(d)) => Some(d),
                _ => None,
            }
        } else {
            None
        };
        if let Some(d) = data {
            if crate::dedup::fpipe::chunk_matches(sh, fp, &d) {
                return Ok(Some(d));
            }
        }
    }
    Ok(None)
}

/// Resolve the cluster-wide OMAP reference count for each fingerprint.
/// Returns `None` when any holder of references is unreachable (a count
/// with a blind spot must never be used to zero live references).
fn cluster_ref_counts(sh: &OsdShared, fps: &[Fingerprint]) -> Result<Option<Vec<u64>>> {
    let ids: Vec<ServerId> = if sh.cfg.dedup == DedupMode::DiskLocal {
        // disk-local keeps an independent CIT per server, matched only
        // by that server's own references.
        vec![sh.id]
    } else {
        // Out servers are excluded: their references left scope with
        // them (recovery re-homes the surviving records), and the audit
        // cannot see them either — counting must match auditing.
        sh.map
            .read()
            .unwrap()
            .servers
            .iter()
            .filter(|s| s.state != crate::cluster::ServerState::Out)
            .map(|s| s.id)
            .collect()
    };
    let mut totals = vec![0u64; fps.len()];
    for id in ids {
        if id == sh.id {
            for (i, n) in count_refs_local(sh, fps)?.into_iter().enumerate() {
                totals[i] += n;
            }
            continue;
        }
        let Ok(addr) = sh.dir.lookup(id, Lane::Backend) else {
            return Ok(None);
        };
        let req = Req::CountRefs { fps: fps.to_vec() };
        let size = req.wire_size();
        match addr.call(req, size) {
            Ok(Resp::RefCounts(counts)) if counts.len() == fps.len() => {
                for (i, n) in counts.into_iter().enumerate() {
                    totals[i] += n;
                }
            }
            Ok(_) => return Ok(None),
            Err(Error::ServerDown(_)) => return Ok(None),
            Err(e) => return Err(e),
        }
    }
    Ok(Some(totals))
}

/// Count this server's local OMAP references for each fingerprint (the
/// [`Req::CountRefs`] handler). Answered from the backreference index —
/// O(log n + referrers) per fingerprint — instead of the pre-index full
/// OMAP table walk (kept as [`crate::dedup::dmshard::DmShard::count_refs_scan`]
/// for audits and the micro-bench).
pub fn count_refs_local(sh: &OsdShared, fps: &[Fingerprint]) -> Result<Vec<u64>> {
    Metrics::add(&sh.metrics.backref_lookups, fps.len() as u64);
    sh.shard.backref_refs_many(fps)
}

/// Ensure-phase (the [`Req::ScrubEnsure`] handler): every fingerprint
/// referenced by this server's OMAP must have a CIT entry at its home so
/// the home's window walk can see it, fix its refcount and restore its
/// data — the audit's "referenced but no CIT entry" case (e.g. a crash
/// that lost the CIT insert but not the replicated OMAP record).
/// The referenced-fingerprint set comes from one ordered walk of the
/// backreference index; no OMAP entry is decoded.
pub fn ensure_referenced(sh: &OsdShared) -> Result<usize> {
    let referenced = sh.shard.backref_referenced()?;
    let mut ensured = 0usize;
    for (fp, len) in referenced {
        let home = match sh.cfg.dedup {
            DedupMode::ClusterWide => match sh.chunk_chain(fp.placement_key()).first() {
                Some(id) => *id,
                None => continue,
            },
            // disk-local and central keep dedup metadata where the OMAP
            // lives; no-dedup has no CIT at all (nothing to ensure).
            DedupMode::DiskLocal | DedupMode::Central => sh.id,
            DedupMode::None => continue,
        };
        if home == sh.id {
            if ensure_cit_local(sh, &fp, len)? {
                ensured += 1;
            }
            continue;
        }
        let Ok(addr) = sh.dir.lookup(home, Lane::Backend) else {
            continue; // dead home: nothing to ensure until it returns
        };
        let req = Req::EnsureCit { fp, len };
        let size = req.wire_size();
        match addr.call(req, size) {
            Ok(_) => ensured += 1,
            Err(Error::ServerDown(_)) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ensured)
}

/// Create a zero-ref invalid CIT entry if the fingerprint is unknown (the
/// [`Req::EnsureCit`] handler); the refcount reconcile and repair steps
/// then restore count and data. Returns true when an entry was created.
pub fn ensure_cit_local(sh: &OsdShared, fp: &Fingerprint, len: u32) -> Result<bool> {
    let now = sh.now_ms();
    let mut created = false;
    // a pending identity (DESIGN.md §16) is re-created Pending, never
    // Invalid: its strong digest is unresolved, so GC's invalid-entry
    // repair (which re-fingerprints) must not touch it — the migration
    // queue finishes the job instead
    let flag = if crate::dedup::fpipe::is_pending(fp) {
        CommitFlag::Pending
    } else {
        CommitFlag::Invalid
    };
    sh.shard.cit_update(fp, |cur| match cur {
        Some(e) => Some(e),
        None => {
            created = true;
            Some(CitEntry {
                refcount: 0,
                flag,
                len,
                flagged_at_ms: now,
            })
        }
    })?;
    if created && flag == CommitFlag::Pending {
        sh.fpipe.enqueue(*fp);
    }
    Ok(created)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_builders() {
        let o = ScrubOptions::deep().with_rate(1 << 20).with_window(0);
        assert_eq!(o.kind, ScrubKind::Deep);
        assert_eq!(o.rate_bytes_per_sec, 1 << 20);
        assert_eq!(o.window, 1, "window clamps to >= 1");
        assert_eq!(ScrubOptions::default().kind, ScrubKind::Light);
    }

    #[test]
    fn ctl_rejects_concurrent_jobs_with_typed_busy() {
        let ctl = ScrubCtl::for_server(9);
        ctl.start(ScrubOptions::light()).unwrap();
        // the race is rejected with the typed error naming the server,
        // and the in-flight job's status is not clobbered
        assert!(matches!(ctl.start(ScrubOptions::light()), Err(Error::ScrubBusy(9))));
        assert_eq!(ctl.status().state, ScrubState::Queued);
        // worker takes the job; status stays Queued until begin
        assert!(ctl.take_job(Duration::from_millis(1)).is_some());
        // still "Queued" state-wise → a second start is still rejected
        assert!(matches!(ctl.start(ScrubOptions::light()), Err(Error::ScrubBusy(9))));
        ctl.update(|st| st.state = ScrubState::Done);
        ctl.start(ScrubOptions::deep()).unwrap();
        assert!(ctl.status().deep);
    }

    #[test]
    fn take_job_times_out_empty() {
        let ctl = ScrubCtl::new();
        assert!(ctl.take_job(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn status_starts_idle() {
        let st = ScrubCtl::new().status();
        assert_eq!(st.state, ScrubState::Idle);
        assert_eq!(st.chunks_checked, 0);
    }
}
