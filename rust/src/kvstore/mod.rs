//! Embedded key-value store backing each DM-Shard.
//!
//! The paper uses SQLite as the per-OSD DM-Shard backend; offline we build
//! the equivalent substrate ourselves:
//!
//! * [`MemKv`] — in-memory BTree store (tests, benches that exclude disk).
//! * [`LogKv`] — bitcask-style persistent store: an append-only log of
//!   CRC-checked records plus an in-memory index, recovery by scan (torn
//!   tails are truncated at the first bad record), tombstoned deletes and
//!   compaction. This gives the consistency experiments honest crash
//!   semantics without any journaling — matching the paper's "no
//!   additional journaling" claim.
//!
//! Keys and values are arbitrary byte strings. All stores are internally
//! synchronized ([`KvStore`] takes `&self`) because the OMAP and CIT of a
//! DM-Shard are deliberately *separate* store instances with independent
//! locks ("reduced congestion on a single data structure", paper §2.2).

pub mod logkv;
pub mod memkv;

pub use logkv::LogKv;
pub use memkv::MemKv;

use crate::error::Result;

/// A synchronized byte-oriented KV store.
pub trait KvStore: Send + Sync {
    /// Insert or overwrite `key`.
    fn put(&self, key: &[u8], value: &[u8]) -> Result<()>;
    /// Fetch a value.
    fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>>;
    /// Delete a key (idempotent); returns whether it existed.
    fn delete(&self, key: &[u8]) -> Result<bool>;
    /// Snapshot of all live keys (used by GC scans and rebalancing).
    fn keys(&self) -> Result<Vec<Vec<u8>>>;
    /// Snapshot of all live `(key, value)` pairs whose key starts with
    /// `prefix`, in ascending key order. This is the indexed range read
    /// the backreference index is built on: both provided stores answer
    /// it from an ordered index (O(log n + matches)), so callers can rely
    /// on it being cheap. The default implementation is a correct but
    /// O(n) fallback for third-party stores.
    fn scan_prefix(&self, prefix: &[u8]) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        let mut out: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
        for key in self.keys()? {
            if !key.starts_with(prefix) {
                continue;
            }
            if let Some(value) = self.get(&key)? {
                out.push((key, value));
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(out)
    }
    /// Number of live keys.
    fn len(&self) -> usize;
    /// True when no live keys exist.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Delete every live key (wipe-and-rejoin support). The default walks
    /// `keys` and deletes one at a time, which keeps any per-store
    /// accounting exact; implementations with a cheaper truncate may
    /// override it.
    fn clear(&self) -> Result<()> {
        for key in self.keys()? {
            self.delete(&key)?;
        }
        Ok(())
    }
    /// Flush buffered writes to stable storage (no-op for MemKv).
    fn sync(&self) -> Result<()>;
}

#[cfg(test)]
pub(crate) mod conformance {
    //! Shared conformance suite run against every `KvStore` impl.
    use super::*;

    pub fn basic_ops(kv: &dyn KvStore) {
        assert!(kv.is_empty());
        kv.put(b"a", b"1").unwrap();
        kv.put(b"b", b"2").unwrap();
        assert_eq!(kv.get(b"a").unwrap().unwrap(), b"1");
        assert_eq!(kv.len(), 2);
        kv.put(b"a", b"overwritten").unwrap();
        assert_eq!(kv.get(b"a").unwrap().unwrap(), b"overwritten");
        assert_eq!(kv.len(), 2);
        assert!(kv.delete(b"a").unwrap());
        assert!(!kv.delete(b"a").unwrap());
        assert_eq!(kv.get(b"a").unwrap(), None);
        let mut keys = kv.keys().unwrap();
        keys.sort();
        assert_eq!(keys, vec![b"b".to_vec()]);
        kv.clear().unwrap();
        assert!(kv.is_empty(), "clear removes every live key");
        assert_eq!(kv.get(b"b").unwrap(), None);
    }

    pub fn binary_safety(kv: &dyn KvStore) {
        let key = [0u8, 255, 10, 13, 0];
        let val = vec![0u8; 1024];
        kv.put(&key, &val).unwrap();
        assert_eq!(kv.get(&key).unwrap().unwrap(), val);
        kv.put(b"", b"empty-key").unwrap();
        assert_eq!(kv.get(b"").unwrap().unwrap(), b"empty-key");
        kv.put(b"empty-val", b"").unwrap();
        assert_eq!(kv.get(b"empty-val").unwrap().unwrap(), b"");
    }

    pub fn prefix_scan(kv: &dyn KvStore) {
        kv.put(b"aa:1", b"v1").unwrap();
        kv.put(b"aa:2", b"v2").unwrap();
        kv.put(b"ab:1", b"v3").unwrap();
        kv.put(b"b", b"v4").unwrap();
        // binary prefix one bit past 0xFF boundary behavior
        kv.put(&[0xFF, 0x00], b"hi").unwrap();
        kv.put(&[0xFF, 0x01], b"ho").unwrap();
        let hits = kv.scan_prefix(b"aa:").unwrap();
        assert_eq!(
            hits,
            vec![
                (b"aa:1".to_vec(), b"v1".to_vec()),
                (b"aa:2".to_vec(), b"v2".to_vec()),
            ],
            "ordered, prefix-bounded"
        );
        assert_eq!(kv.scan_prefix(&[0xFF]).unwrap().len(), 2);
        assert_eq!(kv.scan_prefix(b"zz").unwrap(), vec![]);
        // empty prefix = everything, ascending
        let all = kv.scan_prefix(b"").unwrap();
        assert_eq!(all.len(), kv.len());
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(all, sorted);
        // overwrites and deletes are reflected
        kv.put(b"aa:1", b"v1b").unwrap();
        kv.delete(b"aa:2").unwrap();
        assert_eq!(
            kv.scan_prefix(b"aa:").unwrap(),
            vec![(b"aa:1".to_vec(), b"v1b".to_vec())]
        );
    }
}
