//! Embedded key-value store backing each DM-Shard.
//!
//! The paper uses SQLite as the per-OSD DM-Shard backend; offline we build
//! the equivalent substrate ourselves:
//!
//! * [`MemKv`] — in-memory BTree store (tests, benches that exclude disk).
//! * [`LogKv`] — bitcask-style persistent store: an append-only log of
//!   CRC-checked records plus an in-memory index, recovery by scan (torn
//!   tails are truncated at the first bad record), tombstoned deletes and
//!   compaction. This gives the consistency experiments honest crash
//!   semantics without any journaling — matching the paper's "no
//!   additional journaling" claim.
//!
//! Keys and values are arbitrary byte strings. All stores are internally
//! synchronized ([`KvStore`] takes `&self`) because the OMAP and CIT of a
//! DM-Shard are deliberately *separate* store instances with independent
//! locks ("reduced congestion on a single data structure", paper §2.2).

pub mod logkv;
pub mod memkv;

pub use logkv::LogKv;
pub use memkv::MemKv;

use crate::error::Result;

/// A synchronized byte-oriented KV store.
pub trait KvStore: Send + Sync {
    /// Insert or overwrite `key`.
    fn put(&self, key: &[u8], value: &[u8]) -> Result<()>;
    /// Fetch a value.
    fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>>;
    /// Delete a key (idempotent); returns whether it existed.
    fn delete(&self, key: &[u8]) -> Result<bool>;
    /// Snapshot of all live keys (used by GC scans and rebalancing).
    fn keys(&self) -> Result<Vec<Vec<u8>>>;
    /// Number of live keys.
    fn len(&self) -> usize;
    /// True when no live keys exist.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Flush buffered writes to stable storage (no-op for MemKv).
    fn sync(&self) -> Result<()>;
}

#[cfg(test)]
pub(crate) mod conformance {
    //! Shared conformance suite run against every `KvStore` impl.
    use super::*;

    pub fn basic_ops(kv: &dyn KvStore) {
        assert!(kv.is_empty());
        kv.put(b"a", b"1").unwrap();
        kv.put(b"b", b"2").unwrap();
        assert_eq!(kv.get(b"a").unwrap().unwrap(), b"1");
        assert_eq!(kv.len(), 2);
        kv.put(b"a", b"overwritten").unwrap();
        assert_eq!(kv.get(b"a").unwrap().unwrap(), b"overwritten");
        assert_eq!(kv.len(), 2);
        assert!(kv.delete(b"a").unwrap());
        assert!(!kv.delete(b"a").unwrap());
        assert_eq!(kv.get(b"a").unwrap(), None);
        let mut keys = kv.keys().unwrap();
        keys.sort();
        assert_eq!(keys, vec![b"b".to_vec()]);
    }

    pub fn binary_safety(kv: &dyn KvStore) {
        let key = [0u8, 255, 10, 13, 0];
        let val = vec![0u8; 1024];
        kv.put(&key, &val).unwrap();
        assert_eq!(kv.get(&key).unwrap().unwrap(), val);
        kv.put(b"", b"empty-key").unwrap();
        assert_eq!(kv.get(b"").unwrap().unwrap(), b"empty-key");
        kv.put(b"empty-val", b"").unwrap();
        assert_eq!(kv.get(b"empty-val").unwrap().unwrap(), b"");
    }
}
