//! In-memory KV store (BTreeMap behind a mutex).

use super::KvStore;
use crate::error::Result;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// In-memory `KvStore`; the default DM-Shard backend for tests and for
/// benches that isolate protocol costs from disk costs.
#[derive(Default)]
pub struct MemKv {
    map: Mutex<BTreeMap<Vec<u8>, Vec<u8>>>,
}

impl MemKv {
    /// New empty store.
    pub fn new() -> Self {
        Self::default()
    }
}

impl KvStore for MemKv {
    fn put(&self, key: &[u8], value: &[u8]) -> Result<()> {
        self.map.lock().unwrap().insert(key.to_vec(), value.to_vec());
        Ok(())
    }

    fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        Ok(self.map.lock().unwrap().get(key).cloned())
    }

    fn delete(&self, key: &[u8]) -> Result<bool> {
        Ok(self.map.lock().unwrap().remove(key).is_some())
    }

    fn keys(&self) -> Result<Vec<Vec<u8>>> {
        Ok(self.map.lock().unwrap().keys().cloned().collect())
    }

    fn scan_prefix(&self, prefix: &[u8]) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        // ordered range read off the BTree: O(log n + matches)
        Ok(self
            .map
            .lock()
            .unwrap()
            .range(prefix.to_vec()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect())
    }

    fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    fn sync(&self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::conformance;

    #[test]
    fn conformance_basic() {
        conformance::basic_ops(&MemKv::new());
    }

    #[test]
    fn conformance_binary() {
        conformance::binary_safety(&MemKv::new());
    }

    #[test]
    fn conformance_scan_prefix() {
        conformance::prefix_scan(&MemKv::new());
    }

    #[test]
    fn concurrent_writers() {
        use std::sync::Arc;
        let kv = Arc::new(MemKv::new());
        let mut handles = vec![];
        for t in 0..4 {
            let kv = kv.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    kv.put(format!("k{t}-{i}").as_bytes(), b"v").unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kv.len(), 400);
    }
}
